"""§Perf hillclimb harness: lower a (arch, shape) pair under a named variant,
report the loop-corrected roofline terms + memory against the baseline.

Variants (composable via comma list):
  banded      — banded flash attention: SWA/chunked layers skip masked KV
                blocks (exact numerics; cuts attention FLOPs from O(S^2) to
                O(S*window))
  ssd_heads   — shard SSD head dim over 'model' inside mamba blocks (cuts the
                (B,K,Q,Q,H) intra-chunk tensors 16x)
  sync_hier   — Cohort-Squeeze pod-level sync (paper technique): dense
                intra-pod, EF21-compressed inter-pod every sync_period steps
  sync_efbv   — EF-BV compressed gradient sync on the data axis
  moe_quant   — int8 token gather + bf16 psum in the shard_map MoE
  moe_a2a     — all-to-all expert dispatch: tokens stay d-sharded, only
                routed rows travel (~E/(K*cf) x less MoE traffic)
  no_tp       — pure-FSDP sharding (no tensor parallelism): for small models
                whose TP activation all-reduces dwarf the weights
  accum2x     — double grad-accum microbatching (memory vs collectives trade)

Usage:
  PYTHONPATH=src python -m repro.launch.perf --arch h2o-danube-1.8b \
      --shape prefill_32k --variants banded
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def apply_variants(variants, mesh, cfg):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import attention as attn_lib
    from repro.sharding.context import set_named_specs
    from repro.sharding.rules import data_axes

    daxes = data_axes(mesh)
    dax = daxes if len(daxes) > 1 else daxes[0]
    sync = "dense"
    extra = {}
    if "banded" in variants:
        attn_lib.BANDED = True
    if "ssd_heads" in variants and cfg.mamba is not None:
        set_named_specs({
            "ssd_x": NamedSharding(mesh, P(dax, None, "model", None)),
            "ssd_dt": NamedSharding(mesh, P(dax, None, "model")),
        })
    if "no_tp" in variants:
        from repro.sharding import rules as _rules
        _rules.NO_TP = True
    if "moe_a2a" in variants:
        from repro.sharding.context import set_moe_impl_override
        set_moe_impl_override("alltoall")
    if "moe_quant" in variants:
        from repro.sharding.context import set_moe_gather_quant
        set_moe_gather_quant(True)
    if "sync_hier" in variants:
        sync = "hier"
    if "sync_efbv" in variants:
        sync = "efbv"
    if "accum2x" in variants:
        extra["accum_mult"] = 2
    return sync, extra


def reset_variants():
    from repro.models import attention as attn_lib
    from repro.sharding.context import set_named_specs

    attn_lib.BANDED = False
    set_named_specs(None)
    from repro.sharding.context import set_moe_gather_quant
    set_moe_gather_quant(False)
    from repro.sharding import rules as _rules
    _rules.NO_TP = False
    from repro.sharding.context import set_moe_impl_override
    set_moe_impl_override(None)


def measure(arch, shape_name, variants, multi_pod=False):
    import jax
    from repro.configs.base import INPUT_SHAPES, get_config
    from repro.launch import dryrun as dr
    from repro.launch.costing import corrected_costs, model_flops
    from repro.launch.mesh import make_production_mesh
    from repro.launch import hlo_analysis as hlo
    from repro.obs import trace as obs_trace

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    sync, extra = apply_variants(variants, mesh, cfg)
    try:
        # full lowering -> memory proof (each phase is a flight-recorder span
        # when tracing is on, so a traced hillclimb shows where compiles go)
        t0 = obs_trace.wall_s()
        with obs_trace.span("perf/lower", arch=arch, shape=shape_name,
                            sync=sync):
            if shape.kind == "train":
                ga = None
                if extra.get("accum_mult"):
                    ga = dr.auto_grad_accum(cfg, shape, 32 if multi_pod else 16) * extra["accum_mult"]
                low = dr.build_train_lowering(cfg, mesh, shape, sync_mode=sync, grad_accum=ga)
            elif shape.kind == "prefill":
                low = dr.build_prefill_lowering(cfg, mesh, shape)
            else:
                low = dr.build_decode_lowering(cfg, mesh, shape)
        with obs_trace.span("perf/compile", arch=arch, shape=shape_name):
            comp = low.compile()
        with obs_trace.span("perf/memory"):
            mem = hlo.memory_dict(comp)
        # corrected costs (re-applies the same variant flags inside)
        with obs_trace.span("perf/corrected_costs"):
            cc = corrected_costs(cfg, mesh, shape_name, sync_mode=sync)
        c = cc["corrected"]
        terms = {
            "compute_s": c.get("flops", 0.0) / PEAK_FLOPS,
            "memory_s": c.get("bytes", 0.0) / HBM_BW,
            "collective_s": c.get("coll_total", 0.0) / ICI_BW,
            "interpod_s": c.get("coll_interpod", 0.0) / (ICI_BW / 4),
        }
        mf = model_flops(cfg, shape_name)["model_flops"]
        n_chips = 512 if multi_pod else 256
        return {
            "arch": arch, "shape": shape_name, "variants": variants,
            "sync": sync, "mesh": "2x16x16" if multi_pod else "16x16",
            "terms_s": terms,
            "dominant": max((k for k in terms if k != "interpod_s"),
                            key=lambda k: terms[k]),
            "useful_ratio": mf / (c.get("flops", 1) * n_chips),
            "mem_gb": {k: v / 1e9 for k, v in mem.items() if "size" in k},
            "compile_s": round(obs_trace.wall_s() - t0, 1),
        }
    finally:
        reset_variants()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="", help="comma list; empty = baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    variants = [v for v in args.variants.split(",") if v]
    from repro.obs import trace as obs_trace

    rec = measure(args.arch, args.shape, variants, args.multi_pod)
    if obs_trace.enabled():
        # every perf row carries its trace file (REPRO_TRACE=1)
        obs_trace.set_meta(label=f"perf_{args.arch}_{args.shape}",
                           variants=",".join(variants))
        rec["trace"] = obs_trace.export_jsonl(
            f"TRACE_perf_{args.arch}_{args.shape}.jsonl")
    print(json.dumps(rec, indent=2))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)


if __name__ == "__main__":
    main()
