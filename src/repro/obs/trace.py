"""Round-trace flight recorder: spans, ring buffer, JSONL/Chrome exporters.

Design constraints (why this looks the way it does):

* **Near-zero cost when disabled.**  ``span()`` checks one module-level flag
  and returns a single shared no-op context manager — no object allocation,
  no clock read, no lock.  Tracing is off unless ``enable()`` is called or
  ``REPRO_TRACE=1`` is set in the environment.

* **No host sync inside jit.**  Host-clock spans belong at *dispatch
  boundaries* (the training loop, codec round boundaries, benchmark
  harnesses).  Code that runs under ``jax.jit`` uses :func:`annotate`
  instead — a trace-time ``jax.named_scope`` (optionally doubled with
  ``jax.profiler.TraceAnnotation``) so the phase names line up with XLA
  profiles without ever blocking on a device value.

* **Flight recorder.**  Spans land in a fixed-capacity thread-safe ring
  buffer: a long run keeps the most recent window instead of growing without
  bound, and ``n_evicted`` says how much history scrolled off.

Usage::

    from repro.obs import trace

    trace.enable()
    with trace.span("sync/encode", level="inter") as sp:
        payload = encode(...)
        sp.tag(nbytes=payload.nbytes)

    @trace.traced("codec/roundtrip")
    def roundtrip(x): ...

    trace.export_jsonl("TRACE_round.jsonl")
    trace.export_chrome_trace("TRACE_round.json")   # chrome://tracing
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_TRUTHY = ("1", "true", "yes", "on")

DEFAULT_CAPACITY = 1 << 16  # spans kept before the flight recorder wraps


@dataclass(frozen=True)
class Span:
    """One completed span: [ts_us, ts_us + dur_us) on the tracer's epoch."""
    name: str
    ts_us: float          # start, microseconds since the tracer's epoch
    dur_us: float
    tid: int              # recording thread ident
    depth: int            # nesting depth within the thread (0 = top level)
    tags: Dict[str, object] = field(default_factory=dict)

    def to_json(self) -> dict:
        out = {"name": self.name, "ts_us": round(self.ts_us, 3),
               "dur_us": round(self.dur_us, 3), "tid": self.tid,
               "depth": self.depth}
        if self.tags:
            out["tags"] = self.tags
        return out

    @classmethod
    def from_json(cls, d: dict) -> "Span":
        return cls(d["name"], float(d["ts_us"]), float(d["dur_us"]),
                   int(d.get("tid", 0)), int(d.get("depth", 0)),
                   dict(d.get("tags", {})))

    def encloses(self, other: "Span") -> bool:
        """Interval containment on the same thread (parent candidate)."""
        return (self.tid == other.tid
                and self.ts_us <= other.ts_us
                and self.ts_us + self.dur_us >= other.ts_us + other.dur_us)


class Tracer:
    """Thread-safe fixed-capacity ring buffer of spans + run metadata."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._buf: List[Optional[Span]] = [None] * self.capacity
        self._next = 0          # write cursor
        self._recorded = 0      # total spans ever recorded
        self.meta: Dict[str, object] = {}
        self.epoch_ns = time.perf_counter_ns()

    # -- recording ----------------------------------------------------------
    def record(self, sp: Span) -> None:
        with self._lock:
            self._buf[self._next] = sp
            self._next = (self._next + 1) % self.capacity
            self._recorded += 1

    def reset(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            self._next = 0
            self._recorded = 0
            self.meta = {}
            self.epoch_ns = time.perf_counter_ns()

    # -- introspection ------------------------------------------------------
    @property
    def n_recorded(self) -> int:
        return self._recorded

    @property
    def n_evicted(self) -> int:
        return max(0, self._recorded - self.capacity)

    def spans(self) -> List[Span]:
        """Retained spans in recording (completion) order, oldest first."""
        with self._lock:
            if self._recorded < self.capacity:
                return [s for s in self._buf[:self._next] if s is not None]
            return ([s for s in self._buf[self._next:] if s is not None]
                    + [s for s in self._buf[:self._next] if s is not None])

    def now_us(self) -> float:
        return (time.perf_counter_ns() - self.epoch_ns) / 1e3


def wall_s() -> float:
    """Monotonic host wall clock in seconds.

    The one sanctioned host-time call outside this module: training loops and
    launch tooling time compile/step phases through here so measured wall
    clocks share a clock source with the trace epoch (``repro.lint`` rule
    RL003 rejects raw ``time.*`` calls elsewhere in ``src/repro``).
    """
    return time.perf_counter()


# ---------------------------------------------------------------------------
# module state: one default tracer + the enable flag everything checks
# ---------------------------------------------------------------------------
_tracer = Tracer()
_enabled = os.environ.get("REPRO_TRACE", "").lower() in _TRUTHY
_jax_annotations = os.environ.get("REPRO_TRACE_JAX", "").lower() in _TRUTHY
_tls = threading.local()


def get_tracer() -> Tracer:
    return _tracer


def enabled() -> bool:
    return _enabled


def enable(jax_annotations: Optional[bool] = None,
           capacity: Optional[int] = None) -> None:
    """Turn the flight recorder on (optionally resizing the ring buffer and
    opting into ``jax.profiler`` annotations alongside host spans)."""
    global _enabled, _jax_annotations, _tracer
    if capacity is not None and capacity != _tracer.capacity:
        _tracer = Tracer(capacity)
    if jax_annotations is not None:
        _jax_annotations = bool(jax_annotations)
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def set_meta(**kv) -> None:
    """Attach run-level metadata (sync config, n_params, ...) to the trace;
    exported as the JSONL header line so the report CLI can self-configure."""
    _tracer.meta.update(kv)


def _depth_stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def _ambient_tags() -> Optional[dict]:
    return getattr(_tls, "ambient", None)


# ---------------------------------------------------------------------------
# span context managers
# ---------------------------------------------------------------------------
class _NullSpan:
    """Shared no-op: what ``span()``/``annotate()`` return when disabled."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def tag(self, **kv):
        return self


NULL_SPAN = _NullSpan()


class _SpanCtx:
    __slots__ = ("name", "tags", "_t0_ns", "_jax_ctx")

    def __init__(self, name: str, tags: dict):
        self.name = name
        self.tags = tags
        self._t0_ns = 0
        self._jax_ctx = None

    def tag(self, **kv) -> "_SpanCtx":
        self.tags.update(kv)
        return self

    def __enter__(self):
        _depth_stack().append(self.name)
        if _jax_annotations:
            self._jax_ctx = _enter_jax_annotation(self.name)
        self._t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1_ns = time.perf_counter_ns()
        if self._jax_ctx is not None:
            self._jax_ctx.__exit__(*exc)
        stack = _depth_stack()
        depth = len(stack) - 1
        if stack:
            stack.pop()
        amb = _ambient_tags()
        tags = {**amb, **self.tags} if amb else self.tags
        _tracer.record(Span(self.name,
                            (self._t0_ns - _tracer.epoch_ns) / 1e3,
                            (t1_ns - self._t0_ns) / 1e3,
                            threading.get_ident(), depth, tags))
        return False


def span(name: str, **tags):
    """Host-clock span: ``with span("codec/encode", level="inter") as sp:``.

    Disabled mode returns the shared :data:`NULL_SPAN` — no allocation beyond
    the call itself, no clock read.  ``sp.tag(nbytes=...)`` adds tags that are
    only known at exit time.
    """
    if not _enabled:
        return NULL_SPAN
    return _SpanCtx(name, tags)


def traced(name: Optional[str] = None, **tags):
    """Decorator flavor of :func:`span` (checks the flag per call)."""
    def deco(fn):
        sp_name = name or getattr(fn, "__qualname__", fn.__name__)

        def wrapper(*a, **kw):
            if not _enabled:
                return fn(*a, **kw)
            with _SpanCtx(sp_name, dict(tags)):
                return fn(*a, **kw)

        wrapper.__name__ = getattr(fn, "__name__", sp_name)
        wrapper.__qualname__ = getattr(fn, "__qualname__", sp_name)
        wrapper.__doc__ = fn.__doc__
        wrapper.__wrapped__ = fn
        return wrapper
    return deco


class _AmbientCtx:
    """Thread-local tags merged into every span recorded inside the block —
    how codec spans inherit the aggregation level they run under without the
    codec knowing about levels."""
    __slots__ = ("tags", "_prev")

    def __init__(self, tags: dict):
        self.tags = tags
        self._prev = None

    def __enter__(self):
        self._prev = _ambient_tags()
        merged = {**self._prev, **self.tags} if self._prev else self.tags
        _tls.ambient = merged
        return self

    def __exit__(self, *exc):
        _tls.ambient = self._prev
        return False


def ambient(**tags):
    """``with ambient(level="inter"):`` — tag every span recorded within."""
    if not _enabled:
        return NULL_SPAN
    return _AmbientCtx(tags)


# ---------------------------------------------------------------------------
# jax passthrough (trace-safe: never reads the host clock inside jit)
# ---------------------------------------------------------------------------
def _enter_jax_annotation(name: str):
    try:
        import jax
        ctx = jax.profiler.TraceAnnotation(name)
        ctx.__enter__()
        return ctx
    except Exception:  # profiler unavailable (headless CPU builds)
        return None


def annotate(name: str):
    """Phase annotation for code *inside* jit: a ``jax.named_scope`` so the
    phase shows up in jaxpr/HLO metadata and XLA profiles.  This is the only
    instrumentation allowed under a jit trace — it costs nothing at runtime
    (names are baked in at trace time) and never forces a host sync.  Returns
    the shared no-op when tracing is disabled."""
    if not _enabled:
        return NULL_SPAN
    import jax

    return jax.named_scope(name)


def step_annotation(step: int, name: str = "train"):
    """``jax.profiler.StepTraceAnnotation`` passthrough for round boundaries
    (lines host rounds up with device steps in an XLA profile).  Only active
    when jax annotations were opted into via ``enable(jax_annotations=True)``
    or ``REPRO_TRACE_JAX=1``."""
    if not (_enabled and _jax_annotations):
        return NULL_SPAN
    try:
        import jax
        return jax.profiler.StepTraceAnnotation(name, step_num=step)
    except Exception:
        return NULL_SPAN


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
def export_jsonl(path: str, tracer: Optional[Tracer] = None) -> str:
    """One JSON object per line: a ``{"type": "meta", ...}`` header (run
    metadata + eviction counters) followed by one ``span`` line per span."""
    tr = tracer or _tracer
    spans = tr.spans()
    with open(path, "w") as f:
        header = {"type": "meta", "n_recorded": tr.n_recorded,
                  "n_evicted": tr.n_evicted, "capacity": tr.capacity}
        header.update(tr.meta)
        f.write(json.dumps(header) + "\n")
        for s in spans:
            rec = s.to_json()
            rec["type"] = "span"
            f.write(json.dumps(rec) + "\n")
    return path


def load_jsonl(path: str) -> Tuple[dict, List[Span]]:
    """Inverse of :func:`export_jsonl`: (meta, spans)."""
    meta: dict = {}
    spans: List[Span] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if d.get("type") == "meta":
                meta = {k: v for k, v in d.items() if k != "type"}
            else:
                spans.append(Span.from_json(d))
    return meta, spans


def export_chrome_trace(path: str, tracer: Optional[Tracer] = None) -> str:
    """Chrome ``chrome://tracing`` / Perfetto JSON: complete ("ph": "X")
    events with microsecond timestamps, span tags under ``args``."""
    tr = tracer or _tracer
    events = []
    for s in tr.spans():
        events.append({
            "name": s.name, "ph": "X", "cat": "repro",
            "ts": round(s.ts_us, 3), "dur": round(s.dur_us, 3),
            "pid": os.getpid(), "tid": s.tid,
            "args": {k: v for k, v in s.tags.items()},
        })
    doc = {"traceEvents": events, "displayTimeUnit": "ms",
           "otherData": dict(tr.meta)}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path
