"""Distributed gradient synchronization: the paper's techniques on a TPU mesh.

The federated "client" maps to a data-parallel worker group (one index along
the flattened (pod, data) mesh axes).  Per-group gradients are obtained with
``vmap(grad)`` over a leading group axis that is sharded across (pod, data) —
pure pjit/GSPMD, no replication-invariant tricks: XLA turns the mean over the
group axis into the all-reduce, and when the payload has been compressed to
int8 (qsgd) the all-reduce moves 4x fewer bytes — a *structural* saving
visible in the §Roofline collective term.  Sparsifying compressors (top-k)
keep dense carriers on-chip; their wire payloads are packed and *measured* by
the repro.comm codecs (bits_per_round below is a thin wrapper over that
ledger accounting), and additionally realized in frequency by hier/local
modes (bits * p).

Modes (SyncConfig.mode):
  dense  - mean over groups (baseline all-reduce; what FedAvg does per round)
  efbv   - EF-BV per-group compressed delta sync (Ch. 2): the gradient
           estimate used by the optimizer is h_bar + nu * mean_i C_i(g_i-h_i)
  ef21 / diana - parameter special cases of efbv
  hier   - Cohort-Squeeze (Ch. 5) on the fabric: dense intra-pod mean every
           step; inter-pod mean only every ``sync_period`` steps with the
           compressor applied to the pod-level delta (slow-link traffic
           drops by ~sync_period x payload ratio)
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SyncConfig
from repro.core import compressors as comp_lib
from repro.core.compressors import Compressor
from repro.utils.tree import tree_map


class SyncState(NamedTuple):
    """EF-BV state for the runtime: per-group control variates (leading group
    axis, sharded over (pod, data)) + replicated running average."""
    h: object        # pytree, leaves (G, *param_shape) float32
    h_bar: object    # pytree, leaves (*param_shape,) float32
    step: jax.Array


def build_compressor(sync: SyncConfig) -> Compressor:
    if sync.compressor == "topk_block":
        return comp_lib.block_top_k(sync.compress_ratio)
    if sync.compressor == "rand_k":
        return comp_lib.rand_k(sync.compress_ratio)
    if sync.compressor == "top_k":
        return comp_lib.top_k(sync.compress_ratio)
    if sync.compressor == "qsgd":
        # runtime paths operate on sharded param/grad leaves: last-dim blocks
        return comp_lib.qsgd_sharded(sync.quant_bits)
    if sync.compressor == "identity":
        return comp_lib.identity()
    return comp_lib.make_compressor(sync.compressor)


def sync_state_init(params, n_groups: int, sync: SyncConfig,
                    n_pods: int = 1) -> Optional[SyncState]:
    if sync.mode in ("dense",):
        return None
    if sync.mode == "hier":
        n_groups = n_pods  # control variates live at pod level
    zeros_g = tree_map(
        lambda p: jnp.zeros((n_groups,) + p.shape, jnp.float32), params)
    zeros = tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return SyncState(h=zeros_g, h_bar=zeros, step=jnp.zeros((), jnp.int32))


def sync_params(sync: SyncConfig, n_groups: int) -> Tuple[float, float]:
    """(lambda, nu) for the configured mode/compressor."""
    c = build_compressor(sync)
    if sync.mode in ("efbv", "ef21", "diana", "hier"):
        mode = "efbv" if sync.mode == "hier" else sync.mode
        return comp_lib.lambda_star(c.eta, c.omega), (
            comp_lib.nu_star(c.eta, comp_lib.omega_ran_independent(c.omega, n_groups))
            if mode == "efbv" and not c.deterministic
            else comp_lib.lambda_star(c.eta, c.omega)
            if mode in ("efbv", "ef21")
            else 1.0
        )
    return 1.0, 1.0


# ---------------------------------------------------------------------------
# Sync transforms on stacked per-group gradients (leading axis G)
# ---------------------------------------------------------------------------
def dense_sync(grads_g):
    """Plain mean over the group axis (XLA emits the all-reduce)."""
    return tree_map(lambda g: jnp.mean(g, axis=0), grads_g)


def efbv_sync(key, grads_g, state: SyncState, c: Compressor, lam: float,
              nu: float, bucket_size: Optional[int] = None):
    """EF-BV over stacked per-group grads. Returns (g_est, new_state).

    By default the pytree is fused into fixed-size fp32 buckets
    (repro.comm.buckets) so the whole tree is compressed in ONE vmapped
    call per group instead of a per-leaf Python loop of small kernels —
    top-k/rand-k then select over the full gradient vector (the paper's
    d-dimensional operator) rather than per leaf.  ``bucket_size=0`` keeps
    the legacy per-leaf path (per-leaf compressor semantics).

    Sharding-safe compressors (``flatten=False``, e.g. qsgd_sharded) always
    take the per-leaf path: bucketize's reshape/concat is exactly the
    flatten that forces GSPMD to all-gather 2D-sharded leaves, the thing
    those compressors exist to avoid.
    """
    from repro.comm import buckets as bk

    if bucket_size is None:
        bucket_size = bk.DEFAULT_BUCKET_SIZE
    if not bucket_size or not c.flatten:
        return _efbv_sync_leaves(key, grads_g, state, c, lam, nu)
    g_b, layout = bk.bucketize_groups(grads_g, bucket_size)      # (G, nb, B)
    h_b, _ = bk.bucketize_groups(state.h, bucket_size)
    hb_b, _ = bk.bucketize(state.h_bar, bucket_size)             # (nb, B)
    keys = jax.random.split(key, g_b.shape[0])
    d_i = _fused_compress(c, keys, g_b - h_b, layout.d)
    d = jnp.mean(d_i, axis=0)
    f32 = jnp.float32
    return (
        bk.debucketize(hb_b + nu * d, layout, dtype=f32),
        SyncState(h=bk.debucketize_groups(h_b + lam * d_i, layout, dtype=f32),
                  h_bar=bk.debucketize(hb_b + lam * d, layout, dtype=f32),
                  step=state.step + 1),
    )


def _fused_compress(c: Compressor, keys, delta_b, d: int):
    """One fused compressor pass over the bucketed (G, n_buckets, B) delta.

    The compressor must see the TRUE d-dim vector, not the padded bucket
    matrix: top-k/rand-k derive k (and rand-k its d/k scale) from the input
    size, so compressing the zero-padded tail would inflate k for trees
    smaller than a bucket.  (Only ``flatten=True`` compressors reach this —
    sharding-safe ones stay on the per-leaf path.)
    """
    G = delta_b.shape[0]
    flat = delta_b.reshape(G, -1)
    pad = flat.shape[1] - d
    out = jax.vmap(lambda k, v: c(k, v))(keys, flat[:, :d])
    if pad:
        out = jnp.pad(out, ((0, 0), (0, pad)))
    return out.reshape(delta_b.shape)


def _efbv_sync_leaves(key, grads_g, state: SyncState, c: Compressor,
                      lam: float, nu: float):
    """Per-leaf EF-BV (one compressor kernel per pytree leaf)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads_g)
    h_leaves = treedef.flatten_up_to(state.h)
    hb_leaves = treedef.flatten_up_to(state.h_bar)
    G = leaves[0].shape[0]

    g_est, new_h, new_hb = [], [], []
    for li, (g, h, hb) in enumerate(zip(leaves, h_leaves, hb_leaves)):
        lkey = jax.random.fold_in(key, li)
        keys = jax.random.split(lkey, G)
        delta = g.astype(jnp.float32) - h
        d_i = jax.vmap(lambda k, v: c(k, v))(keys, delta)
        d = jnp.mean(d_i, axis=0)
        new_h.append(h + lam * d_i)
        g_est.append(hb + nu * d)
        new_hb.append(hb + lam * d)
    unf = jax.tree_util.tree_unflatten
    return (
        unf(treedef, g_est),
        SyncState(h=unf(treedef, new_h), h_bar=unf(treedef, new_hb),
                  step=state.step + 1),
    )


def hier_param_sync(key, params_g, state: SyncState, c: Compressor, lam: float,
                    period: int, bucket_size: Optional[int] = None):
    """Cohort-Squeeze / local training on the fabric (param-level EF21 sync).

    params_g: pytree with leading group axis (pods, or (pod x data) worker
    groups for 'local' mode), each group training locally between syncs with
    its own optimizer.  Every ``period`` steps, groups sync through an EF21
    compressed delta against the shared anchor h_bar:

        d_i    = C_i(params_i - h_bar)
        h_bar += lam * mean_i d_i
        params_i <- h_bar                      (everyone adopts the anchor)

    With identity compressor and lam=1 this is exact parameter averaging
    (FedAvg); with top-k/qsgd the inter-group traffic carries only the
    compressed delta.  Returns (new params_g, new state).

    Like ``efbv_sync``, the parameter tree is bucket-fused by default: the
    whole delta is compressed in one vmapped call per group instead of one
    kernel per leaf (``bucket_size=0`` restores the per-leaf loop, and
    sharding-safe ``flatten=False`` compressors always take it — see
    ``efbv_sync``).
    """
    from repro.comm import buckets as bk

    if bucket_size is None:
        bucket_size = bk.DEFAULT_BUCKET_SIZE
    do_sync = (state.step % period) == (period - 1)

    def sync_fused(args):
        params_g, state = args
        p_b, layout = bk.bucketize_groups(params_g, bucket_size)   # (G, nb, B)
        hb_b, _ = bk.bucketize(state.h_bar, bucket_size)
        keys = jax.random.split(key, p_b.shape[0])
        d_i = _fused_compress(c, keys, p_b - hb_b, layout.d)
        hb2 = hb_b + lam * jnp.mean(d_i, axis=0)
        new_hb = bk.debucketize(hb2, layout, dtype=jnp.float32)
        new_p = tree_map(
            lambda hb, p: jnp.broadcast_to(hb.astype(p.dtype)[None], p.shape),
            new_hb, params_g)
        return new_p, SyncState(h=state.h, h_bar=new_hb, step=state.step + 1)

    def sync_leaves(args):
        params_g, state = args
        leaves, treedef = jax.tree_util.tree_flatten(params_g)
        hb_leaves = treedef.flatten_up_to(state.h_bar)
        G = leaves[0].shape[0]
        new_p, new_hb = [], []
        for li, (p, hb) in enumerate(zip(leaves, hb_leaves)):
            keys = jax.random.split(jax.random.fold_in(key, li), G)
            delta = p.astype(jnp.float32) - hb
            d_i = jax.vmap(lambda k, v: c(k, v))(keys, delta)
            hb2 = hb + lam * jnp.mean(d_i, axis=0)
            new_hb.append(hb2)
            new_p.append(jnp.broadcast_to(hb2.astype(p.dtype)[None], p.shape))
        unf = jax.tree_util.tree_unflatten
        return unf(treedef, new_p), SyncState(
            h=state.h, h_bar=unf(treedef, new_hb), step=state.step + 1)

    def local_branch(args):
        params_g, state = args
        return params_g, SyncState(h=state.h, h_bar=state.h_bar, step=state.step + 1)

    sync_branch = sync_fused if (bucket_size and c.flatten) else sync_leaves
    return jax.lax.cond(do_sync, sync_branch, local_branch, (params_g, state))


# ---------------------------------------------------------------------------
# Bits accounting (per communication round, per worker) — the paper's metric
# ---------------------------------------------------------------------------
def bits_per_round(sync: SyncConfig, n_params: int) -> float:
    """Thin wrapper over repro.comm accounting.

    The number is *measured*: the configured compressor's codec encodes a
    probe payload and the packed-buffer bytes are amortized per mode/period
    (see repro.comm.accounting.round_cost).  The old closed-form model lives
    on as RoundCost.analytic_bits, used only as a cross-check.
    """
    from repro.comm import round_bits

    return round_bits(sync, n_params)


def round_comm(sync: SyncConfig, n_params: int, topology=None):
    """Full per-round communication report (bytes per link class + simulated
    wall-clock on the configured link topology). Convenience re-export so the
    runtime sync modes and the launch costing share one accounting path."""
    from repro.comm import round_cost

    return round_cost(sync, n_params, topology=topology)
