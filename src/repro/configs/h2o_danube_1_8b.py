"""H2O-Danube-1.8B. [arXiv:2401.16818]

Llama+Mistral architecture mix: llama-style blocks with Mistral's
sliding-window attention (window 4096), GQA kv=8, vocab 32000.
SWA bounds decode memory by the window -> long_500k runs (ring KV cache).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        citation="arXiv:2401.16818",
        num_layers=24,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=80,
        d_ff=6912,
        vocab_size=32000,
        sliding_window=4096,
        mlp_act="silu",
        mlp_gated=True,
        supports_long_context=True,
    )
)
