"""Fault injection (repro.faults): counter PRNG, degraded aggregation,
wire integrity, retry accounting, and the fault-aware round-time model."""
import dataclasses
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (Link, PayloadError, TreeLevel, TreeTopology, decode,
                        encode, get_tree_topology, round_cost, round_ledger,
                        seal_payload, verify_payload)
from repro.comm.ledger import CommLedger
from repro.comm.topology import (deadline_survivor_frac, norm_ppf,
                                 straggler_level_time_s)
from repro.configs.base import LevelConfig, SyncConfig
from repro.core import compressors as C
from repro.core import distributed as dist
from repro.faults import (FaultConfig, FaultModel, LevelFaults, LinkFaults,
                          RETRY_TAG, corrupt_payload, counter_normal,
                          counter_uniform, expected_transmissions, transmit)


# ---------------------------------------------------------------------------
# counter PRNG
# ---------------------------------------------------------------------------
class TestCounterPRNG:
    def test_deterministic_and_addressable(self):
        a = counter_uniform(3, 7, "uplink/xmit", 16)
        b = counter_uniform(3, 7, "uplink/xmit", 16)
        np.testing.assert_array_equal(a, b)
        # lanes address into the same stream: [lane..lane+n) slices agree
        c = counter_uniform(3, 7, "uplink/xmit", 8, lane=8)
        np.testing.assert_array_equal(a[8:], c)

    def test_decorrelated_across_streams_rounds_seeds(self):
        base = counter_uniform(3, 7, "s", 256)
        for other in (counter_uniform(3, 8, "s", 256),
                      counter_uniform(4, 7, "s", 256),
                      counter_uniform(3, 7, "t", 256)):
            assert not np.array_equal(base, other)
            assert abs(np.corrcoef(base, other)[0, 1]) < 0.2

    def test_range_and_moments(self):
        u = counter_uniform(0, 0, "u", 20_000)
        assert (u >= 0).all() and (u < 1).all()
        assert abs(u.mean() - 0.5) < 0.02
        z = counter_normal(0, 0, "z", 20_000)
        assert abs(z.mean()) < 0.03 and abs(z.std() - 1.0) < 0.03


# ---------------------------------------------------------------------------
# config + model
# ---------------------------------------------------------------------------
class TestFaultConfig:
    def test_default_is_disabled(self):
        assert not FaultConfig().enabled()
        assert FaultConfig(straggler_rate=0.5, straggler_sigma=0.0).enabled() \
            is False

    def test_enabled_by_any_knob(self):
        assert FaultConfig(availability=0.9).enabled()
        assert FaultConfig(drop_rate=0.1).enabled()
        assert FaultConfig(deadline_s=5.0).enabled()
        assert FaultConfig(levels=(LevelFaults("wan", drop_rate=0.1),)) \
            .enabled()

    def test_override_precedence(self):
        cfg = FaultConfig(drop_rate=0.1,
                          levels=(LevelFaults("wan", drop_rate=0.4),))
        assert cfg.link_faults("wan").drop_rate == 0.4
        assert cfg.link_faults("uplink").drop_rate == 0.1
        tree = get_tree_topology("edge_fl_tree")
        assert tree.level_faults(2, cfg).drop_rate == 0.4  # wan override
        assert tree.level_faults(0, cfg).drop_rate == 0.1  # global default

    def test_expected_transmissions(self):
        cfg = FaultConfig(max_retries=2)
        assert cfg.expected_transmissions(0.0) == 1.0
        q = 0.25
        assert cfg.expected_transmissions(q) == pytest.approx(1 + q + q * q)
        assert expected_transmissions(q, 2) == cfg.expected_transmissions(q)


class TestFaultModel:
    def _model(self, **kw):
        return FaultModel(FaultConfig(**kw), get_tree_topology("edge_fl_tree"))

    def test_replay_bit_exact(self):
        kw = dict(seed=11, availability=0.8, drop_rate=0.1,
                  straggler_rate=0.3, deadline_s=30.0)
        p1 = self._model(**kw).round_plan(5)
        p2 = self._model(**kw).round_plan(5)
        for a, b in zip(p1.levels, p2.levels):
            np.testing.assert_array_equal(a.survivors, b.survivors)
            np.testing.assert_array_equal(a.arrival_s, b.arrival_s)
        assert p1.stats() == p2.stats()

    def test_mask_shapes_follow_fanouts(self):
        plan = self._model(seed=1, availability=0.9).round_plan(0)
        assert [m.shape[0] for m in plan.survivor_masks()] == [100, 20, 4]

    def test_dead_subtrees_propagate_up(self):
        fm = self._model(seed=2, availability=0.0)  # nobody checks in
        plan = fm.round_plan(0)
        for lv in plan.levels:
            assert not lv.survivors.any()

    def test_availability_rate(self):
        fm = self._model(seed=3, availability=0.7)
        frac = np.mean([fm.available(t).mean() for t in range(200)])
        assert abs(frac - 0.7) < 0.03


# ---------------------------------------------------------------------------
# degraded aggregation
# ---------------------------------------------------------------------------
def _cascade(comp=None):
    comp = comp or C.identity()
    return (dist.CascadeLevel("cell", comp, 1.0, 1, 4),
            dist.CascadeLevel("cloud", comp, 1.0, 1, 3))


def _consensus(G=12, d=16):
    key = jax.random.PRNGKey(0)
    targets = jax.random.normal(key, (G, d))
    return key, targets, jnp.mean(targets, axis=0)


class TestDegradedSync:
    @pytest.mark.parametrize("bucket_size", [None, 0])  # fused / per-leaf
    def test_all_ones_masks_bit_identical(self, bucket_size):
        levels = _cascade(C.top_k(0.5))
        key, targets, _ = _consensus()
        params = {"w": targets}
        st0 = dist.tree_sync_state_init({"w": jnp.zeros((16,))}, levels)
        ones = (jnp.ones((12,)), jnp.ones((3,)))
        p_a, st_a = dist.tree_param_sync(key, params, st0, levels,
                                         bucket_size=bucket_size)
        p_b, st_b = dist.tree_param_sync(key, params, st0, levels,
                                         bucket_size=bucket_size,
                                         survivors=ones)
        np.testing.assert_array_equal(np.asarray(p_a["w"]),
                                      np.asarray(p_b["w"]))
        for a, b in zip(st_a.anchors, st_b.anchors):
            np.testing.assert_array_equal(np.asarray(a["w"]),
                                          np.asarray(b["w"]))

    def test_none_masks_allowed_per_level(self):
        levels = _cascade()
        key, targets, _ = _consensus()
        st0 = dist.tree_sync_state_init({"w": jnp.zeros((16,))}, levels)
        p_a, _ = dist.tree_param_sync(key, {"w": targets}, st0, levels)
        p_b, _ = dist.tree_param_sync(key, {"w": targets}, st0, levels,
                                      survivors=(None, None))
        np.testing.assert_array_equal(np.asarray(p_a["w"]),
                                      np.asarray(p_b["w"]))

    def test_bad_mask_shape_raises(self):
        levels = _cascade()
        st0 = dist.tree_sync_state_init({"w": jnp.zeros((16,))}, levels)
        with pytest.raises(ValueError, match="survivor mask shape"):
            dist.tree_param_sync(jax.random.PRNGKey(0),
                                 {"w": jnp.zeros((12, 16))}, st0, levels,
                                 survivors=(jnp.ones((4,)), jnp.ones((3,))))

    def test_dropped_leaf_keeps_local_params(self):
        levels = _cascade()
        key, targets, _ = _consensus()
        st0 = dist.tree_sync_state_init({"w": jnp.zeros((16,))}, levels)
        mask = jnp.ones((12,)).at[5].set(0.0)
        p, _ = dist.tree_param_sync(key, {"w": targets}, st0, levels,
                                    survivors=(mask, jnp.ones((3,))))
        # dropped leaf skips adoption; survivors adopt their (shared) anchor
        np.testing.assert_array_equal(np.asarray(p["w"][5]),
                                      np.asarray(targets[5]))
        assert not np.array_equal(np.asarray(p["w"][4]),
                                  np.asarray(targets[4]))

    def test_drop_then_restore_preserves_contraction(self):
        """EF21 contraction survives a transient dropout: the root-anchor
        consensus error never increases round-over-round on the synthetic
        quadratic (the dropped leaf itself transiently drifts — by design it
        keeps its local step — but re-anchors once restored)."""
        levels = _cascade()
        key, targets, center = _consensus()
        lr = 0.5
        params = {"w": jnp.zeros((12, 16))}
        st = dist.tree_sync_state_init({"w": jnp.zeros((16,))}, levels)
        drop_round, root_errs, leaf_errs = 2, [], []
        for t in range(8):
            w = params["w"] - lr * (params["w"] - targets)
            if t == drop_round:
                surv = (jnp.ones((12,)).at[0].set(0.0), jnp.ones((3,)))
            else:
                surv = None
            params, st = dist.tree_param_sync(jax.random.fold_in(key, t),
                                              {"w": w}, st, levels,
                                              survivors=surv)
            root_errs.append(float(
                jnp.linalg.norm(st.anchors[-1]["w"] - center)))
            leaf_errs.append(float(jnp.max(
                jnp.linalg.norm(params["w"] - center, axis=-1))))
        assert np.isfinite(root_errs).all() and np.isfinite(leaf_errs).all()
        # aggregate contraction is unbroken by the dropout
        for a, b in zip(root_errs, root_errs[1:]):
            assert b <= a * (1.0 + 1e-6), root_errs
        # the dropped leaf drifts at the drop round, then snaps back below
        # its pre-drop error on the very next (restored) sync
        assert leaf_errs[drop_round] > leaf_errs[drop_round - 1]
        assert leaf_errs[drop_round + 1] < leaf_errs[drop_round - 1]
        assert leaf_errs[-1] < 0.2 * leaf_errs[0]

    def test_zero_survivor_group_anchor_unchanged(self):
        levels = _cascade()
        key, targets, _ = _consensus()
        st0 = dist.tree_sync_state_init({"w": jnp.zeros((16,))}, levels)
        dead_cell = jnp.ones((12,)).at[:4].set(0.0)  # cell 0 fully dead
        _, st = dist.tree_param_sync(key, {"w": targets}, st0, levels,
                                     survivors=(dead_cell,
                                                jnp.ones((3,)).at[0].set(0.0)))
        # cell 0's anchor took no step (EF21 state carried, not corrupted)
        np.testing.assert_array_equal(np.asarray(st.anchors[0]["w"][0]),
                                      np.asarray(st0.anchors[0]["w"][0]))
        assert not np.array_equal(np.asarray(st.anchors[0]["w"][1]),
                                  np.asarray(st0.anchors[0]["w"][1]))

    def test_local_step_survivors_wiring(self):
        """make_train_step('local') with all-ones masks == no masks, bitwise."""
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.data.synthetic import SyntheticLMDataset, lm_batch_iterator
        from repro.models import init_params
        from repro.training.steps import init_train_state, make_train_step

        cfg = get_config("h2o-danube-1.8b").reduced()
        tc = TrainConfig(model=cfg, seq_len=16, global_batch=4, lr=1e-3,
                         warmup_steps=1, total_steps=2,
                         sync=SyncConfig(mode="local", compressor="identity",
                                         sync_period=1,
                                         faults=FaultConfig(drop_rate=0.1)))
        ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, length=2000, seed=0)
        raw = next(lm_batch_iterator(ds, 4, 16, seed=1))
        batch = {"tokens": jnp.asarray(raw["tokens"][:, :-1]),
                 "targets": jnp.asarray(raw["tokens"][:, 1:])}
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = init_train_state(jax.random.PRNGKey(1), params, tc, 2, 1)
        step = jax.jit(make_train_step(cfg, tc, 2, 1))
        s_none, _ = step(state, batch)
        s_ones, _ = step(state, batch, (jnp.ones((2,)),))
        for a, b in zip(jax.tree_util.tree_leaves(s_none.params),
                        jax.tree_util.tree_leaves(s_ones.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# wire integrity + retry
# ---------------------------------------------------------------------------
def _payload(d=4096, comp=None):
    comp = comp or C.qsgd(8)
    return encode(comp, jax.random.PRNGKey(0),
                  jax.random.normal(jax.random.PRNGKey(1), (d,)))


class TestWireIntegrity:
    def test_seal_verify_roundtrip(self):
        p = seal_payload(_payload())
        verify_payload(p)  # no raise
        assert decode(p) is not None

    def test_corrupt_payload_rejected_with_plane_name(self):
        p = seal_payload(_payload())
        plane = corrupt_payload(p, rnd=0, seed=3)
        assert plane is not None
        with pytest.raises(PayloadError, match=plane) as ei:
            decode(p)
        assert ei.value.plane == plane

    def test_truncated_plane_rejected_with_plane_name(self):
        p = _payload(comp=C.top_k(0.1))
        p.planes["indices"] = p.planes["indices"][:-2]
        with pytest.raises(PayloadError, match="indices"):
            decode(p)

    def test_unsealed_payload_verifies_as_noop(self):
        verify_payload(_payload())  # no checksum planes -> no-op

    def test_transmit_charges_retries_to_retry_tag(self):
        cfg = FaultConfig(seed=1, drop_rate=0.6, max_retries=3)
        led = CommLedger()
        p = _payload(d=512)
        n_attempts = 0
        for child in range(8):
            res = transmit(p, cfg, rnd=0, level_name="uplink", n_children=8,
                           child=child, ledger=led)
            n_attempts += res.attempts
        by_tag = led.bytes_by_tag()
        assert by_tag["uplink"] == 8 * p.nbytes  # first attempts
        assert led.retry_bytes == (n_attempts - 8) * p.nbytes
        assert by_tag.get(RETRY_TAG, 0) == led.retry_bytes
        assert led.retry_bytes > 0

    def test_transmit_matches_fault_model_decisions(self):
        """Wire-level transmit and plan-level FaultModel draw identically."""
        cfg = FaultConfig(seed=9, drop_rate=0.4, max_retries=0)
        tree = TreeTopology("t", (TreeLevel(
            "uplink", 8, Link(gbps=1.0, latency_us=100.0)),))
        fm = FaultModel(cfg, tree)
        dropped, _, _ = fm.attempt_outcomes(0, 0, 0)
        p = _payload(d=512)
        for child in range(8):
            res = transmit(p, cfg, rnd=0, level_name="uplink", n_children=8,
                           child=child)
            assert res.delivered == (not dropped[child])

    def test_corrupted_transmit_retries_and_recovers(self):
        cfg = FaultConfig(seed=4, corrupt_rate=0.5, max_retries=4)
        p = _payload(d=512)
        results = [transmit(p, cfg, rnd=0, level_name="uplink", n_children=16,
                            child=i) for i in range(16)]
        assert any(r.n_corrupt > 0 for r in results)
        for r in results:
            if r.delivered:
                verify_payload(r.payload)


# ---------------------------------------------------------------------------
# costing: retries, order statistics, deadlines
# ---------------------------------------------------------------------------
def _edge_sync(faults=None):
    return SyncConfig(mode="hier", topology="edge_fl_tree", levels=(
        LevelConfig("uplink", 2, "top_k", 0.05),
        LevelConfig("metro", 4, "qsgd", quant_bits=8),
        LevelConfig("wan", 4, "top_k", 0.01)), faults=faults)


class TestFaultCosting:
    N = 1 << 14

    def test_disabled_config_identical_to_none(self):
        a = round_cost(_edge_sync(), self.N)
        b = round_cost(_edge_sync(FaultConfig()), self.N)
        assert a.total_bytes == b.total_bytes
        assert a.time_s == b.time_s
        assert b.retry_bytes == 0.0 and b.degraded_time_s == 0.0

    def test_retry_bytes_sum_into_total(self):
        fc = FaultConfig(drop_rate=0.2)
        cost = round_cost(_edge_sync(fc), self.N)
        base = round_cost(_edge_sync(), self.N)
        assert cost.retry_bytes > 0
        assert cost.total_bytes == pytest.approx(
            base.total_bytes + cost.retry_bytes)
        assert cost.total_bytes == pytest.approx(
            cost.intra_bytes + cost.inter_bytes + cost.retry_bytes)

    def test_round_ledger_emits_retry_records(self):
        fc = FaultConfig(drop_rate=0.2)
        led = round_ledger(_edge_sync(fc), self.N, n_rounds=4)
        assert led.retry_bytes > 0
        clean = round_ledger(_edge_sync(), self.N, n_rounds=4)
        assert clean.retry_bytes == 0
        assert led.total_bytes > clean.total_bytes

    def test_degraded_time_monotone_in_deadline(self):
        fc0 = FaultConfig(straggler_rate=0.3, straggler_sigma=1.5,
                          drop_rate=0.1)
        times = [round_cost(_edge_sync(dataclasses.replace(
            fc0, deadline_s=dl)), self.N).degraded_time_s
            for dl in (1.0, 5.0, 30.0, math.inf)]
        for a, b in zip(times, times[1:]):
            assert a <= b * (1.0 + 1e-9), times
        assert times[0] < times[-1]

    def test_straggler_order_statistics(self):
        # more children -> later completion (max of more draws)
        t_small = straggler_level_time_s(1.0, 0.3, 1.0, 4)
        t_big = straggler_level_time_s(1.0, 0.3, 1.0, 100)
        assert 1.0 <= t_small < t_big
        # a deadline caps it
        assert straggler_level_time_s(1.0, 0.3, 1.0, 100, 2.0) == 2.0
        assert straggler_level_time_s(1.0, 0.0, 1.0, 100) == 1.0

    def test_norm_ppf_and_survivor_frac(self):
        assert norm_ppf(0.5) == pytest.approx(0.0, abs=1e-9)
        assert norm_ppf(0.975) == pytest.approx(1.959964, abs=1e-4)
        f = [deadline_survivor_frac(1.0, 0.4, 1.0, dl)
             for dl in (0.5, 1.0, 3.0, math.inf)]
        assert all(0.0 <= x <= 1.0 for x in f)
        for a, b in zip(f, f[1:]):
            assert a <= b + 1e-12
        assert f[-1] == 1.0

    def test_comm_time_model_degraded(self):
        from repro.launch.costing import comm_time_model

        m = {"coll_total": 1e9, "coll_interpod": 2e8}
        out = comm_time_model(m, faults=FaultConfig(
            straggler_rate=0.2, drop_rate=0.1, deadline_s=10.0))
        assert out["t_comm_degraded_s"] >= out["t_comm_s"]
        assert "t_comm_degraded_s" not in comm_time_model(m)
        tree_out = comm_time_model(
            m, topology=get_tree_topology("edge_fl_tree"),
            faults=FaultConfig(straggler_rate=0.2, drop_rate=0.05))
        assert tree_out["t_comm_degraded_s"] >= tree_out["t_comm_s"]


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------
class TestFaultObservability:
    def test_observe_fault_plan_and_stats(self):
        from repro.obs.metrics import MetricsRegistry

        fm = FaultModel(FaultConfig(seed=1, availability=0.8, drop_rate=0.1),
                        get_tree_topology("edge_fl_tree"))
        reg = MetricsRegistry()
        for t in range(4):
            reg.observe_fault_plan(t, fm.round_plan(t))
        fs = reg.fault_stats()
        assert {"drops", "retries", "deadline_misses", "corrupt",
                "unavailable", "round_time_s"} <= set(fs)
        assert any(k.startswith("survivor_frac/") for k in fs)
        assert fs["unavailable"] > 0

    def test_report_excludes_retry_tag_from_match(self, tmp_path):
        from repro.obs import trace as obs_trace
        from repro.obs.report import build_report

        was = obs_trace.enabled()
        obs_trace.enable()
        obs_trace.get_tracer().reset()
        with obs_trace.span("codec/encode", nbytes=100, level="uplink"):
            pass
        obs_trace.set_meta(label="faults_report_test", n_params=10,
                           n_rounds=1)
        tp = obs_trace.export_jsonl(str(tmp_path / "T.jsonl"))
        if not was:
            obs_trace.disable()

        mp = tmp_path / "M.json"
        doc = {"ledger_bytes_by_tag": {"uplink": 100.0, "retry": 64.0},
               "fault_stats": {"drops": 3.0, "survivor_frac/uplink": 0.9,
                               "round_time_s": 1.5}}
        mp.write_text(json.dumps(doc))
        text, res = build_report(tp, metrics_path=str(mp))
        assert res["bytes_match"] is True  # retry tag shown but not audited
        assert "retry" in text and "degraded rounds" in text
        assert res["fault_stats"]["drops"] == 3.0

        doc["ledger_bytes_by_tag"]["uplink"] = 228.0
        mp.write_text(json.dumps(doc))
        _, res2 = build_report(tp, metrics_path=str(mp))
        assert res2["bytes_match"] is False
