"""Streaming codec pipeline: chunk partition exactness, per-chunk ledger
attribution, bucket fusion, the double-buffered Pallas DMA ring, and the
pipelined round-time model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container lacks hypothesis: deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.comm import (DEFAULT_TILE_BYTES, CodecProfile, CommLedger,
                        bucketize, bucketize_groups, debucketize,
                        debucketize_groups, decode, decode_stream, encode,
                        encode_stream, get_topology, pipelined_time_s,
                        round_cost, split_payload)
from repro.comm import codecs
from repro.configs.base import SyncConfig
from repro.core import compressors as C
from repro.core import distributed as dist


def _compressor(name: str) -> C.Compressor:
    return {
        "identity": lambda: C.identity(),
        "top_k": lambda: C.top_k(0.1),
        "rand_k": lambda: C.rand_k(0.25),
        "block_top_k": lambda: C.block_top_k(0.1, block=64),
        "qsgd8": lambda: C.qsgd(8, 64),
        "qsgd4": lambda: C.qsgd(4, 64),
        "qsgd_sharded": lambda: C.qsgd_sharded(8, 256),
        "qsgd_kernel": lambda: C.qsgd_kernel(8),
    }[name]()


# ---------------------------------------------------------------------------
# chunked == monolithic, property-style over scheme x tile x size
# ---------------------------------------------------------------------------
@settings(max_examples=24, deadline=None)
@given(name=st.sampled_from(["identity", "top_k", "rand_k", "block_top_k",
                             "qsgd8", "qsgd4", "qsgd_sharded", "qsgd_kernel"]),
       tile=st.sampled_from([64, 96, 512, 4096, 1 << 16]),
       d=st.sampled_from([63, 512, 777, 4096, 5000]))
def test_stream_decode_bitexact_and_bytes_sum(name, tile, d):
    comp = _compressor(name)
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.PRNGKey(d), (d,)) * 3
    p = encode(comp, key, x)
    sp = split_payload(p, tile)
    # per-chunk bytes partition the monolithic payload exactly
    assert sp.nbytes == p.nbytes
    assert sum(ch.nbytes for ch in sp.chunks) == p.nbytes
    # chunked decode == whole-payload decode, bit for bit
    np.testing.assert_array_equal(np.asarray(decode_stream(sp)),
                                  np.asarray(decode(p)))
    # chunk coordinate ranges tile the flat space
    starts = [ch.start for ch in sp.chunks]
    stops = [ch.stop for ch in sp.chunks]
    assert starts[0] == 0 and stops[-1] == d
    assert all(a == b for a, b in zip(stops[:-1], starts[1:]))


def test_encode_stream_matches_compressor_bitmap_scheme():
    comp = C.top_k(0.2)
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(jax.random.PRNGKey(5), (777,))
    p = encode(comp, key, x, scheme="sparse_bitmap")
    sp = split_payload(p, 96)
    assert sp.nbytes == p.nbytes
    np.testing.assert_array_equal(np.asarray(decode_stream(sp)),
                                  np.asarray(decode(p)))
    assert codecs.stream_roundtrip_equal(comp, key, x, tile=128)


def test_stream_roundtrip_2d_sharded_fallback():
    """qsgd_sharded on a last-dim that doesn't block evenly (scalar scale)."""
    comp = C.qsgd_sharded(8, 256)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(jax.random.PRNGKey(3), (7, 107))
    p = encode(comp, key, x)
    sp = split_payload(p, 100)
    assert sp.nbytes == p.nbytes
    np.testing.assert_array_equal(np.asarray(decode_stream(sp)),
                                  np.asarray(decode(p)))


# ---------------------------------------------------------------------------
# ledger: per-chunk attribution
# ---------------------------------------------------------------------------
def test_ledger_stream_records_sum_to_payload():
    comp = C.qsgd(8, 64)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (5000,))
    p = encode(comp, key, x)
    sp = split_payload(p, 512)
    led = CommLedger()
    recs = led.record_stream(3, "client->server", sp)
    assert len(recs) == sp.n_chunks > 1
    assert led.total_bytes == p.nbytes
    assert [r.chunk for r in recs] == list(range(sp.n_chunks))
    assert all(r.tag == "quant" and r.round == 3 for r in recs)
    # whole-payload record agrees with the chunk sum
    led2 = CommLedger()
    led2.record_payload(3, "client->server", p)
    assert led2.total_bytes == led.total_bytes


# ---------------------------------------------------------------------------
# bit-stream packing (satellite: vectorized word-wise path)
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(nbits=st.sampled_from([1, 3, 7, 8, 11, 13, 16, 24]),
       n=st.sampled_from([0, 1, 7, 1000]))
def test_pack_uint_stream_matches_bit_reference(nbits, n):
    rng = np.random.default_rng(nbits * 1000 + n)
    vals = rng.integers(0, 1 << nbits, size=n).astype(np.uint64)
    got = codecs._pack_uint_stream(vals, nbits)
    if n:
        bits = ((vals[:, None] >> np.arange(nbits, dtype=np.uint64)) & 1)
        want = np.packbits(bits.astype(np.uint8).reshape(-1), bitorder="little")
        np.testing.assert_array_equal(got, want)
    assert got.nbytes == (n * nbits + 7) // 8
    np.testing.assert_array_equal(codecs._unpack_uint_stream(got, n, nbits),
                                  vals.astype(np.int64))
    # out-of-range values truncate to nbits (old packbits contract) instead
    # of scatter-ORing stray bits into neighboring bytes
    big = vals + (np.uint64(1) << np.uint64(nbits))
    np.testing.assert_array_equal(codecs._pack_uint_stream(big, nbits), got)


# ---------------------------------------------------------------------------
# bucket fusion
# ---------------------------------------------------------------------------
def _tree():
    return {"a": jnp.arange(12., dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((5,), jnp.bfloat16) * 2,
            "c": jnp.float32(3.0)}


def test_bucketize_roundtrip_exact():
    tree = _tree()
    buckets, layout = bucketize(tree, bucket_size=8)
    assert buckets.shape == (layout.n_buckets, 8)
    assert layout.d == 18 and layout.n_buckets == 3
    back = debucketize(buckets, layout)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_bucketize_groups_roundtrip_exact():
    G = 3
    tree_g = jax.tree_util.tree_map(
        lambda p: jnp.stack([jnp.asarray(p, jnp.float32) * (i + 1)
                             for i in range(G)]), _tree())
    buckets, layout = bucketize_groups(tree_g, bucket_size=8)
    assert buckets.shape == (G, layout.n_buckets, 8)
    back = debucketize_groups(buckets, layout, dtype=jnp.float32)
    for a, b in zip(jax.tree_util.tree_leaves(tree_g),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_efbv_sync_fused_matches_per_leaf_with_deterministic_compressor():
    """With the identity compressor both paths are exact arithmetic."""
    G = 4
    params = {"w": jnp.ones((6, 2), jnp.float32), "b": jnp.zeros((3,))}
    grads_g = jax.tree_util.tree_map(
        lambda p: jnp.stack([p * (i + 1) for i in range(G)]), params)
    state = dist.sync_state_init(params, G, SyncConfig(mode="efbv"))
    out = {}
    for bs in (0, 8):
        g, st = dist.efbv_sync(jax.random.PRNGKey(0), grads_g, state,
                               C.identity(), 0.5, 0.7, bucket_size=bs)
        out[bs] = (g, st)
    for a, b in zip(jax.tree_util.tree_leaves(out[0][0]),
                    jax.tree_util.tree_leaves(out[8][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(out[0][1].h_bar),
                    jax.tree_util.tree_leaves(out[8][1].h_bar)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_efbv_sync_fused_is_one_compressor_call(monkeypatch):
    """The fused path must hit the compressor ONCE for the whole tree."""
    calls = []
    base = C.identity()
    counting = C.Compressor("counting", lambda k, x: calls.append(1) or x,
                            eta=0.0, omega=0.0, bits_per_dim=32.0,
                            deterministic=True)
    G = 2
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((7,)), "c": jnp.ones((3,))}
    grads_g = jax.tree_util.tree_map(
        lambda p: jnp.stack([p] * G), params)
    state = dist.sync_state_init(params, G, SyncConfig(mode="efbv"))
    with jax.disable_jit():
        dist.efbv_sync(jax.random.PRNGKey(0), grads_g, state, counting,
                       0.5, 0.5, bucket_size=8)
        fused_calls = len(calls)
        calls.clear()
        dist.efbv_sync(jax.random.PRNGKey(0), grads_g, state, counting,
                       0.5, 0.5, bucket_size=0)
        leaf_calls = len(calls)
    # vmap traces its operand once, so call count == number of compressor
    # program instances: ONE fused pass vs one per pytree leaf
    assert fused_calls == 1
    assert leaf_calls == len(jax.tree_util.tree_leaves(params))
    assert base is not counting


def test_efbv_fused_sparsifier_sees_true_d_not_padded():
    """top_k in the fused path must derive k from the true coordinate count:
    with d=96 << bucket_size, k = 0.05*96 ~ 5 per group, so the compressed
    estimate stays sparse (padded-matrix k would be 0.05*65536 > d and keep
    every coordinate)."""
    G = 2
    params = {"w": jnp.zeros((64,)), "b": jnp.zeros((32,))}
    grads_g = jax.tree_util.tree_map(
        lambda p: jnp.stack([jax.random.normal(jax.random.PRNGKey(i), p.shape)
                             for i in range(G)]), params)
    state = dist.sync_state_init(params, G, SyncConfig(mode="efbv"))
    g_est, _ = dist.efbv_sync(jax.random.PRNGKey(0), grads_g, state,
                              C.top_k(0.05), 1.0, 1.0)  # default bucket_size
    nnz = sum(int(jnp.sum(l != 0)) for l in jax.tree_util.tree_leaves(g_est))
    k = max(1, round(0.05 * 96))
    assert 0 < nnz <= G * k  # union of per-group top-k supports


def test_hier_param_sync_fused_fedavg_and_period():
    params_g = {"w": jnp.stack([jnp.ones((4,)) * 1.0, jnp.ones((4,)) * 3.0])}
    st0 = dist.SyncState(h=(), h_bar={"w": jnp.zeros((4,))},
                         step=jnp.zeros((), jnp.int32))
    new_p, st1 = dist.hier_param_sync(jax.random.PRNGKey(0), params_g, st0,
                                      C.identity(), 1.0, period=1,
                                      bucket_size=8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), 2.0 * np.ones((2, 4)),
                               rtol=1e-6)
    # off-period step leaves params untouched
    new_p, st2 = dist.hier_param_sync(jax.random.PRNGKey(0), params_g, st0,
                                      C.identity(), 1.0, period=4,
                                      bucket_size=8)
    np.testing.assert_array_equal(np.asarray(new_p["w"]),
                                  np.asarray(params_g["w"]))
    assert int(st2.step) == 1


# ---------------------------------------------------------------------------
# streaming DMA ring kernel
# ---------------------------------------------------------------------------
def test_stream_quantize_pack_matches_monolithic():
    from repro.kernels import ops

    for d in (511, 3000, 4097):
        x = jax.random.normal(jax.random.PRNGKey(d), (d,)) * 4
        key = jax.random.PRNGKey(d + 1)
        q1, s1 = ops.quantize_pack(x, key)
        q2, s2 = ops.stream_quantize_pack(x, key)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_stream_kernel_vs_tiled_ref():
    from repro.kernels import quant8, ref, stream

    rows = quant8.TILE_ROWS * 3
    x = jax.random.normal(jax.random.PRNGKey(0), (rows, quant8.QBLOCK)) * 7
    noise = jax.random.uniform(jax.random.PRNGKey(1), x.shape)
    q, s = stream.stream_quant_pack_2d(x, noise, bits=8)
    qr, sr = ref.stream_quant_pack_ref(x, noise, bits=8,
                                       tile_rows=quant8.TILE_ROWS)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-7)


# ---------------------------------------------------------------------------
# pipelined round-time model
# ---------------------------------------------------------------------------
def test_pipelined_time_limits():
    stages = (0.03, 0.05, 0.02)
    # one tile degenerates to the serial sum
    assert pipelined_time_s(stages, 1) == pytest.approx(sum(stages))
    # more tiles always helps, approaching max(stages)
    prev = sum(stages)
    for n in (2, 4, 16, 256):
        t = pipelined_time_s(stages, n)
        assert max(stages) < t < prev + 1e-12
        prev = t
    assert pipelined_time_s(stages, 10_000) == pytest.approx(max(stages),
                                                             rel=1e-2)


def test_streamed_upload_2x_on_geo_wan_default_tile():
    """Acceptance: >=2x round-time reduction for the streamed path on the
    geo-WAN preset at the default tile size (100M-param qsgd8 upload)."""
    sync = SyncConfig(mode="efbv", compressor="qsgd", quant_bits=8)
    from repro.comm import measured_payload_bits

    nbytes = measured_payload_bits(sync, 100_000_000) / 8.0
    link = get_topology("geo_wan").inter
    t_serial = link.serial_codec_time_s(nbytes)
    t_stream = link.stream_time_s(nbytes, DEFAULT_TILE_BYTES)
    assert t_serial / t_stream >= 2.0


def test_round_cost_stream_fields_and_speedup():
    sync = SyncConfig(mode="efbv", compressor="qsgd", quant_bits=8)
    topo = get_topology("geo_wan")
    cost = round_cost(sync, 25_000_000, topology=topo)
    # the SyncConfig default and launch/costing must track the one constant
    assert cost.tile_bytes == sync.stream_tile_bytes == DEFAULT_TILE_BYTES
    from repro.launch.costing import _STREAM_TILE
    assert _STREAM_TILE == DEFAULT_TILE_BYTES
    assert cost.time_s < cost.serial_time_s         # streaming always wins
    assert cost.stream_speedup > 1.0
    # disabling streaming falls back to the serial time
    mono = round_cost(SyncConfig(mode="efbv", compressor="qsgd", quant_bits=8,
                                 stream_tile_bytes=0), 25_000_000,
                      topology=topo)
    assert mono.time_s == pytest.approx(mono.serial_time_s)
    assert mono.time_s == pytest.approx(cost.serial_time_s)
    # dense mode pays no codec, so streaming changes nothing
    dense = round_cost(SyncConfig(mode="dense"), 25_000_000, topology=topo)
    assert dense.time_s == pytest.approx(dense.serial_time_s)


def test_link_stream_time_monotone_in_tile():
    link = get_topology("geo_wan").inter
    profile = CodecProfile(pack_gbps=0.5, unpack_gbps=0.5)
    nbytes = 50e6
    times = [link.stream_time_s(nbytes, tb, profile)
             for tb in (1 << 24, 1 << 22, 1 << 20, 1 << 18)]
    assert all(a >= b for a, b in zip(times, times[1:]))
    assert times[-1] < link.serial_codec_time_s(nbytes, profile)
