"""Ch. 6 reproductions on a real (trained-tiny) transformer layer stack:
  Tab 6.3/6.4 — reconstruction error per method at 50% unstructured sparsity
  Tab 6.5     — training-free fine-tuning (DSnoT vs R2-DSnoT) at 60%
  Tab 6.6     — 2:4 structured sparsity
Also end-task: LM loss delta of the pruned tiny model (perplexity proxy).
Derived: relative reconstruction error / loss after prune."""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, now_s
from repro.configs import get_config
from repro.core import symwanda as sw
from repro.data.synthetic import SyntheticLMDataset, lm_batch_iterator
from repro.models import forward_train, init_params
from repro.models.layers import cross_entropy_loss


def _calibrated_layer(params, cfg, batch):
    """Collect real activations entering pos0's MLP w_in of a tiny model."""
    from repro.models.layers import embed, rmsnorm
    x = embed(params["embed"], batch["tokens"])
    bp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"]["pos0"])
    h = rmsnorm(bp["norm1"], x)
    # pre-MLP activations after attention residual: good calibration proxy
    T = h.shape[0] * h.shape[1]
    X = h.reshape(T, -1)
    W = bp["mlp"]["w_in"] if "mlp" in bp else bp["moe"]["w_in"][0]
    return W, X


def run():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, length=30000, seed=0)
    it = lm_batch_iterator(ds, 8, 64, seed=1)
    b = next(it)
    batch = {"tokens": jnp.asarray(b["tokens"][:, :-1]),
             "targets": jnp.asarray(b["tokens"][:, 1:])}
    W, X = _calibrated_layer(params, cfg, batch)
    rows = []

    # --- Tab 6.3/6.4: methods at 50 %
    for m in ("magnitude", "wanda", "ria", "symwanda", "stochria"):
        t0 = now_s()
        Wp, _ = sw.prune(W, X, method=m, sparsity=0.5, key=jax.random.PRNGKey(1))
        us = (now_s() - t0) * 1e6
        err = float(sw.reconstruction_error(W, Wp, X))
        rows.append((f"symwanda_tab6.3/{m}@50", us, f"recon_err={err:.4f}"))

    # --- beta sweep for the symmetric objective
    for beta in (0.0, 0.5, 1.0):
        Wp, _ = sw.prune(W, X, method="symwanda", sparsity=0.5, beta=beta)
        err = float(sw.reconstruction_error(W, Wp, X))
        rows.append((f"symwanda_sec6.3/beta={beta}", 0.0, f"recon_err={err:.4f}"))

    # --- Tab 6.5: training-free fine-tuning at 60 %
    Wp, mask = sw.prune(W, X, method="wanda", sparsity=0.6)
    e0 = float(sw.reconstruction_error(W, Wp, X))
    for name, use_ria in (("dsnot", False), ("r2_dsnot", True)):
        t0 = now_s()
        Wd, _ = sw.r2_dsnot(W, mask, X, sw.DSnoTConfig(iters=30, use_ria_boundary=use_ria))
        us = (now_s() - t0) * 1e6
        e1 = float(sw.reconstruction_error(W, Wd, X))
        rows.append((f"symwanda_tab6.5/{name}@60", us,
                     f"recon_err={e1:.4f};vs_wanda={e1/e0:.3f}"))

    # --- App E.3.2: optimal lp norm (Tab E.1)
    for p in (1.0, 2.0, float("inf")):
        Wp2, _ = sw.prune(W, X, method="ria", sparsity=0.5, p=p)
        err = float(sw.reconstruction_error(W, Wp2, X))
        rows.append((f"symwanda_tabE.1/ria_p={p}", 0.0, f"recon_err={err:.4f}"))

    # --- App E.3.4: stochRIA sampling ratio (Tab E.3)
    for frac in (0.05, 0.1, 0.25, 1.0):
        Wp2, _ = sw.prune(W, X, method="stochria", sparsity=0.5,
                          key=jax.random.PRNGKey(4), sample_frac=frac)
        err = float(sw.reconstruction_error(W, Wp2, X))
        rows.append((f"symwanda_tabE.3/stochria_frac={frac}", 0.0,
                     f"recon_err={err:.4f}"))

    # --- Tab 6.6: 2:4 structured
    for m in ("magnitude", "wanda", "ria"):
        Wp, _ = sw.prune(W, X, method=m, structured_nm=(2, 4))
        err = float(sw.reconstruction_error(W, Wp, X))
        rows.append((f"symwanda_tab6.6/{m}@2:4", 0.0, f"recon_err={err:.4f}"))

    # --- end-task loss proxy: prune EVERY mlp w_in of the tiny model @50%
    def prune_model(method):
        pruned = jax.tree_util.tree_map(lambda a: a, params)
        for pos in params["blocks"]:
            bp = params["blocks"][pos]
            if "mlp" not in bp:
                continue
            for li in range(bp["mlp"]["w_in"].shape[0]):
                Wl = bp["mlp"]["w_in"][li]
                Wp, _ = sw.prune(Wl, X[:, :Wl.shape[0]], method=method, sparsity=0.5)
                pruned["blocks"][pos]["mlp"]["w_in"] = (
                    pruned["blocks"][pos]["mlp"]["w_in"].at[li].set(Wp))
        return pruned

    base_logits, _ = forward_train(params, cfg, batch)
    base = float(cross_entropy_loss(base_logits, batch["targets"]))
    for m in ("magnitude", "wanda"):
        t0 = now_s()
        pl, _ = forward_train(prune_model(m), cfg, batch)
        us = (now_s() - t0) * 1e6
        loss = float(cross_entropy_loss(pl, batch["targets"]))
        rows.append((f"symwanda_endtask/{m}@50", us,
                     f"loss={loss:.4f};delta={loss-base:+.4f}"))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
