"""RL004 — untagged or unregistered ``CommLedger.record*`` tags.

``CommLedger.bytes_by_tag()`` is the per-level / per-purpose byte
attribution the obs report audits against; a free-typed tag string silently
forks the attribution namespace ("retry" vs "retries").  The rule requires:

* every ledger-looking ``.record(...)`` call carries a ``tag=`` (positional
  arg 6 counts); ``record_payload``/``record_stream`` may omit it — they
  default to the payload's wire scheme, which is registered;
* a *literal* tag must resolve to a constant registered in
  ``src/repro/comm/ledger.py`` (``*_TAG`` constants and the members of any
  ``*TAGS*`` frozenset literal);
* name references ending in ``_TAG`` and dynamic expressions (level names,
  f-strings) are accepted — those resolve at runtime.

"Ledger-looking" means a ``.record(...)`` with >= 3 positional args or any
of the ledger keywords — this skips ``obs`` ``tracer.record(span)``.
``comm/ledger.py`` itself and ``obs/`` are exempt.
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional, Set

from repro.lint.framework import Finding, Project, rule

_LEDGER_KW = {"nbytes", "kind", "phase", "tag", "chunk", "link", "round"}
_TAG_ARG_POS = 5  # record(round, link, nbytes, kind, phase, tag, chunk)
_LEDGER_REL = "src/repro/comm/ledger.py"


def _registered_tags(project: Project) -> Optional[Set[str]]:
    """Tag constants parsed out of comm/ledger.py (AST, no import needed).
    None when the ledger source can't be found — literal tags are then
    unverifiable and only missing/empty tags are flagged."""
    ctx = project.files.get(_LEDGER_REL)
    tree = ctx.tree if ctx is not None else None
    if tree is None:
        path = os.path.join(project.root, _LEDGER_REL)
        if not os.path.exists(path):
            return None
        with open(path, encoding="utf-8") as f:
            tree = ast.parse(f.read(), filename=path)
    tags: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        names = [t.id for t in node.targets if isinstance(t, ast.Name)]
        if not names:
            continue
        if any(n.endswith("_TAG") for n in names) and \
                isinstance(node.value, ast.Constant) and \
                isinstance(node.value.value, str):
            tags.add(node.value.value)
        if any("TAGS" in n for n in names):
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    tags.add(sub.value)
    return tags or None


def _tag_expr(node: ast.Call):
    """(present, expr) for the tag argument of a .record call."""
    for kw in node.keywords:
        if kw.arg == "tag":
            return True, kw.value
    if len(node.args) > _TAG_ARG_POS:
        return True, node.args[_TAG_ARG_POS]
    return False, None


def _exempt(relpath: str) -> bool:
    return (relpath == _LEDGER_REL
            or relpath.startswith("src/repro/obs/")
            or relpath.startswith("tests/") and "lint_fixtures" not in relpath)


@rule("RL004", "CommLedger.record* without a tag, or with a literal tag not "
               "registered in comm/ledger.py")
def check(project: Project) -> List[Finding]:
    known = _registered_tags(project)
    out: List[Finding] = []
    for ctx in project.files.values():
        if _exempt(ctx.relpath):
            continue
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("record", "record_payload",
                                           "record_stream")):
                continue
            ledger_like = (node.func.attr != "record"
                           or len(node.args) >= 3
                           or any(kw.arg in _LEDGER_KW
                                  for kw in node.keywords))
            if not ledger_like:
                continue
            present, expr = _tag_expr(node)
            if not present:
                if node.func.attr == "record":
                    out.append(ctx.finding(
                        "RL004", node,
                        "ledger.record(...) without tag=: bytes land in the "
                        "empty-tag bucket of bytes_by_tag()"))
                continue  # record_payload/record_stream default to the scheme
            if isinstance(expr, ast.Constant) and isinstance(expr.value, str):
                if not expr.value:
                    out.append(ctx.finding(
                        "RL004", node, "empty literal tag"))
                elif known is not None and expr.value not in known:
                    out.append(ctx.finding(
                        "RL004", node,
                        f"tag {expr.value!r} is not a registered constant in "
                        f"comm/ledger.py (known: {', '.join(sorted(known))})"))
            # Name/Attribute ending _TAG and dynamic expressions: accepted
    return out
