"""Aggregation-tree benchmark: Cohort-Squeeze beyond two levels (Ch. 5).

Sweeps tree depth x per-level sync period x per-level compressor and reports
simulated round time/bytes against the flat two-level ``hier`` baseline on
all three topology presets.  The interesting physics: every extra tree level
lets a slower link carry a more aggressively compressed, less frequent
payload, and shrinks the ring that crosses it (100 phones ringing a WAN at
once vs 5 phones per cell edge).

Rows:
  hier_tree/<preset>_flat        flat hier baseline (qsgd8 inter, period 8)
  hier_tree/<preset>_depth2      the same schedule written as a depth-2
                                 levels config — asserted bit-identical to
                                 the flat baseline (acceptance)
  hier_tree/<preset>_tree        the multi-level preset with per-level
                                 compression; derived shows slow-link bytes
                                 and speedup vs flat (strictly better on
                                 edge_fl — acceptance)
  hier_tree/ledger_<preset>      per-level ledger attribution; asserts level
                                 bytes sum to RoundCost.total_bytes per round
  hier_tree/sweep_*              depth x base-period x uplink-compressor
                                 sweep on the edge-FL hierarchy

Smoke mode (env BENCH_SMOKE=1 or --smoke): tiny payloads — used by CI so
tree-costing regressions fail loudly.
"""
from __future__ import annotations

import os
import sys

from benchmarks.common import emit
from repro.comm import (Link, TreeLevel, TreeTopology, get_topology,
                        register_tree_topology, round_cost, round_ledger)
from repro.configs.base import LevelConfig, SyncConfig

P = 8  # base sync period (the flat baseline's sync_period)

# deeper edge hierarchy for the depth sweep: phone -> cell -> zone -> region
# -> cloud (4 aggregation levels, 100 phones like the flat preset)
register_tree_topology(TreeTopology("edge_fl_tree4", (
    TreeLevel("uplink", 5, Link(gbps=0.00625, latency_us=50_000.0)),
    TreeLevel("metro", 5, Link(gbps=1.0, latency_us=2_000.0)),
    TreeLevel("zone", 2, Link(gbps=1.0, latency_us=5_000.0)),
    TreeLevel("wan", 2, Link(gbps=1.0, latency_us=20_000.0)),
)))

# per-preset multi-level schedules: the slowest link gets the strongest
# sparsifier, deeper (faster but rarer) levels stack quantization on top
TREE_LEVELS = {
    "v5p_superpod": ("v5p_superpod_tree", (
        LevelConfig("ici", 1, "identity"),
        LevelConfig("host", P, "qsgd", quant_bits=8),
        LevelConfig("dcn", 2 * P, "top_k", 0.05),
    )),
    "geo_wan": ("geo_wan_tree", (
        LevelConfig("ici", 1, "identity"),
        LevelConfig("dcn", P, "qsgd", quant_bits=8),
        LevelConfig("wan", 2 * P, "top_k", 0.05),
    )),
    "edge_fl": ("edge_fl_tree", (
        LevelConfig("uplink", P, "top_k", 0.05),
        LevelConfig("metro", 2 * P, "qsgd", quant_bits=8),
        LevelConfig("wan", 4 * P, "top_k", 0.01),
    )),
}


def _smoke() -> bool:
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def _flat_sync(preset: str, period: int = P) -> SyncConfig:
    return SyncConfig(mode="hier", compressor="qsgd", quant_bits=8,
                      sync_period=period, topology=preset)


def _depth2_sync(preset: str, period: int = P) -> SyncConfig:
    return SyncConfig(mode="hier", topology=preset, levels=(
        LevelConfig("intra", 1, "identity"),
        LevelConfig("inter", period, "qsgd", quant_bits=8)))


def _slow_bytes(cost, gbps_cut: float) -> float:
    """Per-round bytes riding links no faster than the flat slow link."""
    return sum(lv.bytes_per_round for lv in cost.levels
               if lv.link_gbps <= gbps_cut)


def _preset_rows(n_params: int):
    rows = []
    for preset in ("v5p_superpod", "geo_wan", "edge_fl"):
        flat_topo = get_topology(preset)
        flat = round_cost(_flat_sync(preset), n_params)
        rows.append((f"hier_tree/{preset}_flat_p{P}", flat.time_s * 1e6,
                     f"bytes={int(flat.total_bytes)};"
                     f"slow_MB={flat.inter_bytes / 1e6:.4f};"
                     f"t_ms={flat.time_s * 1e3:.2f}"))

        d2 = round_cost(_depth2_sync(preset), n_params)
        same = all(getattr(d2, f) == getattr(flat, f) for f in
                   ("intra_bytes", "inter_bytes", "time_s", "serial_time_s",
                    "encoded_bits", "analytic_bits"))
        assert same, (preset, d2, flat)  # acceptance: depth-2 == flat hier
        rows.append((f"hier_tree/{preset}_depth2", d2.time_s * 1e6,
                     f"bytes={int(d2.total_bytes)};matches_flat={same}"))

        tree_name, lvls = TREE_LEVELS[preset]
        tcost = round_cost(SyncConfig(mode="hier", topology=tree_name,
                                      levels=lvls), n_params)
        slow = _slow_bytes(tcost, flat_topo.inter.gbps)
        detail = ",".join(f"{lv.name}:{lv.bytes_per_round / 1e6:.3f}MB"
                          for lv in tcost.levels)
        if preset == "edge_fl":
            # acceptance: per-level compression strictly reduces slow-link
            # bytes AND round time vs flat hier at the same uplink period
            assert slow < flat.inter_bytes, (slow, flat.inter_bytes)
            assert tcost.time_s < flat.time_s, (tcost.time_s, flat.time_s)
        rows.append((f"hier_tree/{preset}_tree_d{len(lvls)}",
                     tcost.time_s * 1e6,
                     f"bytes={int(tcost.total_bytes)};"
                     f"slow_MB={slow / 1e6:.4f};"
                     f"speedup_vs_flat={flat.time_s / tcost.time_s:.2f};"
                     f"levels={detail}"))

        led = round_ledger(SyncConfig(mode="hier", topology=tree_name,
                                      levels=lvls), n_params)
        n_rounds = led.n_rounds()
        per_round = led.total_bytes / n_rounds
        drift = abs(per_round - tcost.total_bytes) / tcost.total_bytes
        assert drift < 1e-6, (per_round, tcost.total_bytes)
        rows.append((f"hier_tree/ledger_{preset}", 0.0,
                     f"bytes={led.total_bytes};rounds={n_rounds};"
                     f"levels={len(led.bytes_by_tag())};"
                     f"per_round_matches_cost={drift < 1e-6}"))
    return rows


def _sweep_rows(n_params: int):
    """Depth x base-period x uplink-compressor sweep on the edge hierarchy."""
    flat = round_cost(_flat_sync("edge_fl"), n_params)
    depth_cfgs = {
        2: ("edge_fl", lambda p, c: (
            LevelConfig("intra", 1, "identity"),
            LevelConfig("inter", p, c, 0.05, 8))),
        3: ("edge_fl_tree", lambda p, c: (
            LevelConfig("uplink", p, c, 0.05, 8),
            LevelConfig("metro", 2 * p, "qsgd", quant_bits=8),
            LevelConfig("wan", 4 * p, "top_k", 0.01))),
        4: ("edge_fl_tree4", lambda p, c: (
            LevelConfig("uplink", p, c, 0.05, 8),
            LevelConfig("metro", 2 * p, "qsgd", quant_bits=8),
            LevelConfig("zone", 4 * p, "top_k", 0.02),
            LevelConfig("wan", 8 * p, "top_k", 0.01))),
    }
    rows = []
    for depth, (topo_name, mk) in depth_cfgs.items():
        for comp in ("top_k", "qsgd"):
            sc = SyncConfig(mode="hier", topology=topo_name,
                            levels=mk(P, comp))
            cost = round_cost(sc, n_params)
            rows.append((f"hier_tree/sweep_d{depth}_p{P}_{comp}",
                         cost.time_s * 1e6,
                         f"bytes={int(cost.total_bytes)};"
                         f"speedup_vs_flat={flat.time_s / cost.time_s:.2f}"))
    for base_p in (4, 16):  # P itself is covered by the depth loop
        sc = SyncConfig(mode="hier", topology="edge_fl_tree",
                        levels=depth_cfgs[3][1](base_p, "top_k"))
        cost = round_cost(sc, n_params)
        flat_p = round_cost(_flat_sync("edge_fl", base_p), n_params)
        rows.append((f"hier_tree/sweep_d3_p{base_p}_top_k",
                     cost.time_s * 1e6,
                     f"bytes={int(cost.total_bytes)};"
                     f"speedup_vs_flat={flat_p.time_s / cost.time_s:.2f}"))
    return rows


def run(smoke: bool = False):
    smoke = smoke or _smoke()
    n_params = (1 << 15) if smoke else 1_000_000
    return _preset_rows(n_params) + _sweep_rows(n_params)


def main():
    emit(run(smoke="--smoke" in sys.argv[1:]))


if __name__ == "__main__":
    main()
