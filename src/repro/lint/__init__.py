"""repro.lint — repo-native static analyzer.

Two engines behind one CLI (``python -m repro.lint``):

* **Engine 1** — AST rules RL001–RL005 over ``src/repro`` + ``benchmarks``
  (host syncs in jit, unseeded randomness, wall-clock in modeled paths,
  unregistered ledger tags, tracer branches), with per-line
  ``# repro: noqa[RULE]`` suppressions and a committed baseline.
* **Engine 2** — abstract-interpretation contract checks RC001–RC003
  (``jax.eval_shape`` over the compressor registry, payload-vs-accounting
  byte formulas, Pallas kernel static budgets).
"""
from repro.lint.framework import (  # noqa: F401
    Finding,
    Project,
    all_rules,
    apply_baseline,
    build_project,
    load_baseline,
    run_rules,
    write_baseline,
)
