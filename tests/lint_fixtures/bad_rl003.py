"""RL003 fixture: raw wall-clock reads outside the sanctioned modules."""
import time


def measure(fn):
    t0 = time.time()                 # RL003: use repro.obs.trace.wall_s
    fn()
    return time.perf_counter() - t0  # RL003
