"""Production training launcher.

On a real TPU slice this is the entry each host runs (jax.distributed
initializes from the TPU environment); on this CPU container it runs the same
code over a host mesh, or — with ``--dry-run`` — delegates to the multi-pod
dry-run for the requested arch/shape/sync.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --dry-run
  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --reduced --steps 100 --sync efbv
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--sync", default="dense",
                    choices=["dense", "efbv", "ef21", "diana", "hier", "local"])
    ap.add_argument("--compressor", default="qsgd")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile on the production mesh instead of running")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.dry_run:
        # the dry-run module must own the interpreter from the first import
        os.execv(sys.executable, [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", args.arch, "--shape", args.shape,
            "--multi-pod", "multi" if args.multi_pod else "single",
            "--sync", args.sync, "--compressor", args.compressor,
        ])

    from repro.configs import get_config
    from repro.configs.base import SyncConfig, TrainConfig
    from repro.data.synthetic import SyntheticLMDataset, lm_batch_iterator
    from repro.training.loop import train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tc = TrainConfig(model=cfg, seq_len=args.seq, global_batch=args.batch,
                     lr=3e-3, warmup_steps=10, total_steps=args.steps,
                     sync=SyncConfig(mode=args.sync, compressor=args.compressor))
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, length=100000, seed=0)
    it = lm_batch_iterator(ds, args.batch, args.seq, seed=1)
    n_groups = 2 if args.sync != "dense" else 1
    train(cfg, tc, it, n_groups=n_groups, n_pods=2, steps=args.steps,
          ckpt_path=args.ckpt)


if __name__ == "__main__":
    main()
