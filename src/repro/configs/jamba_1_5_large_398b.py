"""Jamba-1.5-Large (398B total). [arXiv:2403.19887]

Hybrid Mamba+attention at a 7:1 mamba:attention interleave, MoE (16 experts,
top-2) applied every second layer.  The constant-size SSD state plus sparse
attention layers keep decode memory manageable -> long_500k runs (the 9
attention layers keep full KV, the 63 mamba layers keep O(1) state).
"""
from repro.configs.base import ATTN_GLOBAL, MAMBA, MambaConfig, ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        citation="arXiv:2403.19887",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        # period-8 block: attention at position 4, mamba elsewhere (1:7)
        layer_pattern=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN_GLOBAL, MAMBA, MAMBA, MAMBA),
        mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=128, chunk_size=256),
        mlp_act="silu",
        mlp_gated=True,
        moe=MoEConfig(num_experts=16, top_k=2),
        moe_every=2,
        supports_long_context=True,
    )
)
