from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    MambaConfig,
    SyncConfig,
    TrainConfig,
    InputShape,
    INPUT_SHAPES,
    get_config,
    list_configs,
    register,
)
