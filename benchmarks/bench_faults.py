"""Fault-injection benchmark: graceful degradation on the edge-FL hierarchy.

Sweeps dropout x straggler x deadline on the ``edge_fl_tree`` preset and
reports the robustness trade-off the fault model exposes: tighter deadlines
finish rounds sooner but aggregate over fewer survivors; lossy links cost
retry bytes (charged to the ledger's ``retry`` tag) instead of silently
shipping corrupt planes.

Rows:
  faults/nofault_edge_fl     modeled round with no fault config (baseline)
  faults/disabled_identity   a disabled ``FaultConfig()`` produces the same
                             bytes/time as no config at all (acceptance)
  faults/sweep_*             dropout x straggler x deadline: total bytes
                             (retry included), retry bytes, degraded round
                             time on edge_fl_tree
  faults/deadline_monotone   degraded round time is non-decreasing in the
                             deadline (acceptance: the deadline knob trades
                             completion time against survivors monotonically)
  faults/survivors_empirical FaultModel round plans averaged over rounds —
                             drops/retries/survivor fraction actually drawn
  faults/replay              two models, same (seed, round) -> identical plan
  faults/consensus_*         degraded tree_param_sync on a synthetic
                             consensus problem: error still contracts under
                             dropouts and deadline-based partial aggregation

``--corrupt-audit`` runs a tiny traced round, verifies the report CLI is
green on the intact artifacts, stays green when only the untraced ``retry``
tag is present, and exits non-zero once a level's ledger bytes are tampered
with — plus the codec-level checksum catching an actually-corrupted payload.
"""
from __future__ import annotations

import json
import math
import os
import sys

from benchmarks.common import emit
from repro.comm import round_cost
from repro.configs.base import LevelConfig, SyncConfig
from repro.faults import FaultConfig, FaultModel

P = 8  # base uplink sync period (matches bench_hier's edge_fl schedule)

EDGE_LEVELS = (
    LevelConfig("uplink", P, "top_k", 0.05),
    LevelConfig("metro", 2 * P, "qsgd", quant_bits=8),
    LevelConfig("wan", 4 * P, "top_k", 0.01),
)


def _smoke() -> bool:
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def _sync(faults=None) -> SyncConfig:
    return SyncConfig(mode="hier", topology="edge_fl_tree", levels=EDGE_LEVELS,
                      faults=faults)


def _fmt_dl(dl: float) -> str:
    return "inf" if math.isinf(dl) else f"{dl:g}"


def _model_rows(n_params: int):
    base = round_cost(_sync(), n_params)
    rows = [(f"faults/nofault_edge_fl", base.time_s * 1e6,
             f"bytes={int(base.total_bytes)};t_ms={base.time_s * 1e3:.2f};"
             f"retry=0")]

    # acceptance: FaultConfig() is all-off => identical bytes and time
    off = round_cost(_sync(FaultConfig()), n_params)
    same = (off.total_bytes == base.total_bytes and off.time_s == base.time_s
            and off.retry_bytes == 0.0 and off.degraded_time_s == 0.0)
    assert same, (off, base)
    rows.append(("faults/disabled_identity", off.time_s * 1e6,
                 f"bytes={int(off.total_bytes)};matches_nofault={same}"))

    for drop in (0.0, 0.05, 0.2):
        for stragglers in (0.0, 0.3):
            for dl in (2.0, 10.0, math.inf):
                fc = FaultConfig(seed=1, drop_rate=drop,
                                 straggler_rate=stragglers,
                                 straggler_sigma=1.0, deadline_s=dl)
                cost = round_cost(_sync(fc), n_params)
                t = cost.degraded_time_s if fc.enabled() else cost.time_s
                rows.append((
                    f"faults/sweep_drop{drop:g}_str{stragglers:g}"
                    f"_dl{_fmt_dl(dl)}", t * 1e6,
                    f"bytes={int(cost.total_bytes)};"
                    f"retry={int(cost.retry_bytes)};"
                    f"t_degraded_ms={t * 1e3:.2f}"))
    return rows


def _deadline_monotone_row(n_params: int):
    """Acceptance: degraded round time is non-decreasing in the deadline."""
    fc0 = FaultConfig(seed=1, drop_rate=0.1, straggler_rate=0.3,
                      straggler_sigma=1.5)
    times = []
    for dl in (1.0, 2.0, 5.0, 20.0, math.inf):
        import dataclasses

        fc = dataclasses.replace(fc0, deadline_s=dl)
        times.append(round_cost(_sync(fc), n_params).degraded_time_s)
    for a, b in zip(times, times[1:]):
        assert a <= b * (1.0 + 1e-9), times
    return [("faults/deadline_monotone", times[-1] * 1e6,
             "t_ms=" + ",".join(f"{t * 1e3:.2f}" for t in times)
             + ";monotone=True")]


def _empirical_rows(n_rounds: int):
    from repro.comm import get_tree_topology

    tree = get_tree_topology("edge_fl_tree")
    fc = FaultConfig(seed=7, availability=0.9, drop_rate=0.05,
                     straggler_rate=0.2, straggler_sigma=1.0, deadline_s=20.0)
    fm = FaultModel(fc, tree)
    drops = retries = 0
    frac = {lev.name: 0.0 for lev in tree.levels}
    for t in range(n_rounds):
        plan = fm.round_plan(t)
        s = plan.stats()
        drops += s["drops"]
        retries += s["retries"]
        for lev in tree.levels:
            frac[lev.name] += s[f"survivor_frac/{lev.name}"]
    fr = ",".join(f"{k}:{v / n_rounds:.3f}" for k, v in frac.items())
    rows = [("faults/survivors_empirical", 0.0,
             f"rounds={n_rounds};drops={drops};retries={retries};"
             f"survivor_frac={fr}")]

    # acceptance: the counter PRNG replays any round from (seed, round) alone
    fm2 = FaultModel(fc, tree)
    p1, p2 = fm.round_plan(n_rounds // 2), fm2.round_plan(n_rounds // 2)
    same = all((a.survivors == b.survivors).all()
               and (a.arrival_s == b.arrival_s).all()
               for a, b in zip(p1.levels, p2.levels))
    assert same
    rows.append(("faults/replay", 0.0,
                 f"round={n_rounds // 2};identical={same}"))
    return rows


def _consensus_rows(n_rounds: int):
    """Degraded tree sync on a synthetic consensus problem.

    12 leaves (fanouts 4x3), each pulling its replica toward its own target;
    the tree sync pulls everyone toward the global mean.  Under dropouts and
    deadlines the aggregate uses fewer children per round, but the consensus
    error must still contract — graceful degradation, not divergence.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.comm import Link, TreeLevel, TreeTopology
    from repro.core import distributed as dist

    levels = (LevelConfig("cell", 1, "identity"),
              LevelConfig("cloud", 1, "identity"))
    tree = TreeTopology("faults_consensus_tree", (
        TreeLevel("cell", 4, Link(gbps=1.0, latency_us=100.0)),
        TreeLevel("cloud", 3, Link(gbps=0.1, latency_us=1000.0)),
    ))
    cascade = dist.build_cascade(
        SyncConfig(mode="hier", levels=levels, topology="edge_fl_tree"), tree)
    G, d, lr = 12, 32, 0.3
    key = jax.random.PRNGKey(0)
    targets = jax.random.normal(key, (G, d))
    center = jnp.mean(targets, axis=0)
    # the no-sync fixed point: every leaf sits at its own target — the
    # yardstick degraded rounds must stay well inside of
    err_local = float(jnp.mean(jnp.linalg.norm(targets - center, axis=-1)))

    def run_case(name, fc):
        params = {"w": jnp.zeros((G, d))}
        st = dist.tree_sync_state_init({"w": jnp.zeros((d,))}, cascade)
        fm = FaultModel(fc, tree) if fc is not None and fc.enabled() else None
        err0 = float(jnp.mean(jnp.linalg.norm(params["w"] - center, axis=-1)))
        for t in range(n_rounds):
            w = params["w"] - lr * (params["w"] - targets)
            surv = (tuple(jnp.asarray(m)
                          for m in fm.round_plan(t).survivor_masks())
                    if fm is not None else None)
            params, st = dist.tree_param_sync(
                jax.random.fold_in(key, t), {"w": w}, st, cascade,
                survivors=surv)
        err = float(jnp.mean(jnp.linalg.norm(params["w"] - center, axis=-1)))
        return err0, err, params

    err0, err_clean, p_clean = run_case("nofault", None)
    _, err_disabled, p_disabled = run_case("disabled", FaultConfig())
    # acceptance: a disabled config takes the exact legacy path bit-for-bit
    bitwise = bool(jnp.all(p_clean["w"] == p_disabled["w"]))
    assert bitwise
    _, err_drop, _ = run_case("dropout", FaultConfig(
        seed=5, availability=0.7, drop_rate=0.1))
    _, err_dl, _ = run_case("deadline", FaultConfig(
        seed=5, availability=0.8, straggler_rate=0.4, straggler_sigma=2.0,
        deadline_s=0.005))
    # acceptance: the faultless cascade reaches consensus, and degraded
    # rounds stay far inside the no-sync fixed point (graceful degradation:
    # dropped leaves drift one local step, then re-anchor)
    assert np.isfinite(err_clean) and err_clean < 0.1 * err_local, (
        err_clean, err_local)
    for e in (err_drop, err_dl):
        assert np.isfinite(e) and e < 0.5 * err_local, (e, err_local)
    return [
        ("faults/consensus_nofault", 0.0,
         f"err0={err0:.3f};err={err_clean:.4f};err_nosync={err_local:.3f};"
         f"disabled_bitwise={bitwise}"),
        ("faults/consensus_dropout", 0.0,
         f"err0={err0:.3f};err={err_drop:.4f};"
         f"vs_nosync={err_drop / err_local:.3f}"),
        ("faults/consensus_deadline", 0.0,
         f"err0={err0:.3f};err={err_dl:.4f};"
         f"vs_nosync={err_dl / err_local:.3f}"),
    ]


# ---------------------------------------------------------------------------
# CI audit mode
# ---------------------------------------------------------------------------
def corrupt_audit(out_dir: str = ".") -> int:
    """Corrupt-payload / tampered-ledger audit for CI.

    1. the codec checksum rejects an actually-corrupted payload;
    2. the report CLI is green on an intact traced round;
    3. adding retry-tag-only ledger bytes keeps it green (untraced tag);
    4. tampering a level's ledger bytes turns it non-zero.
    """
    import jax
    import jax.numpy as jnp

    from benchmarks.bench_comm import traced_round
    from repro.comm import PayloadError, decode, encode, seal_payload
    from repro.core import compressors as C
    from repro.faults import corrupt_payload
    from repro.obs import report as report_mod

    # 1: checksum catches a one-byte flip in a sealed payload
    p = seal_payload(encode(C.qsgd(8), jax.random.PRNGKey(0),
                            jax.random.normal(jax.random.PRNGKey(1), (4096,))))
    plane = corrupt_payload(p, rnd=0, seed=3)
    try:
        decode(p)
        raise AssertionError("corrupted payload decoded cleanly")
    except PayloadError as e:
        print(f"# checksum caught corruption in plane {plane!r}: {e}",
              file=sys.stderr)

    # 2: intact artifacts -> rc 0
    trace_path, metrics_path = traced_round(
        out_dir=out_dir, n_params=1 << 10, label="bench_faults_audit")
    rc = report_mod.main([trace_path, "--metrics", metrics_path])
    assert rc == 0, f"clean report exited {rc}"

    with open(metrics_path) as f:
        doc = json.load(f)

    # 3: retry bytes are ledger-only and must not fail the audit
    retry_doc = dict(doc)
    retry_doc["ledger_bytes_by_tag"] = dict(doc["ledger_bytes_by_tag"],
                                            retry=4096.0)
    retry_path = os.path.join(out_dir, "METRICS_retry.json")
    with open(retry_path, "w") as f:
        json.dump(retry_doc, f)
    rc = report_mod.main([trace_path, "--metrics", retry_path])
    assert rc == 0, f"retry-tag-only report exited {rc}"

    # 4: a tampered level total must fail the byte audit
    bad_doc = dict(doc)
    tags = dict(doc["ledger_bytes_by_tag"])
    lvl = next(iter(sorted(tags)))
    tags[lvl] += 128.0
    bad_doc["ledger_bytes_by_tag"] = tags
    bad_path = os.path.join(out_dir, "METRICS_bad.json")
    with open(bad_path, "w") as f:
        json.dump(bad_doc, f)
    rc = report_mod.main([trace_path, "--metrics", bad_path])
    assert rc != 0, "tampered ledger bytes passed the audit"
    print(f"# tampered {lvl!r} ledger bytes correctly failed the audit "
          f"(rc={rc})", file=sys.stderr)
    return 0


def run(smoke: bool = False):
    smoke = smoke or _smoke()
    n_params = (1 << 15) if smoke else 1_000_000
    n_rounds = 8 if smoke else 64
    return (_model_rows(n_params) + _deadline_monotone_row(n_params)
            + _empirical_rows(n_rounds) + _consensus_rows(n_rounds))


def main():
    argv = sys.argv[1:]
    if "--corrupt-audit" in argv:
        sys.exit(corrupt_audit(os.environ.get("BENCH_TRACE_DIR", ".")))
    emit(run(smoke="--smoke" in argv))


if __name__ == "__main__":
    main()
