"""Link-topology simulator: cross-device vs cross-pod bandwidth/latency.

The paper's communication-efficiency story is about *heterogeneous* links:
Cohort-Squeeze (Ch. 5) pays c_local per intra-cluster round and c_global per
cross-cluster round and shows K > 1 local rounds win whenever
c_global >> c_local.  This module gives those abstract costs physical units:
a ``Topology`` holds one fast fabric link class ("intra": ICI/NVLink-scale)
and one slow one ("inter": DCN / WAN / federated edge), and converts message
or collective sizes into seconds.

Collective model (ring): an all-reduce over g participants moves
2*(g-1)/g * nbytes per device in 2*(g-1) latency-bound steps; reduce and
broadcast/gather halves are (g-1)/g each.  This matches how
launch/hlo_analysis.py counts per-device collective payload, so simulated
times compose with the HLO-derived byte totals in launch/costing.py.

The streaming extension models the *pipelined* transport the codecs feed
(``codecs.encode_stream`` / the Pallas DMA ring in ``kernels/stream.py``):
pack, send, and unpack run as a 3-stage pipeline over fixed-size tiles, so a
round costs fill (one tile through every stage) plus steady state paced by
the slowest stage — ``max(pack, send, unpack)`` per tile — instead of the
serial ``pack + send + unpack`` sum the monolithic codec pays.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Sequence

DEFAULT_TILE_BYTES = 1 << 20  # streamed transport tile (bytes on the wire)


# ---------------------------------------------------------------------------
# straggler order statistics — expected round time under deadlines
# ---------------------------------------------------------------------------
def norm_ppf(p: float) -> float:
    """Standard-normal inverse CDF (Acklam's rational approximation,
    |rel err| < 1.2e-9 — no scipy in the image)."""
    if not 0.0 < p < 1.0:
        raise ValueError(f"p={p} outside (0, 1)")
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return ((((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                 * q + c[5])
                / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1))
    if p > phigh:
        q = math.sqrt(-2 * math.log(1 - p))
        return -((((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                  * q + c[5])
                 / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1))
    q = p - 0.5
    r = q * q
    return ((((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4])
             * r + a[5]) * q
            / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4])
               * r + 1))


def straggler_scale_quantile(q: float, rate: float, sigma: float) -> float:
    """Quantile of one child's slowdown multiplier under the mixture
    ``(1-rate) * point_mass(1) + rate * exp(sigma * |N(0,1)|)``."""
    if q <= 1 - rate or rate <= 0 or sigma <= 0:
        return 1.0
    # |z| has CDF 2*Phi(z)-1; invert the mixture's straggler branch
    inner = min(1.0 - 1e-12, (q - (1 - rate)) / rate)
    z = norm_ppf((1.0 + inner) / 2.0)
    return math.exp(sigma * max(0.0, z))


def straggler_level_time_s(base_s: float, rate: float, sigma: float,
                           n: int, deadline_s: float = math.inf) -> float:
    """Expected completion time of a level waiting on ``n`` children.

    The level finishes at the MAX of n iid slowdown multipliers times
    ``base_s`` — an order statistic, not the mean: the median of the max is
    the per-child quantile ``q = 0.5 ** (1/n)``.  A finite deadline caps it
    (the aggregator stops waiting): ``min(deadline, base * s_q)``.
    """
    if n <= 0 or base_s <= 0:
        return min(base_s, deadline_s) if math.isfinite(deadline_s) else base_s
    q = 0.5 ** (1.0 / max(1, n))
    s = straggler_scale_quantile(q, rate, sigma)
    return min(base_s * s, deadline_s)


def deadline_survivor_frac(base_s: float, rate: float, sigma: float,
                           deadline_s: float) -> float:
    """P(one child's arrival makes the deadline) under the straggler
    mixture — the modeled per-level survivor fraction the fault counters
    measure empirically."""
    if not math.isfinite(deadline_s):
        return 1.0
    if base_s <= 0:
        return 1.0
    r = deadline_s / base_s
    if r < 1.0:
        return 0.0
    p_on_time = 1.0 - rate
    if rate > 0 and sigma > 0 and r > 1.0:
        # P(exp(sigma*|z|) <= r) = 2*Phi(ln r / sigma) - 1
        z = math.log(r) / sigma
        p_on_time += rate * max(0.0, math.erf(z / math.sqrt(2.0)))
    elif rate > 0 and sigma <= 0:
        p_on_time += rate  # degenerate stragglers arrive exactly at base_s
    return min(1.0, p_on_time)


@dataclass(frozen=True)
class CodecProfile:
    """Sustained encode/decode throughput of the payload codec (GB/s).

    Defaults are host-side numpy codec class numbers (sub-GB/s); a fused
    on-device Pallas pack runs far faster and can be profiled in instead.
    """
    pack_gbps: float = 0.75
    unpack_gbps: float = 0.75

    def pack_s(self, nbytes: float) -> float:
        return float(nbytes) / (self.pack_gbps * 1e9)

    def unpack_s(self, nbytes: float) -> float:
        return float(nbytes) / (self.unpack_gbps * 1e9)


DEFAULT_PROFILE = CodecProfile()


def pipelined_time_s(stage_totals_s: Sequence[float], n_tiles: int) -> float:
    """Wall-clock of a tiled pipeline given each stage's *total* time.

    fill: the first tile flows through every stage back to back; steady
    state: the remaining n-1 tiles emerge paced by the slowest stage.  At
    n_tiles=1 this degenerates to the serial sum; as n_tiles grows it
    approaches max(stages).
    """
    n = max(1, int(n_tiles))
    fill = sum(t / n for t in stage_totals_s)
    return fill + max(stage_totals_s) * (n - 1) / n


def stream_pipeline_s(lat_s: float, pack_total_s: float, wire_total_s: float,
                      unpack_total_s: float, n_tiles: int) -> float:
    """Streamed pack | send | unpack pipeline with per-tile wire latency.

    Every tile pays the wire's per-message latency, but tiles overlap in
    flight (the wire is itself a pipeline), so the full per-pass latency
    surfaces exactly once — in the fill, where the first tile traverses the
    wire end to end — while steady state is paced by the slowest
    bandwidth/codec stage.  ``lat_s`` is the latency of ONE tile's complete
    traversal: a point-to-point message pays one hop, a ring collective pays
    its full 2*(g-1) per-step latencies — the same per-message charge the
    serial path pays, never amortized over the tile count.  The result can
    therefore never beat either the bandwidth-only lower bound
    (``wire_total_s``) or the latency floor (``lat_s``).
    """
    return lat_s + pipelined_time_s(
        (pack_total_s, wire_total_s, unpack_total_s), n_tiles)


def ring_parts_s(link: "Link", g: int, nbytes: float) -> tuple:
    """(latency_s, bandwidth_s) decomposition of a ring all-reduce pass."""
    if g <= 1:
        return 0.0, 0.0
    steps = 2 * (g - 1)
    return steps * link.latency_us * 1e-6, (
        2.0 * (g - 1) / g * float(nbytes)) / (link.gbps * 1e9)


def ring_time_s(link: "Link", g: int, nbytes: float) -> float:
    """Ring all-reduce of an nbytes-per-node buffer over g nodes on one link."""
    lat_s, bw_s = ring_parts_s(link, g, nbytes)
    return lat_s + bw_s


@dataclass(frozen=True)
class Link:
    """One link class: sustained bandwidth (GB/s) + per-message latency."""
    gbps: float          # gigabytes per second, per link
    latency_us: float    # one-way message latency, microseconds

    def time_s(self, nbytes: float) -> float:
        return self.latency_us * 1e-6 + float(nbytes) / (self.gbps * 1e9)

    # -- streamed point-to-point message (pack | send | unpack stages) ------
    def serial_codec_time_s(self, nbytes: float,
                            profile: CodecProfile = DEFAULT_PROFILE) -> float:
        """Monolithic path: encode the whole payload, ship it, decode it."""
        return (profile.pack_s(nbytes) + self.time_s(nbytes)
                + profile.unpack_s(nbytes))

    def stream_time_s(self, nbytes: float,
                      tile_bytes: int = DEFAULT_TILE_BYTES,
                      profile: CodecProfile = DEFAULT_PROFILE) -> float:
        """Streamed path: per-tile pack/send/unpack overlap.  Each tile pays
        the per-message latency, overlapped in flight, so one full hop
        latency lands in the fill (see ``stream_pipeline_s``)."""
        n_tiles = max(1, -(-int(nbytes) // int(tile_bytes)))
        return stream_pipeline_s(self.latency_us * 1e-6,
                                 profile.pack_s(nbytes),
                                 float(nbytes) / (self.gbps * 1e9),
                                 profile.unpack_s(nbytes), n_tiles)


@dataclass(frozen=True)
class Topology:
    name: str
    n_pods: int
    devices_per_pod: int
    intra: Link          # cross-device, same pod (ICI-class)
    inter: Link          # cross-pod (DCN / WAN-class)

    @property
    def n_devices(self) -> int:
        return self.n_pods * self.devices_per_pod

    def link(self, kind: str) -> Link:
        if kind == "intra":
            return self.intra
        if kind == "inter":
            return self.inter
        raise KeyError(f"unknown link kind {kind!r} (intra|inter)")

    # -- collective timing (ring model) ------------------------------------
    def allreduce_time_s(self, nbytes: float, scope: str = "intra") -> float:
        """Ring all-reduce of an nbytes-per-device buffer.

        scope: "intra" (one pod, devices_per_pod ring), "inter" (one ring of
        pod leaders over slow links), "global" (hierarchical: intra reduce ->
        inter all-reduce -> intra broadcast, the standard 2-level schedule).
        """
        if scope == "intra":
            return self._ring(self.intra, self.devices_per_pod, nbytes)
        if scope == "inter":
            return self._ring(self.inter, self.n_pods, nbytes)
        if scope == "global":
            return (self._ring_half(self.intra, self.devices_per_pod, nbytes)
                    + self._ring(self.inter, self.n_pods, nbytes)
                    + self._ring_half(self.intra, self.devices_per_pod, nbytes))
        raise KeyError(f"unknown scope {scope!r}")

    # -- streamed collectives (pack | ring | unpack pipeline) ---------------
    def allreduce_serial_time_s(self, nbytes: float, scope: str = "intra",
                                profile: CodecProfile = DEFAULT_PROFILE) -> float:
        """Monolithic compressed all-reduce: every device encodes its full
        contribution, the ring runs, every device decodes — back to back."""
        return (profile.pack_s(nbytes) + self.allreduce_time_s(nbytes, scope)
                + profile.unpack_s(nbytes))

    def allreduce_parts_s(self, nbytes: float, scope: str = "intra") -> tuple:
        """(latency_s, bandwidth_s) decomposition of one all-reduce pass:
        the per-message ring-step latencies vs the bytes/bandwidth term."""
        if scope == "intra":
            return ring_parts_s(self.intra, self.devices_per_pod, nbytes)
        if scope == "inter":
            return ring_parts_s(self.inter, self.n_pods, nbytes)
        if scope == "global":
            hl, hb = self._ring_half_parts(self.intra, self.devices_per_pod,
                                           nbytes)
            il, ib = ring_parts_s(self.inter, self.n_pods, nbytes)
            return 2 * hl + il, 2 * hb + ib
        raise KeyError(f"unknown scope {scope!r}")

    def allreduce_stream_time_s(self, nbytes: float, scope: str = "intra",
                                tile_bytes: int = DEFAULT_TILE_BYTES,
                                profile: CodecProfile = DEFAULT_PROFILE) -> float:
        """Streamed compressed all-reduce: tiles of the encoded buffer enter
        the ring as soon as they are packed, and decode as they land.  The
        per-tile ring pays its full per-step latencies — the same charge the
        serial path pays — surfaced once in the fill (tiles overlap in
        flight); only the bandwidth/codec stages amortize over tiles, so a
        codec-bound pipeline can no longer hide the ring's latency floor."""
        n_tiles = max(1, -(-int(nbytes) // int(tile_bytes)))
        lat_s, bw_s = self.allreduce_parts_s(nbytes, scope)
        return stream_pipeline_s(lat_s, profile.pack_s(nbytes), bw_s,
                                 profile.unpack_s(nbytes), n_tiles)

    @staticmethod
    def _ring(link: Link, g: int, nbytes: float) -> float:
        return ring_time_s(link, g, nbytes)

    @staticmethod
    def _ring_half_parts(link: Link, g: int, nbytes: float) -> tuple:
        if g <= 1:
            return 0.0, 0.0
        steps = g - 1
        return steps * link.latency_us * 1e-6, (
            (g - 1) / g * float(nbytes)) / (link.gbps * 1e9)

    @staticmethod
    def _ring_half(link: Link, g: int, nbytes: float) -> float:
        """Reduce-scatter or all-gather half of the ring."""
        lat_s, bw_s = Topology._ring_half_parts(link, g, nbytes)
        return lat_s + bw_s


# ---------------------------------------------------------------------------
# presets — the scenarios the repo simulates
# ---------------------------------------------------------------------------
PRESETS: Dict[str, Topology] = {
    # 2 TPU pods: ~100 GB/s ICI per chip, ~12.5 GB/s DCN per host link
    "v5p_superpod": Topology("v5p_superpod", n_pods=2, devices_per_pod=256,
                             intra=Link(gbps=100.0, latency_us=1.0),
                             inter=Link(gbps=12.5, latency_us=25.0)),
    # geo-distributed datacenters over WAN
    "geo_wan": Topology("geo_wan", n_pods=4, devices_per_pod=64,
                        intra=Link(gbps=50.0, latency_us=2.0),
                        inter=Link(gbps=1.0, latency_us=20_000.0)),
    # cross-device federated learning: phones behind broadband uplinks
    "edge_fl": Topology("edge_fl", n_pods=100, devices_per_pod=1,
                        intra=Link(gbps=10.0, latency_us=10.0),
                        inter=Link(gbps=0.00625, latency_us=50_000.0)),
}


def get_topology(name: str) -> Topology:
    if name not in PRESETS:
        raise KeyError(f"unknown topology {name!r}; known {sorted(PRESETS)}")
    return PRESETS[name]
