"""Benchmark entry: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The §Roofline harness
(benchmarks/roofline.py) and the multi-pod dry-run (repro.launch.dryrun) are
separate long-running entries — this file covers the paper-table benchmarks.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import bench_comm, bench_efbv, bench_fedp3, bench_kernels
    from benchmarks import bench_scafflix, bench_scafflix_nn, bench_sppm
    from benchmarks import bench_symwanda
    from benchmarks.common import emit

    modules = [
        ("comm(codecs/ledger/topology)", bench_comm),
        ("efbv(Fig2.2)", bench_efbv),
        ("scafflix(Fig3.1/3.3)", bench_scafflix),
        ("scafflix_nn(Fig3.2)", bench_scafflix_nn),
        ("fedp3(Fig4.2/4.4/Tab4.2)", bench_fedp3),
        ("sppm(Fig5.1-5.6)", bench_sppm),
        ("symwanda(Tab6.3-6.6)", bench_symwanda),
        ("kernels", bench_kernels),
    ]
    print("name,us_per_call,derived")
    for label, mod in modules:
        t0 = time.time()
        try:
            emit(mod.run())
        except Exception as e:  # noqa: BLE001 — keep the suite going
            print(f"{label}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
        print(f"# {label} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
