"""Metrics registry: counters/gauges/histograms with per-round time series.

The registry is the numeric side of the flight recorder: where ``trace``
captures *when* things happened, this captures *how much* — bytes per
aggregation level, modeled round times, loss/grad-norm — as first-class time
series keyed by round.  Two ingest hooks wire it into the comm stack:

* :meth:`MetricsRegistry.observe_round_cost` — per-level ``LevelCost``
  byte/time gauges from a ``RoundCost`` (the sum of the per-level byte
  gauges equals ``RoundCost.total_bytes`` exactly, by construction);
* :meth:`MetricsRegistry.ingest_ledger` — ``CommLedger.bytes_by_tag`` /
  per-round record bytes as counters, so measured wire traffic sits next to
  the modeled numbers under the same names.

Everything is plain Python (no deps, no device sync); ``to_dict`` /
``export_json`` produce the machine-readable blob ``repro.obs.report`` joins
with a trace file.
"""
from __future__ import annotations

import json
import threading
from collections import deque
from typing import Dict, List, Optional, Tuple

HIST_WINDOW = 1024  # observations retained per histogram (flight-recorder)


class _Metric:
    kind = "metric"

    def __init__(self, name: str):
        self.name = name
        self.series: List[Tuple[Optional[int], float]] = []

    def _note(self, step: Optional[int], value: float) -> None:
        self.series.append((step, float(value)))

    def to_dict(self) -> dict:
        return {"kind": self.kind, "name": self.name, "series": self.series}


class Counter(_Metric):
    """Monotone accumulator (bytes shipped, spans recorded, ...)."""
    kind = "counter"

    def __init__(self, name: str):
        super().__init__(name)
        self.total = 0.0

    def inc(self, value: float = 1.0, step: Optional[int] = None) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name}: negative inc {value}")
        self.total += float(value)
        self._note(step, value)

    @property
    def value(self) -> float:
        return self.total

    def to_dict(self) -> dict:
        return dict(super().to_dict(), total=self.total)


class Gauge(_Metric):
    """Last-write-wins value (bytes/round of a level, modeled time, loss)."""
    kind = "gauge"

    def __init__(self, name: str):
        super().__init__(name)
        self._value = 0.0

    def set(self, value: float, step: Optional[int] = None) -> None:
        self._value = float(value)
        self._note(step, value)

    @property
    def value(self) -> float:
        return self._value

    def to_dict(self) -> dict:
        return dict(super().to_dict(), value=self._value)


class Histogram(_Metric):
    """Windowed distribution (span durations, per-chunk bytes)."""
    kind = "histogram"

    def __init__(self, name: str, window: int = HIST_WINDOW):
        super().__init__(name)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._window = deque(maxlen=window)

    def observe(self, value: float, step: Optional[int] = None) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self._window.append(v)
        self._note(step, v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 100] over the retained window (recent observations)."""
        if not self._window:
            return 0.0
        vals = sorted(self._window)
        idx = min(len(vals) - 1, max(0, round(q / 100.0 * (len(vals) - 1))))
        return vals[idx]

    def to_dict(self) -> dict:
        return dict(super().to_dict(), count=self.count, sum=self.sum,
                    min=self.min if self.count else None,
                    max=self.max if self.count else None, mean=self.mean)


class MetricsRegistry:
    """Name -> metric map with typed get-or-create accessors."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} is a {m.kind}, not a "
                                f"{cls.kind}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def reset(self) -> None:
        with self._lock:
            self._metrics = {}

    # -- comm-stack ingestion ----------------------------------------------
    def observe_round_cost(self, rnd: int, cost) -> None:
        """Per-level byte/time gauges from a ``RoundCost``.

        Hier/tree modes: one ``comm/bytes/<level>`` gauge per ``LevelCost``
        (their sum is exactly ``cost.total_bytes``).  Flat modes: the
        intra/inter split under the same prefix.  Modeled round times land
        under ``comm/model/...`` so the report can diff measured vs modeled.
        """
        if cost.levels:
            for lv in cost.levels:
                self.gauge(f"comm/bytes/{lv.name}").set(lv.bytes_per_round,
                                                        step=rnd)
                self.gauge(f"comm/model/time_s/{lv.name}").set(lv.time_s,
                                                               step=rnd)
        else:
            self.gauge("comm/bytes/intra").set(cost.intra_bytes, step=rnd)
            self.gauge("comm/bytes/inter").set(cost.inter_bytes, step=rnd)
        self.gauge("comm/model/round_time_s").set(cost.time_s, step=rnd)
        self.gauge("comm/model/serial_time_s").set(cost.serial_time_s,
                                                   step=rnd)
        self.gauge("comm/model/encoded_bits").set(cost.encoded_bits, step=rnd)

    def level_bytes(self) -> Dict[str, float]:
        """The ``comm/bytes/*`` gauges (per-level byte attribution)."""
        out = {}
        with self._lock:
            for name, m in self._metrics.items():
                if name.startswith("comm/bytes/") and isinstance(m, Gauge):
                    out[name[len("comm/bytes/"):]] = m.value
        return out

    def ingest_ledger(self, ledger) -> None:
        """Measured wire traffic from a ``CommLedger``: one counter per tag
        (``comm/ledger/<tag>``), incremented per record with the record's
        round as the series step, plus the per-round total."""
        for rec in ledger.records:
            tag = rec.tag or rec.kind
            self.counter(f"comm/ledger/{tag}").inc(rec.nbytes, step=rec.round)
        for rnd, nb in sorted(ledger.bytes_by_round().items()):
            self.counter("comm/ledger/total").inc(nb, step=rnd)

    def ledger_bytes(self) -> Dict[str, float]:
        """The ``comm/ledger/<tag>`` counter totals (measured bytes)."""
        out = {}
        with self._lock:
            for name, m in self._metrics.items():
                if (name.startswith("comm/ledger/") and name != "comm/ledger/total"
                        and isinstance(m, Counter)):
                    out[name[len("comm/ledger/"):]] = m.total
        return out

    def observe_fault_plan(self, rnd: int, plan) -> None:
        """Fault counters from a ``repro.faults.RoundFaultPlan``: drops,
        retries, deadline misses, corruptions, unavailable clients as
        ``faults/*`` counters, plus the per-level survivor fraction and the
        degraded round completion time as gauges."""
        stats = plan.stats()
        for key in ("drops", "retries", "deadline_misses", "corrupt",
                    "unavailable"):
            self.counter(f"faults/{key}").inc(stats.get(key, 0.0), step=rnd)
        for lv in plan.levels:
            self.gauge(f"faults/survivor_frac/{lv.name}").set(
                lv.survivor_frac, step=rnd)
        self.gauge("faults/round_time_s").set(stats["time_s"], step=rnd)

    def observe_cohort_round(self, rnd: int, report) -> None:
        """Cohort-round series from a ``repro.cohort.CohortRoundReport``:
        per-level/per-class byte counters (the analytic attribution), the
        participation count, and the sweep's in-jit scalar metrics — plus
        the round's fault plan through ``observe_fault_plan`` when the
        engine ran one."""
        rb = report.bytes
        self.counter("cohort/bytes/total").inc(rb.total_bytes, step=rnd)
        for i, nb in enumerate(rb.leaf_class_nbytes):
            self.counter(f"cohort/bytes/class_{i}").inc(nb, step=rnd)
        self.gauge("cohort/participants").set(report.n_participants,
                                              step=rnd)
        self.gauge("cohort/staged_nbytes").set(report.staged_nbytes,
                                               step=rnd)
        for k, v in report.metrics.items():
            self.gauge(f"cohort/{k}").set(float(v), step=rnd)
        if report.plan is not None:
            self.observe_fault_plan(rnd, report.plan)

    def fault_stats(self) -> Dict[str, float]:
        """The ``faults/*`` totals/values (empty when no faults observed)."""
        out = {}
        with self._lock:
            for name, m in self._metrics.items():
                if name.startswith("faults/"):
                    out[name[len("faults/"):]] = (
                        m.total if isinstance(m, Counter) else m.value)
        return out

    def observe_serve(self, stats, step: Optional[int] = None) -> None:
        """Serving-path bridge: a ``training.serving.ServeStats`` snapshot
        lands as ``serve/*`` gauges next to the pool's ``serve/pool/*``
        counters, so the obs report covers the serving plane."""
        for key in ("admitted", "completed", "decode_steps", "prefills",
                    "tokens_out"):
            self.gauge(f"serve/{key}").set(float(getattr(stats, key)),
                                           step=step)

    def serve_stats(self) -> Dict[str, float]:
        """The ``serve/*`` totals/values (empty when nothing served)."""
        out = {}
        with self._lock:
            for name, m in self._metrics.items():
                if name.startswith("serve/"):
                    out[name[len("serve/"):]] = (
                        m.total if isinstance(m, Counter) else m.value)
        return out

    def observe_train_step(self, step: int, metrics: Dict[str, float]) -> None:
        """Loss/grad-norm (host-fetched floats) next to the byte series."""
        for k, v in metrics.items():
            self.gauge(f"train/{k}").set(float(v), step=step)

    # -- export -------------------------------------------------------------
    def to_dict(self) -> dict:
        with self._lock:
            return {"metrics": [self._metrics[k].to_dict()
                                for k in sorted(self._metrics)]}

    def export_json(self, path: str, extra: Optional[dict] = None) -> str:
        doc = self.to_dict()
        if extra:
            doc.update(extra)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        return path


registry = MetricsRegistry()  # the default process-wide registry
