"""Qwen1.5-4B. [hf:Qwen/Qwen1.5-0.5B family card, 4B variant]

Dense decoder with QKV bias; GQA kv=20 (i.e. MHA at this scale: 20 q heads,
20 kv heads).  Full causal attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        citation="hf:Qwen/Qwen1.5-0.5B",
        num_layers=40,
        d_model=2560,
        num_heads=20,
        num_kv_heads=20,
        head_dim=128,
        d_ff=6912,
        vocab_size=151936,
        qkv_bias=True,
        mlp_act="silu",
        mlp_gated=True,
        supports_long_context=False,
    )
)
