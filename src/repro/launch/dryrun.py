"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

Proves the distribution config is coherent without hardware: jit(...).lower()
against ShapeDtypeStruct inputs, .compile() under the production mesh, then
record memory_analysis / cost_analysis / collective payloads for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --multi-pod both
"""
# The VERY FIRST lines — before ANY other import, jax locks device count on
# first init.  512 placeholder host devices cover both production meshes.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, SyncConfig, TrainConfig, get_config, list_configs
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, skip_reason
from repro.models import cache_specs, init_params, model_dtype
from repro.obs.trace import wall_s
from repro.sharding.rules import (
    batch_specs, cache_pspecs, data_axes, opt_state_specs, param_specs)
from repro.training.steps import init_train_state, make_train_step, make_prefill_step, make_decode_step
from repro.utils.logging import get_logger

log = get_logger("dryrun")


def _sharding(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _abstract(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def auto_grad_accum(cfg, shape, n_data: int, width_shards: int = 16) -> int:
    """Microbatch count so remat residuals + logits fit HBM: scale with the
    per-device token load and residual width."""
    local_batch = max(1, shape.global_batch // n_data)
    resid_gb = (cfg.num_layers * local_batch * shape.seq_len * cfg.d_model * 2
                / width_shards / 1e9)  # model-sharded bf16 stack
    accum = 1
    while resid_gb / accum > 1.0 and accum < local_batch:
        accum *= 2
    return accum


def build_train_lowering(cfg, mesh, shape, sync_mode="dense", compressor="qsgd",
                         sync_period=4, remat="full", grad_accum=None):
    daxes = data_axes(mesh)
    n_groups = 1
    for a in daxes:
        n_groups *= mesh.shape[a]
    n_pods = mesh.shape.get("pod", 1)
    if grad_accum is None:
        from repro.sharding import rules as _r
        if sync_mode != "dense":
            grad_accum = 1
        elif _r.NO_TP:
            grad_accum = auto_grad_accum(
                cfg, shape, n_groups * mesh.shape["model"], width_shards=1)
        else:
            grad_accum = auto_grad_accum(cfg, shape, n_groups)

    tc = TrainConfig(model=cfg, seq_len=shape.seq_len, global_batch=shape.global_batch,
                     remat=remat, grad_accum=grad_accum,
                     sync=SyncConfig(mode=sync_mode, compressor=compressor,
                                     sync_period=sync_period))

    # abstract state
    params_abs = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    state_abs = jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0),
                                 jax.tree_util.tree_map(
                                     lambda s: jnp.zeros(s.shape, s.dtype), params_abs),
                                 tc, n_groups, n_pods))

    # shardings
    mode = sync_mode
    dax = daxes if len(daxes) > 1 else daxes[0]
    from repro.models.transformer import set_activation_sharding
    if mode in ("hier", "local"):
        rep_ax = ("pod",) if mode == "hier" else dax
        fsdp = ("data",) if mode == "hier" else None
        pspecs = param_specs(state_abs.params, mesh, extra_leading=2,
                             replica_axes=rep_ax if not isinstance(rep_ax, tuple) or len(rep_ax) > 1 else rep_ax[0],
                             fsdp_axes=fsdp)
        set_activation_sharding(
            NamedSharding(mesh, P("data", None, "model")) if mode == "hier"
            else NamedSharding(mesh, P(None, None, "model")))
    elif mode == "dense":
        from repro.sharding import rules as _rules
        fsdp_ax = daxes + ("model",) if _rules.NO_TP else daxes
        pspecs = param_specs(state_abs.params, mesh, extra_leading=1, fsdp_axes=fsdp_ax)
        if _rules.NO_TP:
            # pure data parallel: batch over ALL axes, no model-dim sharding
            set_activation_sharding(NamedSharding(mesh, P(daxes + ("model",), None, None)))
        else:
            set_activation_sharding(NamedSharding(mesh, P(dax, None, "model")))
    else:  # efbv family: per-group grads via vmap — batch dim is mapped
        pspecs = param_specs(state_abs.params, mesh, extra_leading=1, fsdp_axes=daxes)
        set_activation_sharding(NamedSharding(mesh, P(None, None, "model")))
    ospecs_mu = jax.tree_util.tree_map(lambda p, s: P(*s), state_abs.opt_state.mu, pspecs)
    opt_specs = type(state_abs.opt_state)(step=P(), mu=ospecs_mu, nu=ospecs_mu)
    if state_abs.sync_state is None:
        sync_specs = None
    else:
        if mode in ("efbv", "ef21", "diana"):
            # h_i per worker group: leading dim over (pod, data); param dims
            # keep tensor-parallel sharding only (no fsdp — the group axis
            # already consumes the data axes)
            h_base = param_specs(state_abs.params, mesh, extra_leading=1)
            h_specs = jax.tree_util.tree_map(lambda s: P(dax, *tuple(s)), h_base)
            hb_specs = jax.tree_util.tree_map(lambda s: P(*s), pspecs)
        else:
            h_specs = ()
            # h_bar: no replica dim — param spec minus the leading replica axis
            hb_specs = jax.tree_util.tree_map(lambda s: P(*tuple(s)[1:]), pspecs)
        sync_specs = type(state_abs.sync_state)(h=h_specs, h_bar=hb_specs, step=P())
    state_specs = type(state_abs)(params=pspecs, opt_state=opt_specs,
                                  sync_state=sync_specs, key=P())

    batch_abs = input_specs(cfg, shape)
    bspecs = batch_specs(batch_abs, mesh)

    # pin gradient sharding to the param sharding so FSDP backward grads are
    # reduce-scattered instead of kept replicated through the f32 update
    from repro.sharding.context import set_grad_specs, set_moe_specs
    if mode == "dense":
        set_grad_specs(_sharding(mesh, pspecs))
    else:
        set_grad_specs(None)
    if cfg.moe is not None:
        # shard_map expert parallelism for train/prefill (scatter dispatch is
        # unpartitionable); efbv's vmap-over-groups keeps the scatter path
        impl = "shardmap" if mode in ("dense", "hier") else "scatter"
        from repro.sharding.context import get_moe_gather_quant, get_moe_impl_override
        impl = get_moe_impl_override() or impl
        set_moe_specs({"impl": impl, "mesh": mesh, "data_axes": daxes,
                       "gather_quant": get_moe_gather_quant(),
                       "tokens": P(None, "model"),
                       "expanded": P(None, "model"),
                       "buf": P("model", None, None)})
    else:
        set_moe_specs(None)

    step_fn = make_train_step(cfg, tc, n_groups, n_pods)
    jitted = jax.jit(
        step_fn,
        in_shardings=(_sharding(mesh, state_specs), _sharding(mesh, bspecs)),
        out_shardings=(_sharding(mesh, state_specs),
                       _sharding(mesh, jax.tree_util.tree_map(lambda _: P(), {"loss": 0, "ce": 0, "grad_norm": 0}))),
    )
    with mesh:
        lowered = jitted.lower(state_abs, batch_abs)
    return lowered


def _serving_fsdp(cfg, mesh):
    """FSDP params for serving only when tensor-parallel-only weights would
    not fit HBM (weight-gathered inference for the >60B archs)."""
    tp_bytes = cfg.param_count() * 2 / mesh.shape["model"]
    return data_axes(mesh) if tp_bytes > 8e9 else None


def build_prefill_lowering(cfg, mesh, shape, remat="full"):
    from repro.models.transformer import set_activation_sharding
    from repro.sharding.context import set_moe_specs
    daxes = data_axes(mesh)
    dax = daxes if len(daxes) > 1 else daxes[0]
    set_activation_sharding(NamedSharding(mesh, P(dax, None, "model")))
    if cfg.moe is not None:
        from repro.sharding.context import get_moe_gather_quant, get_moe_impl_override
        set_moe_specs({"impl": get_moe_impl_override() or "shardmap",
                       "mesh": mesh, "data_axes": daxes,
                       "gather_quant": get_moe_gather_quant()})
    else:
        set_moe_specs(None)
    params_abs = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    pspecs = param_specs(params_abs, mesh, extra_leading=1,
                         fsdp_axes=_serving_fsdp(cfg, mesh))
    batch_abs = input_specs(cfg, shape)
    bspecs = batch_specs(batch_abs, mesh)
    from repro.sharding.rules import maybe_axis
    logits_spec = P(maybe_axis(shape.global_batch, dax, mesh), None,
                    maybe_axis(cfg.padded_vocab(), "model", mesh))
    cache_abs = jax.eval_shape(
        lambda p, b: make_prefill_step(cfg, remat)(p, b)[1], params_abs, batch_abs)
    cspecs = cache_pspecs(cache_abs, mesh)

    jitted = jax.jit(
        make_prefill_step(cfg, remat),
        in_shardings=(_sharding(mesh, pspecs), _sharding(mesh, bspecs)),
        out_shardings=(NamedSharding(mesh, logits_spec), _sharding(mesh, cspecs)),
    )
    with mesh:
        return jitted.lower(params_abs, batch_abs)


def build_decode_lowering(cfg, mesh, shape):
    from repro.models.transformer import set_activation_sharding
    from repro.sharding.context import set_moe_specs
    set_activation_sharding(None)
    set_moe_specs(None)  # decode keeps the scatter dispatch (tiny T)
    params_abs = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    pspecs = param_specs(params_abs, mesh, extra_leading=1,
                         fsdp_axes=_serving_fsdp(cfg, mesh))
    specs = input_specs(cfg, shape)
    token_abs, cache_abs = specs["token"], specs["cache"]
    tspec = batch_specs({"t": token_abs}, mesh)["t"]
    cspecs = cache_pspecs(cache_abs, mesh)
    from repro.sharding.rules import maybe_axis
    logits_spec = P(tuple(tspec)[0], None, maybe_axis(cfg.padded_vocab(), "model", mesh))

    jitted = jax.jit(
        make_decode_step(cfg),
        in_shardings=(_sharding(mesh, pspecs), NamedSharding(mesh, tspec),
                      _sharding(mesh, cspecs)),
        out_shardings=(NamedSharding(mesh, logits_spec), _sharding(mesh, cspecs)),
    )
    with mesh:
        return jitted.lower(params_abs, token_abs, cache_abs)


def run_one(arch: str, shape_name: str, multi_pod: bool, sync_mode: str = "dense",
            compressor: str = "qsgd", remat: str = "full",
            compile_only: bool = False) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "sync": sync_mode}

    reason = skip_reason(cfg, shape_name)
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = wall_s()
    try:
        if shape.kind == "train":
            lowered = build_train_lowering(cfg, mesh, shape, sync_mode, compressor,
                                           remat=remat)
        elif shape.kind == "prefill":
            lowered = build_prefill_lowering(cfg, mesh, shape, remat=remat)
        else:
            lowered = build_decode_lowering(cfg, mesh, shape)
        rec["lower_s"] = round(wall_s() - t0, 2)
        t1 = wall_s()
        compiled = lowered.compile()
        rec["compile_s"] = round(wall_s() - t1, 2)
        rec["memory"] = hlo.memory_dict(compiled)
        rec["cost"] = hlo.cost_dict(compiled)
        rec["collectives"] = hlo.collective_bytes(compiled.as_text()).as_dict()
        rec["status"] = "ok"
        print(f"memory_analysis: {rec['memory']}")
        print(f"cost_analysis flops={rec['cost'].get('flops')} "
              f"bytes={rec['cost'].get('bytes accessed')}")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--sync", default="dense",
                    choices=["dense", "efbv", "ef21", "diana", "hier", "local"])
    ap.add_argument("--compressor", default="qsgd")
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = list_configs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}__{args.sync}"
                log.info("dry-run %s", tag)
                rec = run_one(arch, shape, mp, args.sync, args.compressor, args.remat)
                results.append(rec)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=2)
                log.info("  -> %s (lower %.1fs compile %.1fs)", rec["status"],
                         rec.get("lower_s", 0), rec.get("compile_s", 0))
                if rec["status"] == "error":
                    log.info("  error: %s", rec["error"])
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    log.info("done: %d ok, %d skipped, %d error of %d", ok, sk,
             len(results) - ok - sk, len(results))
    return results


if __name__ == "__main__":
    main()
