"""Deterministic fallback for the hypothesis subset this suite uses.

The tier-1 container does not ship hypothesis; rather than skipping every
property test, this shim replays each ``@given`` test over a fixed-seed
sample of the strategy space.  It covers exactly what the suite imports:
``given`` (kwargs only), ``settings(max_examples=, deadline=)``,
``strategies.integers`` and ``strategies.sampled_from``.  With real
hypothesis installed (see requirements.txt) the shim is never imported.
"""
from __future__ import annotations

import functools
import inspect
import random
import types


class _Strategy:
    def __init__(self, sampler):
        self.sample = sampler


def _integers(min_value=0, max_value=1 << 30):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


strategies = types.SimpleNamespace(integers=_integers, sampled_from=_sampled_from)


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", 10))
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                draw = {k: s.sample(rng) for k, s in strats.items()}
                fn(*args, **{**kwargs, **draw})

        # hide the strategy params from pytest's fixture resolution (real
        # hypothesis does the same): expose only the remaining arguments
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        del wrapper.__wrapped__
        return wrapper
    return deco
