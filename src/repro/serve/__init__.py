"""repro.serve — the personalized-model serving plane.

Serving millions of Scafflix/FedP3-personalized models at a per-user memory
cost of kilobytes: one base model on device, per-user deltas stored as
compressed wire payloads (``repro.comm`` codecs), paged into a fixed block
pool on demand, and applied per-batch-slot inside one jitted forward.

  deltas   DeltaStore: base blocks + certified per-user delta payloads,
           byte-costed under serve/page_out / serve/page_in ledger tags
  pool     BlockPool: fixed-capacity device pool of decoded delta blocks,
           LRU eviction + in-flight pins, hit/miss/paged-byte metrics
  engine   DeltaServeEngine: batched multi-user prefill/decode (per-slot
           delta gather+apply, no per-user recompile) and
           PersonalizedBatcher wiring it into the continuous batcher
"""
from repro.serve.deltas import (DEFAULT_BLOCK, DeltaCertificationError,
                                DeltaStore, delta_blocks, delta_from_params,
                                params_from_delta, personalize_leaves,
                                user_key)
from repro.serve.engine import DeltaServeEngine, PersonalizedBatcher
from repro.serve.pool import (ZERO_ROW, BlockPool, PoolEntry, PoolExhausted)

__all__ = [
    "DEFAULT_BLOCK", "DeltaStore", "DeltaCertificationError",
    "delta_from_params", "params_from_delta", "delta_blocks",
    "personalize_leaves", "user_key",
    "BlockPool", "PoolEntry", "PoolExhausted", "ZERO_ROW",
    "DeltaServeEngine", "PersonalizedBatcher",
]
