"""repro.faults — deterministic fault injection and graceful degradation.

Counter-PRNG fault model (availability, stragglers, per-link drop/corrupt/
delay, per-level deadlines) plus the lossy-link transmit simulation with
checksummed retries.  Any round replays bit-exactly from ``(seed, round)``.
"""
from repro.faults.model import (
    FaultConfig,
    FaultModel,
    LevelFaults,
    LevelPlan,
    LinkFaults,
    RoundFaultPlan,
    counter_normal,
    counter_uniform,
)
from repro.faults.transmit import (
    RETRY_TAG,
    TransmitResult,
    corrupt_payload,
    expected_transmissions,
    transmit,
)

__all__ = [
    "FaultConfig",
    "FaultModel",
    "LevelFaults",
    "LevelPlan",
    "LinkFaults",
    "RoundFaultPlan",
    "counter_normal",
    "counter_uniform",
    "RETRY_TAG",
    "TransmitResult",
    "corrupt_payload",
    "expected_transmissions",
    "transmit",
]
