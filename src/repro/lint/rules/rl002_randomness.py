"""RL002 — unseeded randomness.

The repo's replay claims (``round_plan(rnd)`` from ``(seed, round)`` alone,
bit-identical reruns) die the moment any code path draws from global RNG
state.  Flags:

* ``np.random.<sampler>(...)`` — the legacy global-state API (including
  ``np.random.seed``: global seeding is still shared mutable state);
* ``np.random.default_rng()`` / ``Generator``/``PCG64``/... constructors
  called with **no** seed argument;
* stdlib ``random.<fn>(...)`` module-level calls (``random.Random(seed)``
  instances are fine);
* ``jax.random.PRNGKey()`` with no arguments.

Exempt: ``faults/model.py`` (the counter-PRNG implementation itself) and
anything under ``tests/``.
"""
from __future__ import annotations

import ast
from typing import List

from repro.lint.callgraph import dotted
from repro.lint.framework import Finding, Project, rule

# numpy.random constructors that are fine *when given a seed*
_SEEDED_CTORS = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "MT19937", "SFC64", "BitGenerator", "RandomState"}


def _exempt(relpath: str) -> bool:
    if "lint_fixtures" in relpath:  # the linter's own test corpus IS linted
        return False
    return (relpath.endswith("faults/model.py")
            or relpath.startswith("tests/")
            or "/tests/" in relpath)


def _alias_of(ctx_module, graph, module: str, target: str) -> set:
    return {alias for alias, mod in graph.mod_aliases.get(module, {}).items()
            if mod == target}


@rule("RL002", "unseeded randomness (np.random.*, stdlib random, argless "
               "PRNGKey) outside faults/model.py and tests")
def check(project: Project) -> List[Finding]:
    graph = project.callgraph
    out: List[Finding] = []
    for ctx in project.files.values():
        if _exempt(ctx.relpath):
            continue
        np_aliases = _alias_of(ctx, graph, ctx.module, "numpy")
        rand_aliases = _alias_of(ctx, graph, ctx.module, "random")
        froms = graph.from_imports.get(ctx.module, {})
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d is None:
                continue
            parts = d.split(".")
            has_args = bool(node.args or node.keywords)
            # numpy.random.*
            if len(parts) >= 3 and parts[0] in np_aliases and parts[1] == "random":
                name = parts[2]
                if name in _SEEDED_CTORS:
                    if not has_args:
                        out.append(ctx.finding(
                            "RL002",
                            node, f"np.random.{name}() without a seed: "
                                  f"draws from OS entropy, run is not replayable"))
                else:
                    out.append(ctx.finding(
                        "RL002", node,
                        f"np.random.{name}: global-state RNG; use "
                        f"np.random.default_rng(seed)"))
                continue
            # from numpy import random as npr -> npr.rand(...)
            if len(parts) == 2 and froms.get(parts[0]) == ("numpy", "random"):
                name = parts[1]
                if name in _SEEDED_CTORS and has_args:
                    continue
                out.append(ctx.finding(
                    "RL002", node,
                    f"numpy.random.{name}: global-state or unseeded RNG"))
                continue
            # stdlib random module
            if len(parts) == 2 and parts[0] in rand_aliases:
                if parts[1] in ("Random", "SystemRandom") and has_args:
                    continue
                out.append(ctx.finding(
                    "RL002", node,
                    f"random.{parts[1]}: stdlib global-state RNG; seed an "
                    f"explicit random.Random(seed)"))
                continue
            # argless jax.random.PRNGKey()
            tail = parts[-1]
            if tail in ("PRNGKey", "key") and not node.args and not node.keywords:
                is_jax = (d in ("jax.random.PRNGKey", "jax.random.key")
                          or froms.get(parts[0], ("",))[0] == "jax.random"
                          or (len(parts) == 1
                              and froms.get(tail, ("",))[0] == "jax.random"))
                if is_jax:
                    out.append(ctx.finding(
                        "RL002", node,
                        f"jax.random.{tail}() with no seed argument"))
    return out
