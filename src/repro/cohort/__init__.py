"""repro.cohort — vectorized million-client cohort simulation.

``Population`` is the law of a client population (link classes, Dirichlet
data skew, personalization mixes) evaluated lazily per client id;
``CohortEngine`` runs whole federated rounds over sampled cohorts as single
jitted sweeps, with per-class byte attribution (``CohortAccountant``)
cross-checked against a materialized small-N oracle.
"""
from repro.cohort.accounting import (CohortAccountant, CohortRoundBytes,
                                     materialized_round_bytes,
                                     message_nbytes)
from repro.cohort.engine import (CohortEngine, CohortRoundReport,
                                 flix_local_step)
from repro.cohort.population import (ClientSpecBatch, CohortBuckets,
                                     LinkClass, Population,
                                     bucket_boundaries, bucket_by_size,
                                     bucket_capacities, cohort_compressor,
                                     link_classes_from_tree, sample_cohort)

__all__ = [
    "CohortAccountant", "CohortRoundBytes", "materialized_round_bytes",
    "message_nbytes", "CohortEngine", "CohortRoundReport", "flix_local_step",
    "ClientSpecBatch", "CohortBuckets", "LinkClass", "Population",
    "bucket_boundaries", "bucket_by_size", "bucket_capacities",
    "cohort_compressor", "link_classes_from_tree", "sample_cohort",
]
