"""train/prefill/decode step builders — the functions the launcher jits.

Three train-step flavors, keyed by SyncConfig.mode:

  dense          params shared across all workers; global-batch loss; XLA's
                 all-reduce does the (uncompressed) gradient sync. Baseline.
  efbv/ef21/diana
                 per-group gradients via vmap over a leading group axis
                 (sharded over (pod, data)); EF-BV compressed-delta sync
                 produces the shared gradient estimate (Ch. 2).
  hier / local   per-group model replicas (leading axis sharded over 'pod'
                 for hier, (pod, data) for local); local optimizer steps with
                 EF21-compressed parameter sync every sync_period steps
                 (Ch. 3 local training / Ch. 5 cohort squeeze on the fabric).

All steps take and return sharded pytrees; the launcher supplies
in_shardings/out_shardings from sharding/rules.py.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SyncConfig, TrainConfig
from repro.core import distributed as dist
from repro.obs.trace import annotate
from repro.sharding.context import constrain_grads
from repro.models import loss_fn, prefill, decode_step as model_decode_step
from repro.optim.optimizers import apply_updates, clip_by_global_norm, make_optimizer
from repro.optim.schedules import cosine_schedule
from repro.utils.tree import tree_map


class TrainState(NamedTuple):
    params: object
    opt_state: object
    sync_state: object   # dist.SyncState or None
    key: jax.Array


def _make_optimizer(tc: TrainConfig):
    sched = cosine_schedule(tc.lr, tc.warmup_steps, tc.total_steps)
    return make_optimizer(tc.optimizer, sched, weight_decay=tc.weight_decay)


def _cascade_leaves(cascade) -> int:
    n = 1
    for lev in cascade:
        n *= lev.fanout
    return n


def init_train_state(key, params, tc: TrainConfig, n_groups: int, n_pods: int):
    opt = _make_optimizer(tc)
    mode = tc.sync.mode
    if mode in ("hier", "local"):
        if mode == "hier" and tc.sync.levels:
            # aggregation tree: one replica per tree leaf, one anchor per level
            cascade = dist.build_cascade(tc.sync)
            G = _cascade_leaves(cascade)
            sync_state = dist.tree_sync_state_init(params, cascade)
        else:
            G = n_pods if mode == "hier" else n_groups
            h_bar = tree_map(lambda p: p.astype(jnp.float32), params)
            sync_state = dist.SyncState(h=(), h_bar=h_bar,
                                        step=jnp.zeros((), jnp.int32))
        params_g = tree_map(lambda p: jnp.broadcast_to(p[None], (G,) + p.shape), params)
        opt_state = jax.vmap(opt.init)(params_g)
        return TrainState(params_g, opt_state, sync_state, key)
    opt_state = opt.init(params)
    sync_state = (
        dist.sync_state_init(params, n_groups, tc.sync, n_pods)
        if mode != "dense" else None
    )
    return TrainState(params, opt_state, sync_state, key)


def make_train_step(cfg: ModelConfig, tc: TrainConfig, n_groups: int, n_pods: int):
    opt = _make_optimizer(tc)
    sync = tc.sync
    mode = sync.mode
    if mode != "dense":
        compressor = dist.build_compressor(sync)
        lam, nu = dist.sync_params(sync, n_groups)

    def _loss(params, batch):
        return loss_fn(params, cfg, batch, remat=tc.remat)

    grad_fn = jax.value_and_grad(_loss, has_aux=True)

    def _split_groups(batch, G):
        return tree_map(
            lambda a: a.reshape((G, a.shape[0] // G) + a.shape[1:]), batch)

    # ------------------------------------------------------------------ dense
    # phase annotations are trace-safe jax.named_scopes (repro.obs): they name
    # the step phases in jaxpr/XLA profiles and cost nothing at runtime
    def dense_step(state: TrainState, batch):
        A = max(1, tc.grad_accum)
        if A == 1:
            with annotate("step/grad"):
                (loss, parts), grads = grad_fn(state.params, batch)
            grads = constrain_grads(grads)
        else:
            # microbatch accumulation: bounds remat-residual memory by 1/A
            # (required to fit the >100B archs in 16 GB HBM).  The embedding
            # gather is hoisted out of the scan (see forward_train).
            from repro.models.layers import embed as _embed
            batch = dict(batch)
            batch["inputs_embeds"] = _embed(state.params["embed"], batch["tokens"])
            mb = _split_groups(batch, A)
            zeros = constrain_grads(tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params))

            def accum(carry, mbatch):
                gsum, lsum = carry
                (l, _), g = grad_fn(state.params, mbatch)
                g = constrain_grads(g)
                gsum = tree_map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            (gsum, lsum), _ = jax.lax.scan(accum, (zeros, jnp.zeros(())), mb)
            grads = tree_map(lambda g: g / A, gsum)
            loss = lsum / A
            parts = {"ce": loss}
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        with annotate("step/apply"):
            updates, opt_state = opt.update(grads, state.opt_state,
                                            state.params)
            params = apply_updates(state.params, updates)
        metrics = {"loss": loss, "ce": parts["ce"], "grad_norm": gnorm}
        return TrainState(params, opt_state, None, state.key), metrics

    # ------------------------------------------------------------- efbv-style
    def efbv_step(state: TrainState, batch):
        key, sub = jax.random.split(state.key)
        gbatch = _split_groups(batch, n_groups)
        with annotate("step/grad"):
            (loss_g, parts), grads_g = jax.vmap(grad_fn, in_axes=(None, 0))(
                state.params, gbatch)
        loss = jnp.mean(loss_g)
        with annotate("step/sync"):
            g_est, sync_state = dist.efbv_sync(
                sub, grads_g, state.sync_state, compressor, lam, nu,
                bucket_size=sync.bucket_size)
        g_est = tree_map(lambda g, p: g.astype(p.dtype), g_est, state.params)
        g_est, gnorm = clip_by_global_norm(g_est, tc.grad_clip)
        with annotate("step/apply"):
            updates, opt_state = opt.update(g_est, state.opt_state,
                                            state.params)
            params = apply_updates(state.params, updates)
        metrics = {"loss": loss, "ce": jnp.mean(parts["ce"]), "grad_norm": gnorm}
        return TrainState(params, opt_state, sync_state, key), metrics

    # ---------------------------------------------------- hier / local replicas
    cascade = (dist.build_cascade(sync)
               if mode == "hier" and sync.levels else None)
    G_rep = (_cascade_leaves(cascade) if cascade
             else (n_pods if mode == "hier" else n_groups))

    # survivors: optional per-level float masks from a faults.RoundFaultPlan
    # (sync.faults) — None keeps the exact legacy all-participants sync
    def local_step(state: TrainState, batch, survivors=None):
        key, sub = jax.random.split(state.key)
        gbatch = _split_groups(batch, G_rep)

        def one_group(params, opt_state, gb):
            (loss, parts), grads = grad_fn(params, gb)
            grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
            updates, opt_state = opt.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state, loss, gnorm

        with annotate("step/local_updates"):
            params_g, opt_state, loss_g, gnorm_g = jax.vmap(one_group)(
                state.params, state.opt_state, gbatch)
        with annotate("step/sync"):
            if cascade:
                params_g, sync_state = dist.tree_param_sync(
                    sub, params_g, state.sync_state, cascade,
                    bucket_size=sync.bucket_size, survivors=survivors)
            else:
                params_g, sync_state = dist.hier_param_sync(
                    sub, params_g, state.sync_state, compressor, lam,
                    sync.sync_period, bucket_size=sync.bucket_size,
                    survivors=survivors)
        metrics = {"loss": jnp.mean(loss_g), "ce": jnp.mean(loss_g),
                   "grad_norm": jnp.mean(gnorm_g)}
        return TrainState(params_g, opt_state, sync_state, key), metrics

    if mode == "dense":
        return dense_step
    if mode in ("efbv", "ef21", "diana"):
        return efbv_step
    if mode in ("hier", "local"):
        return local_step
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, remat: str = "dots"):
    def prefill_step(params, batch):
        return prefill(params, cfg, batch, remat=remat)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_one(params, token, cache):
        return model_decode_step(params, cfg, token, cache)

    return decode_one
