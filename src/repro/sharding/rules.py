"""Sharding rules engine: param-path patterns -> PartitionSpec.

The production mesh is (data=16, model=16) single-pod or (pod=2, data=16,
model=16) multi-pod.  Rules follow Megatron-style tensor parallelism on the
``model`` axis (FFN hidden, attention projections, vocab, MoE expert axis)
with batch data-parallel over (pod, data).  A divisibility check drops an
axis when the dimension is smaller than the mesh axis (e.g. batch=1 decode);
GSPMD tolerates uneven sharding, but dims < axis size would be pure padding.

Every rule is a (path regex, spec-for-trailing-dims) pair; leading stack dims
added by the layer-scan (n_periods) or by local-training replicas are handled
by prepending.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# data-parallel axes: ("pod", "data") on the multi-pod mesh, ("data",) else
def data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


DATA_AXES = data_axes  # alias


# §Perf variant: disable tensor parallelism entirely (small models: TP
# all-reduces of activation cotangents dwarf the weights — pure FSDP wins)
NO_TP = False

# (regex on '/'-joined path, trailing-dims partition tuple)
_PARAM_RULES = [
    # embedding table sharded on the FEATURE dim: a gather whose rows are
    # unsharded partitions trivially (each model shard gathers its d-slice);
    # vocab-sharded tables trip GSPMD's gather partitioning inside scan+remat
    (r"embed/tok$", (None, "model")),
    (r"embed/unembed$", (None, "model")),
    (r"(attn|xattn)/wq$", (None, "model")),
    (r"(attn|xattn)/wk$", (None, "model")),
    (r"(attn|xattn)/wv$", (None, "model")),
    (r"(attn|xattn)/wo$", ("model", None)),
    (r"(attn|xattn)/b[qkv]$", ("model",)),
    (r"(mlp|shared)/w_(in|gate)$", (None, "model")),
    (r"(mlp|shared)/w_out$", ("model", None)),
    (r"moe/router$", (None, None)),
    (r"moe/w_(in|gate)$", ("model", None, None)),   # expert parallel
    (r"moe/w_out$", ("model", None, None)),
    (r"mamba/in_proj$", (None, "model")),
    (r"mamba/conv_[wb]$", (None,)),                  # small; replicate
    (r"mamba/(a_log|dt_bias|D)$", (None,)),
    (r"mamba/out_proj$", ("model", None)),
    (r"vision_proj$", (None, "model")),
    (r"norm", (None,)),
    (r"(final_norm|norm1|norm2|norm_x)/scale$", (None,)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        else:
            parts.append(str(p))
    return "/".join(parts)


def maybe_axis(dim: int, axis: Optional[str], mesh: Mesh):
    """Drop the axis unless the dim divides evenly over the mesh axis
    (jax in/out shardings reject uneven partitions, e.g. vocab 50280 on 16)."""
    if axis is None:
        return None
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= mesh.shape[a]
    return axis if (dim >= size and dim % size == 0) else None


def _spec_for(path_s: str, shape, mesh: Mesh, extra_leading: int = 0) -> P:
    for pat, trailing in _PARAM_RULES:
        if re.search(pat, path_s):
            if NO_TP:
                trailing = tuple(None if t == "model" else t for t in trailing)
            nt = len(trailing)
            # conv_b / scalars: trailing rule may be longer than shape
            trailing = trailing[-min(nt, len(shape) - extra_leading):]
            lead = (None,) * (len(shape) - len(trailing))
            spec = list(lead) + [
                maybe_axis(shape[len(lead) + i], ax, mesh)
                for i, ax in enumerate(trailing)
            ]
            return P(*spec)
    return P(*([None] * len(shape)))


def param_specs(params, mesh: Mesh, extra_leading: int = 0, replica_axes=None,
                fsdp_axes=None, fsdp_min_dim: int = 1024):
    """PartitionSpec pytree for a param tree (abstract or concrete).

    ``extra_leading`` dims (scan stacks) stay unsharded unless
    ``replica_axes`` names the mesh axes for the outermost leading dim
    (local-training per-group replicas).

    ``fsdp_axes`` additionally shards the first large unsharded dim of every
    weight over the given data axes (ZeRO-3 / FSDP): required for the >30B
    archs where tensor-parallel-only params exceed per-chip HBM."""

    def one(path, leaf):
        ps = _path_str(path)
        spec = list(_spec_for(ps, leaf.shape, mesh, extra_leading))
        if replica_axes is not None:
            spec[0] = replica_axes
        # embedding tables stay vocab-sharded only: FSDP over the feature dim
        # trips the SPMD partitioner on the (vocab-sharded) gather, and the
        # tables are small next to the FFN stack
        if fsdp_axes and "embed" not in ps:
            size = 1
            for a in (fsdp_axes if isinstance(fsdp_axes, tuple) else (fsdp_axes,)):
                size *= mesh.shape[a]
            start = 1 if replica_axes is not None else 0
            for i in range(start, len(spec)):
                dim = leaf.shape[i]
                if spec[i] is None and dim % size == 0 and dim >= fsdp_min_dim:
                    spec[i] = fsdp_axes
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_specs(params, pspecs, mesh: Mesh, zero1: bool = True):
    """Specs for AdamW moments: same as the param, plus ZeRO-1 style extra
    sharding of the largest unsharded dim over the data axes (moments are
    f32 and dominate state memory on the big archs)."""
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]

    def one(p, spec):
        spec = tuple(spec)
        if not zero1:
            return P(*spec)
        best, best_dim = None, 0
        for i, (ax, dim) in enumerate(zip(spec, p.shape)):
            if ax is None and dim % dsize == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best is None:
            return P(*spec)
        new = list(spec)
        new[best] = daxes if len(daxes) > 1 else daxes[0]
        return P(*new)

    return jax.tree_util.tree_map(one, params, pspecs)


def batch_specs(batch_shapes: dict, mesh: Mesh, group_stacked: bool = False,
                axes=None):
    """Specs for input batches: leading batch dim over (pod, data) — or over
    ALL axes (incl. 'model') in NO_TP mode, where every device is a pure
    data-parallel worker."""
    daxes = axes if axes is not None else data_axes(mesh)
    if axes is None and NO_TP:
        daxes = daxes + ("model",)
    ax = daxes if len(daxes) > 1 else daxes[0]

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        dims = [maybe_axis(leaf.shape[0], ax, mesh)] + [None] * (leaf.ndim - 1)
        return P(*dims)

    return jax.tree_util.tree_map(one, batch_shapes)


def cache_pspecs(cache_shapes, mesh: Mesh):
    """Decode-cache specs. Leaves are stacked (n_periods, B, S, ...) for
    attention K/V, (n_periods, B, H, hd, N)/(n_periods, B, K-1, conv) for SSD,
    plus scalars and the enc memory (B, S, D).

    Batch shards over (pod, data) when divisible; attention cache sequence
    shards over 'model' when batch cannot absorb parallelism (long-context
    flash-decoding style) — and head/channel dims over 'model' otherwise."""
    daxes = data_axes(mesh)
    bax = daxes if len(daxes) > 1 else daxes[0]

    def one(path, leaf):
        ps = _path_str(path)
        if leaf.ndim == 0:
            return P()
        if ps.endswith("enc_memory"):
            b = maybe_axis(leaf.shape[0], bax, mesh)
            return P(b, None, maybe_axis(leaf.shape[2], "model", mesh))
        if re.search(r"/(k|v)$", ps):
            # (n_periods, B, S, KV, hd)
            _, B, S, KV, hd = leaf.shape
            b = maybe_axis(B, bax, mesh)
            s = maybe_axis(S, "model", mesh)
            return P(None, b, s, None, None)
        if ps.endswith("ssm"):
            _, B, H, hd, N = leaf.shape
            return P(None, maybe_axis(B, bax, mesh), maybe_axis(H, "model", mesh),
                     None, None)
        if ps.endswith("conv"):
            _, B, K, C = leaf.shape
            return P(None, maybe_axis(B, bax, mesh), None,
                     maybe_axis(C, "model", mesh))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)
