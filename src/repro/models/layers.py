"""Shared neural layers: norms, rotary embeddings, MLPs, embeddings.

Functional style: ``init_*`` builds a param subtree (nested dict of arrays),
``apply``-style functions consume (params, inputs).  Params use a leading
stacking dim when scanned over layers (see transformer.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def l2norm(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Parameter-free L2 norm over the last dim (QK-norm, chameleon-style)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    return (x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / squared-ReLU / plain)
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_in": _dense_init(ks[0], (d_model, d_ff), dtype),
        "w_out": _dense_init(ks[1], (d_ff, d_model), dtype),
    }
    if gated:
        p["w_gate"] = _dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def mlp(params: dict, x: jax.Array, act: str = "silu", gated: bool = True) -> jax.Array:
    h = x @ params["w_in"]
    if gated:
        g = x @ params["w_gate"]
        if act == "silu":
            h = jax.nn.silu(g) * h
        elif act == "gelu":
            h = jax.nn.gelu(g) * h
        else:
            raise ValueError(act)
    else:
        if act == "relu2":  # nemotron squared-ReLU
            h = jnp.square(jax.nn.relu(h))
        elif act == "gelu":
            h = jax.nn.gelu(h)
        elif act == "silu":
            h = jax.nn.silu(h)
        else:
            raise ValueError(act)
    return h @ params["w_out"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embed(key, vocab: int, d_model: int, dtype, tie: bool) -> dict:
    ks = jax.random.split(key, 2)
    p = {"tok": _dense_init(ks[0], (vocab, d_model), dtype, scale=0.02)}
    if not tie:
        p["unembed"] = _dense_init(ks[1], (d_model, vocab), dtype, scale=0.02)
    return p


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params: dict, x: jax.Array) -> jax.Array:
    if "unembed" in params:
        return x @ params["unembed"]
    return x @ params["tok"].T


def cross_entropy_loss(logits: jax.Array, targets: jax.Array, ignore: int = -1,
                       valid_vocab: Optional[int] = None) -> jax.Array:
    """Mean next-token CE in fp32. logits (..., V), targets (...,) int.
    ``valid_vocab`` masks padded vocab rows out of the partition function."""
    logits = logits.astype(jnp.float32)
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        dead = jnp.arange(logits.shape[-1]) >= valid_vocab
        logits = jnp.where(dead, -1e30, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = logz - gold
    mask = (targets != ignore).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
