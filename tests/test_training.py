"""Training-loop + distributed-sync behaviour on CPU (1 device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import SyncConfig, TrainConfig
from repro.core import distributed as dist
from repro.data.synthetic import SyntheticLMDataset, lm_batch_iterator
from repro.models import init_params
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.loop import train
from repro.training.steps import init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("h2o-danube-1.8b").reduced()


def _iterator(cfg, batch=4, seq=32):
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, length=20000, seed=0)
    return lm_batch_iterator(ds, batch, seq, seed=1)


def test_loss_decreases_dense(tiny_cfg):
    tc = TrainConfig(model=tiny_cfg, seq_len=32, global_batch=8, lr=1e-2,
                     warmup_steps=5, total_steps=80)
    _, hist = train(tiny_cfg, tc, _iterator(tiny_cfg, batch=8), steps=80,
                    log_every=1000)
    first = np.mean([h["loss"] for h in hist[:10]])
    last = np.mean([h["loss"] for h in hist[-10:]])
    assert last < first - 0.8  # clear learning signal on the markov corpus


@pytest.mark.parametrize("mode,comp", [("efbv", "qsgd"), ("ef21", "topk_block"),
                                       ("local", "identity")])
def test_sync_modes_train(tiny_cfg, mode, comp):
    tc = TrainConfig(model=tiny_cfg, seq_len=32, global_batch=4, lr=3e-3,
                     warmup_steps=2, total_steps=30,
                     sync=SyncConfig(mode=mode, compressor=comp,
                                     compress_ratio=0.25, sync_period=4))
    _, hist = train(tiny_cfg, tc, _iterator(tiny_cfg), n_groups=2, n_pods=2,
                    steps=30, log_every=1000)
    assert np.isfinite([h["loss"] for h in hist]).all()
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.1


def test_grad_accum_matches_plain(tiny_cfg):
    """grad_accum=2 must give (numerically) the same update as accum=1."""
    ds_iter = _iterator(tiny_cfg, batch=4, seq=16)
    batch_np = next(ds_iter)
    batch = {"tokens": jnp.asarray(batch_np["tokens"][:, :-1]),
             "targets": jnp.asarray(batch_np["tokens"][:, 1:])}
    params = init_params(jax.random.PRNGKey(0), tiny_cfg)
    outs = {}
    for accum in (1, 2):
        tc = TrainConfig(model=tiny_cfg, seq_len=16, global_batch=4, lr=1e-3,
                         warmup_steps=1, total_steps=2, grad_accum=accum)
        state = init_train_state(jax.random.PRNGKey(1), params, tc, 1, 1)
        step = jax.jit(make_train_step(tiny_cfg, tc, 1, 1))
        new_state, m = step(state, batch)
        outs[accum] = jax.tree_util.tree_leaves(new_state.params)
    for a, b in zip(outs[1], outs[2]):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-2)


def test_hier_sync_is_fedavg_with_identity():
    """hier_param_sync with identity compressor and lam=1 == exact averaging."""
    from repro.core.compressors import identity

    params_g = {"w": jnp.stack([jnp.ones((4,)) * 1.0, jnp.ones((4,)) * 3.0])}
    st = dist.SyncState(h=(), h_bar={"w": jnp.zeros((4,))},
                        step=jnp.zeros((), jnp.int32))
    new_p, st2 = dist.hier_param_sync(jax.random.PRNGKey(0), params_g, st,
                                      identity(), 1.0, period=1)
    np.testing.assert_allclose(np.asarray(new_p["w"][0]), 2.0 * np.ones(4), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_p["w"][1]), 2.0 * np.ones(4), rtol=1e-6)


def test_hier_sync_respects_period():
    from repro.core.compressors import identity

    params_g = {"w": jnp.stack([jnp.zeros(3), jnp.ones(3)])}
    st = dist.SyncState(h=(), h_bar={"w": jnp.zeros(3)}, step=jnp.zeros((), jnp.int32))
    new_p, st2 = dist.hier_param_sync(jax.random.PRNGKey(0), params_g, st,
                                      identity(), 1.0, period=4)
    # step 0 of 4: no sync — params unchanged
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(params_g["w"]))
    assert int(st2.step) == 1


def test_checkpoint_roundtrip(tmp_path, tiny_cfg):
    params = init_params(jax.random.PRNGKey(0), tiny_cfg)
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, params, step=7)
    restored, step = load_checkpoint(path, params)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bits_accounting():
    # bits_per_round is now measured from encoded payloads (repro.comm): int8
    # planes plus per-block scales — within 10% of the analytic 8 bits/dim
    sc = SyncConfig(mode="efbv", compressor="qsgd", quant_bits=8)
    bits = dist.bits_per_round(sc, 1000)
    assert abs(bits - 8000) <= 0.1 * 8000
    sc = SyncConfig(mode="hier", compressor="qsgd", quant_bits=8, sync_period=4)
    assert abs(dist.bits_per_round(sc, 1000) - 2000) <= 0.1 * 2000


def test_round_comm_report():
    sc = SyncConfig(mode="hier", compressor="qsgd", quant_bits=8, sync_period=4)
    cost = dist.round_comm(sc, 1000)
    # hier: dense fp32 intra every step + amortized compressed inter
    assert cost.intra_bytes == 4000
    assert 0 < cost.inter_bytes < 4000 / 4
    assert cost.time_s > 0
    assert abs(cost.encoded_bits / cost.analytic_bits - 1.0) < 0.1
