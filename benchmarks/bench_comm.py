"""repro.comm benchmark: codec sizes vs the analytic model, pack-kernel
throughput, topology-simulated round times per sync mode, and the streamed
(pipelined) vs monolithic (serial) codec path.

Rows:
  comm_codec/<name>       encode+decode one payload (warm-up + median of >=5
                          repeats); derived = encoded bytes (== CommLedger
                          record), the ratio to the analytic payload_bits/8
                          model, and round-trip exactness
  comm_stream/codec_*     encode_stream/decode_stream at several tile sizes;
                          asserts chunked == monolithic bit-for-bit and that
                          per-chunk ledger bytes sum to the payload
  comm_stream/<preset>    simulated round time of the streamed pipeline vs
                          the serial pack->send->unpack path (the acceptance
                          row: >=2x on geo_wan at the default tile size)
  comm_kernel/<name>      Pallas pack kernels (interpret mode) vs jnp refs,
                          including the double-buffered streaming DMA ring
  comm_round/<mode>       per-round encoded bytes from the ledger + simulated
                          wall-clock on two topology presets (Cohort-Squeeze
                          'hier' shows the slow-link amortization)

Smoke mode (env BENCH_SMOKE=1 or --smoke): tiny payloads, 1 repeat — used by
CI so codec perf regressions fail loudly instead of silently.

Traced mode (``--traced``): :func:`traced_round` runs one full root period of
a hier schedule host-side with the real codecs under the repro.obs flight
recorder and writes ``TRACE_round.jsonl`` + ``METRICS_round.json``; feeding
them to ``python -m repro.obs.report`` yields the measured-vs-modeled phase
table whose per-level measured bytes match the ``CommLedger`` exactly.
"""
from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.comm import (DEFAULT_TILE, DEFAULT_TILE_BYTES, CommLedger,
                        analytic_bits, decode, decode_stream, encode,
                        encode_stream, get_topology, round_cost,
                        split_payload)
from repro.configs.base import SyncConfig
from repro.core import compressors as C

D = 1 << 16


def _smoke() -> bool:
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def _codec_rows(d: int, repeats: int):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (d,))
    cases = [
        ("identity", C.identity()),
        ("top_k(0.05)", C.top_k(0.05)),
        ("rand_k(0.1)", C.rand_k(0.1)),
        ("block_top_k(0.05)", C.block_top_k(0.05)),
        ("qsgd_int8", C.qsgd(8)),
        ("qsgd_int4", C.qsgd(4)),
        ("qsgd_kernel_int8", C.qsgd_kernel(8)),
    ]
    rows = []
    for name, comp in cases:
        us = timed(lambda: decode(encode(comp, key, x)), repeats=repeats,
                   name=f"comm_codec/{name}")
        p = encode(comp, key, x)
        exact = bool(jnp.all(comp(key, x) == decode(p)))
        led = CommLedger()
        led.record_payload(0, "probe", p)
        ratio = 8.0 * led.total_bytes / analytic_bits(comp, d)
        rows.append((f"comm_codec/{name}", us,
                     f"bytes={led.total_bytes};vs_analytic={ratio:.3f};exact={exact}"))
    return rows


def _stream_codec_rows(d: int, repeats: int, tiles):
    """Chunked encode/decode at several tile sizes, exactness asserted."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (d,))
    comp = C.qsgd(8)
    p = encode(comp, key, x)
    y = decode(p)
    rows = []
    for tile in tiles:
        us = timed(lambda: decode_stream(encode_stream(comp, key, x, tile=tile)),
                   repeats=repeats, name=f"comm_stream/codec_tile{tile}")
        sp = split_payload(p, tile)
        led = CommLedger()
        led.record_stream(0, "probe", sp)
        exact = bool(jnp.all(decode_stream(sp) == y))
        assert led.total_bytes == p.nbytes, (led.total_bytes, p.nbytes)
        assert exact, tile
        rows.append((f"comm_stream/codec_tile{tile}", us,
                     f"bytes={led.total_bytes};chunks={sp.n_chunks};exact={exact}"))
    return rows


def _stream_time_rows():
    """Streamed vs serial simulated round time (the acceptance comparison).

    The payload is one federated client upload: a 100M-param model's qsgd
    int8 delta (~100 MB) on each preset's slow link at the default tile.
    """
    n_params = 100_000_000
    sync = SyncConfig(mode="efbv", compressor="qsgd", quant_bits=8)
    from repro.comm import measured_payload_bits

    nbytes = measured_payload_bits(sync, n_params) / 8.0
    rows = []
    for preset in ("geo_wan", "v5p_superpod", "edge_fl"):
        link = get_topology(preset).inter
        t_serial = link.serial_codec_time_s(nbytes)
        t_stream = link.stream_time_s(nbytes, DEFAULT_TILE_BYTES)
        rows.append((f"comm_stream/{preset}_upload", t_stream * 1e6,
                     f"bytes={int(nbytes)};serial_ms={t_serial*1e3:.1f};"
                     f"stream_ms={t_stream*1e3:.1f};"
                     f"speedup={t_serial/t_stream:.2f}"))
    return rows


def _kernel_rows(d: int, repeats: int):
    from repro.kernels import ops

    rows = []
    mask = (jax.random.uniform(jax.random.PRNGKey(2), (d,)) < 0.05)
    us = timed(lambda: jax.block_until_ready(ops.pack_bits(mask)),
               repeats=repeats, name="comm_kernel/pack_bits")
    words = ops.pack_bits(mask)
    ok = bool(jnp.all(ops.unpack_bits(words, d) == mask.astype(jnp.uint32)))
    rows.append(("comm_kernel/pack_bits", us,
                 f"words={words.shape[0]};roundtrip={ok}"))

    x = jax.random.normal(jax.random.PRNGKey(3), (d,)) * 5
    key = jax.random.PRNGKey(4)
    us = timed(lambda: jax.block_until_ready(ops.quantize_pack(x, key)[0]),
               repeats=repeats, name="comm_kernel/quantize_pack")
    q, scales = ops.quantize_pack(x, key)
    dq = ops.unpack_dequantize(q, scales, d)
    carrier = ops.quantize_dequantize(x, key)
    ok = bool(jnp.all(dq == carrier.reshape(-1)))
    rows.append(("comm_kernel/quantize_pack", us,
                 f"plane_bytes={q.size + 4 * scales.size};matches_carrier={ok}"))

    us = timed(lambda: jax.block_until_ready(ops.stream_quantize_pack(x, key)[0]),
               repeats=repeats, name="comm_kernel/stream_quantize_pack")
    qs, ss = ops.stream_quantize_pack(x, key)
    ok = bool(jnp.all(qs == q)) and bool(jnp.all(ss == scales))
    rows.append(("comm_kernel/stream_quantize_pack", us,
                 f"plane_bytes={qs.size + 4 * ss.size};matches_monolithic={ok}"))
    return rows


def _round_rows(repeats: int):
    n_params = 25_000_000  # ~100 MB fp32 model
    rows = []
    for label, sync in [
        ("dense", SyncConfig(mode="dense")),
        ("efbv_top_k0.05", SyncConfig(mode="efbv", compressor="top_k",
                                      compress_ratio=0.05)),
        ("efbv_qsgd8", SyncConfig(mode="efbv", compressor="qsgd", quant_bits=8)),
        ("hier_qsgd8_p8", SyncConfig(mode="hier", compressor="qsgd",
                                     quant_bits=8, sync_period=8)),
    ]:
        us = timed(lambda: round_cost(sync, n_params), repeats=repeats,
                   name=f"comm_round/{label}")
        cost = round_cost(sync, n_params)
        wan = round_cost(sync, n_params, topology=get_topology("geo_wan"))
        ratio = cost.encoded_bits / cost.analytic_bits if cost.analytic_bits else 0
        rows.append((f"comm_round/{label}", us,
                     f"MB={cost.total_bytes/1e6:.2f};vs_analytic={ratio:.3f};"
                     f"t_v5p={cost.time_s*1e3:.2f}ms;t_wan={wan.time_s*1e3:.1f}ms;"
                     f"t_wan_serial={wan.serial_time_s*1e3:.1f}ms"))
    return rows


def traced_round(out_dir: str = ".", n_params: int = 1 << 16, sync=None,
                 label: str = "bench_comm_round"):
    """One full root period of a hier schedule, executed host-side with the
    real codecs under tracing.

    Every sync step encodes the same probe payload ``round_ledger`` sizes
    its records from (``x = normal(fold_in(key, 1), (n_params,))`` encoded
    under ``key = PRNGKey(0)``), so the encode-span ``nbytes`` per level sum
    to the ledger's ``bytes_by_tag`` exactly — the invariant
    ``python -m repro.obs.report`` audits.  Writes the trace JSONL and a
    metrics JSON carrying the ledger; returns ``(trace_path, metrics_path)``.
    """
    import numpy as np

    from repro.comm import round_ledger
    from repro.comm.accounting import PROBE_CAP, _hier_levels
    from repro.core.distributed import make_sync_compressor
    from repro.obs import registry, trace as obs_trace

    sync = sync or SyncConfig(mode="hier", compressor="qsgd", quant_bits=8,
                              sync_period=4)
    assert sync.mode == "hier", sync.mode
    assert n_params <= PROBE_CAP, "exact ledger match needs n_params <= probe"
    lcfgs = _hier_levels(sync)
    n_rounds = max(1, lcfgs[-1].period)  # one full root period

    was_enabled = obs_trace.enabled()
    obs_trace.enable()
    obs_trace.get_tracer().reset()
    registry.reset()

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n_params,))
    comps = {lc.name: make_sync_compressor(lc.compressor, lc.compress_ratio,
                                           lc.quant_bits)
             for lc in lcfgs}

    for t in range(n_rounds):
        with obs_trace.span("round/step", round=t):
            for lc in lcfgs:
                period = max(1, lc.period)
                if (t % period) != (period - 1):
                    continue  # this level does not sync at round t
                with obs_trace.ambient(level=lc.name):
                    with obs_trace.span("sync/pack", level=lc.name):
                        host = np.asarray(x)  # host staging of the payload
                    p = encode(comps[lc.name], key, x)  # codec/encode span
                    with obs_trace.span("comm/allreduce", level=lc.name,
                                        nbytes=p.nbytes):
                        # the wire hop: planes cross the level's link
                        wire = {k: v.copy() for k, v in p.planes.items()}
                    y = decode(p)                       # codec/decode span
                    with obs_trace.span("sync/adopt", level=lc.name):
                        host = host + np.asarray(y)     # model adoption
    del wire, host

    sync_meta = {"mode": sync.mode, "compressor": sync.compressor,
                 "compress_ratio": sync.compress_ratio,
                 "quant_bits": sync.quant_bits,
                 "sync_period": sync.sync_period,
                 "topology": sync.topology}
    if sync.levels:
        sync_meta["levels"] = [
            {"name": lc.name, "period": lc.period, "compressor": lc.compressor,
             "compress_ratio": lc.compress_ratio, "quant_bits": lc.quant_bits}
            for lc in sync.levels]
    obs_trace.set_meta(label=label, n_params=n_params, n_rounds=n_rounds,
                       sync=sync_meta)

    # export the trace BEFORE the accounting calls: round_ledger/round_cost
    # size their probes through codecs.encode, which would otherwise leak
    # untagged encode spans into the audited trace
    trace_path = obs_trace.export_jsonl(
        os.path.join(out_dir, "TRACE_round.jsonl"))
    if not was_enabled:
        obs_trace.disable()

    led = round_ledger(sync, n_params, n_rounds=n_rounds)
    registry.observe_round_cost(0, round_cost(sync, n_params))
    registry.ingest_ledger(led)
    metrics_path = registry.export_json(
        os.path.join(out_dir, "METRICS_round.json"),
        extra={"ledger_bytes_by_tag": {k: float(v)
                                       for k, v in led.bytes_by_tag().items()},
               "n_params": n_params, "n_rounds": n_rounds})
    return trace_path, metrics_path


def run(smoke: bool = False):
    smoke = smoke or _smoke()
    d = 1 << 13 if smoke else D
    repeats = 1 if smoke else 5
    # smoke tiles still split the payload (qsgd blocks are 2048 coords wide)
    tiles = ((2048, 4096) if smoke
             else (DEFAULT_TILE // 4, DEFAULT_TILE, DEFAULT_TILE * 4))
    return (_codec_rows(d, repeats) + _stream_codec_rows(d, repeats, tiles)
            + _stream_time_rows() + _kernel_rows(d, repeats)
            + _round_rows(repeats))


def main():
    argv = sys.argv[1:]
    if "--traced" in argv:
        out_dir = os.environ.get("BENCH_TRACE_DIR", ".")
        trace_path, metrics_path = traced_round(out_dir=out_dir)
        print(f"# trace -> {trace_path}", file=sys.stderr)
        print(f"# metrics -> {metrics_path}", file=sys.stderr)
        print(f"# report: python -m repro.obs.report {trace_path} "
              f"--metrics {metrics_path}", file=sys.stderr)
        return
    emit(run(smoke="--smoke" in argv))


if __name__ == "__main__":
    main()
