"""Fixed-capacity device-side pool of decoded delta blocks (the pager).

The serving fleet's device memory holds ONE base model plus this pool; users
page in and out of it the way pie's ``KvBlockStorage`` pages KV-cache blocks
(SNIPPETS.md Snippet 1).  An entry is one user's set of *nonzero* decoded
delta blocks — zero blocks all alias the reserved all-zero row 0, so a
user's resident cost is O(nonzero delta blocks), not O(model blocks).

Paging semantics:
  * miss  — decode the stored wire payload host-side, copy the nonzero
            blocks into free pool rows (host->device), charge exactly
            ``payload.nbytes`` to the ledger under ``serve/page_in``;
  * hit   — the user is already resident: zero decode work, zero bytes;
  * evict — pages are clean (the payload is the durable copy), so eviction
            just frees rows; stale device data is overwritten on reuse.

Entries are LRU-ordered; ``acquire`` pins an entry for the lifetime of a
batch slot and pinned entries are never evicted (``release`` unpins).  All
residency / hit / miss / eviction / paged-byte counters flow into the
``repro.obs`` metrics registry under ``serve/pool/*``.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.comm.ledger import PAGE_IN_TAG
from repro.serve.deltas import DeltaStore

ZERO_ROW = 0  # reserved pool row: the shared all-zero delta block


class PoolExhausted(RuntimeError):
    """Not enough unpinned rows to page a user in — the pool is too small
    for the live batch's working set."""


@dataclass
class PoolEntry:
    """One resident user: which pool rows hold their nonzero blocks."""
    user_id: int
    rows: np.ndarray            # pool rows backing the nonzero blocks
    table: np.ndarray           # (n_model_blocks,) int32 -> pool row (0=zero)
    payload_nbytes: int
    pins: int = 0

    @property
    def n_blocks(self) -> int:
        return int(len(self.rows))


class BlockPool:
    """LRU pager over a ``(capacity+1, block_size)`` device block array."""

    def __init__(self, store: DeltaStore, capacity_blocks: int,
                 metrics=None, link: str = "store->pool"):
        if capacity_blocks < 1:
            raise ValueError("capacity_blocks must be >= 1")
        self.store = store
        self.capacity = int(capacity_blocks)
        bs = store.layout.bucket_size
        # row 0 is the shared zero block; it is never allocated or written.
        self.blocks = jnp.zeros((self.capacity + 1, bs), jnp.float32)
        self._free: List[int] = list(range(self.capacity, 0, -1))
        self._entries: "OrderedDict[int, PoolEntry]" = OrderedDict()
        self.link = link
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_paged_in = 0
        self._events = 0
        if metrics is None:
            from repro.obs.metrics import registry as metrics
        self.metrics = metrics

    # -- queries ------------------------------------------------------------
    @property
    def resident_blocks(self) -> int:
        return self.capacity - len(self._free)

    @property
    def resident_bytes(self) -> int:
        return self.resident_blocks * self.store.layout.bucket_size * 4

    @property
    def device_bytes(self) -> int:
        """Allocated device footprint (fixed at construction)."""
        return int(self.blocks.size) * 4

    def is_resident(self, uid: int) -> bool:
        return int(uid) in self._entries

    def entry(self, uid: int) -> PoolEntry:
        return self._entries[int(uid)]

    def table_for(self, uid: int) -> np.ndarray:
        return self._entries[int(uid)].table

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "bytes_paged_in": self.bytes_paged_in,
                "resident_blocks": self.resident_blocks,
                "resident_users": len(self._entries),
                "pinned_users": sum(1 for e in self._entries.values()
                                    if e.pins > 0)}

    # -- paging -------------------------------------------------------------
    def acquire(self, uid: int) -> PoolEntry:
        """Pin user ``uid`` resident, paging them in on a miss."""
        uid = int(uid)
        entry = self._entries.get(uid)
        if entry is not None:
            self._entries.move_to_end(uid)
            entry.pins += 1
            self.hits += 1
            self.metrics.counter("serve/pool/hits").inc()
            self._note_residency()
            return entry
        return self._page_in(uid)

    def release(self, uid: int) -> None:
        """Unpin (entry stays resident until LRU-evicted)."""
        entry = self._entries[int(uid)]
        if entry.pins <= 0:
            raise RuntimeError(f"release() without matching acquire() "
                               f"for user {uid}")
        entry.pins -= 1
        self._note_residency()

    def _page_in(self, uid: int) -> PoolEntry:
        payload = self.store.payload(uid)
        carrier = self.store.blocks(uid)                   # host decode
        nz = np.flatnonzero(np.any(carrier != 0.0, axis=1))
        rows = self._alloc(len(nz))
        if len(nz):
            self.blocks = self.blocks.at[jnp.asarray(rows)].set(
                jnp.asarray(carrier[nz]))                  # host -> device
        table = np.full(self.store.layout.n_buckets, ZERO_ROW, np.int32)
        table[nz] = rows
        entry = PoolEntry(uid, np.asarray(rows, np.int32), table,
                          payload.nbytes, pins=1)
        self._entries[uid] = entry
        self.misses += 1
        self.bytes_paged_in += payload.nbytes
        self.store.ledger.record(self._events, f"{self.link}/u{uid}",
                                 payload.nbytes, kind="intra", tag=PAGE_IN_TAG)
        self._events += 1
        self.metrics.counter("serve/pool/misses").inc()
        self.metrics.counter("serve/pool/page_in_bytes").inc(payload.nbytes)
        self._note_residency()
        return entry

    def _alloc(self, n: int) -> np.ndarray:
        if n > self.capacity:
            raise PoolExhausted(f"user needs {n} blocks; pool capacity is "
                                f"{self.capacity}")
        while len(self._free) < n:
            if not self._evict_one():
                raise PoolExhausted(
                    f"need {n} free blocks, have {len(self._free)}; every "
                    f"resident entry is pinned")
        return np.asarray([self._free.pop() for _ in range(n)], np.int32)

    def _evict_one(self) -> bool:
        for uid, entry in self._entries.items():       # oldest first
            if entry.pins == 0:
                del self._entries[uid]
                self._free.extend(int(r) for r in entry.rows)
                self.evictions += 1
                self.metrics.counter("serve/pool/evictions").inc()
                return True
        return False

    def _note_residency(self) -> None:
        self.metrics.gauge("serve/pool/resident_blocks").set(
            self.resident_blocks)
        self.metrics.gauge("serve/pool/resident_bytes").set(
            self.resident_bytes)
        self.metrics.gauge("serve/pool/resident_users").set(
            len(self._entries))
