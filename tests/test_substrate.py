"""Substrate tests: optimizers, schedules, HLO analysis parser, data pipeline,
compressor sharding-safety."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressors import qsgd_sharded
from repro.data.synthetic import SyntheticLMDataset, lm_batch_iterator
from repro.launch.hlo_analysis import CollectiveStats, _shape_bytes, collective_bytes
from repro.optim.optimizers import adamw, apply_updates, clip_by_global_norm, sgd
from repro.optim.schedules import cosine_schedule, linear_warmup
from repro.utils.tree import tree_dot, tree_norm


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
def _quad_problem():
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array([1.0])}
    grad = lambda p: {"w": 2 * p["w"], "b": 2 * p["b"]}  # f = ||p||^2
    return params, grad


def test_adamw_minimizes_quadratic():
    params, grad = _quad_problem()
    opt = adamw(lr=0.1, weight_decay=0.0)
    state = opt.init(params)
    for _ in range(200):
        updates, state = opt.update(grad(params), state, params)
        params = apply_updates(params, updates)
    assert float(tree_norm(params)) < 1e-2


def test_sgd_momentum_minimizes():
    params, grad = _quad_problem()
    opt = sgd(lr=0.05, momentum=0.9)
    state = opt.init(params)
    for _ in range(150):
        updates, state = opt.update(grad(params), state, params)
        params = apply_updates(params, updates)
    assert float(tree_norm(params)) < 1e-2


def test_weight_decay_mask():
    """Decay applies to matrices (ndim>=2) but not vectors by default."""
    params = {"W": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    opt = adamw(lr=0.0, weight_decay=0.5)  # lr=0 isolates... decay scales by lr
    state = opt.init(params)
    updates, _ = opt.update({"W": jnp.zeros((4, 4)), "b": jnp.zeros((4,))},
                            state, params)
    # lr=0 => all updates zero; use lr>0 to see decay on W only
    opt = adamw(lr=0.1, weight_decay=0.5)
    state = opt.init(params)
    updates, _ = opt.update({"W": jnp.zeros((4, 4)), "b": jnp.zeros((4,))},
                            state, params)
    assert float(jnp.max(jnp.abs(updates["W"]))) > 0
    assert float(jnp.max(jnp.abs(updates["b"]))) == 0


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(tree_norm(clipped)) - 1.0) < 1e-5
    assert abs(float(norm) - np.sqrt(300)) < 1e-3


def test_tree_dot_no_flatten():
    """tree_dot must not use vdot (sharding hazard) and must be exact."""
    a = {"x": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    assert abs(float(tree_dot(a, a)) - float(sum(i * i for i in range(6)))) < 1e-5


def test_schedules():
    s = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(s(0)) < 0.2            # warmup start
    assert abs(float(s(10)) - 1.0) < 0.1
    assert float(s(99)) < 0.2           # decayed
    w = linear_warmup(2.0, 4)
    assert abs(float(w(3)) - 2.0) < 1e-6


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------
HLO_SAMPLE = """
  %ar = bf16[1024,512] all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[64,256] all-gather(%y), replica_groups={{0,1},{2,3}}, dimensions={0}
  %rs = f32[128] reduce-scatter(%z), replica_groups={{0,1,2,3}}, to_apply=%add
  %aa = bf16[32,32] all-to-all(%w), replica_groups={{0,1,2,3}}
  %cp = s8[100] collective-permute(%v), source_target_pairs={{0,1}}
"""


def test_collective_parser_kinds_and_bytes():
    st = collective_bytes(HLO_SAMPLE)
    assert st.count_by_kind["all-reduce"] == 1
    assert st.count_by_kind["all-gather"] == 1
    assert abs(st.bytes_by_kind["all-reduce"] - 1024 * 512 * 2) < 1
    # all-gather payload = result / group_size (group 2)
    assert abs(st.bytes_by_kind["all-gather"] - 64 * 256 * 4 / 2) < 1
    assert st.total_bytes > 0


def test_shape_bytes_tuple():
    assert _shape_bytes("(bf16[8,8], f32[4])") == 8 * 8 * 2 + 4 * 4


def test_interpod_classifier():
    intra = "%a = f32[64] all-reduce(%x), replica_groups={{0,1,2,3}}"
    inter = "%a = f32[64] all-reduce(%x), replica_groups={{0,256},{1,257}}"
    st_i = collective_bytes(intra)
    st_x = collective_bytes(inter)
    assert st_i.inter_pod_bytes == 0
    assert st_x.inter_pod_bytes > 0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_synthetic_corpus_deterministic_and_learnable():
    a = SyntheticLMDataset(vocab_size=256, length=5000, seed=3)
    b = SyntheticLMDataset(vocab_size=256, length=5000, seed=3)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    # markov structure: bigram entropy well below unigram-uniform
    toks = a.tokens
    pairs = toks[:-1].astype(np.int64) * 256 + toks[1:]
    _, counts = np.unique(pairs, return_counts=True)
    p = counts / counts.sum()
    bigram_h = -(p * np.log(p)).sum()
    assert bigram_h < 2 * np.log(256) * 0.8


def test_batch_iterator_shapes():
    ds = SyntheticLMDataset(vocab_size=64, length=2000, seed=0)
    it = lm_batch_iterator(ds, batch=4, seq_len=16, seed=1)
    b = next(it)
    assert b["tokens"].shape == (4, 17)
    assert b["tokens"].dtype == np.int32


# ---------------------------------------------------------------------------
# sharding-safe compressor
# ---------------------------------------------------------------------------
def test_qsgd_sharded_no_flatten_and_bounded():
    c = qsgd_sharded(bits=8, block=8)
    assert c.flatten is False
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 5
    y = c(jax.random.PRNGKey(1), x)
    assert y.shape == x.shape
    # per-(row, block) absmax scale bounds the error
    xb = np.asarray(x).reshape(4, 2, 8)
    yb = np.asarray(y).reshape(4, 2, 8)
    scale = np.abs(xb).max(-1, keepdims=True) / 127
    assert (np.abs(yb - xb) <= scale + 1e-6).all()


def test_qsgd_sharded_odd_lastdim_fallback():
    c = qsgd_sharded(bits=8, block=8)
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 7))  # 7 % 8 != 0
    y = c(jax.random.PRNGKey(1), x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
