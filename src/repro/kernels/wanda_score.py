"""Pallas TPU kernel: fused pruning-score + threshold-mask application.

SymWanda's pruning pass scores every weight and masks below a per-output
threshold.  The naive chain materializes the full (d_in, d_out) score matrix
in HBM (score -> top-k threshold -> compare -> mask): three extra HBM passes
over a matrix the size of the weights.  The fused kernel recomputes the score
in VMEM from O(d_in + d_out) statistics and applies the mask in the same tile
pass — weights are read once and written once.

Score modes (static):
  wanda:    s_ij = |w_ij| * xnorm_i
  ria:      s_ij = (|w_ij|/rowsum_i + |w_ij|/colsum_j) * xnorm_i^alpha
  symwanda: s_ij = beta * |w_ij| xnorm_i / mu_in + (1-beta) |w_ij| ynorm_j / mu_out

Per-output thresholds tau_j are computed once outside (global top-k over a
cheap column pass) and broadcast into the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 128
TILE_C = 128


def _score(w, xnorm_col, stats, mode: str, alpha: float, beta: float):
    aw = jnp.abs(w.astype(jnp.float32))
    if mode == "wanda":
        return aw * xnorm_col
    if mode == "ria":
        rowsum, colsum = stats
        return (aw / rowsum + aw / colsum) * (xnorm_col ** alpha)
    if mode == "symwanda":
        ynorm_row, mu_in, mu_out = stats
        return beta * aw * xnorm_col / mu_in + (1.0 - beta) * aw * ynorm_row / mu_out
    raise ValueError(mode)


def _wanda_kernel(w_ref, xn_ref, tau_ref, rs_ref, cs_ref, out_ref, mask_ref,
                  *, mode: str, alpha: float, beta: float):
    w = w_ref[...]
    xn = xn_ref[...]           # (1, TILE_R) input-channel norms for this row tile
    tau = tau_ref[...]         # (1, TILE_C) per-output thresholds
    if mode == "ria":
        stats = (rs_ref[...].T, cs_ref[...])     # rowsum (TILE_R,1), colsum (1,TILE_C)
    elif mode == "symwanda":
        stats = (cs_ref[...], rs_ref[0, 0], rs_ref[0, 1])
    else:
        stats = None
    s = _score(w, xn.T, stats, mode, alpha, beta)
    keep = (s >= tau).astype(w.dtype)
    mask_ref[...] = keep
    out_ref[...] = w * keep


def wanda_prune_2d(w: jax.Array, xnorm: jax.Array, tau: jax.Array,
                   mode: str = "wanda", alpha: float = 0.5, beta: float = 0.5,
                   rowsum: jax.Array = None, colsum: jax.Array = None,
                   ynorm: jax.Array = None, interpret: bool = True):
    """w (d_in, d_out); xnorm (d_in,); tau (d_out,). RIA: rowsum (d_in,),
    colsum (d_out,). SymWanda: ynorm (d_out,) + normalizers packed by ops.py.
    Returns (pruned w, mask)."""
    d_in, d_out = w.shape
    assert d_in % TILE_R == 0 and d_out % TILE_C == 0
    grid = (d_in // TILE_R, d_out // TILE_C)
    wspec = pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j))
    rowvec = pl.BlockSpec((1, TILE_R), lambda i, j: (0, i))
    colvec = pl.BlockSpec((1, TILE_C), lambda i, j: (0, j))

    if mode == "wanda":
        rs = jnp.zeros((1, d_in), jnp.float32)
        cs = jnp.zeros((1, d_out), jnp.float32)
        rs_spec, cs_spec = rowvec, colvec
    elif mode == "ria":
        rs = rowsum.reshape(1, d_in).astype(jnp.float32)
        cs = colsum.reshape(1, d_out).astype(jnp.float32)
        rs_spec, cs_spec = rowvec, colvec
    elif mode == "symwanda":
        # rs carries the two scalar normalizers; cs carries ynorm per output
        rs = jnp.zeros((1, 128), jnp.float32).at[0, 0].set(rowsum).at[0, 1].set(colsum)
        cs = ynorm.reshape(1, d_out).astype(jnp.float32)
        rs_spec = pl.BlockSpec((1, 128), lambda i, j: (0, 0))
        cs_spec = colvec
    else:
        raise ValueError(mode)

    return pl.pallas_call(
        functools.partial(_wanda_kernel, mode=mode, alpha=alpha, beta=beta),
        grid=grid,
        in_specs=[wspec, rowvec, colvec, rs_spec, cs_spec],
        out_specs=[wspec, wspec],
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
        ],
        interpret=interpret,
    )(w, xnorm.reshape(1, d_in).astype(jnp.float32),
      tau.reshape(1, d_out).astype(jnp.float32), rs, cs)
