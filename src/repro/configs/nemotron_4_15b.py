"""Nemotron-4-15B. [arXiv:2402.16819]

Dense decoder with squared-ReLU MLP (non-gated), GQA kv=8, 256000 vocab
(SentencePiece multilingual), rotary position embeddings.
Full causal attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="nemotron-4-15b",
        family="dense",
        citation="arXiv:2402.16819",
        num_layers=32,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        mlp_act="relu2",
        mlp_gated=False,
        supports_long_context=False,
    )
)
