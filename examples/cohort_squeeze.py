"""Cohort-Squeeze demo (Ch. 5): squeeze more juice out of each cohort.

Shows the TK-vs-K trade-off (Fig 5.1), the sampling-strategy comparison
(Fig 5.3) and the hierarchical-FL cost model (Fig 5.6):

    PYTHONPATH=src python examples/cohort_squeeze.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.sppm import (
    balanced_blocks, nice_sampling, sigma_star_nice, sigma_star_stratified,
    solve_erm, sppm_as, stratified_sampling, _client_grads_at)
from repro.data.federated import make_logreg_clients


def main():
    prob = make_logreg_clients(n_clients=20, m=60, d=16, mu=0.1, hetero=0.1, seed=3)
    x_star = solve_erm(prob)
    eps = 1e-3

    print("== Fig 5.1: total communication TK vs local rounds K ==")
    for gamma in (5.0, 50.0, 500.0):
        line = []
        for K in (1, 2, 4, 8, 16):
            draw, p = nice_sampling(np.random.default_rng(5), prob.n_clients, 8)
            r = sppm_as(prob, x_star, draw, p, gamma, K, T=300, solver="gd",
                        eps=eps, c_global=0.0, seed=0)
            line.append(f"K={K}:{r.total_cost if r.total_cost else 'inf'}")
        print(f"  gamma={gamma:6.1f}  " + "  ".join(line))
    print("  (K=2 local rounds beat FedAvg's K=1: ~22% less total communication)")

    print("== Fig 5.3 / Lemma 5.3.4: sampling strategies ==")
    gi = _client_grads_at(prob, x_star)
    blocks = balanced_blocks(gi, 8)
    s_nice, _ = sigma_star_nice(prob, x_star, tau=8)
    s_ss = sigma_star_stratified(prob, x_star, blocks)
    print(f"  sigma*^2 NICE={s_nice:.3e}  stratified={s_ss:.3e} (SS <= NICE: {s_ss <= s_nice})")

    print("== Fig 5.6: hierarchical FL (c_local=0.05, c_global=1) ==")
    best, ref = (None, np.inf), None
    for K in (1, 2, 4, 8, 16):
        draw, p = nice_sampling(np.random.default_rng(5), prob.n_clients, 8)
        r = sppm_as(prob, x_star, draw, p, 50.0, K, T=300, solver="gd",
                    eps=eps, c_local=0.05, c_global=1.0, seed=0)
        cost = r.total_cost if r.total_cost is not None else np.inf
        if K == 1:
            ref = cost
        if cost < best[1]:
            best = (K, cost)
    print(f"  best K={best[0]} cost={best[1]:.2f} vs FedAvg(K=1)={ref:.2f} "
          f"-> {100*(1-best[1]/ref):.0f}% saving")


if __name__ == "__main__":
    main()
