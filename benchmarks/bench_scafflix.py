"""Fig 3.1 / 3.3 reproduction: Scafflix double acceleration.

(a) per-alpha convergence: comm rounds for Scafflix vs distributed GD on the
    FLIX objective (class-wise non-iid synthetic logreg);
(b) communication-probability ablation (Fig 3.3c): smaller p converges in
    fewer communications.
Derived: communicated rounds + CommLedger-encoded bytes to reach the gap
target (each communicated round ships one dense fp32 model per client: the
encoded payload of the identity codec, recorded per round in the ledger)."""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, now_s
from repro.comm import UPLOAD_TAG, CommLedger, encode
from repro.core import compressors as C
from repro.core.scafflix import (
    flix_objective, flix_optimum, local_optimum, logreg_grads,
    scafflix_init, scafflix_run)
from repro.data.federated import make_logreg_clients

TARGET = 1e-5
ROUNDS = 800


def run():
    prob = make_logreg_clients(n_clients=10, m=100, d=30, mu=0.1, hetero=0.6, seed=1)
    A, b = jnp.asarray(prob.A), jnp.asarray(prob.b)
    n, _, d = A.shape
    Ls = prob.smoothness()
    x_loc = jnp.stack([local_optimum(A[i], b[i], prob.mu) for i in range(n)])
    gfn = lambda xt: logreg_grads(xt, A, b, prob.mu)
    rows = []
    # one communicated round ships one dense fp32 model per client (up):
    # measure the encoded payload once, record it per communicated round
    ident = C.identity()
    msg_bytes = encode(ident, jax.random.PRNGKey(0),
                       jnp.zeros((d,), jnp.float32)).nbytes

    def ledger_bytes(comms, upto):
        led = CommLedger()
        for t, did_comm in enumerate(np.asarray(comms)[: upto + 1]):
            if did_comm:
                led.record(t, "client->server", msg_bytes, kind="inter",
                           tag=UPLOAD_TAG)
        return led.total_bytes

    for alpha in (0.1, 0.3, 0.5, 0.9):
        alphas = jnp.full((n,), alpha)
        xf = flix_optimum(A, b, prob.mu, alphas, x_loc, steps=30000)
        fstar = float(flix_objective(xf, A, b, prob.mu, alphas, x_loc))

        # --- Scafflix (p=0.2, per-client stepsizes 1/L_i)
        t0 = now_s()
        st = scafflix_init(jnp.ones(d), n, x_loc)
        ev = lambda st: flix_objective(jnp.mean(st.x, 0), A, b, prob.mu, alphas, x_loc)
        _, (trace, comms) = scafflix_run(
            jax.random.PRNGKey(0), st, gfn, 0.2, jnp.asarray(1.0 / Ls), alphas,
            ROUNDS, ev)
        us = (now_s() - t0) * 1e6
        gaps = np.asarray(trace) - fstar
        cum_comms = np.cumsum(np.asarray(comms))
        hit = np.argmax(gaps < TARGET) if (gaps < TARGET).any() else -1
        derived = (f"comms_to_{TARGET:g}={cum_comms[hit]};"
                   f"bytes={ledger_bytes(comms, hit)}" if hit >= 0
                   else f"gap={gaps[-1]:.1e}")
        rows.append((f"scafflix_fig3.1/alpha={alpha}/scafflix", us, derived))

        # --- GD baseline on FLIX (communicates every round)
        L = float(np.max(Ls))
        x = jnp.ones(d)
        gd_gaps = []
        t0 = now_s()
        for t in range(ROUNDS):
            xt = alphas[:, None] * x[None] + (1 - alphas[:, None]) * x_loc
            g = jnp.mean(alphas[:, None] * gfn(xt), axis=0)
            x = x - (1.0 / L) * g
            gd_gaps.append(float(flix_objective(x, A, b, prob.mu, alphas, x_loc)) - fstar)
        us = (now_s() - t0) * 1e6
        gd_gaps = np.asarray(gd_gaps)
        hit = np.argmax(gd_gaps < TARGET) if (gd_gaps < TARGET).any() else -1
        derived = (f"comms_to_{TARGET:g}={hit};"
                   f"bytes={ledger_bytes(np.ones(ROUNDS), hit)}" if hit >= 0
                   else f"gap={gd_gaps[-1]:.1e}")
        rows.append((f"scafflix_fig3.1/alpha={alpha}/gd", us, derived))

    # --- Fig 3.3c: p ablation at alpha=0.3
    alphas = jnp.full((n,), 0.3)
    xf = flix_optimum(A, b, prob.mu, alphas, x_loc, steps=30000)
    fstar = float(flix_objective(xf, A, b, prob.mu, alphas, x_loc))
    for p in (0.1, 0.2, 0.5):
        st = scafflix_init(jnp.ones(d), n, x_loc)
        ev = lambda st: flix_objective(jnp.mean(st.x, 0), A, b, prob.mu, alphas, x_loc)
        t0 = now_s()
        _, (trace, comms) = scafflix_run(
            jax.random.PRNGKey(2), st, gfn, p, jnp.asarray(1.0 / Ls), alphas,
            ROUNDS, ev)
        us = (now_s() - t0) * 1e6
        gaps = np.asarray(trace) - fstar
        cum = np.cumsum(np.asarray(comms))
        hit = np.argmax(gaps < TARGET) if (gaps < TARGET).any() else -1
        derived = (f"comms_to_{TARGET:g}={cum[hit]};"
                   f"bytes={ledger_bytes(comms, hit)}" if hit >= 0
                   else f"gap={gaps[-1]:.1e}")
        rows.append((f"scafflix_fig3.3c/p={p}", us, derived))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
