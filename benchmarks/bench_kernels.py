"""Kernel microbenchmarks (interpret mode on CPU: correctness plumbing +
relative cost only; real perf numbers require TPU).  Derived: throughput
relative to the pure-jnp oracle on the same host."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels import ops, ref


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512, 512))

    k_fn = jax.jit(lambda x: ops.quantize_dequantize(x, key, bits=8))
    k_fn(x).block_until_ready()
    us_k = timed(lambda: k_fn(x).block_until_ready())
    rows.append(("kernels/quant8_interp", us_k, "shape=512x512"))

    W = jax.random.normal(key, (512, 256)) * 0.1
    s = jnp.abs(W)
    nm_fn = jax.jit(lambda W, s: ops.prune_nm(W, s, 2, 4))
    nm_fn(W, s)[0].block_until_ready()
    us = timed(lambda: nm_fn(W, s)[0].block_until_ready())
    rows.append(("kernels/nm_prune_interp", us, "shape=512x256 2:4"))

    X = jax.random.normal(jax.random.PRNGKey(1), (128, 512))
    w_fn = jax.jit(lambda W, X: ops.prune_scored(W, X, mode="ria", sparsity=0.5))
    w_fn(W, X)[0].block_until_ready()
    us = timed(lambda: w_fn(W, X)[0].block_until_ready())
    rows.append(("kernels/wanda_score_interp", us, "mode=ria 512x256"))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
