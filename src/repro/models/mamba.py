"""Mamba2 SSD (state-space duality) block. [arXiv:2405.21060]

TPU adaptation: the SSD algorithm is already matmul-dominated (the paper's
point), so it maps naturally onto the MXU.  Training/prefill uses the chunked
formulation: quadratic attention-like term inside chunks of length Q plus an
inter-chunk state recurrence handled with ``jax.lax.associative_scan`` (log-
depth, shardable).  Decode is the O(1) recurrent step on a (B, H, hd, N)
state.

Parameterization follows the reference: in_proj -> [z, x, B, C, dt], causal
depthwise conv over (x,B,C), A scalar-per-head (negative via -exp(a_log)),
per-head dt bias, D skip, gated RMSNorm before out_proj.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, init_rmsnorm, rmsnorm


def mamba_dims(d_model: int, cfg) -> dict:
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.head_dim
    conv_dim = d_inner + 2 * cfg.n_groups * cfg.d_state
    in_dim = 2 * d_inner + 2 * cfg.n_groups * cfg.d_state + n_heads
    return dict(d_inner=d_inner, n_heads=n_heads, conv_dim=conv_dim, in_dim=in_dim)


def init_mamba(key, d_model: int, cfg, dtype) -> dict:
    dims = mamba_dims(d_model, cfg)
    ks = jax.random.split(key, 4)
    H = dims["n_heads"]
    return {
        "in_proj": _dense_init(ks[0], (d_model, dims["in_dim"]), dtype),
        "conv_w": _dense_init(ks[1], (cfg.d_conv, dims["conv_dim"]), dtype, scale=0.5),
        "conv_b": jnp.zeros((dims["conv_dim"],), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),  # softplus^-1(~0.12)
        "D": jnp.ones((H,), jnp.float32),
        "norm": init_rmsnorm(dims["d_inner"], dtype),
        "out_proj": _dense_init(ks[2], (dims["d_inner"], d_model), dtype),
    }


def _split_proj(params, u, cfg, dims):
    """u (B,S,d_model) -> z,(conv inputs x,B,C),dt."""
    zxbcdt = u @ params["in_proj"]
    di, G, N, H = dims["d_inner"], cfg.n_groups, cfg.d_state, dims["n_heads"]
    z, xBC, dt = jnp.split(zxbcdt, [di, di + dims["conv_dim"]], axis=-1)
    return z, xBC, dt


def _causal_conv(params, xBC, cfg):
    """Depthwise causal conv1d along S. xBC (B,S,conv_dim)."""
    K = cfg.d_conv
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1], :] * params["conv_w"][i] for i in range(K))
    return jax.nn.silu(out + params["conv_b"])


def _ssd_chunked(x, dt, A, B_, C_, D, chunk: int):
    """SSD chunked scan.
    x (B,S,H,hd); dt (B,S,H) (post-softplus); A (H,) negative;
    B_,C_ (B,S,G,N); D (H,). Returns y (B,S,H,hd) and final state (B,H,hd,N).
    """
    Bsz, S, H, hd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    nch = S // chunk
    rep = H // G

    xc = x.reshape(Bsz, nch, chunk, H, hd).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nch, chunk, H).astype(jnp.float32)
    Bc = B_.reshape(Bsz, nch, chunk, G, N).astype(jnp.float32)
    Cc = C_.reshape(Bsz, nch, chunk, G, N).astype(jnp.float32)

    dA = dtc * A[None, None, None, :]                 # (B,K,Q,H), negative
    cs = jnp.cumsum(dA, axis=2)                       # cumulative log-decay
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,K,Q,Q,H) log decay i<-j
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk (diagonal) term: per group then broadcast to heads
    CB = jnp.einsum("bkqgn,bkpgn->bkqpg", Cc, Bc)     # (B,K,Q,Q,G)
    CB = jnp.repeat(CB, rep, axis=-1)                 # (B,K,Q,Q,H)
    M = CB * L * dtc[:, :, None, :, :]                # weight for source pos p
    y_diag = jnp.einsum("bkqph,bkphd->bkqhd", M, xc)

    # chunk states: sum_p decay(end<-p) * dt_p * x_p outer B_p
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs)        # (B,K,Q,H)
    w = decay_end * dtc                               # (B,K,Q,H)
    Brep = jnp.repeat(Bc, rep, axis=3)                # (B,K,Q,H,N)
    states = jnp.einsum("bkqh,bkqhd,bkqhn->bkhdn", w, xc, Brep)

    # inter-chunk recurrence: S_k = exp(sum dA_k) * S_{k-1} + states_k
    chunk_decay = jnp.exp(cs[:, :, -1, :])            # (B,K,H)

    def combine(a, b):
        d1, s1 = a
        d2, s2 = b
        return d1 * d2, s2 + d2[..., None, None] * s1

    dec, st = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1)
    # state entering chunk k is st[k-1]
    init = jnp.zeros_like(st[:, :1])
    st_prev = jnp.concatenate([init, st[:, :-1]], axis=1)  # (B,K,H,hd,N)

    # off-diagonal term: y_q += C_q . (decay(q<-start) * S_prev)
    decay_in = jnp.exp(cs)                            # (B,K,Q,H)
    Crep = jnp.repeat(Cc, rep, axis=3)                # (B,K,Q,H,N)
    y_off = jnp.einsum("bkqhn,bkhdn,bkqh->bkqhd", Crep, st_prev, decay_in)

    y = (y_diag + y_off).reshape(Bsz, S, H, hd)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    final_state = st[:, -1]                           # (B,H,hd,N)
    return y, final_state


def mamba_train(params, u, cfg, d_model: int) -> jax.Array:
    y, _ = mamba_forward(params, u, cfg, d_model)
    return y


def mamba_forward(params, u, cfg, d_model: int, return_cache: bool = False):
    dims = mamba_dims(d_model, cfg)
    di, H, G, N = dims["d_inner"], dims["n_heads"], cfg.n_groups, cfg.d_state
    hd = cfg.head_dim
    Bsz, S, _ = u.shape

    from repro.sharding.context import constrain_named

    z, xBC_raw, dt = _split_proj(params, u, cfg, dims)
    xBC = _causal_conv(params, xBC_raw, cfg)
    x, B_, C_ = jnp.split(xBC, [di, di + G * N], axis=-1)
    # optional SSD head sharding (perf variant): keeps the (B,K,Q,Q,H)
    # intra-chunk tensors model-sharded over heads instead of replicated
    x = constrain_named("ssd_x", x.reshape(Bsz, S, H, hd))
    B_ = B_.reshape(Bsz, S, G, N)
    C_ = C_.reshape(Bsz, S, G, N)
    dt = constrain_named("ssd_dt",
                         jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"]))
    A = -jnp.exp(params["a_log"])

    chunk = min(cfg.chunk_size, S)
    if S % chunk:  # pad to a chunk multiple (masked tail contributes ~0 via dt)
        padlen = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, padlen), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, padlen), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, padlen), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, padlen), (0, 0), (0, 0)))
    y, state = _ssd_chunked(x, dt, A, B_, C_, params["D"], chunk)
    y = y[:, :S]

    y = y.reshape(Bsz, S, di).astype(u.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    if return_cache:
        # decode-compatible cache: final SSM state + last (d_conv-1) raw conv inputs
        K = cfg.d_conv
        tail = xBC_raw[:, -(K - 1):, :]
        if S < K - 1:
            tail = jnp.pad(xBC_raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
        return out, {"ssm": state, "conv": tail}
    return out, state


def mamba_cache_spec(d_model: int, cfg, batch: int, dtype):
    dims = mamba_dims(d_model, cfg)
    return {
        "ssm": jax.ShapeDtypeStruct(
            (batch, dims["n_heads"], cfg.head_dim, cfg.d_state), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, dims["conv_dim"]), dtype),
    }


def mamba_decode(params, u, cache: dict, cfg, d_model: int):
    """One-token step. u (B,1,d_model); cache {ssm (B,H,hd,N), conv (B,K-1,conv_dim)}."""
    dims = mamba_dims(d_model, cfg)
    di, H, G, N = dims["d_inner"], dims["n_heads"], cfg.n_groups, cfg.d_state
    hd = cfg.head_dim
    Bsz = u.shape[0]

    z, xBC, dt = _split_proj(params, u, cfg, dims)     # (B,1,*)
    conv_in = jnp.concatenate([cache["conv"], xBC], axis=1)  # (B,K,conv_dim)
    w = params["conv_w"]                                # (K, conv_dim)
    conv_out = jnp.sum(conv_in * w[None], axis=1, keepdims=True) + params["conv_b"]
    xBC_t = jax.nn.silu(conv_out)                       # (B,1,conv_dim)
    new_conv = conv_in[:, 1:]

    x, B_, C_ = jnp.split(xBC_t[:, 0], [di, di + G * N], axis=-1)
    x = x.reshape(Bsz, H, hd).astype(jnp.float32)
    B_ = B_.reshape(Bsz, G, N).astype(jnp.float32)
    C_ = C_.reshape(Bsz, G, N).astype(jnp.float32)
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["a_log"])
    da = jnp.exp(dt_t * A[None])                        # (B,H)

    rep = H // G
    Brep = jnp.repeat(B_, rep, axis=1)                  # (B,H,N)
    Crep = jnp.repeat(C_, rep, axis=1)
    state = cache["ssm"] * da[..., None, None] + jnp.einsum(
        "bh,bhd,bhn->bhdn", dt_t, x, Brep)
    y = jnp.einsum("bhdn,bhn->bhd", state, Crep) + x * params["D"][None, :, None]

    y = y.reshape(Bsz, 1, di).astype(u.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    return out, {"ssm": state, "conv": new_conv}
