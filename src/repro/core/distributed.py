"""Distributed gradient synchronization: the paper's techniques on a TPU mesh.

The federated "client" maps to a data-parallel worker group (one index along
the flattened (pod, data) mesh axes).  Per-group gradients are obtained with
``vmap(grad)`` over a leading group axis that is sharded across (pod, data) —
pure pjit/GSPMD, no replication-invariant tricks: XLA turns the mean over the
group axis into the all-reduce, and when the payload has been compressed to
int8 (qsgd) the all-reduce moves 4x fewer bytes — a *structural* saving
visible in the §Roofline collective term.  Sparsifying compressors (top-k)
keep dense carriers on-chip; their wire payloads are packed and *measured* by
the repro.comm codecs (bits_per_round below is a thin wrapper over that
ledger accounting), and additionally realized in frequency by hier/local
modes (bits * p).

Modes (SyncConfig.mode):
  dense  - mean over groups (baseline all-reduce; what FedAvg does per round)
  efbv   - EF-BV per-group compressed delta sync (Ch. 2): the gradient
           estimate used by the optimizer is h_bar + nu * mean_i C_i(g_i-h_i)
  ef21 / diana - parameter special cases of efbv
  hier   - Cohort-Squeeze (Ch. 5) on the fabric: dense intra-pod mean every
           step; inter-pod mean only every ``sync_period`` steps with the
           compressor applied to the pod-level delta (slow-link traffic
           drops by ~sync_period x payload ratio)
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SyncConfig
from repro.core import compressors as comp_lib
from repro.core.compressors import Compressor
from repro.obs.trace import annotate
from repro.utils.tree import tree_map


class SyncState(NamedTuple):
    """EF-BV state for the runtime: per-group control variates (leading group
    axis, sharded over (pod, data)) + replicated running average."""
    h: object        # pytree, leaves (G, *param_shape) float32
    h_bar: object    # pytree, leaves (*param_shape,) float32
    step: jax.Array


class TreeSyncState(NamedTuple):
    """Anchor cascade state for aggregation-tree sync (mode=hier + levels).

    ``anchors[l]`` is level l's anchor pytree, leaf-most level first: leaves
    carry a leading node axis of size n_parents(l), except the root (last
    level), whose anchor is unstacked — exactly ``SyncState.h_bar``'s shape,
    making the depth-1 cascade the classic hier state."""
    anchors: Tuple[object, ...]
    step: jax.Array


class CascadeLevel(NamedTuple):
    """Runtime spec of one cascade level (built from LevelConfig + tree)."""
    name: str
    compressor: Compressor
    lam: float
    period: int
    fanout: int


def make_sync_compressor(name: str, compress_ratio: float,
                         quant_bits: int) -> Compressor:
    """The registry mapping the runtime sync paths use (qsgd resolves to the
    sharded last-dim variant so 2D-sharded leaves stay unflattened)."""
    if name == "topk_block":
        return comp_lib.block_top_k(compress_ratio)
    if name == "rand_k":
        return comp_lib.rand_k(compress_ratio)
    if name == "top_k":
        return comp_lib.top_k(compress_ratio)
    if name == "qsgd":
        # runtime paths operate on sharded param/grad leaves: last-dim blocks
        return comp_lib.qsgd_sharded(quant_bits)
    if name == "identity":
        return comp_lib.identity()
    return comp_lib.make_compressor(name)


def build_compressor(sync: SyncConfig) -> Compressor:
    return make_sync_compressor(sync.compressor, sync.compress_ratio,
                                sync.quant_bits)


def build_cascade(sync: SyncConfig, tree=None) -> Tuple[CascadeLevel, ...]:
    """Resolve ``SyncConfig.levels`` against the (tree) topology preset.

    Level l's lambda comes from the compressor calculus (lambda_star) like
    the flat hier mode; fanouts come from the tree topology, paired by order.
    Periods must be nested (each a multiple of the level below) so that a
    level only syncs on steps where everything underneath it syncs too.
    """
    from repro.comm.tree import get_tree_topology

    if not sync.levels:
        raise ValueError("build_cascade needs SyncConfig.levels")
    if tree is None:
        tree = get_tree_topology(sync.topology)
    if len(sync.levels) != len(tree.levels):
        raise ValueError(
            f"SyncConfig.levels has {len(sync.levels)} levels but tree "
            f"topology {tree.name!r} has {len(tree.levels)}")
    out, prev = [], None
    for lc, tl in zip(sync.levels, tree.levels):
        c = make_sync_compressor(lc.compressor, lc.compress_ratio,
                                 lc.quant_bits)
        if lc.period < 1:
            raise ValueError(f"level {lc.name!r}: period must be >= 1")
        if prev is not None and lc.period % prev != 0:
            raise ValueError(
                f"level {lc.name!r}: period {lc.period} is not a multiple of "
                f"the level below ({prev}); cascade periods must be nested")
        lam = (comp_lib.lambda_star(c.eta, c.omega)
               if c.eta is not None and c.omega is not None else 1.0)
        out.append(CascadeLevel(lc.name or tl.name, c, lam, lc.period,
                                tl.fanout))
        prev = lc.period
    return tuple(out)


def sync_state_init(params, n_groups: int, sync: SyncConfig,
                    n_pods: int = 1) -> Optional[SyncState]:
    if sync.mode in ("dense",):
        return None
    if sync.mode == "hier":
        n_groups = n_pods  # control variates live at pod level
    zeros_g = tree_map(
        lambda p: jnp.zeros((n_groups,) + p.shape, jnp.float32), params)
    zeros = tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return SyncState(h=zeros_g, h_bar=zeros, step=jnp.zeros((), jnp.int32))


def sync_params(sync: SyncConfig, n_groups: int) -> Tuple[float, float]:
    """(lambda, nu) for the configured mode/compressor."""
    c = build_compressor(sync)
    if sync.mode in ("efbv", "ef21", "diana", "hier"):
        mode = "efbv" if sync.mode == "hier" else sync.mode
        return comp_lib.lambda_star(c.eta, c.omega), (
            comp_lib.nu_star(c.eta, comp_lib.omega_ran_independent(c.omega, n_groups))
            if mode == "efbv" and not c.deterministic
            else comp_lib.lambda_star(c.eta, c.omega)
            if mode in ("efbv", "ef21")
            else 1.0
        )
    return 1.0, 1.0


# ---------------------------------------------------------------------------
# Sync transforms on stacked per-group gradients (leading axis G)
# ---------------------------------------------------------------------------
def dense_sync(grads_g):
    """Plain mean over the group axis (XLA emits the all-reduce)."""
    return tree_map(lambda g: jnp.mean(g, axis=0), grads_g)


def efbv_sync(key, grads_g, state: SyncState, c: Compressor, lam: float,
              nu: float, bucket_size: Optional[int] = None):
    """EF-BV over stacked per-group grads. Returns (g_est, new_state).

    By default the pytree is fused into fixed-size fp32 buckets
    (repro.comm.buckets) so the whole tree is compressed in ONE vmapped
    call per group instead of a per-leaf Python loop of small kernels —
    top-k/rand-k then select over the full gradient vector (the paper's
    d-dimensional operator) rather than per leaf.  ``bucket_size=0`` keeps
    the legacy per-leaf path (per-leaf compressor semantics).

    Sharding-safe compressors (``flatten=False``, e.g. qsgd_sharded) always
    take the per-leaf path: bucketize's reshape/concat is exactly the
    flatten that forces GSPMD to all-gather 2D-sharded leaves, the thing
    those compressors exist to avoid.
    """
    from repro.comm import buckets as bk

    if bucket_size is None:
        bucket_size = bk.DEFAULT_BUCKET_SIZE
    if not bucket_size or not c.flatten:
        with annotate("sync/efbv"):
            return _efbv_sync_leaves(key, grads_g, state, c, lam, nu)
    with annotate("sync/efbv"):
        with annotate("sync/bucketize"):
            g_b, layout = bk.bucketize_groups(grads_g, bucket_size)  # (G, nb, B)
            h_b, _ = bk.bucketize_groups(state.h, bucket_size)
            hb_b, _ = bk.bucketize(state.h_bar, bucket_size)         # (nb, B)
        keys = jax.random.split(key, g_b.shape[0])
        with annotate("sync/compress"):
            d_i = _fused_compress(c, keys, g_b - h_b, layout.d)
        d = jnp.mean(d_i, axis=0)
        f32 = jnp.float32
        with annotate("sync/debucketize"):
            return (
                bk.debucketize(hb_b + nu * d, layout, dtype=f32),
                SyncState(h=bk.debucketize_groups(h_b + lam * d_i, layout,
                                                  dtype=f32),
                          h_bar=bk.debucketize(hb_b + lam * d, layout,
                                               dtype=f32),
                          step=state.step + 1),
            )


def fused_apply(fn, delta_b, d: int):
    """Apply ``fn`` to the true d-dim rows of a bucketed (G, nb, B) delta.

    ``fn`` maps a ``(G, d)`` matrix to a ``(G, d)`` matrix; the zero-padded
    bucket tail is stripped before and restored after, so size-dependent
    operators (top-k's k, rand-k's d/k scale) see the real dimension.  This
    is the reshape/pad contract every fused compression pass shares — the
    cohort engine's per-class leaf compression plugs in through it.
    """
    G = delta_b.shape[0]
    flat = delta_b.reshape(G, -1)
    pad = flat.shape[1] - d
    out = fn(flat[:, :d])
    if pad:
        out = jnp.pad(out, ((0, 0), (0, pad)))
    return out.reshape(delta_b.shape)


def _fused_compress(c: Compressor, keys, delta_b, d: int):
    """One fused compressor pass over the bucketed (G, n_buckets, B) delta.

    The compressor must see the TRUE d-dim vector, not the padded bucket
    matrix: top-k/rand-k derive k (and rand-k its d/k scale) from the input
    size, so compressing the zero-padded tail would inflate k for trees
    smaller than a bucket.  (Only ``flatten=True`` compressors reach this —
    sharding-safe ones stay on the per-leaf path.)
    """
    return fused_apply(
        lambda core: jax.vmap(lambda k, v: c(k, v))(keys, core), delta_b, d)


def _efbv_sync_leaves(key, grads_g, state: SyncState, c: Compressor,
                      lam: float, nu: float):
    """Per-leaf EF-BV (one compressor kernel per pytree leaf)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads_g)
    h_leaves = treedef.flatten_up_to(state.h)
    hb_leaves = treedef.flatten_up_to(state.h_bar)
    G = leaves[0].shape[0]

    g_est, new_h, new_hb = [], [], []
    for li, (g, h, hb) in enumerate(zip(leaves, h_leaves, hb_leaves)):
        lkey = jax.random.fold_in(key, li)
        keys = jax.random.split(lkey, G)
        delta = g.astype(jnp.float32) - h
        d_i = jax.vmap(lambda k, v: c(k, v))(keys, delta)
        d = jnp.mean(d_i, axis=0)
        new_h.append(h + lam * d_i)
        g_est.append(hb + nu * d)
        new_hb.append(hb + lam * d)
    unf = jax.tree_util.tree_unflatten
    return (
        unf(treedef, g_est),
        SyncState(h=unf(treedef, new_h), h_bar=unf(treedef, new_hb),
                  step=state.step + 1),
    )


def tree_sync_state_init(params, levels: Sequence[CascadeLevel]) -> TreeSyncState:
    """Anchors for every cascade level, all seeded from the shared params."""
    n = 1
    for lev in levels:
        n *= lev.fanout
    anchors = []
    for l, lev in enumerate(levels):
        n //= lev.fanout
        if l == len(levels) - 1:
            anchors.append(tree_map(lambda p: p.astype(jnp.float32), params))
        else:
            anchors.append(tree_map(
                lambda p, n=n: jnp.broadcast_to(
                    p.astype(jnp.float32)[None], (n,) + p.shape), params))
    return TreeSyncState(anchors=tuple(anchors), step=jnp.zeros((), jnp.int32))


def _level_key(key, l: int, n_levels: int):
    """Per-level PRNG key, stable under added depth: keyed by distance from
    the root so the top (inter) level of any cascade draws the same noise as
    the classic single-level ``hier_param_sync``."""
    dist = n_levels - 1 - l
    return key if dist == 0 else jax.random.fold_in(key, dist)


def _survivor_masks(survivors, levels):
    """Normalize per-level survivor masks (None = everyone made the round).

    ``survivors[l]`` masks level l's *children* (the training leaves for
    l=0, the level-(l-1) aggregators above that); entries > 0 participated.
    Returns a list of float32 arrays or Nones, one per level.
    """
    if survivors is None:
        return [None] * len(levels)
    survivors = tuple(survivors)
    if len(survivors) != len(levels):
        raise ValueError(f"{len(survivors)} survivor masks for "
                         f"{len(levels)} cascade levels")
    n = 1
    for lev in levels:
        n *= lev.fanout
    out = []
    for l, (m, lev) in enumerate(zip(survivors, levels)):
        if m is None:
            out.append(None)
        else:
            m = jnp.asarray(m, jnp.float32)
            if m.shape != (n,):
                raise ValueError(
                    f"level {lev.name!r}: survivor mask shape {m.shape}, "
                    f"expected ({n},)")
            out.append(m)
        n //= lev.fanout
    return out


def _survivor_weights(m, f: int):
    """Mean-preserving reweighting for a masked mean over ``f`` children.

    ``jnp.mean(d * w)`` over the child axis equals the mean over survivors
    only: ``w = m * (f / max(sum(m), 1))``.  With an all-ones mask ``w`` is
    *exactly* 1.0 (f/f), so the weighted mean lowers to the identical XLA op
    as the unmasked one — the bit-identity guarantee the zero-fault path
    rides on.  A group with zero survivors gets w == 0 everywhere: its
    anchor takes no step this round (EF21 state carried, not corrupted).
    """
    if m.ndim == 1:
        return m * (f / jnp.maximum(jnp.sum(m), 1.0))
    return m * (f / jnp.maximum(jnp.sum(m, axis=1, keepdims=True), 1.0))


def tree_param_sync(key, params_g, state: TreeSyncState,
                    levels: Sequence[CascadeLevel],
                    bucket_size: Optional[int] = None,
                    survivors=None, leaf_compress=None):
    """Multi-level anchor cascade (Cohort-Squeeze beyond two levels).

    params_g: pytree with leading leaf axis G = prod(fanout_l) — one training
    replica per tree leaf.  Level l (leaf-most first) keeps one anchor per
    aggregator node; every ``period[l]`` steps its children (the leaves for
    l=0, the level-(l-1) anchors above that) sync through a compressed EF21
    delta against their parent anchor:

        d_i        = C_l(child_i - anchor_parent)
        anchor    += lam_l * mean_i d_i
        child_i   <- anchor            (the whole subtree adopts — see below)

    Periods are nested (validated by ``build_cascade``): a level only syncs
    on steps where every level below it also syncs, so one bottom-up pass
    folds fresh leaf progress into each anchor before it is pushed upward,
    and a final top-down pass makes every node below the highest synced
    level adopt that ancestor's new anchor.  The depth-1 cascade is exactly
    the classic ``hier_param_sync`` (which now wraps this), and a depth-2
    [intra=identity/period 1, inter=C/period p] cascade reproduces it on the
    per-pod means bit-for-bit.

    Like ``efbv_sync`` the tree is bucket-fused by default; ``bucket_size=0``
    or any sharding-safe ``flatten=False`` level compressor selects the
    per-leaf path.  Returns (new params_g, new TreeSyncState).

    ``survivors`` (optional, from ``FaultModel.round_plan``) is one mask per
    level over that level's children; non-survivors are excluded from the
    anchor update via a mean-preserving reweighting (``_survivor_weights``)
    and dropped *leaves* skip the top-down adoption — they keep their local
    params and re-anchor on their next surviving round, so their EF21 state
    is carried, never corrupted.  ``survivors=None`` (or all-ones masks) is
    bit-identical to the faultless path; the aggregator down-path is modeled
    reliable, so inner anchors always adopt.

    ``leaf_compress`` (optional) replaces level 0's fused compressor pass
    with a custom ``(keys, delta_b, d) -> d_i`` callable (same bucketed
    shapes — build it on ``fused_apply``).  The cohort engine uses this to
    compress each leaf's delta with its *own link class's* compressor while
    the rest of the cascade runs unchanged.  Fused path only: heterogeneous
    per-leaf compression over a stacked dense cohort has no per-leaf
    (sharding-safe) analogue.
    """
    from repro.comm import buckets as bk

    if bucket_size is None:
        bucket_size = bk.DEFAULT_BUCKET_SIZE
    levels = tuple(levels)
    prev = None
    for lev in levels:
        if prev is not None and lev.period % prev != 0:
            raise ValueError(
                f"level {lev.name!r}: period {lev.period} not a multiple of "
                f"the level below ({prev}); cascade periods must be nested")
        prev = lev.period
    G = jax.tree_util.tree_leaves(params_g)[0].shape[0]
    n_expected = 1
    for lev in levels:
        n_expected *= lev.fanout
    if G != n_expected:
        raise ValueError(f"params_g has {G} leaves but cascade fanouts "
                         f"multiply to {n_expected}")

    # nested periods: the number of levels syncing this step fully describes
    # the round (level l syncs => every level below does too)
    n_sync = jnp.zeros((), jnp.int32)
    for lev in levels:
        n_sync = n_sync + ((state.step % lev.period)
                           == (lev.period - 1)).astype(jnp.int32)

    fused = bool(bucket_size) and all(lev.compressor.flatten for lev in levels)
    if leaf_compress is not None and not fused:
        raise ValueError(
            "leaf_compress requires the fused (bucketized) path: set a "
            "bucket_size > 0 and use flatten=True level compressors")
    masks = _survivor_masks(survivors, levels)

    # gate the whole sync (including the fused path's bucketize/debucketize
    # round-trip) behind the step test, so off-period steps stay free like
    # the old single-level lax.cond did
    def do_sync(args):
        params_g, anchors, n_sync = args
        st = TreeSyncState(anchors=anchors, step=state.step)
        if fused:
            return _tree_sync_fused(key, params_g, st, levels, bucket_size,
                                    n_sync, masks, leaf_compress)
        return _tree_sync_leaves(key, params_g, st, levels, n_sync, masks)

    def no_sync(args):
        params_g, anchors, _ = args
        return params_g, anchors

    new_p, new_anchors = jax.lax.cond(
        n_sync > 0, do_sync, no_sync, (params_g, state.anchors, n_sync))
    return new_p, TreeSyncState(anchors=new_anchors, step=state.step + 1)


def _tree_sync_fused(key, params_g, state, levels, bucket_size, n_sync,
                     masks=None, leaf_compress=None):
    from repro.comm import buckets as bk

    L = len(levels)
    masks = masks or [None] * L
    p_b, layout = bk.bucketize_groups(params_g, bucket_size)     # (G, nb, B)
    G = p_b.shape[0]
    anchors_b = []
    for l in range(L):
        if l == L - 1:
            a_b, _ = bk.bucketize(state.anchors[l], bucket_size)  # (nb, B)
        else:
            a_b, _ = bk.bucketize_groups(state.anchors[l], bucket_size)
        anchors_b.append(a_b)

    def compress(l, keys, delta_b):
        if l == 0 and leaf_compress is not None:
            return leaf_compress(keys, delta_b, layout.d)
        return _fused_compress(levels[l].compressor, keys, delta_b, layout.d)

    def level_sync(l, child_b, parent_b):
        lev = levels[l]
        m = masks[l]
        with annotate(f"sync/level/{lev.name}"):
            keys = jax.random.split(_level_key(key, l, L), child_b.shape[0])
            if parent_b.ndim == 2:                  # root: unstacked anchor
                d_i = compress(l, keys, child_b - parent_b)
                if m is not None:
                    d_i = d_i * _survivor_weights(m, d_i.shape[0])[:, None, None]
                return parent_b + lev.lam * jnp.mean(d_i, axis=0)
            n_par = parent_b.shape[0]
            f = child_b.shape[0] // n_par
            d_i = compress(l, keys,
                           child_b - jnp.repeat(parent_b, f, axis=0))
            d_g = d_i.reshape((n_par, f) + d_i.shape[1:])
            if m is not None:
                w = _survivor_weights(m.reshape(n_par, f), f)
                d_g = d_g * w[:, :, None, None]
            return parent_b + lev.lam * jnp.mean(d_g, axis=1)

    def make_branch(j):
        def branch(args):
            p_b, anchors = args
            anchors = list(anchors)
            child = p_b
            for l in range(j):
                anchors[l] = level_sync(l, child, anchors[l])
                child = anchors[l] if anchors[l].ndim == 3 else anchors[l][None]
            if j:
                top = anchors[j - 1]
                top_s = top if top.ndim == 3 else top[None]
                for l in range(j - 1):
                    reps = anchors[l].shape[0] // top_s.shape[0]
                    adopted = jnp.repeat(top_s, reps, axis=0)
                    if masks[l + 1] is not None:
                        # groups whose uplink was dead carry their EF21
                        # anchor instead of adopting the ancestor
                        adopted = jnp.where(masks[l + 1][:, None, None] > 0,
                                            adopted, anchors[l])
                    anchors[l] = adopted
                p_out = jnp.repeat(top_s, G // top_s.shape[0], axis=0)
                if masks[0] is not None:
                    # dropped leaves keep their local params this round
                    p_out = jnp.where(masks[0][:, None, None] > 0, p_out, p_b)
            else:
                p_out = p_b
            return p_out, tuple(anchors)
        return branch

    p_out, anchors_out = jax.lax.switch(
        n_sync, [make_branch(j) for j in range(L + 1)], (p_b, tuple(anchors_b)))
    new_anchors = tuple(
        bk.debucketize(anchors_out[l], layout, dtype=jnp.float32)
        if l == L - 1 else
        bk.debucketize_groups(anchors_out[l], layout, dtype=jnp.float32)
        for l in range(L))
    return bk.debucketize_groups(p_out, layout), new_anchors


def _tree_sync_leaves(key, params_g, state, levels, n_sync, masks=None):
    """Per-leaf cascade (one compressor kernel per pytree leaf per level)."""
    L = len(levels)
    masks = masks or [None] * L
    leaves, treedef = jax.tree_util.tree_flatten(params_g)
    anchors_lv = [tuple(treedef.flatten_up_to(a)) for a in state.anchors]

    def _wcol(w, ndim):
        return w.reshape(w.shape + (1,) * (ndim - w.ndim))

    def level_sync(l, li, child, parent):
        lev = levels[l]
        m = masks[l]
        with annotate(f"sync/level/{lev.name}"):
            keys = jax.random.split(
                jax.random.fold_in(_level_key(key, l, L), li), child.shape[0])
            delta = child.astype(jnp.float32)
            if parent.ndim == child.ndim:           # stacked (non-root) anchor
                n_par = parent.shape[0]
                f = child.shape[0] // n_par
                delta = delta - jnp.repeat(parent, f, axis=0)
                d_i = jax.vmap(lambda k, v: lev.compressor(k, v))(keys, delta)
                d_g = d_i.reshape((n_par, f) + d_i.shape[1:])
                if m is not None:
                    w = _survivor_weights(m.reshape(n_par, f), f)
                    d_g = d_g * _wcol(w, d_g.ndim)
                return parent + lev.lam * jnp.mean(d_g, axis=1)
            d_i = jax.vmap(lambda k, v: lev.compressor(k, v))(keys,
                                                              delta - parent)
            if m is not None:
                d_i = d_i * _wcol(_survivor_weights(m, d_i.shape[0]),
                                  d_i.ndim)
            return parent + lev.lam * jnp.mean(d_i, axis=0)

    def make_branch(j):
        def branch(args):
            leaves, anchors = args
            anchors = [list(a) for a in anchors]
            new_leaves = list(leaves)
            for li, p in enumerate(leaves):
                child = p
                for l in range(j):
                    anchors[l][li] = level_sync(l, li, child, anchors[l][li])
                    a = anchors[l][li]
                    child = a if a.ndim == p.ndim else a[None]
                if j:
                    top = anchors[j - 1][li]
                    top_s = top if top.ndim == p.ndim else top[None]
                    for l in range(j - 1):
                        reps = anchors[l][li].shape[0] // top_s.shape[0]
                        adopted_a = jnp.repeat(top_s, reps, axis=0)
                        if masks[l + 1] is not None:
                            # dead-uplink groups carry their EF21 anchor
                            adopted_a = jnp.where(
                                _wcol(masks[l + 1], adopted_a.ndim) > 0,
                                adopted_a, anchors[l][li])
                        anchors[l][li] = adopted_a
                    adopted = jnp.repeat(
                        top_s.astype(p.dtype), p.shape[0] // top_s.shape[0],
                        axis=0) if top_s.shape[0] > 1 else jnp.broadcast_to(
                            top_s[0].astype(p.dtype)[None], p.shape)
                    if masks[0] is not None:
                        # dropped leaves keep their local params this round
                        adopted = jnp.where(
                            _wcol(masks[0], p.ndim) > 0, adopted, p)
                    new_leaves[li] = adopted
            return tuple(new_leaves), tuple(tuple(a) for a in anchors)
        return branch

    leaves_out, anchors_out = jax.lax.switch(
        n_sync, [make_branch(j) for j in range(L + 1)],
        (tuple(leaves), tuple(anchors_lv)))
    unf = jax.tree_util.tree_unflatten
    new_anchors = tuple(unf(treedef, list(a)) for a in anchors_out)
    return unf(treedef, list(leaves_out)), new_anchors


def hier_param_sync(key, params_g, state: SyncState, c: Compressor, lam: float,
                    period: int, bucket_size: Optional[int] = None,
                    survivors=None):
    """Cohort-Squeeze / local training on the fabric (param-level EF21 sync).

    params_g: pytree with leading group axis (pods, or (pod x data) worker
    groups for 'local' mode), each group training locally between syncs with
    its own optimizer.  Every ``period`` steps, groups sync through an EF21
    compressed delta against the shared anchor h_bar:

        d_i    = C_i(params_i - h_bar)
        h_bar += lam * mean_i d_i
        params_i <- h_bar                      (everyone adopts the anchor)

    With identity compressor and lam=1 this is exact parameter averaging
    (FedAvg); with top-k/qsgd the inter-group traffic carries only the
    compressed delta.  Returns (new params_g, new state).

    This is the depth-1 special case of ``tree_param_sync`` — one cascade
    level whose fanout is the whole group axis.  Like ``efbv_sync``, the
    parameter tree is bucket-fused by default (``bucket_size=0`` restores the
    per-leaf loop, and sharding-safe ``flatten=False`` compressors always
    take it — see ``efbv_sync``).
    """
    G = jax.tree_util.tree_leaves(params_g)[0].shape[0]
    lev = CascadeLevel("inter", c, lam, int(period), G)
    tstate = TreeSyncState(anchors=(state.h_bar,), step=state.step)
    if survivors is not None and not isinstance(survivors, (tuple, list)):
        survivors = (survivors,)  # single group-axis mask
    new_p, ts = tree_param_sync(key, params_g, tstate, (lev,),
                                bucket_size=bucket_size, survivors=survivors)
    return new_p, SyncState(h=state.h, h_bar=ts.anchors[0], step=ts.step)


# ---------------------------------------------------------------------------
# Bits accounting (per communication round, per worker) — the paper's metric
# ---------------------------------------------------------------------------
def bits_per_round(sync: SyncConfig, n_params: int) -> float:
    """Thin wrapper over repro.comm accounting.

    The number is *measured*: the configured compressor's codec encodes a
    probe payload and the packed-buffer bytes are amortized per mode/period
    (see repro.comm.accounting.round_cost).  The old closed-form model lives
    on as RoundCost.analytic_bits, used only as a cross-check.
    """
    from repro.comm import round_bits

    return round_bits(sync, n_params)


def round_comm(sync: SyncConfig, n_params: int, topology=None):
    """Full per-round communication report (bytes per link class + simulated
    wall-clock on the configured link topology). Convenience re-export so the
    runtime sync modes and the launch costing share one accounting path."""
    from repro.comm import round_cost

    return round_cost(sync, n_params, topology=topology)
