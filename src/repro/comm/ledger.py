"""Byte-accurate communication ledger — the single source of truth for
bits-on-the-wire accounting (the paper's Fig 2.2 x-axis).

Every algorithm/benchmark that used to carry its own analytic bits formula
(``distributed.bits_per_round``, the per-bench counters) now records real
encoded payload sizes here.  A record is one message on one link:

    ledger.record(round=3, link="client7->server", kind="inter",
                  nbytes=payload.nbytes, phase=0)

``kind`` maps the message onto a topology link class ("intra" = fast
cross-device fabric, "inter" = slow cross-pod / WAN); ``phase`` orders
dependent stages inside one round (hierarchical aggregation: phase 0 leaf ->
pod reduce, phase 1 pod -> root), so the wall-clock simulation can overlap
parallel links within a phase but serialize phases.

Cross-checks:
  * ``codecs`` payloads give exact nbytes (encoded-buffer sum);
  * ``crosscheck_hlo`` compares ledger totals against the collective bytes
    launch/hlo_analysis.py parses out of compiled XLA programs.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.comm.topology import Topology

# ---------------------------------------------------------------------------
# tag registry — the closed namespace of ``CommRecord.tag`` values.
#
# ``bytes_by_tag()`` is what the obs report audits; free-typed tag strings
# silently fork that attribution ("retry" vs "retries"), so every literal tag
# must be one of these constants (enforced by ``repro.lint`` rule RL004).
# Dynamic tags — aggregation-tree level names, payload wire schemes — are
# registered at runtime via :func:`register_tag`.
# ---------------------------------------------------------------------------
RETRY_TAG = "retry"          # retransmissions after a drop / checksum failure
UPLOAD_TAG = "upload"        # leaf -> aggregator payloads
BROADCAST_TAG = "broadcast"  # aggregator -> leaf model pushes
PAGE_IN_TAG = "serve/page_in"    # delta store -> serving block pool (a miss)
PAGE_OUT_TAG = "serve/page_out"  # trainer -> delta store persist (a put)
WIRE_SCHEME_TAGS = frozenset(
    {"dense", "sparse_idx32", "sparse_block", "sparse_bitmap", "quant"})

_RUNTIME_TAGS: set = set()


def register_tag(tag: str) -> str:
    """Register a runtime tag (tree level names etc.); returns it unchanged."""
    _RUNTIME_TAGS.add(str(tag))
    return str(tag)


def known_tags() -> frozenset:
    return (frozenset({RETRY_TAG, UPLOAD_TAG, BROADCAST_TAG,
                       PAGE_IN_TAG, PAGE_OUT_TAG})
            | WIRE_SCHEME_TAGS | frozenset(_RUNTIME_TAGS))


@dataclass(frozen=True)
class CommRecord:
    round: int
    link: str
    kind: str       # "intra" | "inter"
    nbytes: int
    phase: int = 0
    tag: str = ""
    chunk: int = -1  # streamed-tile index (-1 = whole-payload message)


@dataclass
class CommLedger:
    records: List[CommRecord] = field(default_factory=list)

    # -- recording ----------------------------------------------------------
    def record(self, round: int, link: str, nbytes, kind: str = "inter",
               phase: int = 0, tag: str = "", chunk: int = -1) -> CommRecord:
        rec = CommRecord(int(round), link, kind, int(nbytes), int(phase), tag,
                         int(chunk))
        self.records.append(rec)
        return rec

    def record_payload(self, round: int, link: str, payload,
                       kind: str = "inter", phase: int = 0,
                       tag: str = "") -> CommRecord:
        return self.record(round, link, payload.nbytes, kind=kind, phase=phase,
                           tag=tag or payload.scheme)

    def record_stream(self, round: int, link: str, stream,
                      kind: str = "inter", phase: int = 0,
                      tag: str = "") -> List[CommRecord]:
        """One record per in-flight chunk of a ``codecs.StreamPayload``; the
        chunk records sum exactly to the whole payload's ``nbytes``."""
        base = tag or stream.scheme
        return [self.record(round, link, ch.nbytes, kind=kind, phase=phase,
                            tag=base, chunk=ch.index)
                for ch in stream.chunks]

    def merge(self, other: "CommLedger") -> "CommLedger":
        self.records.extend(other.records)
        return self

    @classmethod
    def from_rounds(cls, nbytes, n_rounds: int, link: str = "client->server",
                    kind: str = "inter", phase: int = 0) -> "CommLedger":
        """Ledger with one constant-size message per round — the shape of
        every fixed-payload benchmark (size-invariant compressors)."""
        led = cls()
        for t in range(n_rounds):
            led.record(t, link, nbytes, kind=kind, phase=phase)
        return led

    # -- aggregates ---------------------------------------------------------
    @property
    def total_bytes(self) -> int:
        return sum(r.nbytes for r in self.records)

    @property
    def total_bits(self) -> int:
        return 8 * self.total_bytes

    def n_rounds(self) -> int:
        return (max(r.round for r in self.records) + 1) if self.records else 0

    def bytes_by_round(self) -> Dict[int, int]:
        out: Dict[int, int] = defaultdict(int)
        for r in self.records:
            out[r.round] += r.nbytes
        return dict(out)

    def bytes_by_link(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for r in self.records:
            out[r.link] += r.nbytes
        return dict(out)

    def bytes_by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for r in self.records:
            out[r.kind] += r.nbytes
        return dict(out)

    def bytes_by_tag(self) -> Dict[str, int]:
        """Per-tag byte totals (aggregation-tree rounds tag records with the
        level name, so this is the per-level attribution)."""
        out: Dict[str, int] = defaultdict(int)
        for r in self.records:
            out[r.tag] += r.nbytes
        return dict(out)

    @property
    def retry_bytes(self) -> int:
        """Bytes charged to retransmissions (faulty links re-sending after a
        drop or a checksum-caught corruption, tag :data:`RETRY_TAG`)."""
        return sum(r.nbytes for r in self.records if r.tag == RETRY_TAG)

    def cumulative_bytes(self) -> List[int]:
        """Running total after each round 0..n_rounds-1 (Fig 2.2 x-axis)."""
        per = self.bytes_by_round()
        out, acc = [], 0
        for t in range(self.n_rounds()):
            acc += per.get(t, 0)
            out.append(acc)
        return out

    def bits_per_node(self, n_nodes: int) -> float:
        """Total bits divided by participating nodes — the paper's metric."""
        return self.total_bits / max(1, n_nodes)

    # -- simulation ---------------------------------------------------------
    def round_time_s(self, topo: Topology, round: int) -> float:
        """Simulated wall-clock of one round: links within a phase run in
        parallel (each link serializes its own messages), phases run back to
        back."""
        by_phase: Dict[int, Dict[str, float]] = defaultdict(lambda: defaultdict(float))
        for r in self.records:
            if r.round != round:
                continue
            by_phase[r.phase][r.link] += topo.link(r.kind).time_s(r.nbytes)
        return sum(max(links.values()) for links in by_phase.values()) if by_phase else 0.0

    def total_time_s(self, topo: Topology) -> float:
        return sum(self.round_time_s(topo, t) for t in range(self.n_rounds()))

    def summary(self) -> str:
        kinds = ";".join(f"{k}={v}" for k, v in sorted(self.bytes_by_kind().items()))
        return (f"rounds={self.n_rounds()} msgs={len(self.records)} "
                f"bytes={self.total_bytes} ({kinds})")


# ---------------------------------------------------------------------------
# HLO cross-check
# ---------------------------------------------------------------------------
def crosscheck_hlo(ledger: CommLedger, stats,
                   rel_tol: float = 0.25) -> dict:
    """Compare ledger totals against hlo_analysis.CollectiveStats.

    The HLO parse counts per-device collective payload of the compiled
    program (one step); the ledger counts encoded message bytes.  They agree
    when the program's collectives carry the encoded planes (int8 all-reduce
    for qsgd) and diverge when compression is only modeled — the ratio is the
    audit number.
    """
    hlo_total = float(stats.total_bytes)
    led_total = float(ledger.total_bytes)
    ratio = led_total / hlo_total if hlo_total > 0 else float("inf")
    return {
        "ledger_bytes": led_total,
        "hlo_bytes": hlo_total,
        "hlo_inter_pod_bytes": float(stats.inter_pod_bytes),
        "ratio": ratio,
        "consistent": hlo_total > 0 and abs(ratio - 1.0) <= rel_tol,
    }
