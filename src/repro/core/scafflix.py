"""Scafflix: explicit personalization + accelerated local training (Ch. 3).

Implements Algorithm 4 verbatim on the (FLIX) objective
    min_x  (1/n) sum_i f_i( alpha_i x + (1-alpha_i) x_i* ),
where x_i* = argmin f_i is each client's locally-optimal model.

Per round t (prob-p communication):
    xt_i   = alpha_i x_i + (1-alpha_i) x_i*          # personalized estimate
    g_i    = (stochastic) grad f_i(xt_i)
    xh_i   = x_i - (gamma_i/alpha_i) (g_i - h_i)     # local step
    w.p. p:  xbar = (gamma/n) sum_j (alpha_j^2/gamma_j) xh_j  (server)
             x_i <- xbar;  h_i += (p alpha_i / gamma_i)(xbar - xh_i)
    else:    x_i <- xh_i
with gamma = ( (1/n) sum alpha_i^2 / gamma_i )^{-1}.

``i-Scaffnew`` is the alpha_i = 1 special case (Appendix B.1), and vanilla
Scaffnew additionally forces a shared stepsize.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class ScafflixState(NamedTuple):
    x: jax.Array        # (n, d) per-client iterates
    h: jax.Array        # (n, d) control variates (sum_i h_i = 0 invariant)
    x_star: jax.Array   # (n, d) local optima (personalization anchors)


def scafflix_init(x0: jax.Array, n: int, x_star: jax.Array) -> ScafflixState:
    d = x0.shape[0]
    return ScafflixState(
        x=jnp.tile(x0[None], (n, 1)),
        h=jnp.zeros((n, d), x0.dtype),
        x_star=x_star,
    )


def scafflix_round(key, state: ScafflixState, grad_fn: Callable, p: float,
                   gammas: jax.Array, alphas: jax.Array):
    """One Scafflix round. grad_fn(xt: (n,d)) -> (n,d) per-client gradients
    evaluated at the personalized points. Returns (new_state, communicated)."""
    n = state.x.shape[0]
    xt = alphas[:, None] * state.x + (1 - alphas[:, None]) * state.x_star
    g = grad_fn(xt)
    xh = state.x - (gammas / alphas)[:, None] * (g - state.h)

    theta = jax.random.bernoulli(key, p)
    gamma_srv = 1.0 / jnp.mean(alphas**2 / gammas)
    w = (alphas**2 / gammas)[:, None]
    xbar = gamma_srv * jnp.mean(w * xh, axis=0)

    x_comm = jnp.tile(xbar[None], (n, 1))
    h_comm = state.h + (p * alphas / gammas)[:, None] * (xbar[None] - xh)

    new_x = jnp.where(theta, x_comm, xh)
    new_h = jnp.where(theta, h_comm, state.h)
    return ScafflixState(x=new_x, h=new_h, x_star=state.x_star), theta


def scafflix_run(key, state: ScafflixState, grad_fn, p: float, gammas, alphas,
                 rounds: int, eval_fn=None):
    """Returns (final state, per-round (metric, communicated) trace)."""

    def body(st, k):
        st, comm = scafflix_round(k, st, grad_fn, p, gammas, alphas)
        m = eval_fn(st) if eval_fn is not None else jnp.zeros(())
        return st, (m, comm)

    keys = jax.random.split(key, rounds)
    state, trace = jax.lax.scan(body, state, keys)
    return state, trace


# ---------------------------------------------------------------------------
# FLIX helpers on the federated logreg problem (Ch. 3.3.1 experiments)
# ---------------------------------------------------------------------------
def flix_objective(x, A, b, mu, alphas, x_star):
    """f~(x) = (1/n) sum_i f_i(alpha_i x + (1-alpha_i) x_i*)."""
    xt = alphas[:, None] * x[None] + (1 - alphas[:, None]) * x_star  # (n,d)
    z = jnp.einsum("nmd,nd->nm", A, xt)
    loss = jnp.mean(jnp.log1p(jnp.exp(-b * z)), axis=1) + 0.5 * mu * jnp.sum(xt**2, axis=1)
    return jnp.mean(loss)


def logreg_grads(xt, A, b, mu):
    """Per-client logreg gradients at per-client points xt (n,d)."""
    z = jnp.einsum("nmd,nd->nm", A, xt)
    s = -b * jax.nn.sigmoid(-b * z)           # d/dz log(1+exp(-bz))
    g = jnp.einsum("nm,nmd->nd", s, A) / A.shape[1]
    return g + mu * xt


def local_optimum(A_i, b_i, mu, steps: int = 500, tol: float = 1e-10):
    """x_i* = argmin f_i via Newton (logreg Hessian is closed-form)."""
    m, d = A_i.shape

    def grad_hess(x):
        z = A_i @ x
        sig = jax.nn.sigmoid(-b_i * z)
        g = (A_i.T @ (-b_i * sig)) / m + mu * x
        w = sig * (1 - sig)
        H = (A_i.T * w) @ A_i / m + mu * jnp.eye(d)
        return g, H

    def body(carry, _):
        x, done = carry
        g, H = grad_hess(x)
        step = jnp.linalg.solve(H, g)
        new_x = jnp.where(done, x, x - step)
        done = done | (jnp.linalg.norm(g) < tol)
        return (new_x, done), None

    (x, _), _ = jax.lax.scan(body, (jnp.zeros(d), jnp.asarray(False)), None, length=steps)
    return x


def flix_optimum(A, b, mu, alphas, x_star, steps: int = 2000, lr: float = None):
    """Solve (FLIX) to high precision with GD (convex, smooth)."""
    n, m, d = A.shape
    L = jnp.max(jnp.sum(A**2, axis=(1, 2)) / (4 * m)) + mu
    lr = (1.0 / L) if lr is None else lr

    def body(x, _):
        g = jax.grad(flix_objective)(x, A, b, mu, alphas, x_star)
        return x - lr * g, None

    x, _ = jax.lax.scan(body, jnp.zeros(d), None, length=steps)
    return x
