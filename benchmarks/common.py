"""Shared benchmark utilities: timing + CSV row emission."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]


def timed(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds.

    ``warmup`` calls run first and are discarded so JIT/trace cost doesn't
    pollute the median (codec rows used to time a single cold call).
    """
    for _ in range(max(0, warmup)):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
