"""GQA attention with global / sliding-window / chunked-local variants.

Three entry points:
  * ``attention_train``   — full-sequence causal attention, blockwise
    (flash-style) over KV so S=32k never materializes an S x S score matrix.
  * ``attention_prefill`` — same math, also returns the KV cache.
  * ``attention_decode``  — one query token against a cache (full, ring-buffer
    for SWA/chunked, per the layer kind).

Shapes: x (B, S, D); heads H query / KV kv-heads (GQA groups G = H/KV).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, apply_rope, l2norm

NEG_INF = -1e30


def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int, head_dim: int,
                   bias: bool, dtype) -> dict:
    ks = jax.random.split(key, 4)
    q_dim, kv_dim = num_heads * head_dim, num_kv_heads * head_dim
    p = {
        "wq": _dense_init(ks[0], (d_model, q_dim), dtype),
        "wk": _dense_init(ks[1], (d_model, kv_dim), dtype),
        "wv": _dense_init(ks[2], (d_model, kv_dim), dtype),
        "wo": _dense_init(ks[3], (q_dim, d_model), dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((q_dim,), dtype)
        p["bk"] = jnp.zeros((kv_dim,), dtype)
        p["bv"] = jnp.zeros((kv_dim,), dtype)
    return p


def _project_qkv(params, x, num_heads, num_kv_heads, head_dim, qk_norm, use_rope,
                 positions, rope_theta):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, num_heads, head_dim)
    k = k.reshape(B, S, num_kv_heads, head_dim)
    v = v.reshape(B, S, num_kv_heads, head_dim)
    if qk_norm:
        q, k = l2norm(q), l2norm(k)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _block_mask(q_idx, k_idx, kind: str, window: int, chunk: int):
    """(Sq, Sk) additive mask for one (q-block, k-block) pair of indices."""
    if kind == "full":  # non-causal (encoder / cross-attention)
        return jnp.zeros((q_idx.shape[0], k_idx.shape[0]), jnp.float32)
    causal = q_idx[:, None] >= k_idx[None, :]
    ok = causal
    if kind == "attn_swa":
        ok = ok & (q_idx[:, None] - k_idx[None, :] < window)
    elif kind == "attn_chunk":
        ok = ok & ((q_idx[:, None] // chunk) == (k_idx[None, :] // chunk))
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# Tile sizes: (BLOCK_Q x BLOCK_K) transient score tiles. Overridable by the
# dry-run costing harness (which exploits linearity in the block size).
BLOCK_Q = 512
BLOCK_K = 1024

# Banded flash (perf option, §Perf hillclimb): SWA/chunked layers only visit
# the KV blocks their window/chunk can reach instead of all of them.  The
# baseline (False) is the paper-faithful full sweep with masking — identical
# numerics, O(S^2) work; banded cuts attention work to O(S * window).
BANDED = False


def _flash_attention(q, k, v, kind: str, window: int, chunk: int,
                     q_offset: int = 0, block_q: Optional[int] = None,
                     block_k: Optional[int] = None):
    """2D-tiled (flash-style) softmax attention. q (B,Sq,H,hd); k,v (B,Sk,KV,hd).

    Outer scan over query tiles, inner scan over KV tiles keeping a running
    (max, denom, accum) per query.  The inner body is wrapped in
    ``jax.checkpoint`` so reverse-mode AD recomputes the (Bq x Bk) score tile
    instead of stashing one per iteration — transient memory is
    O(block_q * block_k) and saved residuals are O(S) per layer.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    bq = min(block_q or BLOCK_Q, Sq)
    bk = min(block_k or BLOCK_K, Sk)
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    if nq * bq != Sq:
        qpad = nq * bq - Sq
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if nk * bk != Sk:
        kpad = nk * bk - Sk
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))

    qf = (q.astype(jnp.float32) * scale).reshape(B, nq, bq, KV, G, hd)
    kb = k.reshape(B, nk, bk, KV, hd)
    vb = v.reshape(B, nk, bk, KV, hd)

    @jax.checkpoint
    def kv_body(carry, blk):
        q_tile, qi = carry[3], carry[4]
        m_prev, l_prev, acc = carry[0], carry[1], carry[2]
        k_blk, v_blk, ki = blk
        q_idx = q_offset + qi * bq + jnp.arange(bq)
        k_idx = ki * bk + jnp.arange(bk)
        s = jnp.einsum("bqkgh,bnkh->bqkgn", q_tile, k_blk.astype(jnp.float32))
        mask = _block_mask(q_idx, k_idx, kind, window, chunk)   # (bq, bk)
        pad_mask = jnp.where(k_idx < Sk, 0.0, NEG_INF)
        s = s + (mask + pad_mask[None, :])[None, :, None, None, :]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqkgn,bnkh->bqkgh", p, v_blk.astype(jnp.float32))
        return (m_new, l_new, acc, q_tile, qi), None

    # banded mode: number of KV blocks any query tile can actually reach
    banded = BANDED and kind in ("attn_swa", "attn_chunk")
    if banded:
        reach = window if kind == "attn_swa" else chunk
        R = min(nk, -(-reach // bk) + (2 if bq > 1 else 1))

    kbs = kb.swapaxes(0, 1)  # (nk, B, bk, KV, hd)
    vbs = vb.swapaxes(0, 1)

    def q_body(_, q_blk):
        q_tile, qi = q_blk
        m0 = jnp.full((B, bq, KV, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, bq, KV, G), jnp.float32)
        a0 = jnp.zeros((B, bq, KV, G, hd), jnp.float32)
        if banded:
            # visit blocks qb_end, qb_end-1, ..., down to the window floor
            qb_end = (qi * bq + bq - 1 + q_offset) // bk

            def band_body(carry, r):
                blk = qb_end - r
                valid = blk >= 0
                blk_c = jnp.clip(blk, 0, nk - 1)
                k_blk = jax.lax.dynamic_index_in_dim(kbs, blk_c, 0, keepdims=False)
                v_blk = jax.lax.dynamic_index_in_dim(vbs, blk_c, 0, keepdims=False)
                new_carry, _ = kv_body(carry, (k_blk, v_blk, blk_c))
                # invalid (negative) blocks contribute nothing
                merged = jax.tree_util.tree_map(
                    lambda new, old: jnp.where(valid, new, old), new_carry, carry)
                return merged, None

            (m, l, acc, _, _), _ = jax.lax.scan(
                band_body, (m0, l0, a0, q_tile, qi), jnp.arange(R))
        else:
            (m, l, acc, _, _), _ = jax.lax.scan(
                kv_body, (m0, l0, a0, q_tile, qi), (kbs, vbs, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out

    _, out = jax.lax.scan(q_body, None,
                          (qf.swapaxes(0, 1), jnp.arange(nq)))
    # out: (nq, B, bq, KV, G, hd) -> (B, Sq, H, hd)
    out = out.swapaxes(0, 1).reshape(B, nq * bq, H, hd)[:, :Sq]
    return out.astype(q.dtype)


def attention_train(params, x, *, cfg_attn: dict, positions=None):
    """cfg_attn keys: num_heads num_kv_heads head_dim kind window chunk
    qk_norm use_rope rope_theta."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(params, x, cfg_attn["num_heads"], cfg_attn["num_kv_heads"],
                           cfg_attn["head_dim"], cfg_attn["qk_norm"], cfg_attn["use_rope"],
                           positions, cfg_attn["rope_theta"])
    out = _flash_attention(q, k, v, cfg_attn["kind"], cfg_attn["window"], cfg_attn["chunk"])
    return out.reshape(B, S, -1) @ params["wo"]


def attention_prefill(params, x, *, cfg_attn: dict):
    """Returns (output, cache{k,v}). Cache keeps full K/V; for SWA/chunked
    layers the decode path only reads the live window (ring semantics are
    realized at decode time via position masking, keeping shapes static)."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(params, x, cfg_attn["num_heads"], cfg_attn["num_kv_heads"],
                           cfg_attn["head_dim"], cfg_attn["qk_norm"], cfg_attn["use_rope"],
                           positions, cfg_attn["rope_theta"])
    out = _flash_attention(q, k, v, cfg_attn["kind"], cfg_attn["window"], cfg_attn["chunk"])
    out = out.reshape(B, S, -1) @ params["wo"]
    return out, {"k": k, "v": v}


def cache_spec(cfg_attn: dict, batch: int, seq_len: int, dtype):
    """Decode-cache shapes for one attention layer.

    SWA / chunked layers bound the live context, so the cache is the window
    (this is exactly why those archs qualify for long_500k)."""
    kind = cfg_attn["kind"]
    if kind == "attn_swa":
        S = min(seq_len, cfg_attn["window"])
    elif kind == "attn_chunk":
        S = min(seq_len, cfg_attn["chunk"])
    else:
        S = seq_len
    kv, hd = cfg_attn["num_kv_heads"], cfg_attn["head_dim"]
    return {
        "k": jax.ShapeDtypeStruct((batch, S, kv, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, S, kv, hd), dtype),
    }


def attention_decode(params, x, cache: dict, pos: jax.Array, *, cfg_attn: dict):
    """One-token decode. x (B,1,D); cache{k,v} (B,Sc,KV,hd); pos () int32 —
    number of tokens already in context.  Ring-buffer write for windowed
    layers; returns (out, new_cache)."""
    B = x.shape[0]
    H, KV, hd = cfg_attn["num_heads"], cfg_attn["num_kv_heads"], cfg_attn["head_dim"]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, H, KV, hd, cfg_attn["qk_norm"],
                                   cfg_attn["use_rope"], positions, cfg_attn["rope_theta"])
    Sc = cache["k"].shape[1]
    slot = jnp.mod(pos, Sc)  # ring for windowed layers; == pos when Sc==seq_len
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))

    # live-slot mask: slot index valid if it holds one of the last `live` tokens
    kind = cfg_attn["kind"]
    idx = jnp.arange(Sc)
    age_by_slot = jnp.mod(slot - idx, Sc)  # 0 = newest
    written = idx <= jnp.minimum(pos, Sc - 1)  # slots ever written
    if kind == "attn_swa":
        live = age_by_slot < cfg_attn["window"]
    elif kind == "attn_chunk":
        # tokens in the current chunk only
        pos_of_slot = pos - age_by_slot
        live = (pos_of_slot // cfg_attn["chunk"]) == (pos // cfg_attn["chunk"])
    else:
        live = jnp.ones((Sc,), bool)
    valid = (written & live).astype(jnp.float32)
    bias = jnp.where(valid > 0, 0.0, NEG_INF)

    scale = 1.0 / math.sqrt(hd)
    qf = (q.astype(jnp.float32) * scale).reshape(B, 1, KV, H // KV, hd)
    s = jnp.einsum("bqkgh,bnkh->bqkgn", qf, k.astype(jnp.float32))
    s = s + bias[None, None, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgn,bnkh->bqkgh", p, v.astype(jnp.float32))
    out = out.reshape(B, 1, H * hd).astype(x.dtype) @ params["wo"]
    return out, {"k": k, "v": v}
