"""Lightweight jit call-graph over the linted files.

Good enough for RL001/RL005, deliberately not a type checker:

* **Roots** are functions whose bodies XLA traces: ``@jax.jit`` /
  ``@partial(jax.jit, ...)`` decorated defs, functions passed to
  ``jax.jit(f)``, bodies handed to ``lax.scan/cond/switch/fori_loop/
  while_loop``, kernels handed to ``pl.pallas_call``, and — for the
  ``jax.jit(make_step(...))`` factory idiom — every def nested inside the
  factory.
* **Edges** are name-based: a bare ``f(...)`` call resolves to any same-module
  function named ``f`` (including nested defs); ``mod.f(...)`` resolves
  through the file's ``import x as mod`` / ``from pkg import x as mod`` maps.
  ``from pkg import f`` resolves bare ``f`` cross-module.
* **Static params**: ``static_argnames`` / ``static_argnums`` on the jit
  wrapper are recorded so RL005 doesn't taint config-style arguments.

Over-approximation (same-name functions merge) is fine — it only means a
function gets *checked*; it never hides one.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

LAX_TRACED = {  # lax entry points whose callable args run under trace
    "scan": (0,), "cond": (1, 2), "switch": (1,),
    "fori_loop": (2,), "while_loop": (0, 1), "map": (0,),
    "associative_scan": (0,), "custom_root": (0, 1),
}
JIT_NAMES = {"jit"}          # bare names that mean jax.jit when imported
PALLAS_CALL = "pallas_call"


@dataclass
class FuncNode:
    module: str
    qualname: str           # "outer.inner" for nested defs
    relpath: str
    node: ast.AST           # FunctionDef | AsyncFunctionDef | Lambda
    is_root: bool = False
    root_reasons: List[str] = field(default_factory=list)
    static_params: Set[str] = field(default_factory=set)
    calls: Set[str] = field(default_factory=set)        # bare local names
    attr_calls: Set[Tuple[str, str]] = field(default_factory=set)  # (alias, name)

    @property
    def key(self) -> Tuple[str, str]:
        return (self.module, self.qualname)

    @property
    def bare(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    def params(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return [n for n in names if n not in ("self", "cls")]

    def mark_root(self, reason: str, static: Optional[Set[str]] = None):
        self.is_root = True
        if reason not in self.root_reasons:
            self.root_reasons.append(reason)
        if static:
            self.static_params |= static


def dotted(node: ast.AST) -> Optional[str]:
    """'jax.lax.scan' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _static_from_call(call: ast.Call) -> Set[str]:
    """static_argnames from a partial(jax.jit, ...) / jax.jit(...) call."""
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            if isinstance(kw.value, ast.Constant) and isinstance(kw.value.value, str):
                out.add(kw.value.value)
            elif isinstance(kw.value, (ast.Tuple, ast.List, ast.Set)):
                for elt in kw.value.elts:
                    if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                        out.add(elt.value)
    return out


class _ModuleScan(ast.NodeVisitor):
    """One pass over a file: functions, import maps, jit/lax/pallas sites."""

    def __init__(self, ctx):
        self.ctx = ctx
        self.stack: List[str] = []
        self.nodes: Dict[str, FuncNode] = {}       # qualname -> node
        self.mod_aliases: Dict[str, str] = {}      # alias -> dotted module
        self.from_imports: Dict[str, Tuple[str, str]] = {}  # name -> (mod, name)
        self.root_marks: List[Tuple[str, str, Set[str], int]] = []  # (name, why, static, bound_pos)
        self.factory_marks: List[Tuple[str, str]] = []         # (name, why)

    # -- imports ------------------------------------------------------------
    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self.mod_aliases[a.asname or a.name.split(".")[0]] = a.name

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module and node.level == 0:
            for a in node.names:
                self.from_imports[a.asname or a.name] = (node.module, a.name)

    # -- functions ----------------------------------------------------------
    def _handle_func(self, node):
        qual = ".".join(self.stack + [node.name])
        fn = FuncNode(self.ctx.module, qual, self.ctx.relpath, node)
        for deco in node.decorator_list:
            d = dotted(deco)
            if d in ("jax.jit", "jit", "pjit", "jax.pjit"):
                fn.mark_root(f"@{d}")
            elif isinstance(deco, ast.Call):
                dc = dotted(deco.func)
                if dc in ("jax.jit", "jit", "pjit", "jax.pjit"):
                    fn.mark_root(f"@{dc}(...)", _static_from_call(deco))
                elif dc in ("partial", "functools.partial") and deco.args:
                    inner = dotted(deco.args[0])
                    if inner in ("jax.jit", "jit", "pjit", "jax.pjit"):
                        fn.mark_root(f"@partial({inner})",
                                     _static_from_call(deco))
        self.nodes[qual] = fn
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()
        self._collect_calls(fn)

    visit_FunctionDef = _handle_func
    visit_AsyncFunctionDef = _handle_func

    def _collect_calls(self, fn: FuncNode):
        """Call edges out of ``fn``, not descending into nested defs (those
        are their own nodes, reached through the bare-name edge)."""
        for stmt in fn.node.body:
            for sub in ast.walk(stmt):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and sub is not fn.node:
                    continue
                if isinstance(sub, ast.Call):
                    if isinstance(sub.func, ast.Name):
                        fn.calls.add(sub.func.id)
                    elif isinstance(sub.func, ast.Attribute) and \
                            isinstance(sub.func.value, ast.Name):
                        fn.attr_calls.add((sub.func.value.id, sub.func.attr))

    # -- jit/lax/pallas call sites -------------------------------------------
    def visit_Call(self, node: ast.Call):
        d = dotted(node.func)
        if d:
            tail = d.rsplit(".", 1)[-1]
            if d in ("jax.jit", "jax.pjit") or (tail in JIT_NAMES and
                                                d == tail):
                self._mark_traced_arg(node.args[0] if node.args else None,
                                      f"{d}()", _static_from_call(node))
            elif tail in LAX_TRACED and ("lax" in d or d == tail):
                for i in LAX_TRACED[tail]:
                    if i < len(node.args):
                        self._mark_traced_arg(node.args[i], f"{d} body", set())
            elif tail == PALLAS_CALL:
                self._mark_traced_arg(node.args[0] if node.args else None,
                                      "pallas_call kernel", set())
        self.generic_visit(node)

    def _mark_traced_arg(self, arg, why: str, static: Set[str],
                         bound_pos: int = 0):
        if arg is None:
            return
        if isinstance(arg, ast.Name):
            self.root_marks.append((arg.id, why, static, bound_pos))
        elif isinstance(arg, ast.Call):
            # jax.jit(make_step(...)) / partial(kernel, ...): the factory's
            # nested defs (or the partial'd function itself) get traced
            inner = dotted(arg.func)
            if inner in ("partial", "functools.partial") and arg.args:
                # partial-bound arguments are static python values, not
                # tracers: keywords by name, positionals by leading count
                bound = static | {kw.arg for kw in arg.keywords if kw.arg}
                self._mark_traced_arg(arg.args[0], why, bound,
                                      bound_pos + len(arg.args) - 1)
            elif isinstance(arg.func, ast.Name):
                self.factory_marks.append((arg.func.id, f"{why} via factory"))
        elif isinstance(arg, (ast.List, ast.Tuple)):
            for elt in arg.elts:
                self._mark_traced_arg(elt, why, static, bound_pos)
        elif isinstance(arg, ast.ListComp):
            self._mark_traced_arg(arg.elt, why, static, bound_pos)
        elif isinstance(arg, ast.Lambda):
            pass  # lambdas carry no name; their bodies are tiny — skip


@dataclass
class CallGraph:
    nodes: Dict[Tuple[str, str], FuncNode]
    by_bare: Dict[Tuple[str, str], List[Tuple[str, str]]]  # (mod, bare) -> keys
    mod_aliases: Dict[str, Dict[str, str]]                 # module -> alias map
    from_imports: Dict[str, Dict[str, Tuple[str, str]]]
    reachable: Set[Tuple[str, str]] = field(default_factory=set)

    @classmethod
    def build(cls, project) -> "CallGraph":
        nodes: Dict[Tuple[str, str], FuncNode] = {}
        by_bare: Dict[Tuple[str, str], List[Tuple[str, str]]] = {}
        aliases: Dict[str, Dict[str, str]] = {}
        froms: Dict[str, Dict[str, Tuple[str, str]]] = {}
        pending: List[Tuple[str, str, str, Set[str], int, bool]] = []
        for ctx in project.files.values():
            scan = _ModuleScan(ctx)
            scan.visit(ctx.tree)
            aliases[ctx.module] = scan.mod_aliases
            froms[ctx.module] = scan.from_imports
            for fn in scan.nodes.values():
                nodes[fn.key] = fn
                by_bare.setdefault((ctx.module, fn.bare), []).append(fn.key)
            for name, why, static, bound_pos in scan.root_marks:
                pending.append((ctx.module, name, why, static, bound_pos, False))
            for name, why in scan.factory_marks:
                pending.append((ctx.module, name, why, set(), 0, True))

        graph = cls(nodes, by_bare, aliases, froms)
        for module, name, why, static, bound_pos, factory in pending:
            for key in graph.resolve(module, name):
                if factory:
                    for nested in graph.nested_of(key):
                        nested.mark_root(why)
                else:
                    fn = nodes[key]
                    fn.mark_root(why, static | set(fn.params()[:bound_pos]))

        graph._compute_reachability()
        return graph

    def resolve(self, module: str, name: str) -> List[Tuple[str, str]]:
        """Function keys a bare name may refer to in ``module``."""
        hits = list(self.by_bare.get((module, name), []))
        tgt = self.from_imports.get(module, {}).get(name)
        if tgt is not None:
            hits += self.by_bare.get(tgt, [])
        return hits

    def resolve_attr(self, module: str, alias: str, name: str
                     ) -> List[Tuple[str, str]]:
        mod = self.mod_aliases.get(module, {}).get(alias)
        if mod is None:
            tgt = self.from_imports.get(module, {}).get(alias)
            if tgt is None:
                return []
            mod = ".".join(tgt)
        return list(self.by_bare.get((mod, name), []))

    def nested_of(self, key: Tuple[str, str]) -> List[FuncNode]:
        module, qual = key
        prefix = qual + "."
        return [fn for k, fn in self.nodes.items()
                if k[0] == module and k[1].startswith(prefix)]

    def _compute_reachability(self):
        work = [k for k, fn in self.nodes.items() if fn.is_root]
        seen = set(work)
        while work:
            key = work.pop()
            fn = self.nodes[key]
            targets: List[Tuple[str, str]] = []
            for name in fn.calls:
                targets += self.resolve(fn.module, name)
            for alias, name in fn.attr_calls:
                targets += self.resolve_attr(fn.module, alias, name)
            for t in targets:
                if t not in seen:
                    seen.add(t)
                    work.append(t)
        self.reachable = seen

    def reachable_nodes(self) -> List[FuncNode]:
        return [self.nodes[k] for k in sorted(self.reachable)]

    def root_nodes(self) -> List[FuncNode]:
        return [fn for fn in self.nodes.values() if fn.is_root]
