"""repro.comm — wire-level payload codecs, byte-accurate ledger, and the
link-topology simulator.

Layers:
  codecs      encode/decode packed payloads for every compressor family;
              decode(encode(x)) == compressor(x) bit-for-bit
  ledger      CommLedger: per-round, per-link encoded byte records — the one
              audited source of truth for bits-on-the-wire
  topology    Link/Topology: cross-device vs cross-pod bandwidth/latency,
              ring-collective timing, presets (TPU superpod / WAN / edge FL)
  accounting  RoundCost per sync mode (measured, amortized, simulated time);
              backs distributed.bits_per_round
"""
from repro.comm.accounting import (RoundCost, measured_payload_bits,
                                   round_bits, round_cost)
from repro.comm.codecs import (Payload, analytic_bits, decode, encode,
                               encoded_bits, roundtrip_equal)
from repro.comm.ledger import CommLedger, CommRecord, crosscheck_hlo
from repro.comm.topology import PRESETS, Link, Topology, get_topology

__all__ = [
    "Payload", "encode", "decode", "encoded_bits", "analytic_bits",
    "roundtrip_equal", "CommLedger", "CommRecord", "crosscheck_hlo",
    "Link", "Topology", "PRESETS", "get_topology",
    "RoundCost", "round_cost", "round_bits", "measured_payload_bits",
]
