"""Multi-device sharding tests.

These spawn subprocesses with XLA_FORCE_HOST_PLATFORM_DEVICE_COUNT=8 so the
main pytest process keeps its single CPU device (per the harness contract).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str) -> dict:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {root!r} + "/src")
        import jax, jax.numpy as jnp
        import numpy as np
        out = {{}}
    """).format(root=ROOT) + textwrap.dedent(snippet) + "\nprint(json.dumps(out))\n"
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_mini_dryrun_reduced_multipod():
    """A (2,2,2) 'multi-pod' mesh lowers+compiles train/decode for a reduced
    hybrid MoE arch — the same machinery the production dry-run uses."""
    out = _run("""
        from repro.configs import get_config
        from repro.configs.base import INPUT_SHAPES, InputShape
        from repro.launch import dryrun as dr
        cfg = get_config("jamba-1.5-large-398b").reduced()
        import repro.configs.base as base
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = InputShape("t", 32, 8, "train")
        low = dr.build_train_lowering(cfg, mesh, shape, grad_accum=2)
        comp = low.compile()
        out["train_ok"] = True
        out["collectives"] = "all-reduce" in comp.as_text() or "all-gather" in comp.as_text()
        shape_d = InputShape("d", 64, 8, "decode")
        low2 = dr.build_decode_lowering(cfg, mesh, shape_d)
        comp2 = low2.compile()
        out["decode_ok"] = True
    """)
    assert out["train_ok"] and out["decode_ok"]
    assert out["collectives"]


@pytest.mark.slow
def test_shardmap_moe_matches_scatter():
    """Expert-parallel shard_map MoE == scatter-dispatch MoE numerically."""
    out = _run("""
        from jax.sharding import PartitionSpec as P
        from repro.models import moe as moe_lib
        from repro.sharding.context import set_moe_specs
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        E, K, d, ff = 4, 2, 64, 128
        params = moe_lib.init_moe(jax.random.PRNGKey(0), d, ff, E, True, False,
                                  jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, d))
        kw = dict(num_experts=E, top_k=K, capacity_factor=float(E)/K,
                  act="silu", gated=True, shared_expert=False)
        y_ref, aux_ref = moe_lib.moe_ffn(params, x, **kw)
        with mesh:
            y_sm, aux_sm = jax.jit(lambda p, x: moe_lib.moe_ffn_shardmap(
                p, x, mesh=mesh, data_axes=("data",), **kw))(params, x)
        err = float(jnp.max(jnp.abs(y_ref - y_sm)))
        scale = float(jnp.max(jnp.abs(y_ref)))
        out["rel_err"] = err / (scale + 1e-9)
        # aux is computed per data shard then averaged (standard per-device
        # load-balance); it differs from the global statistic by a Jensen gap
        out["aux_gap"] = abs(float(aux_ref) - float(aux_sm))
    """)
    assert out["rel_err"] < 1e-4, out
    assert out["aux_gap"] < 0.1, out


@pytest.mark.slow
def test_alltoall_moe_matches_scatter():
    """all-to-all expert dispatch == scatter-dispatch MoE (exact: same
    deterministic routing, same drop-free capacity)."""
    out = _run("""
        from repro.models import moe as moe_lib
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        E, K, d, ff = 4, 2, 64, 128
        params = moe_lib.init_moe(jax.random.PRNGKey(0), d, ff, E, True, False,
                                  jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, d))
        kw = dict(num_experts=E, top_k=K, capacity_factor=float(E)/K,
                  act="silu", gated=True, shared_expert=False)
        y_ref, _ = moe_lib.moe_ffn(params, x, **kw)
        with mesh:
            y, _ = jax.jit(lambda p, x: moe_lib.moe_ffn_alltoall(
                p, x, mesh=mesh, data_axes=("data",), **kw))(params, x)
        out["rel_err"] = float(jnp.linalg.norm(y - y_ref) /
                               (jnp.linalg.norm(y_ref) + 1e-9))
    """)
    assert out["rel_err"] < 1e-5, out


@pytest.mark.slow
def test_efbv_sync_mode_lowered_multidev():
    out = _run("""
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.launch import dryrun as dr
        cfg = get_config("qwen1.5-4b").reduced()
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        shape = InputShape("t", 32, 8, "train")
        low = dr.build_train_lowering(cfg, mesh, shape, sync_mode="efbv",
                                      compressor="qsgd")
        comp = low.compile()
        out["ok"] = True
    """)
    assert out["ok"]


def test_param_specs_rules():
    """Rules engine: spot-check specs (a shape-only fake mesh suffices —
    param_specs consults only mesh.shape / axis_names)."""
    from types import SimpleNamespace

    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.sharding.rules import param_specs

    prod = SimpleNamespace(shape={"data": 16, "model": 16},
                           axis_names=("data", "model"))
    cfg_full = get_config("dbrx-132b")
    params_full = jax.eval_shape(lambda k: init_params(k, cfg_full),
                                 jax.random.PRNGKey(0))
    specs_full = param_specs(params_full, prod, extra_leading=1,
                             fsdp_axes=("data",))
    flat_full = {jax.tree_util.keystr(k): tuple(v)
                 for k, v in jax.tree_util.tree_flatten_with_path(specs_full)[0]}
    moe_win = [v for k, v in flat_full.items() if "moe" in k and "w_in" in k]
    assert moe_win and all(v[1] == "model" for v in moe_win)  # (stack, E, d, ff)
    assert all(v[2] in ("data", ("data",)) for v in moe_win)  # fsdp on d
    attn_wq = [v for k, v in flat_full.items() if "attn" in k and "wq" in k]
    assert attn_wq and all(v[-1] == "model" for v in attn_wq)
    embeds = [v for k, v in flat_full.items() if "embed" in k and "tok" in k]
    assert embeds and all(v[-1] == "model" and v[-2] is None for v in embeds)
