"""Round-level communication accounting on top of the codecs + topology.

Replaces the ad-hoc analytic bits computations that each algorithm carried
(``distributed.bits_per_round``, per-bench counters): byte counts come from
*encoding an actual payload* with the configured compressor's codec, and the
topology simulator turns them into per-round wall-clock.

Measured sizes are obtained on a probe tensor.  For models larger than the
probe cap the VALUE planes scale linearly (bits per kept coordinate are
constant for every registered compressor), while the index-side planes —
uint32 indices, bitpacked block-local indices, per-block counts, bitmap
words, quantizer scales — are sized analytically from the true dimension
(``codecs.extrapolate_bits``): a uint32 index plane is 32 bits per kept
coordinate no matter how large d grows, whereas block-granular planes grow
with d's block count, so pure linear scaling misstates sparse payloads.

Hierarchical modes are costed per aggregation level: ``hier`` (with or
without ``SyncConfig.levels``) runs through the tree path, so a ``RoundCost``
carries one ``LevelCost`` per level and a per-round ledger can tag every
record with its level name.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax

from repro.comm import codecs
from repro.comm.ledger import RETRY_TAG, CommLedger
from repro.comm.topology import (DEFAULT_PROFILE, DEFAULT_TILE_BYTES,
                                 CodecProfile, Topology, get_topology)
from repro.comm.tree import TreeTopology, get_tree_topology

PROBE_CAP = 1 << 20  # max coordinates actually encoded when sizing a round


@dataclass(frozen=True)
class LevelCost:
    """One aggregation-tree level's share of a sync round (per child node)."""
    name: str
    fanout: int
    period: int
    compressor: str
    link_gbps: float
    bytes_per_round: float   # encoded bytes, amortized over the level period
    time_s: float            # amortized simulated time (streamed if enabled)
    serial_time_s: float     # amortized monolithic pack -> ring -> unpack
    retry_bytes: float = 0.0      # expected retransmitted bytes (faults)
    degraded_time_s: float = 0.0  # straggler order-stat time, deadline-capped


@dataclass(frozen=True)
class RoundCost:
    """One synchronization round, per worker: encoded traffic + simulated time."""
    mode: str
    n_params: int
    intra_bytes: float       # fast-fabric bytes per device per round (tree
                             # modes: the leaf level's share)
    inter_bytes: float       # slow-link bytes per device per round (tree
                             # modes: every level above the leaves)
    time_s: float            # simulated wall-clock of the round (streamed
                             # pipeline when tile_bytes > 0, else serial)
    encoded_bits: float      # per-node payload bits per round (amortized)
    analytic_bits: float     # the seed's closed-form model (cross-check)
    serial_time_s: float = 0.0   # monolithic pack -> send -> unpack wall-clock
    tile_bytes: int = 0          # streamed transport tile (0 = monolithic)
    levels: Tuple[LevelCost, ...] = ()  # per-level attribution (hier modes)
    retry_bytes: float = 0.0     # expected retransmitted bytes (fault model;
                                 # the ledger charges these under tag "retry")
    degraded_time_s: float = 0.0  # expected round time under stragglers/
                                  # deadlines (order statistics, not the mean)

    @property
    def total_bytes(self) -> float:
        return self.intra_bytes + self.inter_bytes + self.retry_bytes

    @property
    def stream_speedup(self) -> float:
        return self.serial_time_s / self.time_s if self.time_s > 0 else 1.0


def payload_bits_for(c, n_params: int, key=None) -> float:
    """Measured wire bits of one message from compressor ``c`` at dim
    ``n_params`` (probe-capped; index planes sized analytically beyond)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    probe_d = min(int(n_params), PROBE_CAP)
    x = jax.random.normal(jax.random.fold_in(key, 1), (probe_d,))
    p = codecs.encode(c, key, x)
    if probe_d == int(n_params):
        return float(p.nbits)
    return codecs.extrapolate_bits(p, probe_d, int(n_params))


def measured_payload_bits(sync, n_params: int, key=None) -> float:
    """Encode a probe gradient with the configured compressor; exact bits."""
    from repro.core.distributed import build_compressor

    return payload_bits_for(build_compressor(sync), n_params, key=key)


def _hier_levels(sync):
    """The level configs of a hier round: ``SyncConfig.levels`` verbatim, or
    the classic two-level schedule (dense intra every step + compressed inter
    every sync_period) when unset."""
    from repro.configs.base import LevelConfig

    if getattr(sync, "levels", None):
        return tuple(sync.levels)
    return (LevelConfig("intra", period=1, compressor="identity"),
            LevelConfig("inter", period=max(1, sync.sync_period),
                        compressor=sync.compressor,
                        compress_ratio=sync.compress_ratio,
                        quant_bits=sync.quant_bits))


def _hier_tree(sync, topology: Optional[Topology]) -> TreeTopology:
    if isinstance(topology, TreeTopology):
        return topology
    if topology is not None:
        return TreeTopology.from_flat(topology)
    return get_tree_topology(getattr(sync, "topology", "v5p_superpod"))


def _level_costs(sync, n_params: int, tree: TreeTopology, tile_bytes: int,
                 key=None, profile: Optional[CodecProfile] = None,
                 faults=None) -> Tuple[LevelCost, ...]:
    """Per-level byte/time attribution of one tree round (per child node).
    ``profile`` overrides every compressed level's codec profile; ``faults``
    (a ``FaultConfig``) adds expected retransmission bytes and the
    deadline-capped straggler order-statistic time per level."""
    from repro.core.distributed import make_sync_compressor

    lcfgs = _hier_levels(sync)
    if len(lcfgs) != len(tree.levels):
        raise ValueError(
            f"sync has {len(lcfgs)} levels but tree topology {tree.name!r} "
            f"has {len(tree.levels)}")
    faulty = faults is not None and faults.enabled()
    out = []
    for l, (lc, tl) in enumerate(zip(lcfgs, tree.levels)):
        period = max(1, lc.period)
        if lc.compressor == "identity":
            enc_bytes = 4.0 * n_params         # dense fp32, no codec
            serial = tree.ring_time_s(l, enc_bytes)
            stream = serial
        else:
            c = make_sync_compressor(lc.compressor, lc.compress_ratio,
                                     lc.quant_bits)
            enc_bytes = payload_bits_for(c, n_params, key=key) / 8.0
            serial = tree.level_serial_time_s(l, enc_bytes, profile=profile)
            stream = (tree.level_stream_time_s(l, enc_bytes, tile_bytes,
                                               profile=profile)
                      if tile_bytes > 0 else serial)
        retry_b = degraded = 0.0
        if faulty:
            lf = tree.level_faults(l, faults)
            e_tx = faults.expected_transmissions(lf.loss_rate)
            retry_b = (e_tx - 1.0) * enc_bytes / period
            degraded = tree.level_degraded_time_s(
                l, enc_bytes, faults, codec=lc.compressor != "identity",
                profile=profile) / period
        out.append(LevelCost(tl.name, tl.fanout, period, lc.compressor,
                             tl.link.gbps, enc_bytes / period,
                             stream / period, serial / period,
                             retry_bytes=retry_b, degraded_time_s=degraded))
    return tuple(out)


def round_cost(sync, n_params: int, topology=None,
               key=None, profile: Optional[CodecProfile] = None) -> RoundCost:
    """Per-round, per-worker communication of one sync mode.

    dense       every round: full fp32 payload on the slow links
    efbv/ef21/diana  every round: encoded compressed delta on the slow links
    local       full fp32 payload every sync_period rounds (amortized)
    hier        per aggregation-tree level: an encoded delta every
                ``period[l]`` rounds on level l's link (Cohort-Squeeze); the
                classic intra/inter schedule is the depth-2 special case

    Compressed payloads pay the codec: ``serial_time_s`` is the monolithic
    pack -> collective -> unpack sum; ``time_s`` is the streamed pipeline
    (``SyncConfig.stream_tile_bytes``-sized tiles overlapping the three
    stages) when streaming is enabled, otherwise the serial time.
    """
    from repro.core.distributed import build_compressor

    tile_bytes = int(getattr(sync, "stream_tile_bytes", DEFAULT_TILE_BYTES))
    dense_bytes = 4.0 * n_params

    faults = getattr(sync, "faults", None)
    if sync.mode == "hier":
        tree = _hier_tree(sync, topology)
        lvls = _level_costs(sync, n_params, tree, tile_bytes, key=key,
                            profile=profile, faults=faults)
        intra = lvls[0].bytes_per_round
        inter = sum(lv.bytes_per_round for lv in lvls[1:])
        serial_s = sum(lv.serial_time_s for lv in lvls)
        stream_s = sum(lv.time_s for lv in lvls)
        retry_b = sum(lv.retry_bytes for lv in lvls)
        degraded_s = sum(lv.degraded_time_s for lv in lvls)
        # the paper's per-node bits metric: every compressed level, plus
        # dense non-leaf levels (fp32 on a real link); the leaf level's dense
        # fabric sync is the one hop it excludes
        bits = sum(8.0 * lv.bytes_per_round for l, lv in enumerate(lvls)
                   if l > 0 or lv.compressor != "identity")
        analytic = 0.0
        from repro.core.distributed import make_sync_compressor
        for l, lc in enumerate(_hier_levels(sync)):
            if l == 0 and lc.compressor == "identity":
                continue
            c = make_sync_compressor(lc.compressor, lc.compress_ratio,
                                     lc.quant_bits)
            analytic += codecs.analytic_bits(c, n_params) / max(1, lc.period)
        return RoundCost(sync.mode, n_params, intra, inter,
                         stream_s if tile_bytes > 0 else serial_s,
                         bits, analytic, serial_time_s=serial_s,
                         tile_bytes=max(0, tile_bytes), levels=lvls,
                         retry_bytes=retry_b, degraded_time_s=degraded_s)

    topo = topology or get_topology(getattr(sync, "topology", "v5p_superpod"))
    if isinstance(topo, TreeTopology):
        raise ValueError(f"mode {sync.mode!r} takes a flat Topology")
    period = max(1, sync.sync_period)
    prof = profile or DEFAULT_PROFILE
    if sync.mode in ("dense", "local"):
        enc_bits = 32.0 * n_params  # fp32 on the wire, no compressor
    else:
        enc_bits = measured_payload_bits(sync, n_params, key=key)
    enc_bytes = enc_bits / 8.0

    if sync.mode == "dense":
        intra, inter = 0.0, dense_bytes
        serial_s = stream_s = topo.allreduce_time_s(dense_bytes, scope="global")
        bits = 8.0 * dense_bytes
    elif sync.mode in ("efbv", "ef21", "diana"):
        intra, inter = 0.0, enc_bytes
        serial_s = topo.allreduce_serial_time_s(enc_bytes, "global", prof)
        stream_s = (topo.allreduce_stream_time_s(enc_bytes, "global",
                                                 tile_bytes, prof)
                    if tile_bytes > 0 else serial_s)
        bits = enc_bits
    elif sync.mode == "local":
        intra, inter = 0.0, dense_bytes / period
        serial_s = stream_s = (
            topo.allreduce_time_s(dense_bytes, scope="global") / period)
        bits = 8.0 * dense_bytes / period
    else:
        raise KeyError(f"unknown sync mode {sync.mode!r}")

    c = build_compressor(sync)
    analytic = codecs.analytic_bits(c, n_params)
    if sync.mode == "local":
        analytic = 32.0 * n_params / period
    if sync.mode == "dense":
        analytic = 32.0 * n_params  # fp32, no compressor on the wire
    # codec-free modes (dense/local fp32 wires) have nothing to stream:
    # report tile_bytes=0 so consumers don't claim a pipeline that isn't there
    if sync.mode in ("dense", "local"):
        tile_bytes = 0
    retry_b = degraded_s = 0.0
    if faults is not None and faults.enabled():
        # flat modes: the slow inter link is the faulty one (depth-1 view)
        from repro.comm.topology import straggler_level_time_s

        lf = faults.link_faults("inter")
        e_tx = faults.expected_transmissions(lf.loss_rate)
        retry_b = (e_tx - 1.0) * inter
        degraded_s = straggler_level_time_s(
            serial_s * e_tx + faults.backoff_s * (e_tx - 1.0),
            faults.straggler_rate, faults.straggler_sigma, topo.n_pods,
            faults.level_deadline_s("inter"))
    return RoundCost(sync.mode, n_params, intra, inter,
                     stream_s if tile_bytes > 0 else serial_s,
                     bits, analytic, serial_time_s=serial_s,
                     tile_bytes=max(0, tile_bytes),
                     retry_bytes=retry_b, degraded_time_s=degraded_s)


def round_ledger(sync, n_params: int, n_rounds: Optional[int] = None,
                 topology=None, key=None) -> CommLedger:
    """CommLedger of a hier/tree schedule: one record per level per sync
    step, tagged with the level name (phase = level index, so the cascade's
    bottom-up dependency shows up in the round timing model).

    Defaults to one full root period of rounds, over which the per-level
    record bytes average exactly to ``RoundCost.total_bytes`` per round.
    With ``SyncConfig.faults`` enabled, each sync step additionally charges
    the expected retransmitted bytes under tag ``"retry"`` — disabled or
    absent faults add no records at all (bit-identical ledger totals).
    """
    if sync.mode != "hier":
        raise ValueError("round_ledger models hier/tree schedules")
    tree = _hier_tree(sync, topology)
    tile_bytes = int(getattr(sync, "stream_tile_bytes", DEFAULT_TILE_BYTES))
    faults = getattr(sync, "faults", None)
    lvls = _level_costs(sync, n_params, tree, tile_bytes, key=key,
                        faults=faults)
    if n_rounds is None:
        n_rounds = lvls[-1].period
    led = CommLedger()
    for t in range(n_rounds):
        for l, lv in enumerate(lvls):
            if (t % lv.period) != (lv.period - 1):
                continue
            kind = "intra" if l == 0 else "inter"
            led.record(t, f"{lv.name}->up", round(lv.bytes_per_round * lv.period),
                       kind=kind, phase=l, tag=lv.name)
            if lv.retry_bytes > 0:
                led.record(t, f"{lv.name}->up",
                           round(lv.retry_bytes * lv.period),
                           kind=kind, phase=l, tag=RETRY_TAG)
    return led


def round_bits(sync, n_params: int) -> float:
    """Per-round, per-node encoded payload bits (the Fig 2.2 y-axis unit).

    This is what ``distributed.bits_per_round`` now wraps: measured from the
    codec's packed buffers, amortized over the sync period per mode.
    """
    return round_cost(sync, n_params).encoded_bits
