"""Population specs: million-client populations as pure functions.

A cross-device population is too large to materialize — 10^6 clients times
per-client data, link quality, and personalization state would dwarf the
model being trained.  ``Population`` therefore stores only the *law* of the
population: a link-class mix drawn around the topology's leaf level, a
Dirichlet(alpha) data-skew knob, dataset-size and personalization ranges.
Any client's realization derives on demand as a pure function of
``(spec, client_id)`` through the counter PRNG from ``repro.faults``.

Slicing invariance is the design contract: deriving specs for a sampled
cohort equals slicing the full-population derivation at those ids
(``client_spec(ids)[i] == client_spec([ids[i]])``), so the engine's memory
scales with the cohort, never the population.

Two further pieces keep population-scale rounds jit-friendly:

* ``sample_cohort`` — a keyed Feistel permutation over the id domain with
  cycle-walking, giving ``cohort`` *distinct* client ids replayable from
  ``(seed, round)`` in O(cohort) time and memory (no population-sized
  array is ever allocated, which the bench's memory-scaling gate checks).
* ``bucket_boundaries`` / ``bucket_by_size`` — the tensor2tensor
  ``data_reader`` bucketing idiom: cohort members are grouped into
  geometric size buckets with *static* padded capacities, so ragged
  per-client local-step counts become a few fixed-shape scans instead of
  one scan padded to the population max.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Tuple

import numpy as np

from repro.comm.topology import Link
from repro.comm.tree import TreeTopology, get_tree_topology
from repro.core import compressors as comp_lib
from repro.core.compressors import Compressor
from repro.data.federated import dirichlet_mixtures
# the population is addressed by the same counter PRNG as the fault
# processes: one mixer, one replay story ((seed, round, stream, lane))
from repro.faults.model import _GOLDEN, _mix64, counter_normal, counter_uniform


# ---------------------------------------------------------------------------
# link classes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LinkClass:
    """One client link class: uplink physics + the uplink codec it can afford.

    Classes differ in *bytes*, not just time: a fiber client ships an int8
    quantized delta while a congested cell client ships a 1% top-k — the
    per-class byte formulas the cohort ledger attributes analytically.
    """
    name: str
    weight: float            # population fraction (weights sum to 1)
    link: Link
    compressor: str = "top_k"
    compress_ratio: float = 0.05
    quant_bits: int = 8

    def make_compressor(self) -> Compressor:
        return cohort_compressor(self.compressor, self.compress_ratio,
                                 self.quant_bits)


def cohort_compressor(name: str, compress_ratio: float,
                      quant_bits: int) -> Compressor:
    """Resolve a compressor name for the cohort sweep's stacked dense rows.

    Unlike ``make_sync_compressor``, ``qsgd`` resolves to the dense
    (``flatten=True``) quantizer: cohort leaves are stacked 1-D vectors, not
    2D-sharded model leaves, and the fused cascade (plus the per-class
    ``leaf_compress`` hook) requires flattenable operators.
    """
    if name == "qsgd":
        return comp_lib.qsgd(quant_bits)
    from repro.core.distributed import make_sync_compressor

    c = make_sync_compressor(name, compress_ratio, quant_bits)
    if not c.flatten:
        raise ValueError(f"cohort compressor {name!r} is not flattenable "
                         "(sharding-safe variants cannot join the fused "
                         "cohort sweep)")
    return c


def link_classes_from_tree(tree: TreeTopology,
                           weights: Tuple[float, float, float] =
                           (0.2, 0.5, 0.3)) -> Tuple[LinkClass, ...]:
    """Three client classes drawn around ``tree``'s leaf (uplink) level.

    The middle class IS the preset uplink; "fiber" is ~16x faster and ships
    the dense fp32 delta uncompressed (the quant codec's 2 KiB block floor
    would cost more than dense at cohort-model dims), "cell" is 4x slower
    and ships a 1% top-k.  Weights are the population mix.
    """
    up = tree.levels[0].link
    return (
        LinkClass("fiber", weights[0],
                  Link(gbps=up.gbps * 16.0, latency_us=up.latency_us / 10.0),
                  compressor="identity"),
        LinkClass("broadband", weights[1], up,
                  compressor="top_k", compress_ratio=0.05),
        LinkClass("cell", weights[2],
                  Link(gbps=up.gbps / 4.0, latency_us=up.latency_us * 1.6),
                  compressor="top_k", compress_ratio=0.01),
    )


# ---------------------------------------------------------------------------
# cohort sampling — keyed Feistel permutation, O(cohort) not O(population)
# ---------------------------------------------------------------------------
def _feistel_perm(v: np.ndarray, base: np.uint64, half: int) -> np.ndarray:
    """4-round Feistel network on uint64 values < 2**(2*half) — a keyed
    bijection of the domain, vectorized over ``v``."""
    mask = np.uint64((1 << half) - 1)
    sh = np.uint64(half)
    left = v >> sh
    right = v & mask
    with np.errstate(over="ignore"):
        for r in range(4):
            f = _mix64(base + _GOLDEN * np.uint64(r + 1) + right) & mask
            left, right = right, left ^ f
    return (left << sh) | right


def sample_cohort(seed: int, rnd: int, n_population: int,
                  cohort: int) -> np.ndarray:
    """``cohort`` distinct client ids in [0, n_population), replayable from
    ``(seed, round)`` alone, in O(cohort) time and memory.

    A keyed Feistel permutation over the smallest even-bit domain covering
    the population maps ``0..cohort-1`` to distinct pseudo-random ids;
    out-of-range values cycle-walk (re-apply the bijection) back into range,
    which terminates because the domain is at most 4x the population.  No
    population-sized array is allocated — the property the engine's
    memory-scaling gate depends on.
    """
    if not 0 < cohort <= n_population:
        raise ValueError(f"cohort {cohort} outside (0, {n_population}]")
    bits = max(2, int(n_population - 1).bit_length())
    bits += bits % 2
    half = bits // 2
    with np.errstate(over="ignore"):
        base = _mix64(_GOLDEN * np.uint64(seed & 0xFFFFFFFFFFFFFFFF)
                      + np.uint64(rnd & 0xFFFFFFFFFFFFFFFF))
        base ^= np.uint64(zlib.crc32(b"cohort"))
    ids = _feistel_perm(np.arange(cohort, dtype=np.uint64), base, half)
    for _ in range(128):
        out = ids >= np.uint64(n_population)
        if not out.any():
            return ids.astype(np.int64)
        ids[out] = _feistel_perm(ids[out], base, half)
    raise RuntimeError("cycle walk did not converge")  # unreachable: bijection


# ---------------------------------------------------------------------------
# size bucketing (tensor2tensor data_reader idiom)
# ---------------------------------------------------------------------------
def bucket_boundaries(max_size: int, min_size: int = 8,
                      step: float = 1.25) -> Tuple[int, ...]:
    """Geometric bucket boundaries ``min_size <= b_0 < ... <= max_size``.

    A client with ``m`` local samples runs in the smallest bucket with
    ``boundary >= m``, so each bucket's scan length is its boundary — the
    padded-shape schedule tensor2tensor's ``_bucket_boundaries`` uses for
    ragged sequence lengths.
    """
    if not 1 <= min_size <= max_size:
        raise ValueError(f"need 1 <= min_size <= max_size, got "
                         f"[{min_size}, {max_size}]")
    if step <= 1.0:
        raise ValueError(f"step must be > 1, got {step}")
    out, x = [], int(min_size)
    while x < max_size:
        out.append(x)
        x = max(x + 1, int(x * step))
    out.append(int(max_size))
    return tuple(out)


def bucket_capacities(boundaries: Tuple[int, ...], cohort: int,
                      samples_min: int, samples_max: int,
                      slack: float = 0.2, floor: int = 8) -> Tuple[int, ...]:
    """Static per-bucket capacities for a cohort of uniform[min, max] sizes.

    Capacity = expected occupancy + binomial headroom (4 sigma) + ``floor``;
    shapes must be static for the jitted sweep, so capacities come from the
    population's size *law*, not the realized cohort.  Rare overflow spills
    into the next (larger) bucket — see ``bucket_by_size``.
    """
    span = samples_max - samples_min + 1
    caps, lo = [], samples_min - 1
    for b in boundaries:
        hi = min(b, samples_max)
        p = max(0, hi - lo) / span
        lo = hi
        mean = cohort * p
        caps.append(min(cohort, int(np.ceil(mean * (1.0 + slack)
                                            + 4.0 * np.sqrt(max(mean, 1.0))
                                            + floor))))
    return tuple(caps)


@dataclass(frozen=True)
class CohortBuckets:
    """Cohort slots partitioned into padded size buckets.

    ``index[b]`` holds cohort-slot indices padded to the bucket's static
    capacity with -1; ``valid[b]`` marks real entries.  Every cohort slot
    appears in exactly one bucket.
    """
    boundaries: Tuple[int, ...]
    index: Tuple[np.ndarray, ...]
    valid: Tuple[np.ndarray, ...]

    @property
    def padded_steps(self) -> int:
        """Total scan work (sum of capacity * boundary) — the quantity
        bucketing minimizes vs one max-padded batch."""
        return sum(len(ix) * b for ix, b in zip(self.index, self.boundaries))


def bucket_by_size(sizes: np.ndarray, boundaries: Tuple[int, ...],
                   capacities: Tuple[int, ...]) -> CohortBuckets:
    """Assign each cohort slot to the smallest bucket covering its size.

    Overflow beyond a bucket's static capacity spills into the next larger
    bucket (always correct — a longer scan still covers the member, just
    with more masked steps); exhausting the top bucket raises, which the
    4-sigma headroom in ``bucket_capacities`` makes effectively impossible.
    """
    sizes = np.asarray(sizes)
    if sizes.size and int(sizes.max()) > boundaries[-1]:
        raise ValueError(f"size {int(sizes.max())} exceeds the top boundary "
                         f"{boundaries[-1]}")
    want = np.searchsorted(np.asarray(boundaries), sizes, side="left")
    idx_out, val_out = [], []
    carry = np.zeros(0, np.int64)
    for b, cap in enumerate(capacities):
        members = np.concatenate([carry, np.flatnonzero(want == b)])
        take, carry = members[:cap], members[cap:]
        idx = np.full(cap, -1, np.int64)
        idx[: take.shape[0]] = take
        idx_out.append(idx)
        val_out.append(idx >= 0)
    if carry.size:
        raise RuntimeError(
            f"bucket capacities exhausted: {carry.size} cohort member(s) "
            "unplaced — raise bucket_capacities slack")
    return CohortBuckets(tuple(boundaries), tuple(idx_out), tuple(val_out))


# ---------------------------------------------------------------------------
# the population law
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ClientSpecBatch:
    """Realized spec of a batch of clients (all derived, nothing stored)."""
    ids: np.ndarray            # (n,) population ids
    class_ids: np.ndarray      # (n,) index into Population.classes
    targets: np.ndarray        # (n, dim) float32 local optima x_i*
    flix_alpha: np.ndarray     # (n,) float32 Scafflix personalization mix
    n_samples: np.ndarray      # (n,) int32 local dataset size


@dataclass(frozen=True)
class Population:
    """The law of a client population; every field is O(1) in n_clients.

    Per-client data follows the dissertation's S2 skew: client i's class
    mixture is Dirichlet(alpha) (``dirichlet_mixtures``), its local optimum
    the mixture-weighted combination of shared class prototypes — alpha ->
    inf gives IID clients (all targets at the prototype mean), alpha -> 0
    one-class clients.  FLIX personalization mixes and local dataset sizes
    are uniform in their ranges; link classes follow ``classes`` weights.
    """
    n_clients: int
    dim: int = 32
    n_classes: int = 10
    alpha: float = 0.3
    tree: str = "edge_fl_tree"
    classes: Tuple[LinkClass, ...] = ()
    seed: int = 0
    samples_min: int = 8
    samples_max: int = 64
    flix_min: float = 0.25
    flix_max: float = 1.0

    def __post_init__(self):
        if self.n_clients < 1 or self.dim < 1 or self.n_classes < 1:
            raise ValueError("n_clients, dim, n_classes must be >= 1")
        if not 1 <= self.samples_min <= self.samples_max:
            raise ValueError(f"bad sample range [{self.samples_min}, "
                             f"{self.samples_max}]")
        if not 0.0 <= self.flix_min <= self.flix_max <= 1.0:
            raise ValueError(f"flix range [{self.flix_min}, {self.flix_max}] "
                             "outside [0, 1]")
        if not self.classes:
            object.__setattr__(
                self, "classes",
                link_classes_from_tree(get_tree_topology(self.tree)))
        w = sum(lc.weight for lc in self.classes)
        if not np.isclose(w, 1.0):
            raise ValueError(f"class weights sum to {w}, expected 1")

    # -- lane-addressed derivations (pure in (spec, client_id)) --------------
    def _ids(self, ids) -> np.ndarray:
        if np.ndim(ids) == 0:
            ids = np.arange(int(ids))
        ids = np.asarray(ids, np.int64)
        if ids.size and (ids.min() < 0 or ids.max() >= self.n_clients):
            raise ValueError(f"client ids outside [0, {self.n_clients})")
        return ids

    def link_class_ids(self, ids) -> np.ndarray:
        ids = self._ids(ids)
        u = counter_uniform(self.seed, 0, "pop/class", ids.shape[0], lane=ids)
        cum = np.cumsum([lc.weight for lc in self.classes])
        cum[-1] = 1.0  # guard float roundoff at the top edge
        return np.searchsorted(cum, u, side="right").astype(np.int32)

    def prototypes(self) -> np.ndarray:
        """Shared (n_classes, dim) class prototypes — the only population-
        level tensor, and it is O(classes), not O(clients)."""
        z = counter_normal(self.seed, 0, "pop/proto",
                           self.n_classes * self.dim)
        return (z.reshape(self.n_classes, self.dim)
                / np.sqrt(self.dim)).astype(np.float32)

    def mixtures(self, ids) -> np.ndarray:
        return dirichlet_mixtures(self._ids(ids), self.n_classes, self.alpha,
                                  seed=self.seed)

    def targets(self, ids) -> np.ndarray:
        """Per-client local optimum: mixture-weighted prototype blend."""
        return (self.mixtures(ids) @ self.prototypes()).astype(np.float32)

    def flix_alpha(self, ids) -> np.ndarray:
        ids = self._ids(ids)
        u = counter_uniform(self.seed, 0, "pop/flix", ids.shape[0], lane=ids)
        return (self.flix_min
                + u * (self.flix_max - self.flix_min)).astype(np.float32)

    def n_samples(self, ids) -> np.ndarray:
        ids = self._ids(ids)
        u = counter_uniform(self.seed, 0, "pop/m", ids.shape[0], lane=ids)
        span = self.samples_max - self.samples_min + 1
        return (self.samples_min
                + np.minimum((u * span).astype(np.int64), span - 1)
                ).astype(np.int32)

    def client_spec(self, ids) -> ClientSpecBatch:
        ids = self._ids(ids)
        return ClientSpecBatch(
            ids=ids,
            class_ids=self.link_class_ids(ids),
            targets=self.targets(ids),
            flix_alpha=self.flix_alpha(ids),
            n_samples=self.n_samples(ids),
        )

    def class_mix_counts(self, ids) -> np.ndarray:
        """(n_link_classes,) realized class occupancy of ``ids``."""
        return np.bincount(self.link_class_ids(ids),
                           minlength=len(self.classes))
