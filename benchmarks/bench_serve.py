"""Personalized serving-plane benchmark: base + paged compressed deltas.

Sweeps users x pool-size x compressor through ``repro.serve`` and pins the
three properties the serving plane exists for:

* **bitwise identity** — a batch where every slot applies its own user's
  compressed delta from the pool decodes logits bit-for-bit equal to serving
  each user's fully materialized personalized params through the same traced
  forward (``bitident_*`` rows, asserted at prefill and every decode step);
* **O(delta) residency** — per-user resident device cost is the user's
  nonzero delta blocks, not a model copy: constant across a 10x user sweep
  and orders of magnitude below the model bytes (``resident_o_delta`` row,
  asserted);
* **exact page accounting** — a pool miss charges exactly the wire payload's
  ``nbytes`` to the ledger under ``serve/page_in``; a hit charges zero; an
  eviction brings the next acquire back as a full-price miss
  (``pool_hit_miss`` row, asserted).

Byte columns are deterministic (seeded keys, deterministic LRU), so the
committed baseline pins them at 0% drift tolerance like every other bench.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import device_live_bytes, host_peak_rss_mb, timed
from repro.comm import PAGE_IN_TAG
from repro.configs import get_config
from repro.core.compressors import make_compressor
from repro.models import init_params
from repro.serve import (BlockPool, DeltaServeEngine, DeltaStore,
                         PersonalizedBatcher, personalize_leaves)
from repro.training.serving import Request

BLOCK = 4096
ARCH = "h2o-danube-1.8b"

COMPRESSORS = {
    "topk": lambda: make_compressor("top_k", k_frac=0.01),
    "qsgd8": lambda: make_compressor("qsgd", bits=8),
}


def _smoke() -> bool:
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def _base():
    cfg = get_config(ARCH).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _store(params, comp_name: str, n_users: int,
           match=("norm",), scale: float = 0.05) -> DeltaStore:
    store = DeltaStore(params, COMPRESSORS[comp_name](), block_size=BLOCK,
                       seed=7)
    key = jax.random.PRNGKey(1)
    for uid in range(n_users):
        store.put(uid, personalize_leaves(params, jax.random.fold_in(key, uid),
                                          match=match, scale=scale))
    return store


def _page_in_bytes(store: DeltaStore) -> int:
    return store.ledger.bytes_by_tag().get(PAGE_IN_TAG, 0)


# ---------------------------------------------------------------------------
# bitwise identity: delta-applied engine == materialized personalized params
# ---------------------------------------------------------------------------
def _bitident_rows(cfg, params):
    rows = []
    n_users, steps = 3, 4
    for comp_name in ("topk", "qsgd8"):
        store = _store(params, comp_name, n_users)
        pool = BlockPool(store, capacity_blocks=64)
        eng = DeltaServeEngine(cfg, store, max_len=32)
        tables = np.stack([pool.acquire(u).table for u in range(n_users)])
        toks = np.arange(1, 1 + n_users * 5, dtype=np.int32).reshape(n_users, 5)

        logits, cache = eng.prefill(pool, tables, toks)
        eff = eng.eff_blocks_for(
            [store.personalized_params(u) for u in range(n_users)])
        lm, cm = eng.prefill_materialized(eff, toks)
        assert np.asarray(logits).tobytes() == np.asarray(lm).tobytes(), \
            (comp_name, "prefill")
        tok = np.asarray(jnp.argmax(logits[:, -1, :cfg.vocab_size],
                                    -1))[:, None].astype(np.int32)
        for s in range(steps):
            logits, cache = eng.decode(pool, tables, tok, cache)
            lm, cm = eng.decode_materialized(eff, tok, cm)
            assert np.asarray(logits).tobytes() == np.asarray(lm).tobytes(), \
                (comp_name, "decode", s)
            tok = np.asarray(jnp.argmax(logits[:, -1, :cfg.vocab_size],
                                        -1))[:, None].astype(np.int32)

        def one_decode(eng=eng, pool=pool, tables=tables, tok=tok,
                       cache=cache):
            out, _ = eng.decode(pool, tables, tok, cache)
            jax.block_until_ready(out)

        us = timed(one_decode, repeats=3, warmup=1)
        rows.append((f"serve/bitident_{comp_name}", us,
                     f"bytes={store.total_payload_bytes()};users={n_users};"
                     f"steps=1+{steps};bitwise=True"))
    return rows


# ---------------------------------------------------------------------------
# residency: O(delta blocks) per user, not O(model), across a 10x user sweep
# ---------------------------------------------------------------------------
def _measure_residency(params, n_users: int):
    """(blocks-per-user, device-bytes-delta) for paging ``n_users`` into a
    right-sized pool.  Scoped as a function so each sweep point's arrays die
    before the next measurement (gc first: live-array diffs must not see
    frees from a previous point)."""
    import gc

    store = _store(params, "topk", n_users)
    probe = BlockPool(store, capacity_blocks=store.layout.n_buckets)
    bpu = probe.acquire(0).n_blocks       # nonzero delta blocks per user
    del probe
    gc.collect()
    before = device_live_bytes()
    pool = BlockPool(store, capacity_blocks=n_users * bpu)
    for u in range(n_users):
        pool.acquire(u)
    jax.block_until_ready(pool.blocks)
    dev = device_live_bytes() - before
    assert pool.resident_blocks == n_users * bpu, \
        (pool.resident_blocks, n_users, bpu)
    return bpu, dev


def _residency_rows(cfg, params):
    model_bytes = 4 * sum(int(np.prod(l.shape))
                          for l in jax.tree_util.tree_leaves(params))
    sweep = (4, 40)                       # the asserted 10x user sweep
    per_user_blocks, per_user_dev = [], []
    for n_users in sweep:
        bpu, dev = _measure_residency(params, n_users)
        per_user_blocks.append(bpu)
        per_user_dev.append(dev / n_users)
    # per-user residency is constant in the number of users ...
    assert per_user_blocks[0] == per_user_blocks[1], per_user_blocks
    analytic = per_user_blocks[0] * BLOCK * 4
    # ... matches the analytic nonzero-block cost (the +1 shared zero row
    # amortizes across users) ...
    for dev_pu, n_users in zip(per_user_dev, sweep):
        assert abs(dev_pu - analytic) <= 2 * BLOCK * 4 / n_users + 1024, \
            (dev_pu, analytic)
    # ... and is far below a per-user model copy
    assert analytic * 10 < model_bytes, (analytic, model_bytes)
    return [
        ("serve/resident_o_delta", 0.0,
         f"bytes={analytic};model_bytes={model_bytes};"
         f"users_sweep={sweep[0]}->{sweep[1]};blocks_per_user="
         f"{per_user_blocks[0]};copy_ratio={model_bytes / analytic:.1f};"
         f"peak_rss_mb={host_peak_rss_mb():.0f}")]


# ---------------------------------------------------------------------------
# page accounting: miss == payload.nbytes, hit == 0, evict -> full-price miss
# ---------------------------------------------------------------------------
def _pool_rows(cfg, params):
    store = _store(params, "topk", 3)
    bpu = BlockPool(store, capacity_blocks=64).acquire(0).n_blocks
    pool = BlockPool(store, capacity_blocks=2 * bpu)   # two users fit

    b0 = _page_in_bytes(store)
    pool.acquire(0)                                    # miss
    miss_cost = _page_in_bytes(store) - b0
    assert miss_cost == store.nbytes(0), (miss_cost, store.nbytes(0))
    pool.release(0)

    b1 = _page_in_bytes(store)
    pool.acquire(0)                                    # hit
    assert _page_in_bytes(store) - b1 == 0
    pool.release(0)

    pool.acquire(1); pool.release(1)
    pool.acquire(2); pool.release(2)                   # evicts user 0
    assert pool.evictions >= 1 and not pool.is_resident(0)
    b2 = _page_in_bytes(store)
    pool.acquire(2)                                    # still resident: hit
    assert _page_in_bytes(store) - b2 == 0
    b3 = _page_in_bytes(store)
    pool.acquire(0)                                    # evicted: full miss
    assert _page_in_bytes(store) - b3 == store.nbytes(0)
    return [
        ("serve/pool_hit_miss", 0.0,
         f"bytes={_page_in_bytes(store)};hits={pool.hits};"
         f"misses={pool.misses};evictions={pool.evictions};exact=True")]


# ---------------------------------------------------------------------------
# sweep: users x pool-size x compressor through the continuous batcher
# ---------------------------------------------------------------------------
def _sweep_rows(cfg, params, smoke: bool):
    grid = [(6, "fit", "topk")]
    if not smoke:
        grid += [(6, "tight", "topk"), (12, "fit", "qsgd8"),
                 (12, "tight", "qsgd8")]
    rows = []
    for n_users, sizing, comp_name in grid:
        store = _store(params, comp_name, n_users)
        bpu = BlockPool(store, capacity_blocks=store.layout.n_buckets) \
            .acquire(0).n_blocks
        cap = n_users * bpu if sizing == "fit" else max(2, n_users // 2) * bpu
        pool = BlockPool(store, capacity_blocks=cap)
        b = PersonalizedBatcher(cfg, store, pool, n_slots=2, max_len=64)
        for rid in range(2 * n_users):
            b.submit(Request(rid=rid, prompt=np.array([3, 4, 5], np.int32),
                             max_new=4, user_id=rid % n_users))
        t_us = timed(lambda: b.run(max_ticks=500), repeats=1, warmup=0)
        assert b.stats.completed == 2 * n_users
        # one jitted decode serves every user: no per-user recompile
        sizes = b.engine.compile_cache_sizes()
        assert sizes["decode"] == 1, sizes
        hit_rate = pool.hits / max(1, pool.hits + pool.misses)
        rows.append(
            (f"serve/sweep_u{n_users}_{sizing}_{comp_name}", t_us,
             f"bytes={pool.bytes_paged_in};hits={pool.hits};"
             f"misses={pool.misses};evictions={pool.evictions};"
             f"hit_rate={hit_rate:.2f};tokens={b.stats.tokens_out};"
             f"pool_blocks={cap}"))
    return rows


def run(smoke: bool = False):
    smoke = smoke or _smoke()
    cfg, params = _base()
    rows = []
    rows += _bitident_rows(cfg, params)
    rows += _residency_rows(cfg, params)
    rows += _pool_rows(cfg, params)
    rows += _sweep_rows(cfg, params, smoke)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
