"""Aggregation trees: TreeTopology, the multi-level anchor cascade, and
per-level round-cost/ledger attribution (Cohort-Squeeze beyond two levels)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (Link, TreeLevel, TreeTopology, get_topology,
                        get_tree_topology, register_tree_topology,
                        round_cost, round_ledger)
from repro.configs.base import LevelConfig, SyncConfig, TrainConfig
from repro.core import compressors as C
from repro.core import distributed as dist


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------
def test_tree_presets_shapes():
    tree = get_tree_topology("edge_fl_tree")
    assert tree.depth == 3
    assert tree.n_leaves == 100  # 5 phones x 5 cells x 4 regions
    assert tree.n_leaves == get_topology("edge_fl").n_devices
    assert tree.n_parents(0) == 20 and tree.n_parents(2) == 1
    assert tree.level("uplink").fanout == 5
    with pytest.raises(KeyError):
        tree.level("nope")


def test_tree_from_flat_is_depth2_special_case():
    topo = get_topology("v5p_superpod")
    tree = get_tree_topology("v5p_superpod")  # flat name -> depth-2 lift
    assert tree.depth == 2
    assert tree.levels[0].fanout == topo.devices_per_pod
    assert tree.levels[1].fanout == topo.n_pods
    assert tree.n_leaves == topo.n_devices
    nb = 1 << 20
    # level timing is the flat preset's ring model, bit for bit
    assert tree.ring_time_s(0, nb) == topo.allreduce_time_s(nb, "intra")
    assert tree.ring_time_s(1, nb) == topo.allreduce_time_s(nb, "inter")
    assert tree.level_serial_time_s(1, nb) == \
        topo.allreduce_serial_time_s(nb, "inter")
    assert tree.level_stream_time_s(1, nb) == \
        topo.allreduce_stream_time_s(nb, "inter")


def test_register_tree_topology():
    t = register_tree_topology(TreeTopology("tiny_tree_t1", (
        TreeLevel("a", 2, Link(gbps=1.0, latency_us=1.0)),
        TreeLevel("b", 3, Link(gbps=0.5, latency_us=10.0)),
    )))
    assert get_tree_topology("tiny_tree_t1") is t
    assert t.n_leaves == 6


# ---------------------------------------------------------------------------
# cascade: depth-2 reproduces hier_param_sync bit-for-bit
# ---------------------------------------------------------------------------
def _rand_tree(key, G):
    kw, kb = jax.random.split(key)
    return {"w": jax.random.normal(kw, (G, 6)),
            "b": jax.random.normal(kb, (G, 3))}


def _zero_like(tree):
    return jax.tree_util.tree_map(lambda l: jnp.zeros(l.shape[1:]), tree)


@pytest.mark.parametrize("comp,period,bucket", [
    (C.qsgd(8, 4), 1, None),          # stochastic, fused path
    (C.top_k(0.4), 2, None),          # deterministic, fused path
    (C.qsgd_sharded(8, 3), 2, None),  # flatten=False -> per-leaf path
    (C.qsgd(8, 4), 4, 0),             # legacy per-leaf path
], ids=["qsgd-fused", "topk-fused", "sharded-leaves", "qsgd-bucket0"])
def test_cascade_depth2_reproduces_hier_bitwise(comp, period, bucket):
    """Property (acceptance): a depth-2 [intra=identity/1, inter=C/p] cascade
    over the device leaves produces, for one full inter period from fresh
    anchors, exactly the outputs of today's hier_param_sync over the pod
    means — bit for bit, on both the fused and the per-leaf paths."""
    f, n_pods = 2, 3
    G = f * n_pods
    leaves = _rand_tree(jax.random.PRNGKey(0), G)
    lam = (C.lambda_star(comp.eta, comp.omega)
           if comp.eta is not None and comp.omega is not None else 1.0)
    levels = (dist.CascadeLevel("intra", C.identity(), 1.0, 1, f),
              dist.CascadeLevel("inter", comp, lam, period, n_pods))
    tstate = dist.tree_sync_state_init(_zero_like(leaves), levels)

    pod_means = jax.tree_util.tree_map(
        lambda l: jnp.mean(l.reshape((n_pods, f) + l.shape[1:]), axis=1),
        leaves)
    hstate = dist.SyncState(h=(), h_bar=_zero_like(leaves),
                            step=jnp.zeros((), jnp.int32))

    p_tree, p_hier = leaves, pod_means
    for t in range(period):
        key = jax.random.PRNGKey(100 + t)
        p_tree, tstate = dist.tree_param_sync(key, p_tree, tstate, levels,
                                              bucket_size=bucket)
        p_hier, hstate = dist.hier_param_sync(key, p_hier, hstate, comp, lam,
                                              period, bucket_size=bucket)
    for a, b in zip(jax.tree_util.tree_leaves(p_tree),
                    jax.tree_util.tree_leaves(p_hier)):
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(jnp.repeat(b, f, axis=0)))
    for a, b in zip(jax.tree_util.tree_leaves(tstate.anchors[-1]),
                    jax.tree_util.tree_leaves(hstate.h_bar)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(tstate.step) == int(hstate.step) == period


def test_cascade_intermediate_level_syncs_alone():
    """Between root syncs the leaf level still aggregates: leaves adopt their
    pod anchor (the pod mean) while the root anchor stays untouched."""
    f, n_pods = 2, 2
    leaves = _rand_tree(jax.random.PRNGKey(3), f * n_pods)
    levels = (dist.CascadeLevel("intra", C.identity(), 1.0, 1, f),
              dist.CascadeLevel("inter", C.identity(), 1.0, 4, n_pods))
    tstate = dist.tree_sync_state_init(_zero_like(leaves), levels)
    new_p, ts = dist.tree_param_sync(jax.random.PRNGKey(4), leaves, tstate,
                                     levels)
    pod_means = jax.tree_util.tree_map(
        lambda l: jnp.mean(l.reshape((n_pods, f) + l.shape[1:]), axis=1),
        leaves)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray(jnp.repeat(pod_means["w"], f, axis=0)),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ts.anchors[0]["w"]),
                                  np.asarray(pod_means["w"]))
    # root anchor untouched on an intermediate-only step
    np.testing.assert_array_equal(np.asarray(ts.anchors[1]["w"]),
                                  np.zeros((6,), np.float32))


def test_cascade_full_sync_adopts_root_everywhere():
    f, n_pods = 2, 2
    leaves = _rand_tree(jax.random.PRNGKey(5), f * n_pods)
    levels = (dist.CascadeLevel("intra", C.identity(), 1.0, 1, f),
              dist.CascadeLevel("inter", C.identity(), 1.0, 1, n_pods))
    tstate = dist.tree_sync_state_init(_zero_like(leaves), levels)
    new_p, ts = dist.tree_param_sync(jax.random.PRNGKey(6), leaves, tstate,
                                     levels)
    mean = jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), leaves)
    # everyone — leaves, pod anchors, root — holds the global mean
    np.testing.assert_allclose(np.asarray(new_p["w"][0]),
                               np.asarray(mean["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ts.anchors[0]["w"][1]),
                               np.asarray(mean["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ts.anchors[1]["w"]),
                               np.asarray(mean["w"]), rtol=1e-6)


def test_cascade_rejects_non_nested_periods_and_bad_fanout():
    leaves = _rand_tree(jax.random.PRNGKey(7), 4)
    levels = (dist.CascadeLevel("a", C.identity(), 1.0, 2, 2),
              dist.CascadeLevel("b", C.identity(), 1.0, 3, 2))
    st = dist.tree_sync_state_init(_zero_like(leaves), levels)
    with pytest.raises(ValueError, match="nested"):
        dist.tree_param_sync(jax.random.PRNGKey(0), leaves, st, levels)
    ok = (dist.CascadeLevel("a", C.identity(), 1.0, 2, 2),
          dist.CascadeLevel("b", C.identity(), 1.0, 4, 3))  # 6 leaves != 4
    st = dist.tree_sync_state_init(_zero_like(leaves), ok)
    with pytest.raises(ValueError, match="fanout"):
        dist.tree_param_sync(jax.random.PRNGKey(0), leaves, st, ok)


def test_build_cascade_from_config():
    sc = SyncConfig(mode="hier", topology="edge_fl_tree", levels=(
        LevelConfig("uplink", 2, "top_k", 0.1),
        LevelConfig("metro", 4, "qsgd", quant_bits=8),
        LevelConfig("wan", 8, "top_k", 0.02)))
    cascade = dist.build_cascade(sc)
    assert [lev.fanout for lev in cascade] == [5, 5, 4]
    assert [lev.period for lev in cascade] == [2, 4, 8]
    assert cascade[0].compressor.name.startswith("top_k")
    bad = SyncConfig(mode="hier", topology="edge_fl_tree", levels=(
        LevelConfig("uplink", 2), LevelConfig("metro", 3),
        LevelConfig("wan", 6)))
    with pytest.raises(ValueError, match="nested"):
        dist.build_cascade(bad)
    mismatched = SyncConfig(mode="hier", topology="edge_fl_tree",
                            levels=(LevelConfig("uplink", 1),))
    with pytest.raises(ValueError, match="levels"):
        dist.build_cascade(mismatched)


# ---------------------------------------------------------------------------
# accounting: per-level attribution
# ---------------------------------------------------------------------------
def _tree_sync(period=4):
    return SyncConfig(mode="hier", topology="edge_fl_tree", levels=(
        LevelConfig("uplink", period, "top_k", 0.05),
        LevelConfig("metro", 2 * period, "qsgd", quant_bits=8),
        LevelConfig("wan", 4 * period, "top_k", 0.01)))


def test_round_cost_depth2_matches_flat_hier_bitwise():
    """Acceptance: the depth-2 levels config reproduces flat hier exactly."""
    n = 100_000
    for preset in ("v5p_superpod", "geo_wan", "edge_fl"):
        flat = round_cost(SyncConfig(mode="hier", compressor="qsgd",
                                     quant_bits=8, sync_period=8,
                                     topology=preset), n)
        d2 = round_cost(SyncConfig(mode="hier", topology=preset, levels=(
            LevelConfig("intra", 1, "identity"),
            LevelConfig("inter", 8, "qsgd", quant_bits=8))), n)
        for f in ("intra_bytes", "inter_bytes", "time_s", "serial_time_s",
                  "encoded_bits", "analytic_bits", "tile_bytes"):
            assert getattr(d2, f) == getattr(flat, f), (preset, f)
        assert len(flat.levels) == len(d2.levels) == 2


def test_round_cost_levels_sum_to_total_bytes():
    cost = round_cost(_tree_sync(), 50_000)
    assert len(cost.levels) == 3
    total = sum(lv.bytes_per_round for lv in cost.levels)
    assert total == pytest.approx(cost.total_bytes)
    assert cost.intra_bytes == cost.levels[0].bytes_per_round
    assert cost.inter_bytes == pytest.approx(
        sum(lv.bytes_per_round for lv in cost.levels[1:]))
    # times add across levels too
    assert cost.serial_time_s == pytest.approx(
        sum(lv.serial_time_s for lv in cost.levels))
    assert cost.time_s <= cost.serial_time_s  # streaming never hurts


def test_round_ledger_tags_levels_and_sums():
    """Acceptance: per-level ledger bytes sum to RoundCost.total_bytes."""
    sync = _tree_sync(period=2)
    n = 30_000
    cost = round_cost(sync, n)
    led = round_ledger(sync, n)
    assert led.n_rounds() == 8  # one full root period
    by_tag = led.bytes_by_tag()
    assert set(by_tag) == {"uplink", "metro", "wan"}
    assert sum(by_tag.values()) == led.total_bytes
    # amortized per round, the tagged records reproduce the RoundCost total
    assert led.total_bytes / led.n_rounds() == pytest.approx(
        cost.total_bytes, rel=1e-6)
    # each level's amortized share matches its LevelCost
    for lv in cost.levels:
        assert by_tag[lv.name] / led.n_rounds() == pytest.approx(
            lv.bytes_per_round, rel=1e-6)


def test_edge_fl_tree_beats_flat_hier():
    """Acceptance: >=3-level tree with per-level compression strictly reduces
    slow-link bytes AND simulated round time vs flat hier at equal periods."""
    n = 200_000
    flat = round_cost(SyncConfig(mode="hier", compressor="qsgd", quant_bits=8,
                                 sync_period=8, topology="edge_fl"), n)
    tree = round_cost(SyncConfig(mode="hier", topology="edge_fl_tree", levels=(
        LevelConfig("uplink", 8, "top_k", 0.05),
        LevelConfig("metro", 16, "qsgd", quant_bits=8),
        LevelConfig("wan", 32, "top_k", 0.01))), n)
    slow_gbps = get_topology("edge_fl").inter.gbps
    slow_tree = sum(lv.bytes_per_round for lv in tree.levels
                    if lv.link_gbps <= slow_gbps)
    assert slow_tree < flat.inter_bytes
    assert tree.time_s < flat.time_s


# ---------------------------------------------------------------------------
# training-step wiring
# ---------------------------------------------------------------------------
def test_tree_training_smoke():
    from repro.configs import get_config
    from repro.data.synthetic import SyntheticLMDataset, lm_batch_iterator
    from repro.training.loop import train

    register_tree_topology(TreeTopology("tiny_tree_2x2", (
        TreeLevel("edge", 2, Link(gbps=1.0, latency_us=10.0)),
        TreeLevel("wan", 2, Link(gbps=0.1, latency_us=1000.0)),
    )))
    cfg = get_config("h2o-danube-1.8b").reduced()
    sync = SyncConfig(mode="hier", topology="tiny_tree_2x2", levels=(
        LevelConfig("edge", 1, "identity"),
        LevelConfig("wan", 2, "qsgd", quant_bits=8)))
    tc = TrainConfig(model=cfg, seq_len=32, global_batch=4, lr=3e-3,
                     warmup_steps=2, total_steps=6, sync=sync)
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, length=2000, seed=0)
    _, hist = train(cfg, tc, lm_batch_iterator(ds, 4, 32, seed=1),
                    steps=6, log_every=1000)
    assert np.isfinite([h["loss"] for h in hist]).all()
