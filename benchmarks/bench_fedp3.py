"""Ch. 4 reproductions:
  Fig 4.2 — layer-overlap strategies (LowerB / OPU2 / OPU3 / full) accuracy vs
            upload bytes on class-wise (S1) and Dirichlet (S2) non-IID splits
  Fig 4.4 — global pruning ratio sweep
  Tab 4.2 — local pruning strategies (fixed / uniform / ordered dropout)
Derived: final accuracy + relative upload cost.

Upload accounting rides the CommLedger: fedp3_train's per-round uploaded
floats become per-round inter-link byte records (4 bytes each, the dense fp32
wire format the clients actually ship), so the relative-upload column and the
absolute MB both come from the ledger, not a separate counter."""
from __future__ import annotations


import numpy as np

from benchmarks.common import emit, now_s
from repro.comm import UPLOAD_TAG, CommLedger
from repro.core.fedp3 import FedP3Config, fedp3_train, make_classification
from repro.data.federated import classwise_split, dirichlet_split

ROUNDS = 25
SIZES = [24, 64, 64, 48, 6]  # 4 dense layers (EMNIST-L style)


def _data(split):
    X, y = make_classification(n=2400, d=24, nclass=6, seed=0)
    Xte, yte = make_classification(n=600, d=24, nclass=6, seed=1)
    if split == "S1":
        idx = classwise_split(y, 10, classes_per_client=2, seed=0)
    else:
        idx = dirichlet_split(y, 10, alpha=0.5, seed=0)
    return [X[i] for i in idx], [y[i] for i in idx], Xte, yte


def _upload_ledger(up_trace) -> CommLedger:
    """Per-round uploaded floats -> per-round inter-link byte records."""
    led = CommLedger()
    prev = 0.0
    for t, cum_floats in enumerate(np.asarray(up_trace)):
        led.record(t, "clients->server", (cum_floats - prev) * 4, kind="inter",
                   tag=UPLOAD_TAG)
        prev = cum_floats
    return led


def run():
    rows = []
    # --- Fig 4.2: layer overlap
    for split in ("S1", "S2"):
        Xs, Ys, Xte, Yte = _data(split)
        full_bytes = None
        for name, k in (("full", 4), ("OPU3", 3), ("OPU2", 2), ("LowerB", 1)):
            cfg = FedP3Config(n_clients=10, clients_per_round=5, layers_per_client=k,
                              global_prune_ratio=0.9, local_steps=4, lr=0.2, seed=0)
            t0 = now_s()
            acc, up, _ = fedp3_train(cfg, Xs, Ys, SIZES, ROUNDS, Xte, Yte)
            us = (now_s() - t0) * 1e6
            led = _upload_ledger(up)
            if full_bytes is None:
                full_bytes = led.total_bytes
            rows.append((f"fedp3_fig4.2/{split}/{name}", us,
                         f"acc={acc[-1]:.3f};upload_rel={led.total_bytes/full_bytes:.2f};"
                         f"upload_kb={led.total_bytes/1e3:.1f}"))

    # --- Fig 4.4: global pruning ratio
    Xs, Ys, Xte, Yte = _data("S2")
    for r in (1.0, 0.9, 0.7, 0.5):
        cfg = FedP3Config(n_clients=10, clients_per_round=5, layers_per_client=3,
                          global_prune_ratio=r, local_steps=4, lr=0.2, seed=0)
        t0 = now_s()
        acc, _, _ = fedp3_train(cfg, Xs, Ys, SIZES, ROUNDS, Xte, Yte)
        us = (now_s() - t0) * 1e6
        rows.append((f"fedp3_fig4.4/prune={r}", us, f"acc={acc[-1]:.3f}"))

    # --- Tab 4.2: local pruning strategies
    for strat in ("fixed", "uniform", "ordered_dropout"):
        cfg = FedP3Config(n_clients=10, clients_per_round=5, layers_per_client=3,
                          global_prune_ratio=0.9, local_strategy=strat,
                          local_steps=4, lr=0.2, seed=0)
        t0 = now_s()
        acc, _, _ = fedp3_train(cfg, Xs, Ys, SIZES, ROUNDS, Xte, Yte)
        us = (now_s() - t0) * 1e6
        rows.append((f"fedp3_tab4.2/{strat}", us, f"acc={acc[-1]:.3f}"))

    # --- Fig 4.5: aggregation strategies
    for agg in ("simple", "weighted"):
        cfg = FedP3Config(n_clients=10, clients_per_round=5, layers_per_client=3,
                          aggregation=agg, local_steps=4, lr=0.2, seed=0)
        t0 = now_s()
        acc, _, _ = fedp3_train(cfg, Xs, Ys, SIZES, ROUNDS, Xte, Yte)
        us = (now_s() - t0) * 1e6
        rows.append((f"fedp3_fig4.5/{agg}", us, f"acc={acc[-1]:.3f}"))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
