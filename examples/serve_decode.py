"""End-to-end serving driver: batched prefill + decode with request batching.

A small continuous-batching server loop over the reduced config of any
assigned architecture: requests arrive with different prompt lengths, get
left-padded into a batch, prefilled once, then decoded step-by-step with
per-request stop handling. Demonstrates the serve path the decode_32k /
long_500k dry-run shapes lower at production scale.

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-2.7b
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # requests with ragged prompt lengths -> right-aligned into one batch
    lens = rng.integers(8, 24, size=args.batch)
    maxlen = int(lens.max())
    prompts = np.zeros((args.batch, maxlen), np.int32)
    for i, L in enumerate(lens):
        prompts[i, maxlen - L:] = rng.integers(1, cfg.vocab_size, size=L)
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.enc_layers:
        batch["src_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, 16, cfg.enc_d_model))
    if cfg.vision_tokens:
        batch["vision_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.vision_tokens, cfg.d_model))

    t0 = time.time()
    prefill_fn = jax.jit(lambda p, b: prefill(p, cfg, b, cache_len=maxlen + args.gen + 1))
    logits, cache = prefill_fn(params, batch)
    print(f"prefill {args.batch}x{maxlen} in {time.time()-t0:.2f}s")

    step_fn = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    outs = [[] for _ in range(args.batch)]
    done = np.zeros(args.batch, bool)
    t0 = time.time()
    for step in range(args.gen):
        logits, cache = step_fn(params, tok, cache)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
        for i in range(args.batch):
            t = int(tok[i, 0])
            if not done[i]:
                outs[i].append(t)
                if t == 0:  # token 0 as stop
                    done[i] = True
        if done.all():
            break
    dt = time.time() - t0
    total = sum(len(o) for o in outs)
    print(f"decoded {total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s on CPU)")
    for i, o in enumerate(outs):
        print(f"  req{i} (prompt {lens[i]}): {o[:12]}{'...' if len(o) > 12 else ''}")


if __name__ == "__main__":
    main()
