"""Diff freshly-measured BENCH_*.json against the committed baselines.

The committed files under ``benchmarks/baselines/`` pin the comm / hier
benchmark trajectory (row names, payload bytes, wall-time order of
magnitude).  This check fails when:

* a baseline row is missing from the current run (a bench silently dropped);
* a row's ``bytes`` drifts beyond ``--bytes-tol`` (default 2% — encoded
  payload sizes are deterministic, so any drift is a codec change and must
  be re-baselined deliberately);
* a row's wall-time exceeds ``--time-ratio`` x the baseline (default 25x —
  generous, because CI machines vary; it catches accidental O(n) -> O(n^2)
  cliffs, not noise).

Usage (CI runs the no-argument form after ``BENCH_SMOKE=1`` benches)::

    python -m benchmarks.check_regression                # cwd vs baselines/
    python -m benchmarks.check_regression CUR.json BASE.json [--bytes-tol ..]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Tuple

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")
DEFAULT_PAIRS = (("BENCH_comm.json", "BENCH_comm.json"),
                 ("BENCH_hier.json", "BENCH_hier.json"),
                 ("BENCH_faults.json", "BENCH_faults.json"),
                 ("BENCH_cohort.json", "BENCH_cohort.json"),
                 ("BENCH_serve.json", "BENCH_serve.json"))


def load_rows(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: r for r in doc["rows"]}


def diff(current: dict, baseline: dict, bytes_tol: float,
         time_ratio: float) -> Tuple[List[str], List[str]]:
    """Returns (failures, notes)."""
    failures, notes = [], []
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            failures.append(f"missing row: {name}")
            continue
        b_bytes, c_bytes = base.get("bytes"), cur.get("bytes")
        if b_bytes and c_bytes is not None:
            drift = abs(c_bytes - b_bytes) / b_bytes
            if drift > bytes_tol:
                failures.append(
                    f"bytes drift {name}: {b_bytes} -> {c_bytes} "
                    f"({drift * 100:.1f}% > {bytes_tol * 100:.1f}%)")
        b_us, c_us = base.get("us", 0.0), cur.get("us", 0.0)
        if b_us > 0 and c_us > time_ratio * b_us:
            failures.append(
                f"time cliff {name}: {b_us:.1f}us -> {c_us:.1f}us "
                f"(> {time_ratio:.0f}x baseline)")
    for name in sorted(set(current) - set(baseline)):
        notes.append(f"NEW row {name!r}: no baseline yet — not a failure; "
                     "commit the refreshed baseline file to pin it")
    return failures, notes


def check_pair(cur_path: str, base_path: str, bytes_tol: float,
               time_ratio: float) -> List[str]:
    """Returns every failure for this pair (empty list = pass), each
    carrying the baseline path so a red CI log says exactly which committed
    file to re-baseline."""
    label = os.path.basename(cur_path)
    if not os.path.exists(cur_path):
        fail = f"{label}: current file {cur_path} not found (vs {base_path})"
        print(f"FAIL {fail}")
        return [fail]
    if not os.path.exists(base_path):
        # a brand-new bench has nothing to regress against: report clearly
        # instead of crashing with a bare missing-file traceback
        n_rows = len(load_rows(cur_path))
        print(f"NEW {label}: {n_rows} row(s), no baseline at {base_path} — "
              "commit one to start pinning this bench")
        return []
    failures, notes = diff(load_rows(cur_path), load_rows(base_path),
                           bytes_tol, time_ratio)
    for n in notes:
        print(f"  note {label}: {n}")
    failures = [f"{label}: {f} [baseline: {base_path}]" for f in failures]
    for f in failures:
        print(f"  FAIL {f}")
    n_rows = len(load_rows(base_path))
    status = "FAIL" if failures else "ok"
    print(f"{status} {label}: {n_rows} baseline rows vs {base_path}, "
          f"{len(failures)} failure(s)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.check_regression", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("current", nargs="?", default=None)
    ap.add_argument("baseline", nargs="?", default=None)
    ap.add_argument("--bytes-tol", type=float, default=0.02,
                    help="relative bytes tolerance (default 0.02)")
    ap.add_argument("--time-ratio", type=float, default=25.0,
                    help="max wall-time ratio vs baseline (default 25x)")
    args = ap.parse_args(argv)

    if args.current:
        pairs = [(args.current, args.baseline or os.path.join(
            BASELINE_DIR, os.path.basename(args.current)))]
    else:
        pairs = [(cur, os.path.join(BASELINE_DIR, base))
                 for cur, base in DEFAULT_PAIRS]

    all_failures: List[str] = []
    for cur, base in pairs:
        all_failures.extend(
            check_pair(cur, base, args.bytes_tol, args.time_ratio))
    if all_failures:
        print(f"\n{len(all_failures)} failure(s) across "
              f"{len(pairs)} benchmark file(s):")
        for f in all_failures:
            print(f"  {f}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
