"""SPPM-AS (Ch. 5) and FedP3 (Ch. 4) behaviour tests."""
import numpy as np
import pytest

from repro.core.fedp3 import FedP3Config, fedp3_train, make_classification
from repro.core.sppm import (
    CohortProblem, balanced_blocks, kmeans_blocks, nice_sampling,
    prox_gd, prox_newton, prox_newton_cg, sigma_star_nice,
    sigma_star_stratified, solve_erm, sppm_as, stratified_sampling,
    _client_grads_at)
from repro.data.federated import dirichlet_split, classwise_split, make_logreg_clients


@pytest.fixture(scope="module")
def prob():
    return make_logreg_clients(n_clients=20, m=60, d=16, mu=0.1, hetero=0.4, seed=3)


@pytest.fixture(scope="module")
def x_star(prob):
    return solve_erm(prob)


def test_solve_erm_is_optimal(prob, x_star):
    cp = CohortProblem(prob.A, prob.b, np.full(prob.n_clients, 1 / prob.n_clients), prob.mu)
    assert np.linalg.norm(cp.grad(x_star)) < 1e-9


def test_prox_solvers_agree(prob, x_star):
    cp = CohortProblem(prob.A[:5], prob.b[:5], np.full(5, 1 / 5), prob.mu)
    x0 = np.ones(prob.dim)
    y_newton = prox_newton(cp, x0, gamma=1.0, K=20)
    y_gd = prox_gd(cp, x0, gamma=1.0, K=4000)
    y_cg = prox_newton_cg(cp, x0, gamma=1.0, K=16)
    assert np.linalg.norm(y_newton - y_gd) < 1e-3
    # CG solves the quadraticized prox: close but not identical
    assert np.linalg.norm(y_newton - y_cg) < 5e-2


def test_prox_decreases_moreau_objective(prob):
    cp = CohortProblem(prob.A[:4], prob.b[:4], np.full(4, 0.25), prob.mu)
    x0 = np.ones(prob.dim) * 2
    y = prox_newton(cp, x0, gamma=2.0, K=10)
    phi = lambda z: cp.value(z) + np.sum((z - x0) ** 2) / 4.0
    assert phi(y) < phi(x0)


def test_sppm_converges_to_neighborhood(prob, x_star):
    draw, p = nice_sampling(np.random.default_rng(0), prob.n_clients, 8)
    r = sppm_as(prob, x_star, draw, p, gamma=0.5, K=8, T=300, solver="newton")
    gi = _client_grads_at(prob, x_star)
    sigma2 = np.mean(np.sum(gi**2, 1))
    assert r.errors[-50:].mean() <= sigma2 / prob.mu**2  # inside theory nbhd


def test_more_local_rounds_cut_total_cost():
    """Cohort-Squeeze's claim: some K>1 reaches eps with smaller total cost
    TK than K=1 (Fig 5.1 U-curve).  Regime: eps above the cohort-sampling
    neighborhood, mild heterogeneity."""
    prob2 = make_logreg_clients(n_clients=20, m=60, d=16, mu=0.1, hetero=0.1, seed=3)
    xs = solve_erm(prob2)
    costs = {}
    for K in (1, 2, 4):
        draw, p = nice_sampling(np.random.default_rng(5), prob2.n_clients, 8)
        r = sppm_as(prob2, xs, draw, p, gamma=50.0, K=K, T=500,
                    solver="gd", eps=1e-3, c_global=0.0, seed=0)
        costs[K] = r.total_cost if r.total_cost is not None else np.inf
    assert min(costs[2], costs[4]) < costs[1]


def test_stratified_beats_nice_variance(prob, x_star):
    gi = _client_grads_at(prob, x_star)
    blocks = balanced_blocks(gi, 5)
    s_nice, s_closed = sigma_star_nice(prob, x_star, tau=5)
    s_ss = sigma_star_stratified(prob, x_star, blocks)
    assert abs(s_nice - s_closed) / s_closed < 0.3  # MC matches closed form
    assert s_ss <= s_nice * 1.05  # Lemma 5.3.4 under uniform balanced clusters


def test_samplings_are_proper(prob):
    draw, p = stratified_sampling(np.random.default_rng(0),
                                  balanced_blocks(prob.A.mean(1), 4))
    assert (p > 0).all()
    C = draw()
    assert len(C) == 4


def test_sigma_star_nice_mc_tracks_closed_form(prob, x_star):
    """sigma*^2_NICE after the dead-code removal: the MC estimate still
    tracks the closed form (n/tau-1)/(n-1)*sigma*^2(1) across tau, and the
    full sampling (tau=n) has (near-)zero variance."""
    for tau in (2, 5, 10):
        mc, closed = sigma_star_nice(prob, x_star, tau=tau, n_mc=1024, seed=1)
        assert closed > 0
        assert abs(mc - closed) / closed < 0.3
    mc_full, closed_full = sigma_star_nice(prob, x_star, tau=prob.n_clients)
    assert closed_full == 0.0
    assert mc_full < 1e-15  # grad f(x*) = 0: deterministic cohort


def test_kmeans_blocks_reseeds_empty_clusters():
    """Regression: coincident initial centers used to leave stale duplicate
    centers forever (argmin ties send every point to the lower index), so
    kmeans_blocks returned fewer blocks than requested and stratified
    sampling silently drew from fewer strata."""
    # 30 identical points at the origin + 3 distant singletons: any seed that
    # picks duplicated origin rows as centers collapses without re-seeding
    feats = np.zeros((33, 2))
    feats[30] = (10.0, 0.0)
    feats[31] = (0.0, 10.0)
    feats[32] = (-10.0, -10.0)
    for seed in range(6):
        blocks = kmeans_blocks(feats, n_blocks=4, seed=seed, iters=20)
        assert len(blocks) == 4, seed
        allidx = np.concatenate(blocks)
        assert len(allidx) == 33 and len(np.unique(allidx)) == 33
    # the re-seeded centers should isolate the far points into their own
    # clusters (farthest-point repair), keeping the partition sensible
    blocks = kmeans_blocks(feats, n_blocks=4, seed=0, iters=20)
    sizes = sorted(len(b) for b in blocks)
    assert sizes == [1, 1, 1, 30]


def test_kmeans_blocks_still_clusters_separated_data():
    rng = np.random.default_rng(0)
    feats = np.concatenate([rng.normal(loc=c, scale=0.05, size=(12, 3))
                            for c in (-5.0, 0.0, 5.0)])
    blocks = kmeans_blocks(feats, n_blocks=3, seed=1)
    assert sorted(len(b) for b in blocks) == [12, 12, 12]
    for b in blocks:
        assert np.ptp(b // 12) == 0  # each block is one ground-truth cluster


# ---------------------------------------------------------------------------
# FedP3
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def fed_data():
    X, y = make_classification(n=1500, d=24, nclass=6, seed=0)
    Xte, yte = make_classification(n=400, d=24, nclass=6, seed=1)
    idx = dirichlet_split(y, 10, alpha=0.5, seed=0)
    return [X[i] for i in idx], [y[i] for i in idx], Xte, yte


def test_fedp3_learns_and_saves_upload(fed_data):
    Xs, Ys, Xte, Yte = fed_data
    sizes = [24, 64, 64, 48, 6]
    cfg_full = FedP3Config(n_clients=10, clients_per_round=5,
                           layers_per_client=3, global_prune_ratio=1.0,
                           local_steps=4, lr=0.2, seed=0)
    acc, up, _ = fedp3_train(cfg_full, Xs, Ys, sizes, rounds=20, X_test=Xte, Y_test=Yte)
    assert acc[-1] > 0.5  # well above 1/6 chance

    cfg_opu2 = FedP3Config(n_clients=10, clients_per_round=5,
                           layers_per_client=2, global_prune_ratio=0.9,
                           local_steps=4, lr=0.2, seed=0)
    acc2, up2, _ = fedp3_train(cfg_opu2, Xs, Ys, sizes, rounds=20, X_test=Xte, Y_test=Yte)
    assert up2[-1] < up[-1]          # fewer uploaded floats
    assert acc2[-1] > 0.4            # accuracy holds up (paper's OPU claim)


def test_fedp3_ldp_noise_still_learns(fed_data):
    Xs, Ys, Xte, Yte = fed_data
    cfg = FedP3Config(n_clients=10, clients_per_round=5, layers_per_client=3,
                      ldp_sigma=0.01, local_steps=4, lr=0.2, seed=0)
    acc, _, _ = fedp3_train(cfg, Xs, Ys, [24, 64, 64, 48, 6], rounds=15,
                            X_test=Xte, Y_test=Yte)
    assert acc[-1] > 0.4


def test_splits_partition():
    _, y = make_classification(n=500, d=8, nclass=5, seed=2)
    for split in (dirichlet_split(y, 7, 0.3), classwise_split(y, 7, 2)):
        allidx = np.concatenate(split)
        assert len(np.unique(allidx)) == len(allidx)  # disjoint
        assert len(allidx) <= len(y)


def test_splits_non_contiguous_labels():
    """Regression: classwise_split indexed its per-class counters with the
    raw label VALUE — labels like {1, 3, 7} crashed (or, when they happened
    to fit, credited the wrong class and mis-allocated pools).  Both splits
    must treat labels as opaque values."""
    rng = np.random.default_rng(0)
    y = rng.choice([1, 3, 7], size=300)
    for n_clients, split in ((6, classwise_split(y, 6, 2, seed=1)),
                             (6, dirichlet_split(y, 6, 0.5, seed=1))):
        assert len(split) == n_clients
        allidx = np.concatenate([s for s in split if len(s)])
        assert len(np.unique(allidx)) == len(allidx)          # disjoint
        assert set(allidx).issubset(set(range(len(y))))
    # classwise: every client actually holds samples of exactly the classes
    # it was assigned (2 per client), and allocation is spread across clients
    # sharing a class rather than the first client draining the pool
    split = classwise_split(y, 6, 2, seed=1)
    for s in split:
        assert len(s) > 0
        assert len(np.unique(y[s])) <= 2
    # a label set far outside the class count must not crash either
    y_wide = rng.choice([10, 200, 4000], size=90)
    split = classwise_split(y_wide, 3, 2, seed=0)
    assert sum(len(s) for s in split) > 0


def test_classwise_split_shares_pools_with_nonzero_counts():
    """With all clients assigned the same two (non-contiguous) classes, the
    per-class sharer count is 4 for BOTH classes — the old label-indexed
    counter would have read counts[5]/counts[9] out of bounds."""
    y = np.repeat([5, 9], 120)
    split = classwise_split(y, 4, classes_per_client=2, seed=3)
    assert len(split) == 4
    for s in split:
        assert len(s) > 0
        assert set(np.unique(y[s])) == {5, 9}  # both classes represented
