"""repro.obs: flight recorder, metrics registry, measured-vs-modeled report."""
import json
import os
import sys
import time

import pytest

from repro.configs.base import SyncConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _reset_obs():
    # restore the default-capacity tracer (a test may have shrunk the ring)
    obs_trace.enable(capacity=obs_trace.DEFAULT_CAPACITY)
    obs_trace.disable()
    obs_trace.get_tracer().reset()
    obs_trace.get_tracer().meta.clear()
    obs_metrics.registry.reset()


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Every test starts and ends with tracing off and empty global state."""
    _reset_obs()
    yield
    _reset_obs()


# ---------------------------------------------------------------------------
# trace: spans, nesting, ring buffer
# ---------------------------------------------------------------------------
def test_span_nesting_and_ordering():
    obs_trace.enable()
    with obs_trace.span("outer", level="inter") as outer:
        with obs_trace.span("inner") as inner:
            time.sleep(0.001)
            inner.tag(nbytes=42)
        outer.tag(ok=True)
    spans = obs_trace.get_tracer().spans()
    assert [s.name for s in spans] == ["inner", "outer"]  # close order
    inner, outer = spans
    assert inner.depth == 1 and outer.depth == 0
    assert outer.encloses(inner) and not inner.encloses(outer)
    assert inner.tags == {"nbytes": 42}
    assert outer.tags == {"level": "inter", "ok": True}
    assert inner.dur_us > 0 and outer.dur_us >= inner.dur_us


def test_traced_decorator_and_ambient_tags():
    obs_trace.enable()

    @obs_trace.traced("work/fn", kind="unit")
    def fn(x):
        return x + 1

    with obs_trace.ambient(level="dcn"):
        assert fn(1) == 2
    (s,) = obs_trace.get_tracer().spans()
    assert s.name == "work/fn"
    assert s.tags["kind"] == "unit" and s.tags["level"] == "dcn"


def test_ring_buffer_eviction():
    obs_trace.enable(capacity=8)
    for i in range(20):
        with obs_trace.span(f"s{i}"):
            pass
    tr = obs_trace.get_tracer()
    spans = tr.spans()
    assert len(spans) == 8
    assert tr.n_recorded == 20 and tr.n_evicted == 12
    # the survivors are the most recent spans, in chronological order
    assert [s.name for s in spans] == [f"s{i}" for i in range(12, 20)]


def test_disabled_mode_is_null():
    assert not obs_trace.enabled()
    s1 = obs_trace.span("a", big="tag")
    s2 = obs_trace.span("b")
    assert s1 is s2 is obs_trace.NULL_SPAN  # shared singleton, no allocation
    with s1 as s:
        s.tag(nbytes=1)  # must be a no-op, not an error
    assert obs_trace.get_tracer().n_recorded == 0


def test_export_jsonl_roundtrip(tmp_path):
    obs_trace.enable()
    with obs_trace.span("phase/x", nbytes=10):
        pass
    obs_trace.set_meta(label="t", n_params=7)
    path = obs_trace.export_jsonl(str(tmp_path / "t.jsonl"))
    meta, spans = obs_trace.load_jsonl(path)
    assert meta["label"] == "t" and meta["n_params"] == 7
    assert meta["n_recorded"] == 1 and meta["n_evicted"] == 0
    (s,) = spans
    assert s.name == "phase/x" and s.tags == {"nbytes": 10}


def test_chrome_trace_schema(tmp_path):
    obs_trace.enable()
    with obs_trace.span("a", level="intra"):
        with obs_trace.span("b"):
            pass
    path = obs_trace.export_chrome_trace(str(tmp_path / "t.json"))
    with open(path) as f:
        doc = json.load(f)
    assert isinstance(doc["traceEvents"], list) and len(doc["traceEvents"]) == 2
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X"  # complete events
        assert isinstance(ev["name"], str)
        assert isinstance(ev["ts"], (int, float))
        assert isinstance(ev["dur"], (int, float)) and ev["dur"] >= 0
        assert "pid" in ev and "tid" in ev
    by_name = {ev["name"]: ev for ev in doc["traceEvents"]}
    assert by_name["a"]["args"] == {"level": "intra"}


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_counter_gauge_histogram():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("c").inc(3, step=0)
    reg.counter("c").inc(4, step=1)
    assert reg.counter("c").total == 7
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)
    reg.gauge("g").set(2.5, step=0)
    assert reg.gauge("g").value == 2.5
    h = reg.histogram("h")
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100 and h.percentile(50) == pytest.approx(50, abs=1)
    with pytest.raises(TypeError):
        reg.gauge("c")  # name already bound to a counter


def test_level_byte_gauges_sum_to_round_cost_total():
    from repro.comm import round_cost

    for sync in (SyncConfig(mode="hier", compressor="qsgd", quant_bits=8,
                            sync_period=4),
                 SyncConfig(mode="hier", topology="edge_fl"),
                 SyncConfig(mode="efbv", compressor="top_k",
                            compress_ratio=0.05)):
        reg = obs_metrics.MetricsRegistry()
        cost = round_cost(sync, 1 << 14)
        reg.observe_round_cost(0, cost)
        assert sum(reg.level_bytes().values()) == pytest.approx(
            cost.total_bytes, rel=0, abs=1e-9)


def test_ingest_ledger_matches_bytes_by_tag():
    from repro.comm import round_ledger

    sync = SyncConfig(mode="hier", compressor="qsgd", quant_bits=8,
                      sync_period=4)
    led = round_ledger(sync, 1 << 14)
    reg = obs_metrics.MetricsRegistry()
    reg.ingest_ledger(led)
    assert reg.ledger_bytes() == {k: float(v)
                                  for k, v in led.bytes_by_tag().items()}
    assert reg.counter("comm/ledger/total").total == float(led.total_bytes)


# ---------------------------------------------------------------------------
# report: phases, byte audit, e2e
# ---------------------------------------------------------------------------
def test_phase_classification_outermost_only():
    from repro.obs import report

    obs_trace.enable()
    with obs_trace.span("codec/encode", nbytes=100, level="inter"):
        with obs_trace.span("codec/encode_chunk", chunk=0, nbytes=50):
            pass
        with obs_trace.span("codec/encode_chunk", chunk=1, nbytes=50):
            pass
    spans = obs_trace.get_tracer().spans()
    measured = report.measured_phase_seconds(spans)
    # nested same-phase chunk spans don't double the encode total
    outer = [s for s in spans if s.name == "codec/encode"][0]
    assert measured["encode"] == pytest.approx(outer.dur_us / 1e6)
    # ...and chunk spans don't re-count payload bytes
    assert report.measured_bytes_by_level(spans) == {"inter": 100.0}


def test_report_e2e_traced_round(tmp_path):
    from benchmarks.bench_comm import traced_round
    from repro.obs import report

    trace_path, metrics_path = traced_round(out_dir=str(tmp_path),
                                            n_params=1 << 13)
    assert not obs_trace.enabled()  # restored
    text, result = report.build_report(trace_path, metrics_path=metrics_path)
    assert result["bytes_match"] is True
    assert result["trace_bytes"] == result["ledger_bytes"]
    assert set(result["trace_bytes"]) == {"intra", "inter"}
    for phase in ("pack", "encode", "allreduce", "decode", "adopt"):
        assert result["measured_s"][phase] > 0.0, phase
    assert "per-level measured bytes match CommLedger: True" in text
    # the CLI agrees and exits 0
    assert report.main([trace_path, "--metrics", metrics_path]) == 0


def test_report_cli_fails_on_byte_mismatch(tmp_path):
    from benchmarks.bench_comm import traced_round
    from repro.obs import report

    trace_path, metrics_path = traced_round(out_dir=str(tmp_path),
                                            n_params=1 << 13)
    with open(metrics_path) as f:
        doc = json.load(f)
    doc["ledger_bytes_by_tag"]["inter"] += 1  # corrupt the ledger
    with open(metrics_path, "w") as f:
        json.dump(doc, f)
    assert report.main([trace_path, "--metrics", metrics_path]) == 1


# ---------------------------------------------------------------------------
# instrumented paths stay live
# ---------------------------------------------------------------------------
def test_codec_spans_record_nbytes():
    import jax

    from repro.comm import codecs
    from repro.core import compressors as C

    obs_trace.enable()
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4096,))
    p = codecs.encode(C.qsgd(8), key, x)
    codecs.decode(p)
    spans = {s.name: s for s in obs_trace.get_tracer().spans()}
    assert spans["codec/encode"].tags["nbytes"] == p.nbytes
    assert spans["codec/decode"].tags["nbytes"] == p.nbytes


def test_train_loop_traced_smoke():
    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.data.synthetic import SyntheticLMDataset, lm_batch_iterator
    from repro.training.loop import train

    cfg = get_config("h2o-danube-1.8b").reduced()
    tc = TrainConfig(model=cfg, seq_len=32, global_batch=4, lr=1e-3,
                     warmup_steps=1, total_steps=2)
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, length=2000, seed=0)

    obs_trace.enable()
    _, history = train(cfg, tc, lm_batch_iterator(ds, 4, 32, seed=1),
                       steps=2, log_every=1)
    assert len(history) == 2
    names = [s.name for s in obs_trace.get_tracer().spans()]
    assert names.count("round/step") == 2
    assert names.count("round/blocking_fetch") == 2
    loss = obs_metrics.registry.gauge("train/loss")
    assert len(loss.series) == 2
