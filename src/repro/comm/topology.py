"""Link-topology simulator: cross-device vs cross-pod bandwidth/latency.

The paper's communication-efficiency story is about *heterogeneous* links:
Cohort-Squeeze (Ch. 5) pays c_local per intra-cluster round and c_global per
cross-cluster round and shows K > 1 local rounds win whenever
c_global >> c_local.  This module gives those abstract costs physical units:
a ``Topology`` holds one fast fabric link class ("intra": ICI/NVLink-scale)
and one slow one ("inter": DCN / WAN / federated edge), and converts message
or collective sizes into seconds.

Collective model (ring): an all-reduce over g participants moves
2*(g-1)/g * nbytes per device in 2*(g-1) latency-bound steps; reduce and
broadcast/gather halves are (g-1)/g each.  This matches how
launch/hlo_analysis.py counts per-device collective payload, so simulated
times compose with the HLO-derived byte totals in launch/costing.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class Link:
    """One link class: sustained bandwidth (GB/s) + per-message latency."""
    gbps: float          # gigabytes per second, per link
    latency_us: float    # one-way message latency, microseconds

    def time_s(self, nbytes: float) -> float:
        return self.latency_us * 1e-6 + float(nbytes) / (self.gbps * 1e9)


@dataclass(frozen=True)
class Topology:
    name: str
    n_pods: int
    devices_per_pod: int
    intra: Link          # cross-device, same pod (ICI-class)
    inter: Link          # cross-pod (DCN / WAN-class)

    @property
    def n_devices(self) -> int:
        return self.n_pods * self.devices_per_pod

    def link(self, kind: str) -> Link:
        if kind == "intra":
            return self.intra
        if kind == "inter":
            return self.inter
        raise KeyError(f"unknown link kind {kind!r} (intra|inter)")

    # -- collective timing (ring model) ------------------------------------
    def allreduce_time_s(self, nbytes: float, scope: str = "intra") -> float:
        """Ring all-reduce of an nbytes-per-device buffer.

        scope: "intra" (one pod, devices_per_pod ring), "inter" (one ring of
        pod leaders over slow links), "global" (hierarchical: intra reduce ->
        inter all-reduce -> intra broadcast, the standard 2-level schedule).
        """
        if scope == "intra":
            return self._ring(self.intra, self.devices_per_pod, nbytes)
        if scope == "inter":
            return self._ring(self.inter, self.n_pods, nbytes)
        if scope == "global":
            return (self._ring_half(self.intra, self.devices_per_pod, nbytes)
                    + self._ring(self.inter, self.n_pods, nbytes)
                    + self._ring_half(self.intra, self.devices_per_pod, nbytes))
        raise KeyError(f"unknown scope {scope!r}")

    @staticmethod
    def _ring(link: Link, g: int, nbytes: float) -> float:
        if g <= 1:
            return 0.0
        steps = 2 * (g - 1)
        return steps * link.latency_us * 1e-6 + (
            2.0 * (g - 1) / g * float(nbytes)) / (link.gbps * 1e9)

    @staticmethod
    def _ring_half(link: Link, g: int, nbytes: float) -> float:
        """Reduce-scatter or all-gather half of the ring."""
        if g <= 1:
            return 0.0
        steps = g - 1
        return steps * link.latency_us * 1e-6 + (
            (g - 1) / g * float(nbytes)) / (link.gbps * 1e9)


# ---------------------------------------------------------------------------
# presets — the scenarios the repo simulates
# ---------------------------------------------------------------------------
PRESETS: Dict[str, Topology] = {
    # 2 TPU pods: ~100 GB/s ICI per chip, ~12.5 GB/s DCN per host link
    "v5p_superpod": Topology("v5p_superpod", n_pods=2, devices_per_pod=256,
                             intra=Link(gbps=100.0, latency_us=1.0),
                             inter=Link(gbps=12.5, latency_us=25.0)),
    # geo-distributed datacenters over WAN
    "geo_wan": Topology("geo_wan", n_pods=4, devices_per_pod=64,
                        intra=Link(gbps=50.0, latency_us=2.0),
                        inter=Link(gbps=1.0, latency_us=20_000.0)),
    # cross-device federated learning: phones behind broadband uplinks
    "edge_fl": Topology("edge_fl", n_pods=100, devices_per_pod=1,
                        intra=Link(gbps=10.0, latency_us=10.0),
                        inter=Link(gbps=0.00625, latency_us=50_000.0)),
}


def get_topology(name: str) -> Topology:
    if name not in PRESETS:
        raise KeyError(f"unknown topology {name!r}; known {sorted(PRESETS)}")
    return PRESETS[name]
