"""SymWanda: symmetric post-training pruning + R^2-DSnoT (Ch. 6).

Scores for pruning a weight matrix W (out = X @ W, X: (tokens, d_in)):

  magnitude   S_ij = |W_ij|
  wanda       S_ij = |W_ij| * ||X_:i||_2          (input-activation aware)
  ria         S_ij = (|W_ij|/sum_k|W_kj| + |W_ij|/sum_k|W_ik|) * ||X_:i||^alpha
              (relative importance x activation, Zhang et al. 2024)
  symwanda    beta * wanda-term + (1-beta) * output-side term
              |W_ij| * ||Y_j:||, the symmetric objective of Sect. 6.3 that
              recovers Wanda (beta=1) and the output-only variant (beta=0)
  stochria    RIA computed from a row-subsampled calibration batch
              (Sect. 6.4.1 "efficiency of stochastic methods")

Masking: unstructured (global or per-output) and N:M structured (2:4).
R^2-DSnoT: training-free prune-and-grow fine-tuning with a relative-importance
regularized decision boundary (Sect. 6.3.6).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Activation statistics from a calibration batch
# ---------------------------------------------------------------------------
def act_norms(X: jax.Array, p: float = 2.0) -> jax.Array:
    """Per-input-channel lp norms ||X_:i||_p of calibration activations
    (T, d_in).  The paper's App. E.3.2/E.3.3 sweeps p (1, 2, inf): p=2 is
    Wanda's choice; p=1 weights dense moderate activations more, p=inf only
    the peak."""
    Xa = jnp.abs(X.astype(jnp.float32))
    if p == float("inf"):
        return jnp.max(Xa, axis=0)
    return jnp.sum(Xa ** p, axis=0) ** (1.0 / p)


# ---------------------------------------------------------------------------
# Scores
# ---------------------------------------------------------------------------
def score_magnitude(W, X=None, **kw):
    return jnp.abs(W)


def score_wanda(W, X, p: float = 2.0, **kw):
    return jnp.abs(W) * act_norms(X, p)[:, None]


def score_ria(W, X, alpha: float = 0.5, p: float = 2.0, **kw):
    aW = jnp.abs(W)
    row_sum = jnp.sum(aW, axis=1, keepdims=True)   # sum over outputs for input i
    col_sum = jnp.sum(aW, axis=0, keepdims=True)   # sum over inputs for output j
    ri = aW / jnp.maximum(row_sum, 1e-12) + aW / jnp.maximum(col_sum, 1e-12)
    return ri * (act_norms(X, p)[:, None] ** alpha)


def score_symwanda(W, X, beta: float = 0.5, Y: Optional[jax.Array] = None, **kw):
    """Symmetric objective: input-side ||X_:i|| and output-side ||Y_:j|| terms.
    Y defaults to the layer's calibration output X @ W."""
    inp = jnp.abs(W) * act_norms(X)[:, None]
    Yc = X @ W if Y is None else Y
    out = jnp.abs(W) * act_norms(Yc)[None, :]
    # normalize each side so beta trades off comparable magnitudes
    inp = inp / jnp.maximum(jnp.mean(inp), 1e-12)
    out = out / jnp.maximum(jnp.mean(out), 1e-12)
    return beta * inp + (1.0 - beta) * out


def score_stochria(W, X, key=None, sample_frac: float = 0.1, alpha: float = 0.5, **kw):
    T = X.shape[0]
    k = max(1, int(sample_frac * T))
    idx = jax.random.choice(key, T, shape=(k,), replace=False)
    return score_ria(W, X[idx], alpha=alpha)


SCORES = {
    "magnitude": score_magnitude,
    "wanda": score_wanda,
    "ria": score_ria,
    "symwanda": score_symwanda,
    "stochria": score_stochria,
}


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------
def mask_unstructured(S: jax.Array, sparsity: float, per_output: bool = True):
    """Keep the top (1-sparsity) fraction by score; Wanda prunes per output."""
    if per_output:
        k = max(1, int(round((1 - sparsity) * S.shape[0])))
        thresh = jax.lax.top_k(S.T, k)[0][:, -1]     # per column j
        return (S >= thresh[None, :]).astype(S.dtype)
    k = max(1, int(round((1 - sparsity) * S.size)))
    thresh = jax.lax.top_k(S.reshape(-1), k)[0][-1]
    return (S >= thresh).astype(S.dtype)


def mask_nm(S: jax.Array, n: int = 2, m: int = 4):
    """N:M structured: keep the n largest scores in every group of m along the
    input dim (so each output column is N:M sparse along inputs)."""
    d_in, d_out = S.shape
    assert d_in % m == 0, (d_in, m)
    grp = S.T.reshape(d_out, d_in // m, m)          # (out, groups, m)
    thresh = jax.lax.top_k(grp, n)[0][..., -1:]
    mask = (grp >= thresh).astype(S.dtype)
    return mask.reshape(d_out, d_in).T


def prune(W, X, method: str = "wanda", sparsity: float = 0.5,
          structured_nm: Optional[tuple] = None, key=None, **score_kw):
    """Returns (pruned W, mask)."""
    S = SCORES[method](W, X, key=key, **score_kw)
    if structured_nm is not None:
        mask = mask_nm(S, *structured_nm)
    else:
        mask = mask_unstructured(S, sparsity)
    return W * mask, mask


# ---------------------------------------------------------------------------
# Reconstruction metrics (the paper's minimization objective, Sect. 6.3)
# ---------------------------------------------------------------------------
def reconstruction_error(W, W_pruned, X) -> jax.Array:
    """||X W - X W~||_F / ||X W||_F (input-side objective)."""
    Y, Yp = X @ W, X @ W_pruned
    return jnp.linalg.norm(Y - Yp) / jnp.maximum(jnp.linalg.norm(Y), 1e-12)


def symmetric_error(W, W_pruned, X, Z) -> jax.Array:
    """Symmetric objective ||X dW||_F + ||dW^T Z||_F (Z: output-side probe)."""
    dW = W - W_pruned
    return jnp.linalg.norm(X @ dW) + jnp.linalg.norm(dW.T @ Z)


# ---------------------------------------------------------------------------
# R^2-DSnoT: training-free prune-and-grow fine-tuning (Sect. 6.3.6)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DSnoTConfig:
    iters: int = 20
    swap_frac: float = 0.02      # fraction of each column swapped per iter
    reg: float = 0.5             # relative-importance regularization strength
    use_ria_boundary: bool = True  # R^2 variant; False = vanilla DSnoT


def r2_dsnot(W, mask, X, cfg: DSnoTConfig = DSnoTConfig(), ria_alpha: float = 0.5):
    """Iteratively swap pruned/kept weights to reduce per-output reconstruction
    error, with the decision boundary regularized by relative importance.

    Growth criterion: pruned weight whose reinstatement best cancels the
    current output residual mean; pruning criterion: kept weight with least
    (wanda + reg * RIA) importance.  Swaps are rank-matched per output column.
    """
    Xf = X.astype(jnp.float32)
    Xn2 = jnp.sum(Xf**2, axis=0)                             # (d_in,) ||X_:i||^2
    Wf = W.astype(jnp.float32)
    ria = score_ria(W, X, alpha=ria_alpha)
    ria = ria / jnp.maximum(jnp.mean(ria), 1e-12)
    reg_term = cfg.reg * jnp.abs(Wf) * jnp.sqrt(Xn2)[:, None] * ria
    d_out = W.shape[1]
    cols = jnp.arange(d_out)

    def one_iter(mask, _):
        # residual R = X (W - W~); exact second-moment criterion:
        # growing W_ij:  d||R||^2 = -2 W_ij (X^T R)_ij + W_ij^2 ||X_:i||^2
        # pruning W_ij:  d||R|| ^2= +2 W_ij (X^T R)_ij + W_ij^2 ||X_:i||^2
        R = Xf @ (Wf * (1 - mask))                           # (T, d_out)
        XtR = Xf.T @ R                                       # (d_in, d_out)
        quad = (Wf**2) * Xn2[:, None]
        grow_delta = -2.0 * Wf * XtR + quad
        grow_score = jnp.where(mask > 0, jnp.inf, grow_delta)   # want most negative
        prune_delta = 2.0 * Wf * XtR + quad
        if cfg.use_ria_boundary:
            # R^2: regularize the decision boundary with relative importance
            prune_delta = prune_delta + reg_term
        prune_score = jnp.where(mask > 0, prune_delta, jnp.inf)  # want least harmful

        grow_val, grow_idx = jax.lax.top_k(-grow_score.T, 1)    # per column
        prune_val, prune_idx = jax.lax.top_k(-prune_score.T, 1)
        grow_val, grow_idx = -grow_val[:, 0], grow_idx[:, 0]
        prune_val, prune_idx = -prune_val[:, 0], prune_idx[:, 0]
        net_gain = -(grow_val + prune_val)                      # >0 => swap reduces error
        do = net_gain > 0
        new_mask = mask.at[grow_idx, cols].set(
            jnp.where(do, 1.0, mask[grow_idx, cols]))
        new_mask = new_mask.at[prune_idx, cols].set(
            jnp.where(do, 0.0, new_mask[prune_idx, cols]))
        return new_mask, jnp.sum(do)

    mask, swaps = jax.lax.scan(one_iter, mask, None, length=cfg.iters)
    return W * mask, mask
