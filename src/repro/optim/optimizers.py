"""Optimizers as (init, update) pairs over pytrees — optax-style but local.

States mirror param pytree structure leaf-for-leaf so the sharding rules that
apply to a param apply verbatim to its optimizer moments (critical for the
multi-pod dry-run: AdamW moments of a model-sharded weight stay model-sharded).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_map


class OptState(NamedTuple):
    step: jax.Array
    mu: object       # first moment (or momentum); zeros pytree for sgd w/o momentum
    nu: object       # second moment; empty tuple for sgd


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)


def clip_by_global_norm(grads, max_norm: float):
    from repro.utils.tree import tree_norm

    norm = tree_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return tree_map(lambda g: g * scale, grads), norm


def adamw(
    lr: Callable | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    mask: Optional[Callable] = None,
) -> Optimizer:
    """AdamW with decoupled weight decay.

    ``mask(path-free param leaf) -> bool`` selects leaves that receive weight
    decay (default: every leaf with ndim >= 2, i.e. matrices but not
    norms/biases).
    """
    sched = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))
    decay_mask = mask or (lambda p: p.ndim >= 2)

    def init(params):
        zeros = tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros)

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr_t = sched(step)
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)

        mu = tree_map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = tree_map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads)

        def upd(m, v, p):
            mhat = m / b1c
            vhat = v / b2c
            u = mhat / (jnp.sqrt(vhat) + eps)
            if decay_mask(p):
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        updates = tree_map(upd, mu, nu, params)
        return updates, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def sgd(lr: Callable | float, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    sched = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        mu = tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params) if momentum else ()
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=())

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr_t = sched(step)
        if momentum:
            mu = tree_map(lambda m, g: momentum * m + g.astype(jnp.float32), state.mu, grads)
            eff = tree_map(lambda m, g: momentum * m + g.astype(jnp.float32), mu, grads) if nesterov else mu
            updates = tree_map(lambda e, p: (-lr_t * e).astype(p.dtype), eff, params)
            return updates, OptState(step=step, mu=mu, nu=())
        updates = tree_map(lambda g, p: (-lr_t * g).astype(p.dtype), grads, params)
        return updates, OptState(step=step, mu=(), nu=())

    return Optimizer(init=init, update=update)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "sgd":
        return sgd(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")


def apply_updates(params, updates):
    return tree_map(lambda p, u: p + u, params, updates)
