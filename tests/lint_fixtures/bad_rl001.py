"""RL001 fixture: host synchronization inside jit-traced code."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def step(x):
    lo = x.min().item()          # RL001: .item() forces a device sync
    host = np.asarray(x)         # RL001: np.asarray materializes on host
    return x - lo + host.sum()


def scan_body(carry, x):
    probe = jax.device_get(carry)  # RL001: reachable via lax.scan below
    return carry + x, probe


def run(xs):
    return jax.lax.scan(scan_body, jnp.zeros(()), xs)
