"""Sharding-aware checkpointing: flat-key npz with pytree structure manifest.

No orbax offline; .npz + json manifest is deterministic, dependency-free and
round-trips every state pytree in the framework (params, opt moments, EF
control variates).  On save, sharded arrays are gathered to host (fine at the
example scale this container runs; a production deployment would swap in
per-shard files keyed by shard index — the manifest format already carries
the spec strings for that).
"""
from __future__ import annotations

import json
import os
from typing import Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[dict, dict]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays, manifest = {}, {}
    for i, (path, leaf) in enumerate(leaves):
        key = f"leaf_{i}"
        arrays[key] = np.asarray(leaf)
        manifest[key] = jax.tree_util.keystr(path)
    return arrays, manifest


def save_checkpoint(path: str, tree, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays, manifest = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    meta = {"step": step, "manifest": manifest}
    with open(path.replace(".npz", "") + ".json", "w") as f:
        json.dump(meta, f)


def load_checkpoint(path: str, like_tree):
    """Restore into the structure of ``like_tree`` (dtypes/shapes must match)."""
    base = path.replace(".npz", "")
    data = np.load(base + ".npz")
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    restored = [
        np.asarray(data[f"leaf_{i}"]).astype(leaf.dtype).reshape(leaf.shape)
        for i, leaf in enumerate(leaves)
    ]
    with open(base + ".json") as f:
        meta = json.load(f)
    return jax.tree_util.tree_unflatten(treedef, restored), meta["step"]
