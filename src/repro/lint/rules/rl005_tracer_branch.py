"""RL005 — Python branching on tracer-typed names in jitted scopes.

``if x > 0:`` inside a ``@jax.jit`` function raises a
``TracerBoolConversionError`` at trace time — but only on the code path that
actually executes, so an untested branch ships the bug.  The rule taints the
parameters of every jit *root* (minus declared ``static_argnames``),
propagates taint through simple assignments, and flags ``if``/``while``
tests that concretize a tainted name.

Not flagged (all trace-safe):
* ``x.shape`` / ``x.ndim`` / ``x.dtype`` / ``x.size`` / ``len(x)`` —
  static metadata;
* ``x is None`` / ``x is not None`` — an optional-argument check (tracers
  are never None);
* branches on closure/config values — only root *parameters* seed taint.

Non-root helpers are not analyzed: their arguments routinely mix tracers
with static config, and a name-based pass can't tell them apart.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.lint.framework import Finding, Project, rule

_META_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "weak_type"}


def _is_none_check(node: ast.AST) -> bool:
    return (isinstance(node, ast.Compare)
            and all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops)
            and any(isinstance(c, ast.Constant) and c.value is None
                    for c in node.comparators))


def _offending_names(test: ast.AST, tainted: Set[str]) -> List[ast.Name]:
    """Tainted Name loads in ``test`` that would concretize a tracer."""
    hits: List[ast.Name] = []

    def walk(node):
        if _is_none_check(node):
            return
        if isinstance(node, ast.Attribute) and node.attr in _META_ATTRS:
            return  # x.shape[...] etc — static under trace
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in ("len", "isinstance",
                                                    "getattr", "hasattr"):
                return
            if isinstance(f, ast.Attribute) and f.attr in _META_ATTRS:
                return
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                and node.id in tainted:
            hits.append(node)
            return
        for child in ast.iter_child_nodes(node):
            walk(child)

    walk(test)
    return hits


def _mentions_taint(expr: ast.AST, tainted: Set[str]) -> bool:
    return bool(_offending_names(expr, tainted))


def _body_nodes(fn_node: ast.AST):
    """Walk a function body without descending into nested defs — those are
    their own call-graph nodes (and, for jit factories, their own roots)."""
    stack = list(getattr(fn_node, "body", []))
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stack.append(child)


def _propagate(fn_node: ast.AST, tainted: Set[str]) -> Set[str]:
    """Two fixed passes of ``y = f(tainted)`` => ``y`` tainted (statement
    order, no joins — cheap and good enough for step-function bodies)."""
    for _ in range(2):
        for node in _body_nodes(fn_node):
            value = None
            targets = []
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, ast.AugAssign):
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                value, targets = node.value, [node.target]
            if value is None or not _mentions_taint(value, tainted):
                continue
            for t in targets:
                for sub in ast.walk(t):
                    if isinstance(sub, ast.Name):
                        tainted.add(sub.id)
    return tainted


@rule("RL005", "Python if/while on a tracer-typed name inside a jit root")
def check(project: Project) -> List[Finding]:
    graph = project.callgraph
    out: List[Finding] = []
    by_rel = {ctx.relpath: ctx for ctx in project.files.values()}
    for fn in graph.root_nodes():
        ctx = by_rel.get(fn.relpath)
        if ctx is None or isinstance(fn.node, ast.Lambda):
            continue
        tainted = set(fn.params()) - fn.static_params
        if not tainted:
            continue
        tainted = _propagate(fn.node, tainted)
        why = fn.root_reasons[0] if fn.root_reasons else "jit root"
        for node in _body_nodes(fn.node):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            for name in _offending_names(node.test, tainted):
                out.append(ctx.finding(
                    "RL005", node,
                    f"branch on `{name.id}` in `{fn.qualname}` ({why}): "
                    f"concretizes a tracer at trace time; use jnp.where/"
                    f"lax.cond or declare it static"))
    return out
