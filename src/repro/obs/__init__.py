"""repro.obs — observability for the comm stack: round-trace flight recorder,
metrics registry, and measured-vs-modeled round reports.

Layers:
  trace    lightweight span API (``span("sync/encode", level="inter")`` as a
           context manager or decorator) over a monotonic clock and a
           thread-safe ring buffer acting as a flight recorder; exporters to
           per-round JSONL and Chrome ``chrome://tracing`` JSON, plus an
           optional ``jax.profiler`` passthrough so spans line up with XLA
           profiles.  Near-zero cost when disabled: the module-level enable
           flag short-circuits to a shared no-op span, and code *inside* jit
           uses ``annotate`` (trace-time ``jax.named_scope``) — host-clock
           spans only wrap dispatch boundaries, never force a device sync.
  metrics  counter/gauge/histogram registry with per-round time series; it
           ingests ``CommLedger.bytes_by_tag`` and per-level ``LevelCost``
           so bytes-by-level/compressor are first-class series next to loss
           and grad-norm.
  report   joins a trace JSONL with the ``RoundCost`` model: per-round
           breakdown of measured wall-time per phase (pack -> encode ->
           allreduce -> decode -> adopt) vs ``serial_time_s`` /
           ``pipelined_time_s`` predictions with a model_error% column, and
           a per-level measured-bytes-vs-CommLedger audit.
           CLI: ``python -m repro.obs.report TRACE.jsonl [--metrics M.json]``
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               registry)
from repro.obs.trace import (Span, Tracer, ambient, annotate, disable, enable,
                             enabled, export_chrome_trace, export_jsonl,
                             get_tracer, load_jsonl, set_meta, span,
                             step_annotation, traced)

__all__ = [
    "Span", "Tracer", "span", "traced", "ambient", "annotate",
    "step_annotation", "enable", "disable", "enabled", "get_tracer",
    "set_meta", "export_jsonl", "export_chrome_trace", "load_jsonl",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "registry",
]
