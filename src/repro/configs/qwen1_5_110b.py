"""Qwen1.5-110B. [hf:Qwen/Qwen1.5-0.5B family card, scaled 110B variant]

Dense llama-style decoder with QKV bias (the Qwen1.5 signature), GQA kv=8.
Full causal attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        citation="hf:Qwen/Qwen1.5-0.5B",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=49152,
        vocab_size=152064,
        qkv_bias=True,
        mlp_act="silu",
        mlp_gated=True,
        supports_long_context=False,
    )
)
