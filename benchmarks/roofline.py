"""§Roofline: derive compute / memory / collective terms per (arch x shape).

Reads the dry-run sweep artifacts (results/dryrun/*.json) for memory proof +
raw costs, re-derives loop-corrected flops/bytes/collective-bytes via
launch/costing.py (three small lowerings per combo), and emits the roofline
table: all three terms in seconds, the dominant term, MODEL_FLOPS/HLO_FLOPS
utility ratio, and an auto-generated what-would-help note.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
cost_analysis numbers are per-device post-SPMD, so terms are per-chip already.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--out results/roofline.json]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import glob
import json

from benchmarks.common import now_s  # jax-free; safe before XLA_FLAGS users

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def analyze_combo(arch: str, shape: str, sync: str = "dense"):
    import jax  # after XLA_FLAGS
    from repro.configs.base import get_config
    from repro.launch.costing import corrected_costs, model_flops
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=False)
    t0 = now_s()
    cc = corrected_costs(cfg, mesh, shape, sync_mode=sync)
    mf = model_flops(cfg, shape)
    c = cc["corrected"]
    n_chips = 256
    terms = {
        "compute_s": c.get("flops", 0.0) / PEAK_FLOPS,
        "memory_s": c.get("bytes", 0.0) / HBM_BW,
        "collective_s": c.get("coll_total", 0.0) / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    hlo_flops_global = c.get("flops", 0.0) * n_chips
    ratio = mf["model_flops"] / hlo_flops_global if hlo_flops_global else float("nan")
    advice = {
        "compute_s": "compute-bound: raise arithmetic efficiency (fuse, reduce remat recompute, larger per-chip tiles)",
        "memory_s": "HBM-bound: cut bytes/step (activation dtype, fusion, avoid materialized intermediates, bigger arithmetic intensity)",
        "collective_s": "collective-bound: cut wire bytes (compressed sync / hier mode, overlap collectives with compute, reshard to reduce gather volume)",
    }[dominant]
    return {
        "arch": arch, "shape": shape, "sync": sync,
        "terms_s": terms, "dominant": dominant,
        "model_flops": mf["model_flops"],
        "hlo_flops_per_chip": c.get("flops", 0.0),
        "useful_ratio": ratio,
        "collectives_by_kind": {k[5:]: v for k, v in c.items() if k.startswith("coll_") and k != "coll_total"},
        "advice": advice,
        "analysis_s": round(now_s() - t0, 1),
        "variants": cc["variants"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--sync", default="dense")
    args = ap.parse_args()

    combos = []
    for f in sorted(glob.glob(os.path.join(args.dryrun_dir, "*__sp__dense.json"))):
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            continue
        if args.arch and rec["arch"] != args.arch:
            continue
        if args.shape and rec["shape"] != args.shape:
            continue
        combos.append((rec["arch"], rec["shape"]))

    rows = []
    for arch, shape in combos:
        print(f"[roofline] {arch} x {shape}", flush=True)
        try:
            rows.append(analyze_combo(arch, shape, args.sync))
            t = rows[-1]["terms_s"]
            print(f"  compute {t['compute_s']*1e3:.2f}ms  memory {t['memory_s']*1e3:.2f}ms  "
                  f"collective {t['collective_s']*1e3:.2f}ms  -> {rows[-1]['dominant']}  "
                  f"useful={rows[-1]['useful_ratio']:.2f}", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"  ERROR {type(e).__name__}: {e}", flush=True)
            rows.append({"arch": arch, "shape": shape, "error": str(e)[:500]})
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {args.out} ({len(rows)} combos)")


if __name__ == "__main__":
    main()
