"""Production serving launcher: prefill + batched decode.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --dry-run \
      --shape decode_32k
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --reduced
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k",
                    choices=["prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    if args.dry_run:
        os.execv(sys.executable, [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", args.arch, "--shape", args.shape,
            "--multi-pod", "multi" if args.multi_pod else "single",
        ])

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.models import decode_step, init_params, prefill

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, (args.batch, 16), dtype=np.int64).astype(np.int32))
    batch = {"tokens": prompt}
    if cfg.enc_layers:
        batch["src_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, 16, cfg.enc_d_model))
    if cfg.vision_tokens:
        batch["vision_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.vision_tokens, cfg.d_model))
    logits, cache = jax.jit(
        lambda p, b: prefill(p, cfg, b, cache_len=16 + args.gen + 1))(params, batch)
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    toks = []
    for _ in range(args.gen):
        logits, cache = step(params, tok, cache)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
        toks.append(np.asarray(tok[:, 0]))
    print("decoded:", np.stack(toks, 1).tolist())


if __name__ == "__main__":
    main()
