"""Per-architecture smoke tests: reduced variant of each assigned family runs
one forward/train step on CPU with finite outputs and correct shapes, plus
prefill->decode consistency for one arch per family."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import (
    cache_specs, decode_step, forward_train, init_params, loss_fn, prefill)

ARCHS = [
    "llama4-scout-17b-a16e", "chameleon-34b", "qwen1.5-110b",
    "seamless-m4t-large-v2", "mamba2-2.7b", "qwen1.5-4b", "dbrx-132b",
    "jamba-1.5-large-398b", "h2o-danube-1.8b", "nemotron-4-15b",
]


def _batch(cfg, B=2, S=16, key=jax.random.PRNGKey(0)):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if cfg.vision_tokens:
        batch["vision_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model))
    if cfg.enc_layers:
        batch["src_embeds"] = 0.02 * jax.random.normal(
            key, (B, 12, cfg.enc_d_model or cfg.d_model))
    return batch


def test_all_archs_registered():
    assert sorted(ARCHS) == list_configs()


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.num_layers == 2 and r.d_model <= 512
    if r.moe:
        assert r.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = forward_train(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab())
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, parts = loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    from repro.configs.base import TrainConfig
    from repro.training.steps import init_train_state, make_train_step

    cfg = get_config(arch).reduced()
    tc = TrainConfig(model=cfg, seq_len=16, global_batch=2, lr=1e-3,
                     warmup_steps=2, total_steps=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(jax.random.PRNGKey(1), params, tc, 1, 1)
    step = jax.jit(make_train_step(cfg, tc, 1, 1))
    state, metrics = step(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    leaves = jax.tree_util.tree_leaves(state.params)
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32)))) for l in leaves)


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "h2o-danube-1.8b", "mamba2-2.7b",
                                  "jamba-1.5-large-398b", "llama4-scout-17b-a16e",
                                  "seamless-m4t-large-v2"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe:  # drop-free reference for exactness
        cfg = replace(cfg, moe=replace(cfg.moe,
                                       capacity_factor=float(cfg.moe.num_experts) / cfg.moe.top_k))
    params = init_params(jax.random.PRNGKey(1), cfg)
    B, S = 2, 20
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (B, S + 2), 0, cfg.vocab_size)
    batch = _batch(cfg, B, S, key)
    batch["tokens"] = toks[:, :S]
    full = dict(batch)
    full["tokens"] = toks
    logits_full, _ = forward_train(params, cfg, full)
    _, cache = prefill(params, cfg, batch, cache_len=S + 3)
    for t in range(S, S + 2):
        lg, cache = decode_step(params, cfg, toks[:, t:t + 1], cache)
        a = np.asarray(logits_full[:, t, :], np.float32)
        b = np.asarray(lg[:, 0, :], np.float32)
        assert np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9) < 1e-4


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "llama4-scout-17b-a16e"])
def test_windowed_cache_is_bounded(arch):
    """SWA/chunked archs must hold a window-sized cache, not seq_len."""
    cfg = get_config(arch)
    specs = cache_specs(cfg, batch=1, seq_len=524288)
    for j, kind in enumerate(cfg.layer_kinds()[: len(specs["layers"])]):
        leaf = specs["layers"][f"pos{j}"]
        if "k" in leaf:
            S = leaf["k"].shape[2]
            if kind == "attn_swa":
                assert S <= cfg.sliding_window
            elif kind == "attn_chunk":
                assert S <= cfg.attn_chunk


def test_param_count_matches_init():
    """Analytic param_count agrees with actual init within 1%."""
    for arch in ["qwen1.5-4b", "mamba2-2.7b", "dbrx-132b"]:
        cfg = get_config(arch).reduced()
        params = init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
        assert abs(actual - cfg.param_count()) / actual < 0.01
