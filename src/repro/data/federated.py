"""Federated data substrate: non-IID client splits + convex logreg problems.

The dissertation's convex experiments (Ch. 2, 3, 5) run l2-regularized logistic
regression on LibSVM datasets split feature-wise / class-wise / Dirichlet
non-IID across clients.  LibSVM is unavailable offline, so we generate
controlled synthetic classification data with the same knobs (client
heterogeneity, conditioning) — heterogeneity is what the theory cares about
(mu_i, L_i spread, gradient diversity at the optimum), and we control it
exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np


def dirichlet_split(labels: np.ndarray, n_clients: int, alpha: float, seed: int = 0) -> List[np.ndarray]:
    """Dirichlet(alpha) label-skew split (the paper's S2). Returns index lists."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    client_idx: List[list] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet(alpha * np.ones(n_clients))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            client_idx[i].extend(part.tolist())
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in client_idx]


def classwise_split(labels: np.ndarray, n_clients: int, classes_per_client: int = 2, seed: int = 0) -> List[np.ndarray]:
    """Class-wise non-IID split (the paper's S1): each client sees few classes."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    assign = [rng.choice(classes, size=classes_per_client, replace=False) for _ in range(n_clients)]
    pools = {c: list(np.flatnonzero(labels == c)) for c in classes}
    for c in pools:
        rng.shuffle(pools[c])
    # counts is positional: index by the class's position in `classes`, not by
    # the raw label value (non-contiguous label sets like {1, 3, 7} would
    # crash or silently credit the wrong class)
    pos = {c: i for i, c in enumerate(classes)}
    counts = np.zeros(len(classes), dtype=int)
    for a in assign:
        for c in a:
            counts[pos[c]] += 1
    client_idx: List[list] = [[] for _ in range(n_clients)]
    for i, a in enumerate(assign):
        for c in a:
            pool = pools[c]
            take = max(1, len(pool) // counts[pos[c]])
            client_idx[i].extend(pool[:take])
            pools[c] = pool[take:]
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in client_idx]


@dataclass
class FederatedLogReg:
    """n_clients l2-regularized logistic-regression objectives.

    f_i(x) = 1/n_i sum_j log(1+exp(-b_ij a_ij^T x)) + mu/2 ||x||^2
    Heterogeneity: each client's features are drawn around a client-specific
    mean direction scaled by ``hetero`` (0 => IID).
    """
    A: np.ndarray          # (n_clients, m, d)
    b: np.ndarray          # (n_clients, m) in {-1, +1}
    mu: float

    @property
    def n_clients(self) -> int:
        return self.A.shape[0]

    @property
    def dim(self) -> int:
        return self.A.shape[2]

    def smoothness(self) -> np.ndarray:
        """Per-client L_i = ||A_i||_row^2 / (4 m) + mu (paper Ch.3 formula)."""
        m = self.A.shape[1]
        return (np.sum(self.A**2, axis=(1, 2)) / (4 * m)) + self.mu


def make_logreg_clients(
    n_clients: int = 10,
    m: int = 200,
    d: int = 40,
    mu: float = 0.1,
    hetero: float = 1.0,
    seed: int = 0,
) -> FederatedLogReg:
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_clients, m, d))
    # client-specific shift + scale => heterogeneous mu_i/L_i and non-IID data
    shift = rng.normal(size=(n_clients, 1, d)) * hetero
    scale = 1.0 + hetero * rng.random((n_clients, 1, 1))
    A = (A + shift) * scale
    x_true = rng.normal(size=d)
    w_true = x_true + hetero * rng.normal(size=(n_clients, d))  # per-client label rule
    logits = np.einsum("nmd,nd->nm", A, w_true)
    p = 1 / (1 + np.exp(-logits))
    b = np.where(rng.random((n_clients, m)) < p, 1.0, -1.0)
    return FederatedLogReg(A=A.astype(np.float64), b=b.astype(np.float64), mu=mu)
