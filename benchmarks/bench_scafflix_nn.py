"""Fig 3.2 reproduction: Scafflix generalization on a federated NEURAL NET.

The paper trains CNN/RNN models on FEMNIST/Shakespeare; offline we use the
synthetic non-IID classification task (Dirichlet label skew across 10
clients) with an MLP — the phenomenon under test is the same: personalized
Scafflix reaches higher held-out accuracy in fewer communication rounds than
FedAvg and than FLIX-with-SGD.

Scafflix runs on the *flattened* parameter vector per client (the algorithm
is dimension-agnostic); per-client personalized models are evaluated on
per-client held-out splits (alpha-mixture of global and local-optimal nets).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from benchmarks.common import emit, now_s
from repro.core.fedp3 import init_mlp_params, make_classification, mlp_apply, xent
from repro.core.scafflix import scafflix_init, scafflix_run
from repro.data.federated import dirichlet_split

N_CLIENTS = 10
SIZES = [24, 48, 48, 6]
ROUNDS = 60
P_COMM = 0.2


def _federated_data(seed=0, per_client=120):
    X, y = make_classification(n=4000, d=SIZES[0], nclass=SIZES[-1], seed=seed,
                               sep=0.9, label_noise=0.08)
    idx = dirichlet_split(y, N_CLIENTS, alpha=0.5, seed=seed)
    rng = np.random.default_rng(seed)
    Xtr, Ytr, Xte, Yte = [], [], [], []
    for ix in idx:
        ix = rng.permutation(ix)
        take = rng.choice(ix, size=per_client, replace=True)
        test = rng.choice(ix, size=per_client // 2, replace=True)
        Xtr.append(X[take]); Ytr.append(y[take])
        Xte.append(X[test]); Yte.append(y[test])
    return (jnp.asarray(np.stack(Xtr)), jnp.asarray(np.stack(Ytr)),
            jnp.asarray(np.stack(Xte)), jnp.asarray(np.stack(Yte)))


def run():
    Xtr, Ytr, Xte, Yte = _federated_data()
    params0 = init_mlp_params(jax.random.PRNGKey(0), SIZES)
    flat0, unravel = ravel_pytree(params0)
    d = flat0.shape[0]

    def client_loss(flat, Xc, Yc):
        return xent(unravel(flat), Xc, Yc, SIZES[-1])

    grad_one = jax.grad(client_loss)
    grad_all = jax.jit(jax.vmap(grad_one, in_axes=(0, 0, 0)))

    def acc_personalized(x_global, x_star, alphas):
        xt = alphas[:, None] * x_global[None] + (1 - alphas[:, None]) * x_star
        accs = []
        for i in range(N_CLIENTS):
            logits = mlp_apply(unravel(xt[i]), Xte[i])
            accs.append(float(jnp.mean(jnp.argmax(logits, 1) == Yte[i])))
        return float(np.mean(accs))

    # ---- per-client local optima x_i* (the FLIX anchors)
    t0 = now_s()
    @jax.jit
    def local_opt(Xc, Yc):
        def body(x, _):
            return x - 0.3 * grad_one(x, Xc, Yc), None
        x, _ = jax.lax.scan(body, flat0, None, length=300)
        return x

    x_star = jnp.stack([local_opt(Xtr[i], Ytr[i]) for i in range(N_CLIENTS)])
    t_local = (now_s() - t0) * 1e6

    rows = []
    grads_at = lambda xt: grad_all(xt, Xtr, Ytr)

    # ---- Scafflix at several alphas (personalization sweep, Fig 3.2/3.3a)
    for alpha in (0.3, 0.5, 1.0):
        alphas = jnp.full((N_CLIENTS,), alpha)
        gammas = jnp.full((N_CLIENTS,), 0.1)
        st = scafflix_init(flat0, N_CLIENTS, x_star)
        t0 = now_s()
        st, (_, comms) = scafflix_run(jax.random.PRNGKey(1), st, grads_at,
                                      P_COMM, gammas, alphas, ROUNDS)
        us = (now_s() - t0) * 1e6
        acc = acc_personalized(jnp.mean(st.x, 0), x_star, alphas)
        rows.append((f"scafflix_fig3.2/scafflix_alpha={alpha}", us,
                     f"test_acc={acc:.3f};comms={int(np.asarray(comms).sum())}"))

    # ---- FedAvg baseline: local SGD + periodic averaging (same comm budget)
    t0 = now_s()
    x = jnp.tile(flat0[None], (N_CLIENTS, 1))
    comms = 0
    rng = np.random.default_rng(2)
    for r in range(ROUNDS):
        x = x - 0.1 * grads_at(x)
        if rng.random() < P_COMM:  # same expected communication as Scafflix
            x = jnp.tile(jnp.mean(x, 0)[None], (N_CLIENTS, 1))
            comms += 1
    us = (now_s() - t0) * 1e6
    logits_acc = []
    for i in range(N_CLIENTS):
        logits = mlp_apply(unravel(jnp.mean(x, 0)), Xte[i])
        logits_acc.append(float(jnp.mean(jnp.argmax(logits, 1) == Yte[i])))
    rows.append(("scafflix_fig3.2/fedavg", us,
                 f"test_acc={np.mean(logits_acc):.3f};comms={comms}"))

    # ---- FLIX with plain SGD (the paper's FLIX baseline)
    alphas = jnp.full((N_CLIENTS,), 0.3)
    x = flat0
    t0 = now_s()
    for r in range(ROUNDS):
        xt = alphas[:, None] * x[None] + (1 - alphas[:, None]) * x_star
        g = jnp.mean(alphas[:, None] * grads_at(xt), axis=0)
        x = x - 0.1 * g
    us = (now_s() - t0) * 1e6
    acc = acc_personalized(x, x_star, alphas)
    rows.append(("scafflix_fig3.2/flix_sgd_alpha=0.3", us,
                 f"test_acc={acc:.3f};comms={ROUNDS}"))
    rows.append(("scafflix_fig3.2/local_opt_setup", t_local, "300 steps/client"))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
