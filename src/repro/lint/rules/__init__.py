"""Engine-1 AST rules. Importing this package registers every rule."""
from repro.lint.rules import (  # noqa: F401 — registration side effects
    rl001_host_sync,
    rl002_randomness,
    rl003_wallclock,
    rl004_ledger_tags,
    rl005_tracer_branch,
)
