"""Render results/dryrun/*.json into the §Dry-run markdown table.

    PYTHONPATH=src python -m benchmarks.report_dryrun [--dir results/dryrun]
"""
import argparse
import glob
import json
import os
from collections import defaultdict

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--out", default="results/dryrun_table.md")
    args = ap.parse_args()

    recs = defaultdict(dict)
    for f in glob.glob(os.path.join(args.dir, "*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"])][r["mesh"]] = r

    lines = [
        "| arch | shape | 16x16 | args GB/chip | temp GB/chip | HLO GF/chip (raw) | coll MB/chip | 2x16x16 |",
        "|---|---|---|---|---|---|---|---|",
    ]
    ok = skipped = err = 0
    for (arch, shape) in sorted(recs, key=lambda k: (k[0], SHAPES.index(k[1]))):
        sp = recs[(arch, shape)].get("16x16", {})
        mp = recs[(arch, shape)].get("2x16x16", {})
        st = sp.get("status", "?")
        if st == "skipped":
            lines.append(f"| {arch} | {shape} | skipped | - | - | - | - | skipped |")
            skipped += 1
            continue
        if st == "error":
            lines.append(f"| {arch} | {shape} | ERROR | - | - | - | - | {mp.get('status','?')} |")
            err += 1
            continue
        ok += 1
        mem = sp.get("memory", {})
        cost = sp.get("cost", {})
        coll = sp.get("collectives", {})
        lines.append(
            f"| {arch} | {shape} | ok ({sp.get('compile_s','?')}s) "
            f"| {fmt_bytes(mem.get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(mem.get('temp_size_in_bytes'))} "
            f"| {cost.get('flops', 0)/1e9:.0f} "
            f"| {coll.get('total_bytes', 0)/1e6:.1f} "
            f"| {mp.get('status','?')} ({mp.get('compile_s','?')}s) |")
    summary = f"\n{ok} ok, {skipped} skipped, {err} error of {ok+skipped+err} (arch,shape) combos.\n"
    out = "\n".join(lines) + summary
    with open(args.out, "w") as f:
        f.write(out)
    print(out)


if __name__ == "__main__":
    main()
