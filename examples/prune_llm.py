"""SymWanda post-training pruning of a trained tiny LM (Ch. 6).

Trains a reduced assigned-arch model briefly, collects real calibration
activations, prunes every MLP with magnitude / Wanda / RIA / SymWanda at
50-60% sparsity (optionally 2:4 structured via the Pallas kernel), applies
R^2-DSnoT training-free fine-tuning, and reports the LM loss ladder:

    PYTHONPATH=src python examples/prune_llm.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core import symwanda as sw
from repro.data.synthetic import SyntheticLMDataset, lm_batch_iterator
from repro.models import forward_train
from repro.models.layers import cross_entropy_loss, embed, rmsnorm
from repro.training.loop import train


def calib_acts(params, cfg, batch):
    x = embed(params["embed"], batch["tokens"])
    bp0 = jax.tree_util.tree_map(lambda a: a[0], params["blocks"]["pos0"])
    h = rmsnorm(bp0["norm1"], x)
    return h.reshape(-1, cfg.d_model)


def prune_all_mlps(params, X, method, sparsity, dsnot=False):
    pruned = jax.tree_util.tree_map(lambda a: a, params)
    for pos, bp in params["blocks"].items():
        if "mlp" not in bp:
            continue
        stack = bp["mlp"]["w_in"]
        new = []
        for li in range(stack.shape[0]):
            W = stack[li]
            Wp, mask = sw.prune(W, X, method=method, sparsity=sparsity,
                                key=jax.random.PRNGKey(li))
            if dsnot:
                Wp, _ = sw.r2_dsnot(W, mask, X, sw.DSnoTConfig(iters=20))
            new.append(Wp)
        pruned["blocks"][pos]["mlp"]["w_in"] = jnp.stack(new)
    return pruned


def main():
    cfg = get_config("qwen1.5-4b").reduced()
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, length=60000, seed=0)
    it = lm_batch_iterator(ds, 8, 64, seed=1)
    tc = TrainConfig(model=cfg, seq_len=64, global_batch=8, lr=3e-3,
                     warmup_steps=10, total_steps=300)
    state, hist = train(cfg, tc, it, steps=300, log_every=100)
    params = state.params

    b = next(it)
    batch = {"tokens": jnp.asarray(b["tokens"][:, :-1]),
             "targets": jnp.asarray(b["tokens"][:, 1:])}
    X = calib_acts(params, cfg, batch)

    base_logits, _ = forward_train(params, cfg, batch)
    base = float(cross_entropy_loss(base_logits, batch["targets"]))
    print(f"dense loss: {base:.4f}")

    for sparsity in (0.5, 0.6):
        print(f"-- sparsity {sparsity:.0%} --")
        for method in ("magnitude", "wanda", "ria", "symwanda"):
            p = prune_all_mlps(params, X, method, sparsity)
            lg, _ = forward_train(p, cfg, batch)
            loss = float(cross_entropy_loss(lg, batch["targets"]))
            print(f"  {method:10s} loss {loss:.4f} (+{loss-base:.4f})")
        p = prune_all_mlps(params, X, "wanda", sparsity, dsnot=True)
        lg, _ = forward_train(p, cfg, batch)
        loss = float(cross_entropy_loss(lg, batch["targets"]))
        print(f"  {'wanda+R2DSnoT':10s} loss {loss:.4f} (+{loss-base:.4f})")


if __name__ == "__main__":
    main()
