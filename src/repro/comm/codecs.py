"""Wire-level payload codecs: packed buffers for every compressor family.

The seed repo *modeled* compression savings analytically (``payload_bits``);
this module makes them real: ``encode(compressor, key, x)`` produces the
actual packed planes a transport would ship, and ``decode`` reconstructs the
dense carrier **bit-for-bit equal** to ``compressor(key, x)``.  Byte counts
therefore come from real buffers, not a formula — the CommLedger records
``payload.nbytes`` and the analytic model is only a cross-check.

Schemes (selected by the compressor's ``wire`` spec, overridable):

  dense         fp32 value plane (identity / uncompressed sync)
  sparse_idx32  uint32 global indices + fp32 values — 64 bits per kept
                coordinate, the format the paper's Fig 2.2 counting assumes
                (top-k, rand-k, mix, comp)
  sparse_block  per-block bitpacked local indices (ceil(log2 block) bits) +
                fp32 values + uint16 per-block counts (block top-k)
  sparse_bitmap presence bitmap (1 bit/coordinate, Pallas pack_mask kernel)
                + fp32 values — smaller than idx32 whenever k/d > 1/32
  quant         int8 plane (int4: two nibbles per byte) + per-block fp32
                scales; the ``kernel`` flavor is produced by the fused Pallas
                quantize-pack kernel

Encode/decode run at communication-round boundaries (host side, numpy for the
data-dependent gathers); the Pallas kernels cover the static-shape packing
that would run on-device.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compressors import Compressor, WireSpec
from repro.obs import trace as obs_trace


class PayloadError(ValueError):
    """A wire payload failed validation (truncated/corrupt/inconsistent).

    ``plane`` names the offending plane so transports can report *which*
    buffer was damaged; decode raises this instead of mis-slicing truncated
    buffers into garbage tensors.
    """

    def __init__(self, plane: str, message: str):
        self.plane = plane
        super().__init__(f"plane {plane!r}: {message}")


@dataclass
class Payload:
    """One encoded tensor as it would sit in a transport buffer.

    ``planes`` are the wire buffers (numpy, final dtypes); ``nbytes`` is their
    exact total — the single number every ledger entry and benchmark reports.
    Small per-message header fields (shape, scheme tag, gain) live in ``meta``
    and are excluded from ``nbytes``, matching the analytic model's convention.
    """
    scheme: str
    shape: tuple
    dtype: str
    planes: Dict[str, np.ndarray]
    meta: dict = field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return int(sum(p.nbytes for p in self.planes.values()))

    @property
    def nbits(self) -> int:
        return 8 * self.nbytes


# ---------------------------------------------------------------------------
# bit-stream helpers (little-endian, numpy — host-side transport packing)
# ---------------------------------------------------------------------------
# a value shifted by its in-byte offset (<= 7 bits) must still fit in the
# uint64 scatter words below; repro.lint.contracts checks every registered
# sparse-block width against this bound
_PACK_MAX_NBITS = 56


def _pack_uint_stream(vals: np.ndarray, nbits: int) -> np.ndarray:
    """Pack unsigned ints < 2**nbits into a little-endian uint8 stream.

    Word-wise: value i's bits land at bit offset i*nbits, so after shifting
    each value by its in-byte offset it spans at most ceil(nbits/8)+1 bytes;
    the scatter-or below runs that many vectorized passes instead of
    materializing the (n, nbits) uint8 bit matrix the old packbits path built.
    """
    n = int(vals.size)
    if n == 0:
        return np.zeros((0,), np.uint8)
    assert nbits <= _PACK_MAX_NBITS, nbits
    total = (n * nbits + 7) >> 3
    bitpos = np.arange(n, dtype=np.int64) * nbits
    byte0 = bitpos >> 3
    # truncate to nbits like the old per-bit path did — an out-of-range value
    # must not scatter-OR stray bits into its neighbors' bytes
    vals = vals.astype(np.uint64) & np.uint64((1 << nbits) - 1)
    shifted = vals << (bitpos & 7).astype(np.uint64)
    out = np.zeros(total, np.uint8)
    for b in range(((nbits + 7) >> 3) + 1):
        byte = byte0 + b
        valid = byte < total
        contrib = ((shifted >> np.uint64(8 * b)) & np.uint64(0xFF)).astype(np.uint8)
        np.bitwise_or.at(out, byte[valid], contrib[valid])
    return out


def _unpack_uint_stream(buf: np.ndarray, n: int, nbits: int) -> np.ndarray:
    if n == 0:
        return np.zeros((0,), np.int64)
    assert nbits <= _PACK_MAX_NBITS, nbits
    spans = ((nbits + 7) >> 3) + 1
    bufp = np.concatenate([buf, np.zeros(spans, np.uint8)])  # tail gathers
    bitpos = np.arange(n, dtype=np.int64) * nbits
    byte0 = bitpos >> 3
    acc = np.zeros(n, np.uint64)
    for b in range(spans):
        acc |= bufp[byte0 + b].astype(np.uint64) << np.uint64(8 * b)
    acc >>= (bitpos & 7).astype(np.uint64)
    return (acc & np.uint64((1 << nbits) - 1)).astype(np.int64)


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------
def encode(c: Compressor, key, x, scheme: Optional[str] = None) -> Payload:
    """Compress ``x`` with ``c`` and pack the result into wire planes.

    The dense carrier ``y = c(key, x)`` is what the algorithm consumes; the
    payload is an exact packed representation of it: decode(encode(...)) == y.
    """
    if obs_trace.enabled():  # flight-recorder: host-side pack is a real phase
        with obs_trace.span("codec/encode") as sp:
            p = _encode(c, key, x, scheme)
            sp.tag(scheme=p.scheme, nbytes=p.nbytes)
        return p
    return _encode(c, key, x, scheme)


def _encode(c: Compressor, key, x, scheme: Optional[str] = None) -> Payload:
    spec = c.wire or WireSpec("dense")
    scheme = scheme or spec.scheme
    if scheme == "quant" and spec.axis == "kernel":
        # the fused Pallas path re-derives the planes from x with the same
        # noise; computing the dense carrier here would duplicate that pass
        return _encode_quant(None, x, spec, key)
    y = c(key, x)
    if scheme == "dense":
        return _encode_dense(y)
    if scheme == "sparse_idx32":
        return _encode_sparse_idx32(y)
    if scheme == "sparse_block":
        return _encode_sparse_block(y, spec.block)
    if scheme == "sparse_bitmap":
        return _encode_sparse_bitmap(y)
    if scheme == "quant":
        return _encode_quant(y, x, spec, key)
    raise ValueError(f"unknown wire scheme {scheme!r}")


def _require(cond: bool, plane: str, message: str) -> None:
    if not cond:
        raise PayloadError(plane, message)


def validate_payload(p: Payload) -> None:
    """Check plane lengths / bounds before any slicing; raise ``PayloadError``
    naming the offending plane on truncated or inconsistent buffers."""
    d = int(np.prod(p.shape)) if p.shape else 1
    if p.scheme == "dense":
        v = p.planes.get("values")
        _require(v is not None, "values", "missing")
        _require(v.size == d, "values", f"{v.size} values for shape {p.shape}")
        return
    if p.scheme == "sparse_idx32":
        idx, vals = p.planes.get("indices"), p.planes.get("values")
        _require(idx is not None, "indices", "missing")
        _require(vals is not None, "values", "missing")
        _require(idx.size == vals.size, "indices",
                 f"{idx.size} indices vs {vals.size} values")
        if idx.size:
            _require(int(idx.max()) < d, "indices",
                     f"index {int(idx.max())} out of range for d={d}")
        return
    if p.scheme == "sparse_block":
        block, nbits = p.meta.get("block"), p.meta.get("nbits")
        _require(isinstance(block, int) and block > 0, "local_indices",
                 f"bad block {block!r}")
        _require(isinstance(nbits, int) and 1 <= nbits <= 56, "local_indices",
                 f"nbits {nbits!r} outside [1, 56]")
        counts = p.planes.get("block_counts")
        _require(counts is not None, "block_counts", "missing")
        nb = -(-d // block)
        _require(counts.size == nb, "block_counts",
                 f"{counts.size} counts for {nb} blocks")
        _require(bool(np.all(counts.astype(np.int64) <= block)),
                 "block_counts", f"count exceeds block size {block}")
        k = int(counts.astype(np.int64).sum())
        vals = p.planes.get("values")
        _require(vals is not None, "values", "missing")
        _require(vals.size == k, "values", f"{vals.size} values for k={k}")
        stream = p.planes.get("local_indices")
        _require(stream is not None, "local_indices", "missing")
        want = (k * nbits + 7) >> 3
        _require(stream.nbytes == want, "local_indices",
                 f"{stream.nbytes} bytes, expected {want}")
        return
    if p.scheme == "sparse_bitmap":
        words, vals = p.planes.get("mask_words"), p.planes.get("values")
        _require(words is not None, "mask_words", "missing")
        _require(vals is not None, "values", "missing")
        dd = int(p.meta.get("d", d))
        nw = -(-dd // 32)
        _require(words.size == nw, "mask_words",
                 f"{words.size} words for d={dd}")
        pop = int(np.unpackbits(
            np.ascontiguousarray(words).view(np.uint8)).sum())
        _require(pop == vals.size, "values",
                 f"{vals.size} values vs {pop} set mask bits")
        return
    if p.scheme == "quant":
        bits = p.meta.get("bits")
        _require(isinstance(bits, int) and 1 <= bits <= 8, "q",
                 f"bits {bits!r} outside [1, 8]")
        q, scales = p.planes.get("q"), p.planes.get("scales")
        _require(q is not None, "q", "missing")
        _require(scales is not None, "scales", "missing")
        if p.meta.get("axis") == "kernel":
            rows, qb = p.meta["rows"], p.meta["qblock"]
            kept = _q_keep(int(p.meta["d"]), (rows, qb))
            want = (kept + 1) // 2 if bits <= 4 else kept
            _require(q.nbytes == want, "q",
                     f"{q.nbytes} bytes, expected {want}")
            _require(scales.size == rows, "scales",
                     f"{scales.size} scales for {rows} rows")
            return
        n = int(np.prod(p.meta["qshape"]))
        want = (n + 1) // 2 if bits <= 4 else n
        _require(q.nbytes == want, "q", f"{q.nbytes} bytes, expected {want}")
        nsc = int(np.prod(p.meta["scale_shape"]))
        _require(scales.size == nsc, "scales",
                 f"{scales.size} scales, expected {nsc}")
        return
    raise PayloadError("<scheme>", f"unknown wire scheme {p.scheme!r}")


def seal_payload(p: Payload) -> Payload:
    """Stamp a CRC32 per plane into ``meta['crc32']`` (the checksummed
    payload header a transport ships alongside the planes)."""
    p.meta["crc32"] = {k: zlib.crc32(np.ascontiguousarray(v).view(np.uint8))
                       for k, v in p.planes.items()}
    return p


def verify_payload(p: Payload) -> None:
    """Recompute plane checksums against the sealed header; raise
    ``PayloadError`` naming the first corrupted plane."""
    sums = p.meta.get("crc32")
    if sums is None:
        return
    for k, v in p.planes.items():
        if k not in sums:
            raise PayloadError(k, "no checksum in sealed header")
        got = zlib.crc32(np.ascontiguousarray(v).view(np.uint8))
        if got != sums[k]:
            raise PayloadError(
                k, f"checksum mismatch (got {got:#010x}, "
                   f"sealed {sums[k]:#010x})")


def decode(p: Payload):
    """Reconstruct the dense compressed carrier from the wire planes.

    Validates plane lengths/bounds (and checksums, when the payload was
    sealed) up front — truncated or corrupt buffers raise ``PayloadError``
    instead of mis-slicing into garbage tensors.
    """
    if obs_trace.enabled():
        with obs_trace.span("codec/decode", scheme=p.scheme,
                            nbytes=p.nbytes):
            return _decode(p)
    return _decode(p)


def _decode(p: Payload):
    validate_payload(p)
    verify_payload(p)
    if p.scheme == "dense":
        out = p.planes["values"].astype(p.meta.get("plane_dtype", p.dtype))
        return jnp.asarray(out.reshape(p.shape)).astype(p.dtype)
    if p.scheme == "sparse_idx32":
        flat = np.zeros(int(np.prod(p.shape)), np.float32)
        flat[p.planes["indices"].astype(np.int64)] = p.planes["values"]
        return jnp.asarray(flat.reshape(p.shape)).astype(p.dtype)
    if p.scheme == "sparse_block":
        return _decode_sparse_block(p)
    if p.scheme == "sparse_bitmap":
        return _decode_sparse_bitmap(p)
    if p.scheme == "quant":
        return _decode_quant(p)
    raise ValueError(f"unknown wire scheme {p.scheme!r}")


def roundtrip_equal(c: Compressor, key, x) -> bool:
    """decode(encode(x)) == compressor(x), elementwise exact."""
    y = c(key, x)
    y_hat = decode(encode(c, key, x))
    return bool(jnp.all(jnp.asarray(y) == jnp.asarray(y_hat)))


# ---------------------------------------------------------------------------
# per-scheme implementations
# ---------------------------------------------------------------------------
def _encode_dense(y) -> Payload:
    arr = np.asarray(y)
    return Payload("dense", tuple(arr.shape), str(arr.dtype),
                   {"values": arr.reshape(-1)},
                   {"plane_dtype": str(arr.dtype)})


def _encode_sparse_idx32(y) -> Payload:
    arr = np.asarray(y, np.float32).reshape(-1)
    idx = np.flatnonzero(arr)
    return Payload("sparse_idx32", tuple(np.shape(y)), str(np.asarray(y).dtype),
                   {"indices": idx.astype(np.uint32), "values": arr[idx]})


def _encode_sparse_block(y, block: int) -> Payload:
    arr = np.asarray(y, np.float32).reshape(-1)
    d = arr.shape[0]
    nbits = max(1, math.ceil(math.log2(block)))
    nb = -(-d // block)
    idx = np.flatnonzero(arr)
    counts = np.bincount(idx // block, minlength=nb).astype(np.uint16)
    local = (idx % block).astype(np.uint64)
    return Payload(
        "sparse_block", tuple(np.shape(y)), str(np.asarray(y).dtype),
        {"local_indices": _pack_uint_stream(local, nbits),
         "values": arr[idx],
         "block_counts": counts},
        {"block": block, "nbits": nbits})


def _decode_sparse_block(p: Payload):
    d = int(np.prod(p.shape))
    block, nbits = p.meta["block"], p.meta["nbits"]
    counts = p.planes["block_counts"].astype(np.int64)
    vals = p.planes["values"]
    local = _unpack_uint_stream(p.planes["local_indices"], int(counts.sum()), nbits)
    base = np.repeat(np.arange(counts.shape[0], dtype=np.int64) * block, counts)
    flat = np.zeros(d, np.float32)
    flat[base + local] = vals
    return jnp.asarray(flat.reshape(p.shape)).astype(p.dtype)


def _encode_sparse_bitmap(y) -> Payload:
    from repro.kernels import ops

    arr = np.asarray(y, np.float32).reshape(-1)
    d = arr.shape[0]
    idx = np.flatnonzero(arr)
    words = np.asarray(ops.pack_bits(jnp.asarray(arr != 0.0)))
    return Payload("sparse_bitmap", tuple(np.shape(y)), str(np.asarray(y).dtype),
                   {"mask_words": words, "values": arr[idx]},
                   {"d": d})


def _decode_sparse_bitmap(p: Payload):
    from repro.kernels import ops

    d = p.meta["d"]
    mask = np.asarray(ops.unpack_bits(jnp.asarray(p.planes["mask_words"]), d))
    # pack_bits uses a stride-W bit order; unpack restores flat order, so the
    # set bits enumerate kept coordinates in ascending flat index — the same
    # order flatnonzero produced the value plane in.
    flat = np.zeros(d, np.float32)
    flat[np.flatnonzero(mask)] = p.planes["values"]
    return jnp.asarray(flat.reshape(p.shape)).astype(p.dtype)


def _quant_scales(x, spec: WireSpec):
    """Recompute the compressor's per-block scales from the *input* tensor
    (the scales are derived data the receiver needs: they ride in the
    payload).  Mirrors each quantizer's blocking exactly."""
    s = 2 ** (spec.bits - 1) - 1
    x = jnp.asarray(x)
    if spec.axis == "last":
        last = x.shape[-1] if x.ndim else 1
        if x.ndim >= 1 and last % spec.block == 0:
            shaped = x.reshape(x.shape[:-1] + (last // spec.block, spec.block))
            scale = jnp.max(jnp.abs(shaped), axis=-1, keepdims=True) / s
        else:
            shaped = x
            scale = jnp.max(jnp.abs(x)) / s
        return jnp.where(scale == 0, 1.0, scale), shaped.shape
    flat = x.reshape(-1)
    d = flat.shape[0]
    nb = -(-d // spec.block)
    xp = jnp.pad(flat, (0, nb * spec.block - d)).reshape(nb, spec.block)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / s
    return jnp.where(scale == 0, 1.0, scale), (nb, spec.block)


def _store_q(q: np.ndarray, bits: int) -> np.ndarray:
    if bits <= 4:
        from repro.kernels import ops
        return np.asarray(ops.nibble_pack(jnp.asarray(q)))
    return q.astype(np.int8)


def _load_q(plane: np.ndarray, bits: int, n: int) -> np.ndarray:
    if bits <= 4:
        from repro.kernels import ops
        return np.asarray(ops.nibble_unpack(jnp.asarray(plane), n))
    return plane


def _encode_quant(y, x, spec: WireSpec, key) -> Payload:
    if spec.axis == "kernel":
        # fused Pallas quantize-pack: same padding + noise as the compressor's
        # quantize_dequantize, so q * scales == y bit-for-bit
        from repro.kernels import ops

        q, scales = ops.quantize_pack(jnp.asarray(x), key, bits=spec.bits)
        d = int(np.prod(np.shape(x)))
        kept = _q_keep(d, q.shape)
        rows_used = kept // q.shape[1]
        # the kernel plane is TILE_ROWS-padded; ship only rows that carry data
        # (q AND scales — padding rows' scales are the filler 1.0, dead weight)
        return Payload(
            "quant", tuple(np.shape(x)), str(np.asarray(x).dtype),
            {"q": _store_q(np.asarray(q).reshape(-1)[:kept], spec.bits),
             "scales": np.asarray(scales, np.float32).reshape(-1)[:rows_used]},
            {"bits": spec.bits, "axis": "kernel", "gain": spec.gain,
             "rows": rows_used, "qblock": q.shape[1], "d": d})
    # derive the integer plane from the dense carrier: y = gain * q * scale,
    # so rint(y / (gain * scale)) recovers q exactly (error << 0.5)
    scale, shaped = _quant_scales(x, spec)
    y_shaped = _pad_like(jnp.asarray(y, jnp.float32), spec, shaped)
    q = jnp.rint(y_shaped / (scale * spec.gain)).astype(jnp.int32)
    s = 2 ** (spec.bits - 1) - 1
    q = jnp.clip(q, -s, s)
    qn = np.asarray(q, np.int8).reshape(-1)
    return Payload(
        "quant", tuple(np.shape(y)), str(np.asarray(y).dtype),
        {"q": _store_q(qn, spec.bits),
         "scales": np.asarray(scale, np.float32).reshape(-1)},
        {"bits": spec.bits, "axis": spec.axis, "gain": spec.gain,
         "qshape": tuple(q.shape), "scale_shape": tuple(np.shape(scale)),
         "d": int(np.prod(np.shape(y)))})


def _q_keep(d: int, qshape) -> int:
    # the kernel plane is row-padded; ship only rows that carry data
    rows_used = -(-d // qshape[1])
    return rows_used * qshape[1]


def _pad_like(y_flat, spec: WireSpec, shaped):
    """View the dense carrier in the quantizer's block layout."""
    if spec.axis == "last":
        return y_flat.reshape(shaped)
    d = y_flat.reshape(-1).shape[0]
    nb, block = shaped
    return jnp.pad(y_flat.reshape(-1), (0, nb * block - d)).reshape(nb, block)


def _decode_quant(p: Payload):
    d = p.meta["d"]
    gain = p.meta["gain"]
    if p.meta["axis"] == "kernel":
        rows, qb = p.meta["rows"], p.meta["qblock"]
        kept = _q_keep(d, (rows, qb))
        q = np.zeros((rows * qb,), np.int8)
        q[:kept] = _load_q(p.planes["q"], p.meta["bits"], kept)
        q = q.reshape(rows, qb).astype(np.float32)
        scales = p.planes["scales"].reshape(rows, 1)
        out = (q * scales).reshape(-1)[:d]
        if gain != 1.0:
            out = gain * out
        return jnp.asarray(out.reshape(p.shape)).astype(p.dtype)
    qshape = p.meta["qshape"]
    n = int(np.prod(qshape))
    q = _load_q(p.planes["q"], p.meta["bits"], n).reshape(qshape).astype(np.float32)
    scales = p.planes["scales"].reshape(p.meta["scale_shape"])
    out = q * scales
    if gain != 1.0:
        out = gain * out
    if p.meta["axis"] == "last":
        return jnp.asarray(out.reshape(p.shape)).astype(p.dtype)
    return jnp.asarray(out.reshape(-1)[:d].reshape(p.shape)).astype(p.dtype)


# ---------------------------------------------------------------------------
# streaming (chunked) codecs
# ---------------------------------------------------------------------------
# One Chunk is the wire unit of the overlapped transport: the payload planes
# restricted to a tile of the flat coordinate space.  Chunks PARTITION the
# monolithic planes — concatenating them restores every plane byte-for-byte,
# so chunked decode equals whole-payload decode exactly and per-chunk ledger
# bytes sum exactly to the monolithic ``Payload.nbytes``.  Tile boundaries are
# aligned to each scheme's natural granule (quantizer block, QBLOCK rows,
# 32-bit mask words), matching the bucket layout in ``comm/buckets.py``.

DEFAULT_TILE = 1 << 14  # coordinates per streamed chunk


@dataclass
class Chunk:
    """Plane slices for one tile in flight; [start, stop) is the flat
    coordinate range the tile carries.  Value/index/count/scale planes are
    cut at true coordinate boundaries; the two bit-granular streams follow
    the byte stream instead of coordinates (sparse_block's packed indices
    split at the nearest byte, and sparse_bitmap's words keep the pack
    kernel's stride-W interleaved bit order), so chunks always PARTITION the
    monolithic planes exactly but those two planes only reassemble on
    concatenation — ``decode_stream`` — not per-chunk in isolation."""
    index: int
    start: int
    stop: int
    planes: Dict[str, np.ndarray]

    @property
    def nbytes(self) -> int:
        return int(sum(p.nbytes for p in self.planes.values()))

    @property
    def nbits(self) -> int:
        return 8 * self.nbytes


@dataclass
class StreamPayload:
    """A payload split into per-tile chunks (same wire format, streamed)."""
    scheme: str
    shape: tuple
    dtype: str
    tile: int
    chunks: list
    meta: dict = field(default_factory=dict)

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    @property
    def nbytes(self) -> int:
        return int(sum(ch.nbytes for ch in self.chunks))

    @property
    def nbits(self) -> int:
        return 8 * self.nbytes


def _stream_granule(p: Payload) -> int:
    """Smallest coordinate step a chunk boundary may take for this scheme."""
    if p.scheme == "sparse_block":
        return p.meta["block"]
    if p.scheme == "sparse_bitmap":
        return 32
    if p.scheme == "quant":
        if p.meta["axis"] == "kernel":
            g = p.meta["qblock"]
        else:
            qshape = p.meta["qshape"]
            nsc = max(1, int(np.prod(p.meta["scale_shape"])))
            blocked = nsc * qshape[-1] == int(np.prod(qshape))
            g = qshape[-1] if blocked else 1
        if p.meta["bits"] <= 4 and g % 2:
            g *= 2  # nibble-packed plane: keep chunk splits byte-aligned
        return g
    return 1


def _quant_scale_offsets(p: Payload, elem_off: np.ndarray) -> np.ndarray:
    nsc = p.planes["scales"].shape[0]
    if p.meta["axis"] == "kernel":
        block = p.meta["qblock"]
    else:
        qshape = p.meta["qshape"]
        blocked = nsc * qshape[-1] == int(np.prod(qshape))
        if not blocked:  # single global scale rides with the last chunk
            out = np.full(elem_off.shape, nsc, np.int64)
            out[:-1] = 0
            return out
        block = qshape[-1]
    out = np.minimum(elem_off // block, nsc)
    out[-1] = nsc
    return out


def _plane_offsets(p: Payload, tile: int, n: int) -> Dict[str, np.ndarray]:
    """Per-plane split offsets (length n+1, monotone, 0 .. plane length)."""
    d = int(np.prod(p.shape)) if p.shape else 1
    coord = np.minimum(np.arange(n + 1, dtype=np.int64) * tile, d)
    if p.scheme == "dense":
        return {"values": coord}
    if p.scheme == "sparse_idx32":
        pos = np.searchsorted(p.planes["indices"].astype(np.int64), coord)
        return {"indices": pos, "values": pos}
    if p.scheme == "sparse_block":
        block, nbits = p.meta["block"], p.meta["nbits"]
        nb = p.planes["block_counts"].shape[0]
        blocks = np.minimum(np.arange(n + 1, dtype=np.int64) * (tile // block), nb)
        blocks[-1] = nb
        kept = np.concatenate(
            [[0], np.cumsum(p.planes["block_counts"].astype(np.int64))])[blocks]
        stream_len = p.planes["local_indices"].shape[0]
        # the bitpacked index stream splits at byte granularity: a straddled
        # byte rides with the later chunk, concatenation is still exact
        sbytes = np.minimum((kept * nbits) >> 3, stream_len)
        sbytes[-1] = stream_len
        return {"local_indices": sbytes, "values": kept, "block_counts": blocks}
    if p.scheme == "sparse_bitmap":
        W = p.planes["mask_words"].shape[0]
        words = np.minimum(np.arange(n + 1, dtype=np.int64) * (tile // 32), W)
        words[-1] = W
        # flat-order mask straight from the words (pack_bits stride-W order:
        # bit j of word w is mask[j*W + w]) — no interpret-mode kernel launch
        bits = np.unpackbits(
            np.ascontiguousarray(p.planes["mask_words"]).view(np.uint8),
            bitorder="little").reshape(W, 32)
        mask = bits.T.reshape(-1)[: p.meta["d"]]
        kept = np.concatenate([[0], np.cumsum(mask.astype(np.int64))])[coord]
        return {"mask_words": words, "values": kept}
    if p.scheme == "quant":
        qlen = p.planes["q"].shape[0]
        if p.meta["bits"] <= 4:
            qoff = np.minimum(coord >> 1, qlen)  # two values per byte
        else:
            qoff = np.minimum(coord, qlen)
        qoff = qoff.copy()
        qoff[-1] = qlen  # padded / straddling tail rides with the last chunk
        return {"q": qoff, "scales": _quant_scale_offsets(p, coord)}
    raise ValueError(f"unknown wire scheme {p.scheme!r}")


def split_payload(p: Payload, tile: int = DEFAULT_TILE) -> StreamPayload:
    """Partition a monolithic payload into per-tile chunks (exact: chunk
    bytes sum to ``p.nbytes`` and concatenation restores every plane)."""
    d = int(np.prod(p.shape)) if p.shape else 1
    g = _stream_granule(p)
    tile = max(g, (int(tile) // g) * g)
    n = max(1, -(-d // tile))
    tracing = obs_trace.enabled()
    offs = _plane_offsets(p, tile, n)
    chunks = []
    for t in range(n):
        with (obs_trace.span("codec/encode_chunk", index=t) if tracing
              else obs_trace.NULL_SPAN) as csp:
            planes = {k: v[int(offs[k][t]): int(offs[k][t + 1])]
                      for k, v in p.planes.items()}
            ch = Chunk(t, min(t * tile, d), min((t + 1) * tile, d), planes)
            csp.tag(nbytes=ch.nbytes)
        chunks.append(ch)
    sp = StreamPayload(p.scheme, p.shape, p.dtype, tile, chunks, dict(p.meta))
    assert sp.nbytes == p.nbytes, (sp.nbytes, p.nbytes, p.scheme)
    return sp


def encode_stream(c: Compressor, key, x, tile: int = DEFAULT_TILE,
                  scheme: Optional[str] = None) -> StreamPayload:
    """Compress + pack ``x`` as per-tile chunks a streaming transport ships.

    One fused compressor/codec pass produces the planes and the partition
    attributes them to tiles so pack, send, and unpack overlap.  (The
    double-buffered ring in ``kernels/stream.py`` demonstrates the on-device
    tile-granular producer for the quant scheme — bit-identical planes — but
    this host-side path packs monolithically via ``ops.quantize_pack``.)
    """
    return split_payload(encode(c, key, x, scheme=scheme), tile)


def decode_stream(sp: StreamPayload):
    """Reassemble the chunk planes and decode — bit-exact vs ``decode``."""
    chunks = sorted(sp.chunks, key=lambda ch: ch.index)
    planes = {k: np.concatenate([ch.planes[k] for ch in chunks])
              for k in chunks[0].planes}
    return decode(Payload(sp.scheme, sp.shape, sp.dtype, planes, dict(sp.meta)))


def stream_roundtrip_equal(c: Compressor, key, x, tile: int = DEFAULT_TILE) -> bool:
    """decode_stream(encode_stream(x)) == compressor(x), elementwise exact."""
    y = c(key, x)
    y_hat = decode_stream(encode_stream(c, key, x, tile=tile))
    return bool(jnp.all(jnp.asarray(y) == jnp.asarray(y_hat)))


# ---------------------------------------------------------------------------
# size model
# ---------------------------------------------------------------------------
def encoded_bits(c: Compressor, key, x, scheme: Optional[str] = None) -> int:
    """Exact wire bits for one message (encode and count)."""
    return encode(c, key, x, scheme=scheme).nbits


def extrapolate_bits(p: Payload, probe_d: int, d: int) -> float:
    """Size a payload at dimension ``d`` from a probe encoded at ``probe_d``.

    Value planes scale linearly (the probe measures exact bits per kept
    coordinate), but index-side planes do NOT all scale with the coordinate
    count: a uint32 index is 32 bits per kept coordinate regardless of d,
    bitpacked block-local indices are ceil(log2 block) bits each with a byte-
    granular stream length, and block-count/bitmap/scale planes grow with the
    number of blocks (words) of the TRUE d.  So the index side is sized
    analytically from d while the kept-coordinate count comes from the probe.
    """
    scale = d / probe_d
    if p.scheme == "dense":
        return 8.0 * p.planes["values"].dtype.itemsize * d
    if p.scheme == "sparse_idx32":
        k = int(round(p.planes["values"].shape[0] * scale))
        return 32.0 * k + 32.0 * k           # uint32 indices + fp32 values
    if p.scheme == "sparse_block":
        block, nbits = p.meta["block"], p.meta["nbits"]
        k = int(round(p.planes["values"].shape[0] * scale))
        nb = -(-d // block)
        return (32.0 * k                      # fp32 values (measured k)
                + 8.0 * ((k * nbits + 7) // 8)  # bitpacked local indices
                + 16.0 * nb)                  # uint16 per-block counts
    if p.scheme == "sparse_bitmap":
        k = int(round(p.planes["values"].shape[0] * scale))
        return 32.0 * (-(-d // 32)) + 32.0 * k  # mask words + fp32 values
    if p.scheme == "quant":
        # integer plane is block-padded linear in d; the fp32 scale plane
        # counts the TRUE d's blocks
        bits = p.meta["bits"]
        n_sc = int(p.planes["scales"].size)
        if p.meta["axis"] == "kernel":
            block = p.meta["qblock"]
        else:
            qn = int(np.prod(p.meta["qshape"]))
            block = qn // n_sc if n_sc > 1 else 0
        if block:
            n_blocks = -(-d // block)
            qd, n_scales = n_blocks * block, n_blocks
        else:
            qd, n_scales = d, 1               # single global scale
        q_bytes = (qd + 1) // 2 if bits <= 4 else qd
        return 8.0 * q_bytes + 32.0 * n_scales
    raise ValueError(f"unknown wire scheme {p.scheme!r}")


def analytic_bits(c: Compressor, d: int) -> float:
    """The seed's closed-form model, kept as a cross-check target."""
    return c.payload_bits(d)
