"""Same violations as the bad_* fixtures, each suppressed in place."""
import time

import jax
import numpy as np

from repro.comm.ledger import CommLedger


@jax.jit
def step(x):
    lo = x.min().item()  # repro: noqa[RL001]
    if x > 0:  # repro: noqa[RL005]
        return x - lo
    return x


def noisy(shape):
    t0 = time.time()  # repro: noqa[RL003]
    led = CommLedger()
    led.record(0, "a->b", 128)  # repro: noqa[RL004]
    return np.random.randn(*shape), t0, led  # repro: noqa[RL002]
