"""Aggregation trees: arbitrary-depth link hierarchies (Cohort-Squeeze, Ch. 5).

The flat ``Topology`` hard-codes one intra/inter split, but the deployments
the dissertation measures have *more than two* link classes — device -> host
-> region -> cloud — and hierarchical aggregation wins precisely because each
extra hop lets a slower link carry a more aggressively compressed, less
frequent payload.  A ``TreeTopology`` is an ordered list of ``TreeLevel``s,
leaf-most first: level ``l`` groups ``fanout`` child nodes under one parent
and times their aggregation ring on that level's ``Link`` (with an optional
per-level ``CodecProfile`` for the compressed levels).  Today's two-level
``Topology`` is exactly the depth-2 special case (``TreeTopology.from_flat``).

Node counting: ``n_leaves = prod(fanout_l)``; level ``l`` has
``n_leaves / prod(fanout_0..l)`` parent nodes, and the last level's single
parent is the root.  The collective model per level is the same ring used by
``Topology`` (``ring_parts_s``), so a depth-2 tree reproduces the flat
preset's numbers bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

from repro.comm.topology import (DEFAULT_PROFILE, DEFAULT_TILE_BYTES,
                                 CodecProfile, Link, Topology, get_topology,
                                 ring_parts_s, ring_time_s,
                                 straggler_level_time_s, stream_pipeline_s)
from repro.faults.model import FaultConfig, LinkFaults


@dataclass(frozen=True)
class TreeLevel:
    """One aggregation hop: ``fanout`` children reach their parent over
    ``link``; compressed payloads at this level pay ``profile`` codec time.
    ``faults`` (optional) attaches this link class's per-message fault rates
    — a preset-level default a ``FaultConfig`` can still override by name."""
    name: str
    fanout: int
    link: Link
    profile: CodecProfile = DEFAULT_PROFILE
    faults: Optional[LinkFaults] = None


@dataclass(frozen=True)
class TreeTopology:
    """Named levels leaf-most first; ``levels[-1]`` reaches the root."""
    name: str
    levels: Tuple[TreeLevel, ...]

    def __post_init__(self):
        if not self.levels:
            raise ValueError("TreeTopology needs at least one level")
        from repro.comm.ledger import register_tag
        for lev in self.levels:
            if lev.fanout < 1:
                raise ValueError(f"level {lev.name!r}: fanout must be >= 1")
            # ledger records are tagged with the level name; register it so
            # bytes_by_tag() attribution stays within the known-tag namespace
            register_tag(lev.name)

    # -- shape ---------------------------------------------------------------
    @property
    def depth(self) -> int:
        return len(self.levels)

    @property
    def n_leaves(self) -> int:
        n = 1
        for lev in self.levels:
            n *= lev.fanout
        return n

    def n_parents(self, l: int) -> int:
        """Number of aggregator nodes at level ``l`` (1 at the root)."""
        n = self.n_leaves
        for lev in self.levels[: l + 1]:
            n //= lev.fanout
        return n

    def n_children(self, l: int) -> int:
        """Number of child nodes feeding level ``l`` (leaves for l=0)."""
        n = self.n_leaves
        for lev in self.levels[:l]:
            n //= lev.fanout
        return n

    def level_faults(self, l: int, cfg: Optional[FaultConfig]) -> LinkFaults:
        """Effective fault rates at level ``l``: the ``FaultConfig``'s
        per-level override wins, then the level's attached default, then the
        config's global rates (all-zero without a config)."""
        lev = self.levels[l]
        if cfg is not None and (cfg.has_override(lev.name)
                                or lev.faults is None):
            return cfg.link_faults(lev.name)
        if lev.faults is not None:
            return lev.faults
        return LinkFaults()

    def level_index(self, name: str) -> int:
        for i, lev in enumerate(self.levels):
            if lev.name == name:
                return i
        raise KeyError(f"unknown level {name!r}; known "
                       f"{[lev.name for lev in self.levels]}")

    def level(self, name: str) -> TreeLevel:
        return self.levels[self.level_index(name)]

    # -- timing (per-level ring model) ---------------------------------------
    def ring_parts_s(self, l: int, nbytes: float) -> tuple:
        lev = self.levels[l]
        return ring_parts_s(lev.link, lev.fanout, nbytes)

    def ring_time_s(self, l: int, nbytes: float) -> float:
        lev = self.levels[l]
        return ring_time_s(lev.link, lev.fanout, nbytes)

    def level_serial_time_s(self, l: int, nbytes: float, codec: bool = True,
                            profile: CodecProfile = None) -> float:
        """Monolithic pass at level ``l``: pack -> ring -> unpack (``codec=
        False`` for dense fp32 levels, which ship without a codec;
        ``profile`` overrides the level's own codec profile)."""
        prof = profile or self.levels[l].profile
        t = self.ring_time_s(l, nbytes)
        if not codec:
            return t
        return prof.pack_s(nbytes) + t + prof.unpack_s(nbytes)

    def level_stream_time_s(self, l: int, nbytes: float,
                            tile_bytes: int = DEFAULT_TILE_BYTES,
                            profile: CodecProfile = None) -> float:
        """Streamed pass at level ``l`` (per-tile latency model — see
        ``stream_pipeline_s``)."""
        prof = profile or self.levels[l].profile
        n_tiles = max(1, -(-int(nbytes) // int(tile_bytes)))
        lat_s, bw_s = self.ring_parts_s(l, nbytes)
        return stream_pipeline_s(lat_s, prof.pack_s(nbytes), bw_s,
                                 prof.unpack_s(nbytes), n_tiles)

    def level_degraded_time_s(self, l: int, nbytes: float,
                              cfg: FaultConfig, codec: bool = True,
                              profile: CodecProfile = None) -> float:
        """Modeled completion time of level ``l`` under faults.

        The level finishes at the order statistic of the straggler max over
        its children, capped by the per-level deadline — NOT the mean child
        time (one straggler in 25 children moves the max far more than the
        average).  Lost attempts inflate the base time by the expected
        transmission count plus the expected first backoff.
        """
        lev = self.levels[l]
        base = self.level_serial_time_s(l, nbytes, codec=codec,
                                        profile=profile)
        lf = self.level_faults(l, cfg)
        e_tx = cfg.expected_transmissions(lf.loss_rate)
        base = base * e_tx + cfg.backoff_s * (e_tx - 1.0)
        if lf.delay_rate > 0:
            base += lf.delay_rate * lf.delay_s
        return straggler_level_time_s(base, cfg.straggler_rate,
                                      cfg.straggler_sigma,
                                      self.n_children(l),
                                      cfg.level_deadline_s(lev.name))

    def with_n_leaves(self, n: int) -> "TreeTopology":
        """Same hierarchy rescaled so ``n_leaves == n`` by widening the leaf
        fanout (upper fanouts unchanged).

        The infrastructure above the leaf hop — cells, regions, the root —
        persists while cohorts of any size occupy the leaf slots, which is
        exactly the cross-device picture: ``edge_fl_tree.with_n_leaves(10**5)``
        keeps 5 metro aggregators per region and 4 regions, but each cell now
        fronts 5000 phones.  ``n`` must be a multiple of the upper fanouts'
        product.
        """
        upper = 1
        for lev in self.levels[1:]:
            upper *= lev.fanout
        if n < upper or n % upper != 0:
            raise ValueError(
                f"cannot rescale {self.name!r} to {n} leaves: upper-level "
                f"fanouts multiply to {upper}, need a positive multiple")
        leaf = replace(self.levels[0], fanout=n // upper)
        return TreeTopology(f"{self.name}/leaves{n}",
                            (leaf,) + self.levels[1:])

    # -- depth-2 bridge ------------------------------------------------------
    @classmethod
    def from_flat(cls, topo: Topology) -> "TreeTopology":
        """Lift a flat intra/inter ``Topology`` to its depth-2 tree."""
        return cls(topo.name, (
            TreeLevel("intra", topo.devices_per_pod, topo.intra),
            TreeLevel("inter", topo.n_pods, topo.inter),
        ))


# ---------------------------------------------------------------------------
# presets — multi-level variants of the flat scenarios
# ---------------------------------------------------------------------------
TREE_PRESETS: Dict[str, TreeTopology] = {
    # chip -> host -> pod -> cross-pod: ICI, host interconnect, DCN
    "v5p_superpod_tree": TreeTopology("v5p_superpod_tree", (
        TreeLevel("ici", 16, Link(gbps=100.0, latency_us=1.0)),
        TreeLevel("host", 16, Link(gbps=45.0, latency_us=5.0)),
        TreeLevel("dcn", 2, Link(gbps=12.5, latency_us=25.0)),
    )),
    # device -> host -> datacenter -> region over WAN
    "geo_wan_tree": TreeTopology("geo_wan_tree", (
        TreeLevel("ici", 8, Link(gbps=50.0, latency_us=2.0)),
        TreeLevel("dcn", 8, Link(gbps=12.5, latency_us=25.0)),
        TreeLevel("wan", 4, Link(gbps=1.0, latency_us=20_000.0)),
    )),
    # phone -> cell-edge -> region -> cloud: the cross-device hierarchy of
    # Ch. 5 (broadband uplink, metro fiber, inter-region WAN); 100 phones
    # total, matching the flat edge_fl preset's 100 single-device pods
    "edge_fl_tree": TreeTopology("edge_fl_tree", (
        TreeLevel("uplink", 5, Link(gbps=0.00625, latency_us=50_000.0)),
        TreeLevel("metro", 5, Link(gbps=1.0, latency_us=2_000.0)),
        TreeLevel("wan", 4, Link(gbps=1.0, latency_us=20_000.0)),
    )),
}


def get_tree_topology(name: str) -> TreeTopology:
    """Tree preset by name; flat preset names resolve to their depth-2 lift."""
    if name in TREE_PRESETS:
        return TREE_PRESETS[name]
    return TreeTopology.from_flat(get_topology(name))


def register_tree_topology(tree: TreeTopology) -> TreeTopology:
    """Register a custom tree (benchmark depth sweeps, tests)."""
    TREE_PRESETS[tree.name] = tree
    return tree
