"""RL003 — wall-clock reads in modeled paths.

Round times in this repo are *modeled* (``topology.time_s``, pipelined
stream timing, deadline order statistics); real host clocks belong to the
observability layer.  A stray ``time.time()`` in a costing or training path
is either dead weight or — worse — quietly mixed into modeled numbers.

Allowed locations: ``src/repro/obs/`` (the flight recorder owns the host
clock, exported as ``repro.obs.trace.wall_s``) and ``benchmarks/common.py``
(the shared ``timed``/``now_s`` harness).  Everything else must route
through those helpers.
"""
from __future__ import annotations

import ast
from typing import List

from repro.lint.callgraph import dotted
from repro.lint.framework import Finding, Project, rule

_CLOCK_FNS = {"time", "perf_counter", "perf_counter_ns", "monotonic",
              "monotonic_ns", "clock", "process_time", "process_time_ns"}
_ALLOWED_PREFIXES = ("src/repro/obs/",)
_ALLOWED_FILES = ("benchmarks/common.py",)


def _allowed(relpath: str) -> bool:
    if "lint_fixtures" in relpath:  # the linter's own test corpus IS linted
        return False
    return (relpath.startswith(_ALLOWED_PREFIXES)
            or relpath in _ALLOWED_FILES
            or relpath.startswith("tests/") or "/tests/" in relpath)


@rule("RL003", "wall-clock read (time.time/perf_counter) outside obs/ and "
               "benchmarks/common.py")
def check(project: Project) -> List[Finding]:
    graph = project.callgraph
    out: List[Finding] = []
    for ctx in project.files.values():
        if _allowed(ctx.relpath):
            continue
        time_aliases = {a for a, m in
                        graph.mod_aliases.get(ctx.module, {}).items()
                        if m == "time"}
        froms = graph.from_imports.get(ctx.module, {})
        from_clocks = {name for name, (mod, orig) in froms.items()
                       if mod == "time" and orig in _CLOCK_FNS}
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d is None:
                continue
            parts = d.split(".")
            hit = None
            if len(parts) == 2 and parts[0] in time_aliases \
                    and parts[1] in _CLOCK_FNS:
                hit = d
            elif len(parts) == 1 and parts[0] in from_clocks:
                hit = f"time.{froms[parts[0]][1]}"
            if hit:
                out.append(ctx.finding(
                    "RL003", node,
                    f"{hit}() in a modeled path; use repro.obs.trace.wall_s "
                    f"(or benchmarks.common.now_s in benches)"))
    return out
