"""Llama-4 Scout 17B-active / 16 experts.

[hf:meta-llama/Llama-4-Scout-17B-16E]  MoE (16 experts, top-1 routing, one
shared expert), early-fusion multimodal (vision patch embeddings projected into
the token stream -> frontend stubbed per the carve-out), iRoPE attention:
3 chunked-local (RoPE) layers : 1 global (NoPE) layer.  The chunked-local
attention makes decode memory sub-quadratic in context, so long_500k runs.
"""
from repro.configs.base import ATTN_CHUNK, ATTN_GLOBAL, ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        citation="hf:meta-llama/Llama-4-Scout-17B-16E",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        attn_chunk=8192,
        layer_pattern=(ATTN_CHUNK, ATTN_CHUNK, ATTN_CHUNK, ATTN_GLOBAL),
        mlp_act="silu",
        mlp_gated=True,
        moe=MoEConfig(num_experts=16, top_k=1, shared_expert=True),
        vision_tokens=256,
        rope_theta=500000.0,
        supports_long_context=True,
    )
)
