"""repro.lint: AST rules, noqa/baseline plumbing, and contract checks."""
import json
import os

import pytest

from repro.lint.__main__ import main as lint_main

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
FIXTURES = os.path.join(REPO, "tests", "lint_fixtures")


def run_lint(*paths, extra=()):
    """In-process CLI run; returns (rc, findings-as-dicts)."""
    argv = [os.path.join(FIXTURES, p) for p in paths]
    argv += ["--format", "json", "--no-contracts", *extra]
    import io
    from contextlib import redirect_stdout
    buf = io.StringIO()
    with redirect_stdout(buf):
        rc = lint_main(argv)
    return rc, json.loads(buf.getvalue())["findings"]


# ---------------------------------------------------------------------------
# engine 1: each rule catches its fixture
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fixture,rule,n_min", [
    ("bad_rl001.py", "RL001", 3),
    ("bad_rl002.py", "RL002", 2),
    ("bad_rl003.py", "RL003", 2),
    ("bad_rl004.py", "RL004", 2),
    ("bad_rl005.py", "RL005", 2),
])
def test_rule_catches_fixture(fixture, rule, n_min):
    rc, findings = run_lint(fixture)
    assert rc == 1
    assert len(findings) >= n_min
    assert all(f["rule"] == rule for f in findings)


def test_rl001_sees_through_scan_callgraph():
    # device_get lives in scan_body, a root only via lax.scan(scan_body, ...)
    _, findings = run_lint("bad_rl001.py")
    assert any("scan_body" in f["message"] for f in findings)


def test_rl004_names_known_tags():
    _, findings = run_lint("bad_rl004.py")
    unregistered = [f for f in findings if "bogus_tag" in f["message"]]
    assert len(unregistered) == 1
    assert "retry" in unregistered[0]["message"]


def test_noqa_suppresses_each_rule():
    rc, findings = run_lint("noqa_ok.py")
    assert rc == 0 and findings == []


def test_clean_fixture_passes():
    rc, findings = run_lint("clean.py")
    assert rc == 0 and findings == []


def test_fixture_dir_rule_filter():
    rc, findings = run_lint(".", extra=("--rules", "RL002"))
    assert rc == 1
    assert {f["rule"] for f in findings} == {"RL002"}


def test_unknown_rule_is_usage_error():
    rc, _findings_unused = None, None
    import io
    from contextlib import redirect_stdout, redirect_stderr
    buf = io.StringIO()
    with redirect_stdout(buf), redirect_stderr(buf):
        rc = lint_main([FIXTURES, "--rules", "RL999", "--no-contracts"])
    assert rc == 2


# ---------------------------------------------------------------------------
# the repo itself lints clean with the committed (empty) baseline
# ---------------------------------------------------------------------------
def test_repo_is_lint_clean():
    import io
    from contextlib import redirect_stdout
    buf = io.StringIO()
    with redirect_stdout(buf):
        rc = lint_main([os.path.join(REPO, "src", "repro"),
                        os.path.join(REPO, "benchmarks"),
                        "--format", "json", "--no-contracts"])
    doc = json.loads(buf.getvalue())
    assert rc == 0, doc["findings"]
    assert doc["findings"] == [] and doc["baselined"] == 0


def test_committed_baseline_is_empty():
    from repro import lint as lint_pkg
    path = os.path.join(os.path.dirname(lint_pkg.__file__), "baseline.json")
    with open(path) as f:
        assert json.load(f) == {"fingerprints": []}


# ---------------------------------------------------------------------------
# engine 2: contracts cover the full compressor registry and pass
# ---------------------------------------------------------------------------
def test_contract_params_cover_registry():
    from repro.core.compressors import _REGISTRY
    from repro.lint.contracts import CONTRACT_PARAMS
    assert set(CONTRACT_PARAMS) == set(_REGISTRY)


def test_retry_tag_constants_agree():
    # faults.transmit mirrors the ledger constant instead of importing it
    # (comm.tree -> faults.model would make that import circular)
    from repro.comm.ledger import RETRY_TAG as ledger_tag
    from repro.faults.transmit import RETRY_TAG as transmit_tag
    assert ledger_tag == transmit_tag


def test_contracts_pass():
    from repro.lint.contracts import run_contracts
    findings = run_contracts()
    assert findings == [], [f.format() for f in findings]
