"""Benchmark entry: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The §Roofline harness
(benchmarks/roofline.py) and the multi-pod dry-run (repro.launch.dryrun) are
separate long-running entries — this file covers the paper-table benchmarks.

The comm, hier, faults, cohort and serve rows are additionally written to
``BENCH_comm.json`` / ``BENCH_hier.json`` / ``BENCH_faults.json`` /
``BENCH_cohort.json`` / ``BENCH_serve.json``
(machine-readable: name, wall-us, bytes) so the codec/transport/
aggregation-tree/robustness perf trajectory is tracked across PRs instead of
living only in stdout.
"""
from __future__ import annotations

import json
import os
import re
import sys

_BYTES_RE = re.compile(r"(?:^|;)bytes=(\d+)")


def write_comm_json(rows, path: str = "BENCH_comm.json") -> None:
    """Persist comm benchmark rows: [{name, us, bytes|null, derived}]."""
    out = []
    for name, us, derived in rows:
        m = _BYTES_RE.search(derived)
        out.append({"name": name, "us": round(float(us), 1),
                    "bytes": int(m.group(1)) if m else None,
                    "derived": derived})
    with open(path, "w") as f:
        json.dump({"rows": out}, f, indent=1)
        f.write("\n")


def main() -> None:
    from benchmarks import bench_cohort, bench_comm, bench_efbv
    from benchmarks import bench_faults, bench_fedp3, bench_hier
    from benchmarks import bench_kernels, bench_scafflix, bench_scafflix_nn
    from benchmarks import bench_serve, bench_sppm, bench_symwanda
    from benchmarks.common import emit, module_trace, now_s, trace_dir
    from repro.obs import trace as obs_trace

    modules = [
        ("comm(codecs/ledger/topology)", bench_comm),
        ("hier(aggregation-trees,Ch.5)", bench_hier),
        ("faults(robustness)", bench_faults),
        ("cohort(million-client)", bench_cohort),
        ("serve(personalized-deltas)", bench_serve),
        ("efbv(Fig2.2)", bench_efbv),
        ("scafflix(Fig3.1/3.3)", bench_scafflix),
        ("scafflix_nn(Fig3.2)", bench_scafflix_nn),
        ("fedp3(Fig4.2/4.4/Tab4.2)", bench_fedp3),
        ("sppm(Fig5.1-5.6)", bench_sppm),
        ("symwanda(Tab6.3-6.6)", bench_symwanda),
        ("kernels", bench_kernels),
    ]
    json_sinks = {
        id(bench_comm): ("BENCH_COMM_JSON", "BENCH_comm.json"),
        id(bench_hier): ("BENCH_HIER_JSON", "BENCH_hier.json"),
        id(bench_faults): ("BENCH_FAULTS_JSON", "BENCH_faults.json"),
        id(bench_cohort): ("BENCH_COHORT_JSON", "BENCH_cohort.json"),
        id(bench_serve): ("BENCH_SERVE_JSON", "BENCH_serve.json"),
    }
    print("name,us_per_call,derived")
    for label, mod in modules:
        t0 = now_s()
        short = mod.__name__.rsplit(".", 1)[-1].removeprefix("bench_")
        try:
            # with REPRO_TRACE=1 each module's spans land in its own
            # TRACE_<module>.jsonl next to the CSV rows
            with module_trace(short, module=mod.__name__):
                rows = mod.run()
            emit(rows)
            if obs_trace.enabled():
                print(f"# {label} trace -> "
                      f"{os.path.join(trace_dir(), f'TRACE_{short}.jsonl')}",
                      file=sys.stderr)
            if id(mod) in json_sinks:
                env, default = json_sinks[id(mod)]
                path = os.environ.get(env, default)
                write_comm_json(rows, path)
                print(f"# {label} rows -> {path}", file=sys.stderr)
        except Exception as e:  # noqa: BLE001 — keep the suite going
            print(f"{label}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
        print(f"# {label} done in {now_s()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
