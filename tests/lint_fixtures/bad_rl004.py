"""RL004 fixture: ledger records with missing or unregistered tags."""
from repro.comm.ledger import CommLedger


def account(nbytes):
    led = CommLedger()
    led.record(0, "a->b", nbytes, kind="inter", phase=0)  # RL004: no tag
    led.record(1, "a->b", nbytes, tag="bogus_tag")        # RL004: unregistered
    return led
