"""Imports every per-architecture config module (side effect: registration)."""
from repro.configs import llama4_scout_17b_a16e  # noqa: F401
from repro.configs import chameleon_34b  # noqa: F401
from repro.configs import qwen1_5_110b  # noqa: F401
from repro.configs import seamless_m4t_large_v2  # noqa: F401
from repro.configs import mamba2_2_7b  # noqa: F401
from repro.configs import qwen1_5_4b  # noqa: F401
from repro.configs import dbrx_132b  # noqa: F401
from repro.configs import jamba_1_5_large_398b  # noqa: F401
from repro.configs import h2o_danube_1_8b  # noqa: F401
from repro.configs import nemotron_4_15b  # noqa: F401

# id (with dashes/dots) -> module-registered config names are identical; this
# module exists so `get_config` can lazily trigger all registrations.
