"""Federated data substrate: non-IID client splits + convex logreg problems.

The dissertation's convex experiments (Ch. 2, 3, 5) run l2-regularized logistic
regression on LibSVM datasets split feature-wise / class-wise / Dirichlet
non-IID across clients.  LibSVM is unavailable offline, so we generate
controlled synthetic classification data with the same knobs (client
heterogeneity, conditioning) — heterogeneity is what the theory cares about
(mu_i, L_i spread, gradient diversity at the optimum), and we control it
exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np


def dirichlet_mixtures(client_ids, n_classes: int, alpha: float,
                       seed: int = 0) -> np.ndarray:
    """Per-client Dirichlet(alpha) class mixtures at population scale.

    ``dirichlet_split`` materializes index lists — fine for tens of clients,
    impossible for 10^6.  This is the population-scale form the cohort
    simulator uses: row ``i`` is client ``client_ids[i]``'s class-probability
    vector, drawn from the counter PRNG addressed by ``(seed, class,
    client_id)`` — a pure function of the client id, so deriving a sampled
    cohort's mixtures equals slicing the full population's (lane-sliceable,
    like every `repro.faults` process).

    Gamma draws use the Wilson-Hilferty cube at shape ``alpha + 1`` with the
    exact boost ``Gamma(alpha) = Gamma(alpha+1) * U^(1/alpha)``, normalized
    per client in log space so alpha -> 0 concentrates each client on a
    single class without underflow and alpha -> inf approaches the uniform
    (IID) mixture.

    ``client_ids`` is an ``(n,)`` int array of population ids, or an int n
    (meaning ids ``0..n-1``).
    """
    from repro.faults.model import counter_normal, counter_uniform

    if np.ndim(client_ids) == 0:
        client_ids = np.arange(int(client_ids))
    ids = np.asarray(client_ids, np.int64)
    if alpha <= 0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    a = float(alpha)
    k = a + 1.0
    n = ids.shape[0]
    log_g = np.empty((n, int(n_classes)))
    for c in range(int(n_classes)):
        z = counter_normal(seed, 0, f"dirichlet/{c}", n, lane=ids)
        u = counter_uniform(seed, 0, f"dirichlet/{c}/boost", n, lane=ids)
        # Wilson-Hilferty: Gamma(k) ~= k * (1 - 1/(9k) + z*sqrt(1/(9k)))^3
        wh = k * np.maximum(1.0 - 1.0 / (9.0 * k)
                            + z * np.sqrt(1.0 / (9.0 * k)), 0.0) ** 3
        log_g[:, c] = (np.log(np.maximum(wh, 1e-300))
                       + np.log(np.maximum(u, 1e-300)) / a)
    log_g -= log_g.max(axis=1, keepdims=True)
    mix = np.exp(log_g)
    mix /= mix.sum(axis=1, keepdims=True)
    return mix


def dirichlet_split(labels: np.ndarray, n_clients: int, alpha: float, seed: int = 0) -> List[np.ndarray]:
    """Dirichlet(alpha) label-skew split (the paper's S2). Returns index lists."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    client_idx: List[list] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet(alpha * np.ones(n_clients))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            client_idx[i].extend(part.tolist())
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in client_idx]


def classwise_split(labels: np.ndarray, n_clients: int, classes_per_client: int = 2, seed: int = 0) -> List[np.ndarray]:
    """Class-wise non-IID split (the paper's S1): each client sees few classes."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    assign = [rng.choice(classes, size=classes_per_client, replace=False) for _ in range(n_clients)]
    pools = {c: list(np.flatnonzero(labels == c)) for c in classes}
    for c in pools:
        rng.shuffle(pools[c])
    # counts is positional: index by the class's position in `classes`, not by
    # the raw label value (non-contiguous label sets like {1, 3, 7} would
    # crash or silently credit the wrong class)
    pos = {c: i for i, c in enumerate(classes)}
    counts = np.zeros(len(classes), dtype=int)
    for a in assign:
        for c in a:
            counts[pos[c]] += 1
    client_idx: List[list] = [[] for _ in range(n_clients)]
    for i, a in enumerate(assign):
        for c in a:
            pool = pools[c]
            take = max(1, len(pool) // counts[pos[c]])
            client_idx[i].extend(pool[:take])
            pools[c] = pool[take:]
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in client_idx]


@dataclass
class FederatedLogReg:
    """n_clients l2-regularized logistic-regression objectives.

    f_i(x) = 1/n_i sum_j log(1+exp(-b_ij a_ij^T x)) + mu/2 ||x||^2
    Heterogeneity: each client's features are drawn around a client-specific
    mean direction scaled by ``hetero`` (0 => IID).
    """
    A: np.ndarray          # (n_clients, m, d)
    b: np.ndarray          # (n_clients, m) in {-1, +1}
    mu: float

    @property
    def n_clients(self) -> int:
        return self.A.shape[0]

    @property
    def dim(self) -> int:
        return self.A.shape[2]

    def smoothness(self) -> np.ndarray:
        """Per-client L_i = ||A_i||_row^2 / (4 m) + mu (paper Ch.3 formula)."""
        m = self.A.shape[1]
        return (np.sum(self.A**2, axis=(1, 2)) / (4 * m)) + self.mu


def make_logreg_clients(
    n_clients: int = 10,
    m: int = 200,
    d: int = 40,
    mu: float = 0.1,
    hetero: float = 1.0,
    seed: int = 0,
) -> FederatedLogReg:
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_clients, m, d))
    # client-specific shift + scale => heterogeneous mu_i/L_i and non-IID data
    shift = rng.normal(size=(n_clients, 1, d)) * hetero
    scale = 1.0 + hetero * rng.random((n_clients, 1, 1))
    A = (A + shift) * scale
    x_true = rng.normal(size=d)
    w_true = x_true + hetero * rng.normal(size=(n_clients, d))  # per-client label rule
    logits = np.einsum("nmd,nd->nm", A, w_true)
    p = 1 / (1 + np.exp(-logits))
    b = np.where(rng.random((n_clients, m)) < p, 1.0, -1.0)
    return FederatedLogReg(A=A.astype(np.float64), b=b.astype(np.float64), mu=mu)
