"""Jit'd public wrappers around the Pallas kernels.

These handle shape plumbing (flat -> tiled 2D with padding), compute the
cheap global statistics the kernels consume (per-output thresholds, RIA
row/col sums, symwanda normalizers), and expose drop-in backends:

  * ``quantize_dequantize``  — compressor backend (core/compressors.qsgd)
  * ``prune_nm``             — N:M backend for core/symwanda.mask_nm
  * ``prune_scored``         — fused score+mask backend for core/symwanda.prune

``interpret`` defaults to True (CPU validation container); on a real TPU
deployment it is flipped off by the launcher.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import bitpack as _bp
from repro.kernels import nm_prune as _nm
from repro.kernels import quant8 as _q8
from repro.kernels import wanda_score as _ws
from repro.kernels import ref as _ref


# ---------------------------------------------------------------------------
# bitpack (repro.comm wire formats)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("interpret",))
def pack_bits(mask: jax.Array, interpret: bool = True) -> jax.Array:
    """Flat {0,1} mask (d,) -> uint32 word stream (ceil(d/32),).

    Bit layout: with W = ceil(d/32), bit j of word w is mask[j*W + w] — the
    stride-W order lets the kernel reduce along the 32 sublanes with lanes
    kept 128-aligned.  ``unpack_bits`` inverts it exactly.
    """
    d = mask.shape[0]
    w = -(-d // _bp.PACK_BITS)
    wp = -(-w // _bp.PACK_LANES) * _bp.PACK_LANES
    m2d = (jnp.zeros((_bp.PACK_BITS * w,), jnp.uint32).at[:d]
           .set(mask.astype(jnp.uint32)).reshape(_bp.PACK_BITS, w))
    m2d = jnp.zeros((_bp.PACK_BITS, wp), jnp.uint32).at[:, :w].set(m2d)
    return _bp.pack_mask_2d(m2d, interpret=interpret)[0, :w]


@partial(jax.jit, static_argnames=("d", "interpret"))
def unpack_bits(words: jax.Array, d: int, interpret: bool = True) -> jax.Array:
    """Inverse of pack_bits: (ceil(d/32),) uint32 -> (d,) {0,1} uint32."""
    w = words.shape[0]
    assert w == -(-d // _bp.PACK_BITS), (w, d)
    wp = -(-w // _bp.PACK_LANES) * _bp.PACK_LANES
    wpad = jnp.zeros((1, wp), jnp.uint32).at[0, :w].set(words)
    bits = _bp.unpack_mask_2d(wpad, interpret=interpret)
    return bits[:, :w].reshape(-1)[:d]


def _quant_tiles(x: jax.Array, key: jax.Array):
    """Shared shape plumbing of every quantize entry point: pad the flat
    tensor to whole (TILE_ROWS, QBLOCK) tiles and draw the stochastic-round
    noise.  ONE definition on purpose — quantize_pack, stream_quantize_pack
    and quantize_dequantize are bit-identical only while they pad and draw
    noise identically."""
    flat = x.reshape(-1)
    d = flat.shape[0]
    qb, tr = _q8.QBLOCK, _q8.TILE_ROWS
    rows = -(-d // qb)
    rows_pad = -(-rows // tr) * tr
    padded = jnp.zeros((rows_pad * qb,), x.dtype).at[:d].set(flat).reshape(rows_pad, qb)
    noise = jax.random.uniform(key, padded.shape, jnp.float32)
    return padded, noise, d


@partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_pack(x: jax.Array, key: jax.Array, bits: int = 8,
                  interpret: bool = True):
    """Flat/any-shape tensor -> (int8 plane (rows, QBLOCK), scales (rows, 1)).

    Shape plumbing (padding, noise draw) matches quantize_dequantize exactly,
    so ``q * scales`` reproduces its dequantized output bit-for-bit — the
    codec's decode of the wire planes equals the on-chip compressor carrier.
    """
    padded, noise, _ = _quant_tiles(x, key)
    return _bp.quant_pack_2d(padded, noise, bits=bits, interpret=interpret)


@partial(jax.jit, static_argnames=("d", "interpret"))
def unpack_dequantize(q: jax.Array, scales: jax.Array, d: int,
                      interpret: bool = True) -> jax.Array:
    """Inverse of quantize_pack: wire planes -> flat (d,) float32 tensor."""
    out = _bp.unpack_dequant_2d(q, scales, interpret=interpret)
    return out.reshape(-1)[:d]


@partial(jax.jit, static_argnames=("bits", "interpret"))
def stream_quantize_pack(x: jax.Array, key: jax.Array, bits: int = 8,
                         interpret: bool = True):
    """quantize_pack via the double-buffered streaming DMA ring
    (kernels/stream.py).  Identical shape plumbing and noise draw, so the
    wire planes are bit-identical to ``quantize_pack``'s."""
    from repro.kernels import stream as _st

    padded, noise, _ = _quant_tiles(x, key)
    return _st.stream_quant_pack_2d(padded, noise, bits=bits, interpret=interpret)


def nibble_pack(q: jax.Array) -> jax.Array:
    """int8 plane with values in [-8, 7] -> two-per-byte uint8 (transport
    packing for 4-bit quantizers; pure jnp — runs at round boundaries)."""
    u = (q.reshape(-1).astype(jnp.int32) + 8).astype(jnp.uint8)
    if u.shape[0] % 2:
        u = jnp.concatenate([u, jnp.zeros((1,), jnp.uint8)])
    return (u[0::2] | (u[1::2] << 4)).astype(jnp.uint8)


def nibble_unpack(packed: jax.Array, n: int) -> jax.Array:
    """Inverse of nibble_pack -> int8 (n,) values in [-8, 7]."""
    lo = (packed & 0xF).astype(jnp.int32) - 8
    hi = ((packed >> 4) & 0xF).astype(jnp.int32) - 8
    return jnp.stack([lo, hi], axis=1).reshape(-1)[:n].astype(jnp.int8)


# ---------------------------------------------------------------------------
# quant8
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_dequantize(x: jax.Array, key: jax.Array, bits: int = 8,
                        interpret: bool = True) -> jax.Array:
    """Blockwise absmax quantize-dequantize of an arbitrary-shape tensor."""
    padded, noise, d = _quant_tiles(x, key)
    out = _q8.quant_dequant_2d(padded, noise, bits=bits, interpret=interpret)
    return out.reshape(-1)[:d].reshape(x.shape)


# ---------------------------------------------------------------------------
# N:M prune
# ---------------------------------------------------------------------------
def _pad2d(a, tr, tc):
    r, c = a.shape
    rp, cp = -(-r // tr) * tr, -(-c // tc) * tc
    if (rp, cp) == (r, c):
        return a, r, c
    return jnp.zeros((rp, cp), a.dtype).at[:r, :c].set(a), r, c


@partial(jax.jit, static_argnames=("n", "m", "interpret"))
def prune_nm(w: jax.Array, scores: jax.Array, n: int = 2, m: int = 4,
             interpret: bool = True):
    """(d_in, d_out) N:M prune by score; returns (pruned, mask)."""
    wp, r, c = _pad2d(w, _nm.TILE_R, _nm.TILE_C)
    # padded score rows must never win: fill with -inf
    sp = jnp.full(wp.shape, -jnp.inf, jnp.float32).at[:r, :c].set(
        scores.astype(jnp.float32))
    out, mask = _nm.nm_prune_2d(wp, sp, n=n, m=m, interpret=interpret)
    return out[:r, :c], mask[:r, :c]


# ---------------------------------------------------------------------------
# fused wanda/ria/symwanda prune
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("mode", "sparsity", "interpret"))
def prune_scored(w: jax.Array, X: jax.Array, mode: str = "wanda",
                 sparsity: float = 0.5, alpha: float = 0.5, beta: float = 0.5,
                 interpret: bool = True):
    """Fused score+mask prune of w (d_in, d_out) with calibration X (T, d_in).

    Per-output thresholds come from a top-k over the (recomputed-on-the-fly)
    score columns; the kernel then re-derives scores tile-local and masks.
    Returns (pruned, mask)."""
    d_in, d_out = w.shape
    xnorm = jnp.sqrt(jnp.sum(X.astype(jnp.float32) ** 2, axis=0))
    kw = dict(mode=mode, alpha=alpha, beta=beta)
    rowsum = colsum = ynorm = None
    mu_in = mu_out = 1.0
    if mode == "ria":
        aw = jnp.abs(w.astype(jnp.float32))
        rowsum = jnp.sum(aw, axis=1)
        colsum = jnp.sum(aw, axis=0)
        scores = _ref.wanda_scores_ref(w, xnorm, mode, alpha)
    elif mode == "symwanda":
        Y = X @ w
        ynorm = jnp.sqrt(jnp.sum(Y.astype(jnp.float32) ** 2, axis=0))
        aw = jnp.abs(w.astype(jnp.float32))
        mu_in = jnp.mean(aw * xnorm[:, None])
        mu_out = jnp.mean(aw * ynorm[None, :])
        scores = _ref.wanda_scores_ref(w, xnorm, mode, alpha, beta, ynorm, mu_in, mu_out)
        rowsum, colsum = mu_in, mu_out  # packed as scalars for the kernel
    else:
        scores = _ref.wanda_scores_ref(w, xnorm, "wanda")
    k = max(1, int(round((1 - sparsity) * d_in)))
    tau = jax.lax.top_k(scores.T, k)[0][:, -1]  # per output column

    wp, r, c = _pad2d(w, _ws.TILE_R, _ws.TILE_C)
    xn_p = jnp.zeros((wp.shape[0],), jnp.float32).at[:r].set(xnorm)
    tau_p = jnp.full((wp.shape[1],), jnp.inf, jnp.float32).at[:c].set(tau)
    if mode == "ria":
        rs_p = jnp.ones((wp.shape[0],), jnp.float32).at[:r].set(rowsum)
        cs_p = jnp.ones((wp.shape[1],), jnp.float32).at[:c].set(colsum)
        out, mask = _ws.wanda_prune_2d(wp, xn_p, tau_p, mode=mode, alpha=alpha,
                                       rowsum=rs_p, colsum=cs_p, interpret=interpret)
    elif mode == "symwanda":
        yn_p = jnp.zeros((wp.shape[1],), jnp.float32).at[:c].set(ynorm)
        out, mask = _ws.wanda_prune_2d(wp, xn_p, tau_p, mode=mode, beta=beta,
                                       rowsum=mu_in, colsum=mu_out, ynorm=yn_p,
                                       interpret=interpret)
    else:
        out, mask = _ws.wanda_prune_2d(wp, xn_p, tau_p, mode="wanda",
                                       interpret=interpret)
    return out[:r, :c], mask[:r, :c]
