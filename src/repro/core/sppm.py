"""SPPM-AS: stochastic proximal point with arbitrary sampling (Ch. 5).

Cohort-Squeeze's point: spend K *local communication rounds* inside the
sampled cohort to solve prox_{gamma f_C}(x_t) accurately, and the total cost
T(K)*K drops below FedAvg's best.  We implement:

  * samplings: full (FS), nice-tau (NICE), block (BS), stratified (SS) with
    k-means clustering, nonuniform single-client (NS)
  * theory quantities mu_AS, sigma*_AS^2 (Eq. 5.4) for each sampling
  * prox solvers A: gradient descent (LocalGD-like), conjugate gradient on the
    Newton system, and damped Newton ("BFGS-class" second-order baseline) —
    solver iterations = local communication rounds K
  * the SPPM-AS outer loop and the TK / hierarchical (c1*K + c2)*T cost model

Problem form: federated l2-logreg (data/federated.py), matching Ch. 5.4.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import numpy as np

# NOTE: this module is deliberately numpy-first: the paper's Ch.5 experiments
# are small convex problems where the interesting quantities (mu_AS, sigma*^2,
# TK curves) are scalar analytics; jax buys nothing and numpy keeps the prox
# solvers' control flow simple.


# ---------------------------------------------------------------------------
# Logreg oracle
# ---------------------------------------------------------------------------
def _sigmoid(z):
    return 1.0 / (1.0 + np.exp(-z))


@dataclass
class CohortProblem:
    """f_C(x) = sum_{i in C} 1/(n p_i) f_i(x) for the sampled cohort."""
    A: np.ndarray       # (c, m, d) cohort data
    b: np.ndarray       # (c, m)
    w: np.ndarray       # (c,) client weights 1/(n p_i)
    mu: float

    def value(self, x):
        z = np.einsum("cmd,d->cm", self.A, x)
        per = np.mean(np.logaddexp(0.0, -self.b * z), axis=1) + 0.5 * self.mu * x @ x
        return float(self.w @ per)

    def grad(self, x):
        z = np.einsum("cmd,d->cm", self.A, x)
        s = -self.b * _sigmoid(-self.b * z)
        g = np.einsum("cm,cmd->cd", s, self.A) / self.A.shape[1]
        g = g + self.mu * x[None]
        return self.w @ g

    def hess(self, x):
        z = np.einsum("cmd,d->cm", self.A, x)
        sig = _sigmoid(-self.b * z)
        wgt = sig * (1 - sig) / self.A.shape[1]
        d = self.A.shape[2]
        H = np.einsum("c,cmd,cm,cme->de", self.w, self.A, wgt, self.A)
        return H + self.w.sum() * self.mu * np.eye(d)

    def smoothness(self) -> float:
        m = self.A.shape[1]
        Ls = np.sum(self.A**2, axis=(1, 2)) / (4 * m) + self.mu
        return float(self.w @ Ls)


# ---------------------------------------------------------------------------
# Samplings (Sect. 5.3.3). Each returns (list of cohort index arrays, p_i).
# ---------------------------------------------------------------------------
def nice_sampling(rng, n: int, tau: int):
    p = np.full(n, tau / n)
    draw = lambda: rng.choice(n, size=tau, replace=False)
    return draw, p


def block_sampling(rng, blocks: Sequence[np.ndarray], q: Optional[np.ndarray] = None):
    nb = len(blocks)
    q = np.full(nb, 1.0 / nb) if q is None else q
    n = sum(len(b) for b in blocks)
    p = np.zeros(n)
    for j, blk in enumerate(blocks):
        p[blk] = q[j]
    draw = lambda: blocks[rng.choice(nb, p=q)]
    return draw, p


def stratified_sampling(rng, blocks: Sequence[np.ndarray]):
    n = sum(len(b) for b in blocks)
    p = np.zeros(n)
    for blk in blocks:
        p[blk] = 1.0 / len(blk)
    draw = lambda: np.array([rng.choice(blk) for blk in blocks])
    return draw, p


def balanced_blocks(features: np.ndarray, n_blocks: int) -> List[np.ndarray]:
    """Uniform-size clusters (Assumption D.6.12) homogeneous in feature space:
    contiguous split along the top principal direction.  Lemma 5.3.4's
    sigma*_SS <= sigma*_NICE guarantee assumes uniform cluster sizes; k-means
    with unbalanced clusters can *lose* to NICE (the paper's Example D.6.13)."""
    u = np.linalg.svd(features - features.mean(0), full_matrices=False)[2][0]
    order = np.argsort(features @ u)
    return [np.sort(a) for a in np.array_split(order, n_blocks)]


def kmeans_blocks(features: np.ndarray, n_blocks: int, seed: int = 0,
                  iters: int = 50) -> List[np.ndarray]:
    """Plain k-means on client features (the paper's clustering heuristic for
    SS); returns non-empty clusters as index arrays.

    Empty clusters are re-seeded from the points farthest from their current
    centers (classic k-means++-style repair): a stale center left in place
    can shadow a live one forever, collapsing the block count — stratified
    sampling then silently draws from fewer strata than requested."""
    rng = np.random.default_rng(seed)
    n = features.shape[0]
    centers = features[rng.choice(n, size=n_blocks, replace=False)].astype(float)
    assign = np.zeros(n, dtype=int)
    for _ in range(iters):
        dist = ((features[:, None] - centers[None]) ** 2).sum(-1)
        assign = dist.argmin(1)
        nearest = dist.min(1)
        for j in range(n_blocks):
            members = assign == j
            if members.any():
                centers[j] = features[members].mean(0)
            else:
                far = int(np.argmax(nearest))
                centers[j] = features[far]
                assign[far] = j
                nearest[far] = -np.inf  # next empty cluster picks a new point
    blocks = [np.flatnonzero(assign == j) for j in range(n_blocks)]
    return [b for b in blocks if len(b)]


# ---------------------------------------------------------------------------
# Theory quantities (Eq. 5.4) — exhaustive for small cohort spaces
# ---------------------------------------------------------------------------
def sigma_star_nice(prob, x_star: np.ndarray, tau: int, n_mc: int = 512, seed: int = 0):
    """MC estimate of sigma*^2_NICE(tau) = E ||grad f_C(x*)||^2 (exact value
    via the paper's closed form (n/tau - 1)/(n-1) * sigma*^2(1) is also
    returned for cross-checking)."""
    rng = np.random.default_rng(seed)
    n = prob.n_clients
    gi = _client_grads_at(prob, x_star)            # (n, d)
    closed = (n / tau - 1) / max(n - 1, 1) * np.mean(np.sum(gi**2, axis=1))
    acc = 0.0
    for _ in range(n_mc):
        C = rng.choice(n, size=tau, replace=False)
        acc += np.sum(gi[C].mean(0) ** 2)
    return acc / n_mc, closed


def sigma_star_stratified(prob, x_star: np.ndarray, blocks, n_mc: int = 512, seed: int = 0):
    rng = np.random.default_rng(seed)
    gi = _client_grads_at(prob, x_star)
    n = prob.n_clients
    acc = 0.0
    for _ in range(n_mc):
        g = np.zeros(gi.shape[1])
        for blk in blocks:
            i = rng.choice(blk)
            g += (len(blk) / n) * gi[i]
        acc += np.sum(g**2)
    return acc / n_mc


def _client_grads_at(prob, x):
    z = np.einsum("nmd,d->nm", prob.A, x)
    s = -prob.b * _sigmoid(-prob.b * z)
    g = np.einsum("nm,nmd->nd", s, prob.A) / prob.A.shape[1]
    return g + prob.mu * x[None]


def mu_as_nice(prob, tau: int) -> float:
    """mu_NICE(tau) = min_{|C|=tau} (1/tau) sum mu_i; with uniform mu it's mu."""
    return prob.mu  # every f_i is mu-strongly convex with the same mu


# ---------------------------------------------------------------------------
# Prox solvers (Table 5.2 / D.1): K iterations == K local communication rounds
# ---------------------------------------------------------------------------
def prox_gd(cp: CohortProblem, x0: np.ndarray, gamma: float, K: int):
    """LocalGD on phi(y) = f_C(y) + ||y - x0||^2 / (2 gamma)."""
    L_phi = cp.smoothness() + 1.0 / gamma
    lr = 1.0 / L_phi
    y = x0.copy()
    for _ in range(K):
        y = y - lr * (cp.grad(y) + (y - x0) / gamma)
    return y


def prox_newton_cg(cp: CohortProblem, x0: np.ndarray, gamma: float, K: int):
    """K CG iterations on the Newton system of phi at x0 (1st-order comm/iter)."""
    g = cp.grad(x0)  # phi'(x0) = f'_C(x0); prox term vanishes at y = x0
    H = cp.hess(x0) + np.eye(len(x0)) / gamma
    y = np.zeros_like(x0)
    r = g - H @ y
    p = r.copy()
    for _ in range(K):
        Hp = H @ p
        denom = p @ Hp
        if abs(denom) < 1e-30:
            break
        a = (r @ r) / denom
        y = y + a * p
        r_new = r - a * Hp
        beta = (r_new @ r_new) / max(r @ r, 1e-30)
        p = r_new + beta * p
        r = r_new
    return x0 - y


def prox_newton(cp: CohortProblem, x0: np.ndarray, gamma: float, K: int):
    """K damped-Newton steps (the second-order 'BFGS-class' baseline)."""
    y = x0.copy()
    for _ in range(K):
        g = cp.grad(y) + (y - x0) / gamma
        H = cp.hess(y) + np.eye(len(x0)) / gamma
        y = y - np.linalg.solve(H, g)
    return y


PROX_SOLVERS = {"gd": prox_gd, "cg": prox_newton_cg, "newton": prox_newton}


# ---------------------------------------------------------------------------
# SPPM-AS outer loop (Algorithm 8) + cost accounting
# ---------------------------------------------------------------------------
@dataclass
class SPPMResult:
    errors: np.ndarray       # ||x_t - x*||^2 per global round
    T_to_eps: Optional[int]  # rounds to reach target, None if not reached
    total_cost: Optional[float]


def sppm_as(prob, x_star: np.ndarray, draw: Callable, p: np.ndarray,
            gamma: float, K: int, T: int, solver: str = "gd",
            eps: Optional[float] = None, c_local: float = 1.0,
            c_global: float = 1.0, seed: int = 0) -> SPPMResult:
    """Run SPPM-AS; cost per global round = c_local*K + c_global (hierarchical
    FL cost model of Sect. 5.4.5; classic setting: c_local=1, c_global=0 gives
    cost TK)."""
    rng = np.random.default_rng(seed)
    n = prob.n_clients
    x = np.zeros(prob.dim)
    errs = np.empty(T)
    T_hit = None
    for t in range(T):
        C = np.asarray(draw())
        cp = CohortProblem(A=prob.A[C], b=prob.b[C], w=1.0 / (n * p[C]), mu=prob.mu)
        x = PROX_SOLVERS[solver](cp, x, gamma, K)
        errs[t] = np.sum((x - x_star) ** 2)
        if T_hit is None and eps is not None and errs[t] < eps:
            T_hit = t + 1
    cost = None if T_hit is None else T_hit * (c_local * K + c_global)
    return SPPMResult(errors=errs, T_to_eps=T_hit, total_cost=cost)


def solve_erm(prob, iters: int = 4000) -> np.ndarray:
    """High-precision x* for the full ERM objective via Newton."""
    cp = CohortProblem(A=prob.A, b=prob.b, w=np.full(prob.n_clients, 1.0 / prob.n_clients),
                       mu=prob.mu)
    x = np.zeros(prob.dim)
    for _ in range(60):
        g = cp.grad(x)
        if np.linalg.norm(g) < 1e-13:
            break
        x = x - np.linalg.solve(cp.hess(x), g)
    return x
