"""Token-choice Mixture-of-Experts with capacity-based dispatch.

Design notes (TPU adaptation):
  * All three assigned MoE archs have exactly 16 experts, matching the
    16-way ``model`` mesh axis -> expert parallelism maps 1 expert : 1 model
    group; dispatch becomes an all-to-all under GSPMD.
  * Dispatch avoids the O(T*E*C) one-hot einsum used by older JAX MoE code:
    we argsort token->expert assignments, compute each token's rank within its
    expert, and scatter into an (E, C, d) buffer — memory O(T*topk*d).
  * Tokens over capacity are dropped (standard capacity-factor semantics);
    the router aux loss (load-balance, Switch-style) keeps drop rates low.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, mlp


def moe_apply(params: dict, x: jax.Array, **kw) -> Tuple[jax.Array, jax.Array]:
    """Dispatcher: shard_map expert-parallel path when the launcher installed
    a mesh (production), scatter path otherwise (CPU tests, decode)."""
    from repro.sharding.context import get_moe_specs

    specs = get_moe_specs()
    if specs and specs.get("impl") == "alltoall":
        return moe_ffn_alltoall(params, x, mesh=specs["mesh"],
                                data_axes=specs["data_axes"], **kw)
    if specs and specs.get("impl") == "shardmap":
        return moe_ffn_shardmap(params, x, mesh=specs["mesh"],
                                data_axes=specs["data_axes"],
                                gather_quant=specs.get("gather_quant", False),
                                **kw)
    return moe_ffn(params, x, **kw)


def init_moe(key, d_model: int, d_ff: int, num_experts: int, gated: bool,
             shared_expert: bool, dtype) -> dict:
    ks = jax.random.split(key, 5)
    n_mats = 3 if gated else 2
    p = {
        "router": _dense_init(ks[0], (d_model, num_experts), jnp.float32, scale=0.02),
        "w_in": _dense_init(ks[1], (num_experts, d_model, d_ff), dtype),
        "w_out": _dense_init(ks[2], (num_experts, d_ff, d_model), dtype),
    }
    if gated:
        p["w_gate"] = _dense_init(ks[3], (num_experts, d_model, d_ff), dtype)
    if shared_expert:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], d_model, d_ff, gated, dtype)
    return p


def _expert_ffn(p: dict, x: jax.Array, act: str, gated: bool) -> jax.Array:
    """x: (E, C, d) -> (E, C, d); batched over experts."""
    h = jnp.einsum("ecd,edf->ecf", x, p["w_in"])
    if gated:
        g = jnp.einsum("ecd,edf->ecf", x, p["w_gate"])
        h = jax.nn.silu(g) * h if act == "silu" else jax.nn.gelu(g) * h
    else:
        h = jnp.square(jax.nn.relu(h)) if act == "relu2" else jax.nn.silu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"])


def moe_ffn(params: dict, x: jax.Array, *, num_experts: int, top_k: int,
            capacity_factor: float, act: str, gated: bool,
            shared_expert: bool, no_drop: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (output, aux_loss).

    ``no_drop=True`` sets per-expert capacity to T so no token can be dropped
    (used at decode time, where T is small and drops would make decode diverge
    from teacher forcing)."""
    from repro.sharding.context import constrain_moe

    B, S, d = x.shape
    T = B * S
    E, K = num_experts, top_k
    xt = constrain_moe("tokens", x.reshape(T, d))

    logits = (xt.astype(jnp.float32)) @ params["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, K)                      # (T, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch): E * sum_e frac_tokens_e * frac_prob_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[gate_i.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # ---- capacity + rank-within-expert via sorted assignment
    C = T if no_drop else max(1, int(T * K * capacity_factor / E))
    flat_e = gate_i.reshape(-1)                                   # (T*K,)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    # rank of each sorted element within its expert run
    first_pos = jnp.searchsorted(sorted_e, jnp.arange(E))         # (E,)
    rank_sorted = jnp.arange(T * K) - first_pos[sorted_e]
    rank = jnp.zeros((T * K,), jnp.int32).at[sort_idx].set(rank_sorted.astype(jnp.int32))

    keep = rank < C                                               # (T*K,)
    slot = flat_e * C + jnp.minimum(rank, C - 1)                  # (T*K,)

    token_of = jnp.repeat(jnp.arange(T), K)
    expanded = constrain_moe("expanded", xt[token_of])            # (T*K, d)
    buf = jnp.zeros((E * C, d), x.dtype)
    buf = buf.at[jnp.where(keep, slot, E * C)].add(expanded, mode="drop")
    buf = constrain_moe("buf", buf.reshape(E, C, d))

    out_buf = constrain_moe("buf", _expert_ffn(params, buf, act, gated)).reshape(E * C, d)

    gathered = out_buf[slot] * keep[:, None].astype(x.dtype)      # (T*K, d)
    gathered = constrain_moe("expanded", gathered)
    w = gate_w.reshape(-1)[:, None].astype(x.dtype)
    combined = jnp.zeros((T, d), x.dtype).at[token_of].add(gathered * w)
    combined = constrain_moe("tokens", combined)

    if shared_expert:
        combined = combined + mlp(params["shared"], xt, act=act, gated=gated)
    return combined.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# all-to-all expert-parallel MoE (§Perf B.2: the communication-optimal path).
#
# The shardmap path below replicates every token across the model axis (entry
# all-gather ~ T_loc * d bytes/device).  This path keeps tokens d-SHARDED the
# whole way: routing runs on a psum'd (T,E) logit (tiny), then only the
# *routed* rows travel — two all-to-alls moving ~ T_loc*K*cf*d / n_model
# bytes each, an E/(K*cf) ~ 13x reduction for top-1 routing.
# Requires deterministic routing (identical on every model shard, which holds:
# all shards compute the same psum'd logits).
# ---------------------------------------------------------------------------
def moe_ffn_alltoall(params: dict, x: jax.Array, *, num_experts: int, top_k: int,
                     capacity_factor: float, act: str, gated: bool,
                     shared_expert: bool, mesh, data_axes,
                     model_axis: str = "model") -> Tuple[jax.Array, jax.Array]:
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    B, S, d = x.shape
    E, K = num_experts, top_k
    n_model = mesh.shape[model_axis]
    assert E % n_model == 0, (E, n_model)
    e_per = E // n_model

    dax = data_axes if isinstance(data_axes, tuple) else (data_axes,)
    dspec = dax if len(dax) > 1 else dax[0]

    def local_fn(xt_sh, router, w_in, w_gate, w_out):
        # xt_sh: (T_loc, dsh) my d-slice of the local tokens
        T_loc, dsh = xt_sh.shape
        C = max(1, int(T_loc * K * capacity_factor / E))
        mid = jax.lax.axis_index(model_axis)

        # ---- routing from sharded activations: psum of partial logits
        router_loc = jax.lax.dynamic_slice_in_dim(router, mid * dsh, dsh, 0)
        logits = jax.lax.psum(
            xt_sh.astype(jnp.float32) @ router_loc, model_axis)   # (T_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_i = jax.lax.top_k(probs, K)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[gate_i.reshape(-1)].add(1.0) / (T_loc * K)
        aux = E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, dspec)

        flat_e = gate_i.reshape(-1)
        sort_idx = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[sort_idx]
        first_pos = jnp.searchsorted(sorted_e, jnp.arange(E))
        rank_sorted = jnp.arange(T_loc * K) - first_pos[sorted_e]
        rank = jnp.zeros((T_loc * K,), jnp.int32).at[sort_idx].set(
            rank_sorted.astype(jnp.int32))
        keep = rank < C
        token_of = jnp.repeat(jnp.arange(T_loc), K)

        # ---- dispatch: my d-slice of every routed row, bucketed by expert
        bufs = []
        for e_id in range(E):
            mine = keep & (flat_e == e_id)
            slot = jnp.where(mine, rank, C)
            buf = jnp.zeros((C + 1, dsh), xt_sh.dtype)
            buf = buf.at[slot].add(jnp.where(mine[:, None], xt_sh[token_of], 0))
            bufs.append(buf[:C])
        send = jnp.stack(bufs).reshape(n_model, e_per * C, dsh)
        recv = jax.lax.all_to_all(send, model_axis, 0, 0, tiled=False)
        # recv[j] = d-slice j of my experts' rows -> assemble full-d rows
        full = recv.transpose(1, 0, 2).reshape(e_per, C, n_model * dsh)

        # ---- expert FFN on my experts (full d)
        h = jnp.einsum("ecd,edf->ecf", full, w_in)
        if gated:
            g = jnp.einsum("ecd,edf->ecf", full, w_gate)
            h = (jax.nn.silu(g) * h) if act == "silu" else (jax.nn.gelu(g) * h)
        else:
            h = jnp.square(jax.nn.relu(h)) if act == "relu2" else jax.nn.silu(h)
        y = jnp.einsum("ecf,efd->ecd", h, w_out)                  # (e_per, C, d)

        # ---- return: ship each source shard its d-slice of the outputs
        yb = y.reshape(e_per * C, n_model, dsh).transpose(1, 0, 2)
        back = jax.lax.all_to_all(yb, model_axis, 0, 0, tiled=False)
        # back[m] = my d-slice of shard m's experts' outputs (e_per*C, dsh)
        back = back.reshape(E, C, dsh)

        combined = jnp.zeros((T_loc, dsh), jnp.float32)
        wk_all = gate_w.reshape(-1)
        for e_id in range(E):
            mine = keep & (flat_e == e_id)
            contrib = back[e_id][jnp.minimum(rank, C - 1)]        # (T_loc*K, dsh)
            wk = (wk_all * mine)[:, None]
            combined = combined.at[token_of].add(contrib.astype(jnp.float32) * wk)
        return combined.astype(xt_sh.dtype), aux

    local = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dspec, model_axis), P(), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None)),
        out_specs=(P(dspec, model_axis), P()),
        check_rep=False,
    )
    xt = x.reshape(B * S, d)
    w_gate = params.get("w_gate", params["w_in"])
    out, aux = local(xt, params["router"], params["w_in"], w_gate, params["w_out"])
    if shared_expert:
        out = out + mlp(params["shared"], xt, act=act, gated=gated)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# shard_map expert-parallel MoE (production path).
#
# GSPMD cannot partition the scatter/gather dispatch above (arbitrary index
# vectors force replication of the (T*K, d) carriers — measured 100+ GB/chip
# on dbrx train_4k).  Instead we drop to shard_map: tokens stay sharded over
# the data axes and are replicated over 'model' (the entry all-gather is the
# same collective a dense TP FFN needs anyway); each model shard owns
# E / n_model experts, selects + capacity-ranks its own tokens with LOCAL
# gathers (no SPMD partitioning involved), runs its expert FFN, and the
# per-token combine is a psum over 'model'.  Zero all-to-alls, zero
# partitioned scatters.
# ---------------------------------------------------------------------------
def moe_ffn_shardmap(params: dict, x: jax.Array, *, num_experts: int, top_k: int,
                     capacity_factor: float, act: str, gated: bool,
                     shared_expert: bool, mesh, data_axes,
                     model_axis: str = "model",
                     gather_quant: bool = False) -> Tuple[jax.Array, jax.Array]:
    """``gather_quant`` (§Perf variant): the entry token replication over
    'model' moves int8 payloads (blockwise absmax, one scale per token) and
    the exit psum runs in bf16 — ~2x less MoE collective traffic."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    B, S, d = x.shape
    E, K = num_experts, top_k
    n_model = mesh.shape[model_axis]
    assert E % n_model == 0 or n_model % E == 0, (E, n_model)
    e_per = max(1, E // n_model)

    dax = data_axes if isinstance(data_axes, tuple) else (data_axes,)
    n_data = 1
    for a in dax:
        n_data *= mesh.shape[a]
    dspec = dax if len(dax) > 1 else dax[0]

    def local_gather(xt_shard):
        """(T_loc, d/n_model) my d-shard -> (T_loc, d) full, int8 on the wire."""
        if not gather_quant:
            return jax.lax.all_gather(xt_shard, model_axis, axis=1, tiled=True)
        scale = jnp.max(jnp.abs(xt_shard.astype(jnp.float32)), axis=1,
                        keepdims=True) / 127.0
        scale = jnp.where(scale == 0, 1.0, scale)
        q = jnp.clip(jnp.round(xt_shard.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        qg = jax.lax.all_gather(q, model_axis, axis=1, tiled=True)
        sg = jax.lax.all_gather(scale, model_axis, axis=1, tiled=True)
        # dequant shard-by-shard: scales repeat per d-shard block
        dsh = xt_shard.shape[1]
        qg = qg.reshape(qg.shape[0], n_model, dsh)
        out = qg.astype(jnp.float32) * sg[:, :, None]
        return out.reshape(qg.shape[0], n_model * dsh).astype(xt_shard.dtype)

    def local_fn(xt, router, w_in, w_gate, w_out):
        # xt: model-replicated (T_loc, d), or my d-shard when gather_quant
        if gather_quant:
            xt = local_gather(xt)
        T_loc = xt.shape[0]
        C = max(1, int(T_loc * K * capacity_factor / E))
        logits = xt.astype(jnp.float32) @ router               # (T_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_i = jax.lax.top_k(probs, K)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[gate_i.reshape(-1)].add(1.0) / (T_loc * K)
        aux = E * jnp.sum(me * ce)

        flat_e = gate_i.reshape(-1)                            # (T_loc*K,)
        sort_idx = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[sort_idx]
        first_pos = jnp.searchsorted(sorted_e, jnp.arange(E))
        rank_sorted = jnp.arange(T_loc * K) - first_pos[sorted_e]
        rank = jnp.zeros((T_loc * K,), jnp.int32).at[sort_idx].set(
            rank_sorted.astype(jnp.int32))
        keep = rank < C
        token_of = jnp.repeat(jnp.arange(T_loc), K)

        mid = jax.lax.axis_index(model_axis)
        my_first = mid * e_per
        combined = jnp.zeros((T_loc, d), jnp.float32)
        for j in range(e_per):
            e_id = my_first + j
            mine = keep & (flat_e == e_id)                     # (T_loc*K,)
            slot = jnp.where(mine, rank, C)                    # C = trash slot
            buf = jnp.zeros((C + 1, d), xt.dtype)
            buf = buf.at[slot].add(jnp.where(mine[:, None], xt[token_of], 0))
            h = buf[:C] @ w_in[j]
            if gated:
                g = buf[:C] @ w_gate[j]
                h = (jax.nn.silu(g) * h) if act == "silu" else (jax.nn.gelu(g) * h)
            else:
                h = jnp.square(jax.nn.relu(h)) if act == "relu2" else jax.nn.silu(h)
            y = h @ w_out[j]                                   # (C, d)
            wk = (gate_w.reshape(-1) * mine)[:, None]
            contrib = y[jnp.minimum(rank, C - 1)] * wk         # (T_loc*K, d)
            combined = combined.at[token_of].add(contrib.astype(jnp.float32))
        if gather_quant:
            combined = jax.lax.psum(combined.astype(jnp.bfloat16), model_axis)
        else:
            combined = jax.lax.psum(combined, model_axis)
        # aux is identical across model shards (same routing math) but is a
        # LOCAL-token statistic along the data axes — average it
        aux = jax.lax.pmean(aux, dax if len(dax) > 1 else dax[0])
        return combined.astype(x.dtype), aux

    in_tok_spec = P(dspec, model_axis) if gather_quant else P(dspec, None)
    local = shard_map(
        local_fn, mesh=mesh,
        in_specs=(in_tok_spec, P(), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None)),
        out_specs=(P(dspec, None), P()),
        check_rep=False,
    )
    xt = x.reshape(B * S, d)
    w_gate = params.get("w_gate", params["w_in"])  # placeholder when ungated
    out, aux = local(xt, params["router"], params["w_in"], w_gate, params["w_out"])
    if shared_expert:
        out = out + mlp(params["shared"], xt, act=act, gated=gated)
    return out.reshape(B, S, d), aux
