"""A fixture with zero findings: seeded RNG, tagged records, no host sync."""
import jax
import numpy as np

from repro.comm.ledger import UPLOAD_TAG, CommLedger


@jax.jit
def step(x):
    return x - x.min()


def noisy(shape, seed=0):
    g = np.random.default_rng(seed)
    led = CommLedger()
    led.record(0, "a->b", 128, kind="inter", tag=UPLOAD_TAG)
    return g.standard_normal(shape), led
