"""Shared benchmark utilities: timing, CSV row emission, trace capture.

When tracing is on (``REPRO_TRACE=1`` or ``repro.obs.trace.enable()``),
``timed`` wraps every measured call in a ``bench/<name>`` span and
``module_trace`` exports each bench module's flight-recorder contents to
``TRACE_<label>.jsonl`` (dir from ``BENCH_TRACE_DIR``, default cwd) — so a
traced benchmark run leaves one trace file per module next to the CSV rows.
"""
from __future__ import annotations

import os
import time
from contextlib import contextmanager
from typing import Callable, List, Optional, Tuple

from repro.obs import trace as obs_trace

Row = Tuple[str, float, str]


def trace_dir() -> str:
    return os.environ.get("BENCH_TRACE_DIR", ".")


@contextmanager
def module_trace(label: str, **meta):
    """Reset the flight recorder around one bench module and export its
    spans to ``TRACE_<label>.jsonl`` on exit.  No-op when tracing is off."""
    if not obs_trace.enabled():
        yield None
        return
    tracer = obs_trace.get_tracer()
    tracer.reset()
    obs_trace.set_meta(label=label, **meta)
    try:
        yield tracer
    finally:
        path = os.path.join(trace_dir(), f"TRACE_{label}.jsonl")
        obs_trace.export_jsonl(path)


def now_s() -> float:
    """Monotonic wall clock in seconds for bench timing loops.

    Benches time through here (or :func:`timed`) rather than calling
    ``time.*`` directly — this module is the one RL003-sanctioned clock
    source under ``benchmarks/``.
    """
    return time.perf_counter()


def timed(fn: Callable, repeats: int = 3, warmup: int = 1,
          name: Optional[str] = None) -> float:
    """Median wall-time per call in microseconds.

    ``warmup`` calls run first and are discarded so JIT/trace cost doesn't
    pollute the median (codec rows used to time a single cold call).  With
    tracing on and a ``name``, each measured call records a ``bench/<name>``
    span so the trace file carries one span per (row, repeat).
    """
    for _ in range(max(0, warmup)):
        fn()
    tracing = name is not None and obs_trace.enabled()
    ts = []
    for rep in range(repeats):
        t0 = time.perf_counter()
        if tracing:
            with obs_trace.span(f"bench/{name}", repeat=rep):
                fn()
        else:
            fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------
# memory probes — is a bench O(cohort) or O(population)?
# ---------------------------------------------------------------------------
def device_live_bytes() -> int:
    """Total bytes of live device arrays right now.

    Deterministic (sums ``jax.live_arrays()`` buffer sizes, no allocator
    statistics), so scaling assertions on it are CI-stable: run a workload,
    diff before/after, and the delta is exactly the bytes the workload left
    alive."""
    import jax

    return int(sum(a.nbytes for a in jax.live_arrays()))


def host_peak_rss_mb() -> float:
    """Peak resident set size of this process in MiB (monotonic high-water
    mark — report it per row, don't diff it)."""
    import resource
    import sys

    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    # linux reports KiB, macOS bytes
    return peak / 1024.0 if sys.platform != "darwin" else peak / (1024.0**2)


def mem_probe(fn: Callable) -> Tuple[object, int]:
    """Run ``fn`` and return ``(result, device_bytes_delta)`` — the device
    memory its live results retain.  Pair with ``host_peak_rss_mb`` in the
    derived column for per-row memory attribution."""
    before = device_live_bytes()
    out = fn()
    return out, device_live_bytes() - before
