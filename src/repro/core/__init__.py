"""The paper's primary contribution: communication-efficient distributed
training via compression (EF-BV), local training + personalization (Scafflix),
multi-round cohorts (SPPM-AS), federated pruning (FedP3) and post-training
pruning (SymWanda), plus the TPU-mesh runtime integration (distributed)."""
from repro.core import compressors
from repro.core import distributed
from repro.core import ef_bv
from repro.core import fedp3
from repro.core import scafflix
from repro.core import sppm
from repro.core import symwanda
