"""Synthetic language-model corpus.

Offline container => no downloads.  We generate a Zipf-distributed Markov
token stream with injected n-gram structure so a model actually has signal to
learn (loss drops well below uniform), deterministic per seed.  This feeds the
end-to-end train driver and the serve examples.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass
class SyntheticLMDataset:
    vocab_size: int
    length: int
    seed: int = 0
    order: int = 2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # sparse markov transition: each (prev,) state strongly prefers a few
        # successors, successors drawn zipf-ish so frequent tokens cluster.
        n_states = min(4096, v)
        branch = 8
        self._succ = rng.integers(0, v, size=(n_states, branch), dtype=np.int64)
        zipf = 1.0 / np.arange(1, branch + 1)
        self._succ_p = zipf / zipf.sum()
        self._n_states = n_states
        self._tokens = self._generate(rng)

    def _generate(self, rng) -> np.ndarray:
        out = np.empty(self.length, dtype=np.int32)
        state = 0
        noise = rng.random(self.length)
        picks = rng.integers(0, len(self._succ_p), size=self.length)
        cum = np.cumsum(self._succ_p)
        choice = np.searchsorted(cum, rng.random(self.length))
        uniform = rng.integers(0, self.vocab_size, size=self.length)
        for i in range(self.length):
            if noise[i] < 0.85:
                tok = self._succ[state, choice[i]]
            else:
                tok = uniform[i]
            out[i] = tok
            state = int(tok) % self._n_states
        return out

    @property
    def tokens(self) -> np.ndarray:
        return self._tokens


def lm_batch_iterator(
    ds: SyntheticLMDataset, batch: int, seq_len: int, seed: int = 0
) -> Iterator[dict]:
    """Yields {'tokens': (B, S+1) int32}; model shifts internally."""
    rng = np.random.default_rng(seed)
    n = len(ds.tokens) - seq_len - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        rows = np.stack([ds.tokens[s : s + seq_len + 1] for s in starts])
        yield {"tokens": rows.astype(np.int32)}
