"""Mamba2-2.7B. [arXiv:2405.21060]

Attention-free state-space model using the SSD (state-space duality) block:
chunked matmul formulation for training, O(1)-state recurrent step for decode.
d_inner = 2*d_model = 5120, head_dim 64 -> 80 SSD heads, d_state 128.
No MLP (d_ff=0): the SSD block is the whole layer, as in the paper.
long_500k runs (constant-size recurrent state).
"""
from repro.configs.base import MAMBA, MambaConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        citation="arXiv:2405.21060",
        num_layers=64,
        d_model=2560,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        layer_pattern=(MAMBA,),
        mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
        tie_embeddings=True,
        supports_long_context=True,
    )
)
