"""Fig 2.2 reproduction: suboptimality vs bits-sent for EF-BV / EF21 / DIANA.

The paper plots f(x^t) - f* against bits per node (proportional to t*k) for
comp-(k, d/2) compressors on LibSVM logreg; we use the controlled synthetic
federated logreg (same objective family) and the same three algorithms with
theory stepsizes. Derived column: bits-per-node to reach the target gap
(lower = better; the paper's qualitative claim is EF-BV < DIANA < EF21).

Bit accounting comes from the CommLedger: each round records the *encoded*
payload bytes of the per-client compressed delta (repro.comm codecs), not the
analytic payload_bits model — the size of one encoded probe is exact for
rand-k (fixed k), so it is measured once and recorded per round."""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, now_s, timed
from repro.comm import CommLedger, encode
from repro.core import compressors as C
from repro.core.ef_bv import efbv_gd, efbv_init, efbv_params
from repro.core.scafflix import logreg_grads
from repro.core.sppm import solve_erm
from repro.data.federated import make_logreg_clients

TARGET_GAP = 1e-3
ROUNDS = 800


def run():
    prob = make_logreg_clients(n_clients=16, m=100, d=40, mu=0.1, hetero=0.5, seed=0)
    A, b = jnp.asarray(prob.A), jnp.asarray(prob.b)
    n, m, d = A.shape
    Ls = prob.smoothness()
    L, Lt = float(np.mean(Ls)), float(np.sqrt(np.mean(Ls**2)))
    x_star = solve_erm(prob)

    def f_fn(x):
        z = jnp.einsum("nmd,d->nm", A, x)
        return jnp.mean(jnp.log1p(jnp.exp(-b * z))) + 0.5 * prob.mu * jnp.sum(x**2)

    f_star = float(f_fn(jnp.asarray(x_star)))
    grad_fn = lambda x: logreg_grads(jnp.tile(x[None], (n, 1)), A, b, prob.mu)

    rows = []
    # the paper's rand-k-flavoured randomized compressor (comp uses top of a
    # random support; rand-k keeps the closed-form (eta, omega) for stepsizes)
    for cname, comp in [("rand_k(0.1)", C.rand_k(0.1)),
                        ("rand_k(0.25)", C.rand_k(0.25))]:
        # size one encoded per-client payload (rand-k: size-invariant in the
        # data, so one probe encode gives the exact per-round wire bytes)
        probe = jax.random.normal(jax.random.PRNGKey(9), (d,))
        msg_bytes = encode(comp, jax.random.PRNGKey(10), probe).nbytes
        for mode in ("efbv", "ef21", "diana"):
            lam, nu = efbv_params(comp, n, mode)
            om_ran = comp.omega / n if mode in ("efbv", "diana") else comp.omega
            gamma = C.efbv_stepsize(L, Lt, comp.eta, comp.omega, om_ran, lam, nu)
            t0 = now_s()
            _, _, trace = efbv_gd(jax.random.PRNGKey(0), jnp.zeros(d), grad_fn,
                                  efbv_init(n, d), comp, lam, nu, gamma, ROUNDS, f_fn)
            us = (now_s() - t0) * 1e6
            gaps = np.asarray(trace) - f_star
            hit = np.argmax(gaps < TARGET_GAP) if (gaps < TARGET_GAP).any() else -1
            ledger = CommLedger.from_rounds(
                msg_bytes, len(gaps) if hit < 0 else hit + 1)
            cum_bits = np.asarray(ledger.cumulative_bytes(), np.float64) * 8
            derived = (f"bits_to_{TARGET_GAP:g}={cum_bits[hit]:.0f}" if hit >= 0
                       else f"gap_at_end={gaps[-1]:.2e}")
            rows.append((f"efbv_fig2.2/{cname}/{mode}", us, derived))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
