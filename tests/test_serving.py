"""Continuous-batching scheduler tests."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.training.serving import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def served():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_completes_all_requests(served):
    cfg, params = served
    cb = ContinuousBatcher(cfg, params, n_slots=3, max_len=96)
    rng = np.random.default_rng(0)
    for rid in range(7):  # more requests than slots => refills must happen
        L = int(rng.integers(4, 12))
        cb.submit(Request(rid=rid, prompt=rng.integers(
            1, cfg.vocab_size, size=L).astype(np.int32), max_new=6))
    stats = cb.run(max_ticks=200)
    assert stats.completed == 7
    assert stats.prefills >= 2          # continuous refill happened
    assert stats.tokens_out == 7 * 6
    assert all(len(r.generated) == 6 for r in cb.slots if r is not None)


def test_stop_token_terminates_early(served):
    cfg, params = served
    cb = ContinuousBatcher(cfg, params, n_slots=2, max_len=64)
    # stop on whatever token the model emits first => finishes in 1 step
    cb.submit(Request(rid=0, prompt=np.array([5, 6, 7], np.int32), max_new=50))
    cb.step()
    first_tok = cb.slots[0].generated[0]
    cb2 = ContinuousBatcher(cfg, params, n_slots=2, max_len=64)
    cb2.submit(Request(rid=0, prompt=np.array([5, 6, 7], np.int32),
                       max_new=50, stop_token=first_tok))
    stats = cb2.run(max_ticks=100)
    assert stats.completed == 1
    assert len([t for r in cb2.slots if r for t in r.generated]) == 1


def test_continuation_is_deterministic(served):
    cfg, params = served
    prompts = [np.array([3, 4, 5, 6], np.int32)]
    outs = []
    for _ in range(2):
        cb = ContinuousBatcher(cfg, params, n_slots=1, max_len=64)
        cb.submit(Request(rid=0, prompt=prompts[0], max_new=8))
        cb.run(max_ticks=50)
        outs.append(tuple(cb.slots[0].generated))
    assert outs[0] == outs[1]
