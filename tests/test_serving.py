"""Continuous-batching scheduler tests."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.training.serving import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def served():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_completes_all_requests(served):
    cfg, params = served
    cb = ContinuousBatcher(cfg, params, n_slots=3, max_len=96)
    rng = np.random.default_rng(0)
    for rid in range(7):  # more requests than slots => refills must happen
        L = int(rng.integers(4, 12))
        cb.submit(Request(rid=rid, prompt=rng.integers(
            1, cfg.vocab_size, size=L).astype(np.int32), max_new=6))
    stats = cb.run(max_ticks=200)
    assert stats.completed == 7
    assert stats.prefills >= 2          # continuous refill happened
    assert stats.tokens_out == 7 * 6
    assert all(len(r.generated) == 6 for r in cb.slots if r is not None)


def test_stop_token_terminates_early(served):
    cfg, params = served
    cb = ContinuousBatcher(cfg, params, n_slots=2, max_len=64)
    # stop on whatever token the model emits first => finishes in 1 step
    cb.submit(Request(rid=0, prompt=np.array([5, 6, 7], np.int32), max_new=50))
    cb.step()
    first_tok = cb.slots[0].generated[0]
    cb2 = ContinuousBatcher(cfg, params, n_slots=2, max_len=64)
    cb2.submit(Request(rid=0, prompt=np.array([5, 6, 7], np.int32),
                       max_new=50, stop_token=first_tok))
    stats = cb2.run(max_ticks=100)
    assert stats.completed == 1
    assert len([t for r in cb2.slots if r for t in r.generated]) == 1


def test_continuation_is_deterministic(served):
    cfg, params = served
    prompts = [np.array([3, 4, 5, 6], np.int32)]
    outs = []
    for _ in range(2):
        cb = ContinuousBatcher(cfg, params, n_slots=1, max_len=64)
        cb.submit(Request(rid=0, prompt=prompts[0], max_new=8))
        cb.run(max_ticks=50)
        outs.append(tuple(cb.slots[0].generated))
    assert outs[0] == outs[1]


def test_refill_does_not_stall_live_requests(served):
    """A long request keeps generating one token per tick straight through
    the refills that admit later short requests — progress never resets."""
    cfg, params = served
    cb = ContinuousBatcher(cfg, params, n_slots=2, max_len=96)
    long_req = Request(rid=0, prompt=np.array([3, 4, 5], np.int32), max_new=16)
    cb.submit(long_req)
    for rid in range(1, 5):
        cb.submit(Request(rid=rid, prompt=np.array([7, 8], np.int32),
                          max_new=3))
    progress = []
    for _ in range(200):
        cb.step()
        progress.append(len(long_req.generated))
        if not cb.queue and all(r is None or r.done for r in cb.slots):
            break
    # strictly +1 per tick while live: no tick lost to a refill
    grew = [b - a for a, b in zip(progress, progress[1:]) if b != a or a < 16]
    assert progress[0] == 1
    assert all(d == 1 for d in grew[:15])
    assert long_req.done and len(long_req.generated) == 16
    assert cb.stats.completed == 5
    assert cb.stats.prefills >= 2


def test_stop_token_vs_max_new_termination(served):
    """stop_token ends a request the step it fires; an unmatched stop_token
    falls back to exactly max_new tokens."""
    cfg, params = served
    cb = ContinuousBatcher(cfg, params, n_slots=2, max_len=64)
    never = Request(rid=0, prompt=np.array([5, 6, 7], np.int32), max_new=4,
                    stop_token=-1)  # tokens are >= 0: can never match
    cb.submit(never)
    cb.run(max_ticks=100)
    assert never.done and len(never.generated) == 4

    first_tok = never.generated[0]
    cb2 = ContinuousBatcher(cfg, params, n_slots=2, max_len=64)
    stopped = Request(rid=0, prompt=np.array([5, 6, 7], np.int32),
                      max_new=50, stop_token=first_tok)
    cb2.submit(stopped)
    cb2.run(max_ticks=100)
    assert stopped.done
    assert stopped.generated[-1] == first_tok
    assert len(stopped.generated) < 50


def test_ragged_left_padded_prompts(served):
    """Ragged prompt lengths batch via left-padding: every request finishes
    with its full budget and the batched schedule is deterministic."""
    cfg, params = served
    lens = [1, 3, 9, 14]
    runs = []
    for _ in range(2):
        cb = ContinuousBatcher(cfg, params, n_slots=4, max_len=96)
        rng = np.random.default_rng(42)
        for rid, L in enumerate(lens):
            cb.submit(Request(rid=rid, prompt=rng.integers(
                1, cfg.vocab_size, size=L).astype(np.int32), max_new=5))
        stats = cb.run(max_ticks=100)
        assert stats.completed == len(lens)
        assert all(len(r.generated) == 5 for r in cb.slots if r is not None)
        assert all(0 <= t < cfg.vocab_size
                   for r in cb.slots if r is not None for t in r.generated)
        runs.append([tuple(r.generated) for r in cb.slots])
    assert runs[0] == runs[1]


def test_queue_is_fifo_deque(served):
    """The request queue is a deque admitted in FIFO order."""
    from collections import deque
    cfg, params = served
    cb = ContinuousBatcher(cfg, params, n_slots=2, max_len=64)
    assert isinstance(cb.queue, deque)
    for rid in range(5):
        cb.submit(Request(rid=rid, prompt=np.array([2, 3], np.int32),
                          max_new=2))
    cb.step()
    admitted_first = sorted(r.rid for r in cb.slots if r is not None)
    assert admitted_first == [0, 1]
    assert [r.rid for r in cb.queue] == [2, 3, 4]


def test_serve_stats_metrics_bridge(served):
    """run() publishes ServeStats into the obs metrics registry."""
    from repro.obs.metrics import MetricsRegistry
    cfg, params = served
    cb = ContinuousBatcher(cfg, params, n_slots=2, max_len=64)
    cb.submit(Request(rid=0, prompt=np.array([4, 5], np.int32), max_new=3))
    cb.run(max_ticks=50)
    reg = MetricsRegistry()
    cb.publish_stats(reg)
    stats = reg.serve_stats()
    assert stats["completed"] == 1.0
    assert stats["tokens_out"] == 3.0
    assert stats["decode_steps"] == cb.stats.decode_steps
