"""Batched multi-user forward: per-slot delta application inside one jit.

One jitted prefill/decode pair serves *every* user.  Slot ``b``'s effective
parameters are

    eff_b = base_blocks + pool_blocks[table_b]          # (n_blocks, bs)
    params_b = debucketize(eff_b)                       # the user's tree

computed inside the jit from the shared ``(capacity+1, block)`` pool array
and a per-slot int32 block table — a gather plus an add, no host syncs, no
tracer branching (RL001/RL005-clean), and the jit signature is shape-static
in users, so admitting a new user never recompiles.

``prefill_eff``/``decode_eff`` take fully materialized per-slot blocks
instead of (pool, tables); they share the exact same traced forward, which
is what lets ``bench_serve`` certify the delta path bitwise against serving
a user's materialized personalized params.

:class:`PersonalizedBatcher` plugs this engine into the continuous batcher:
admission pins the user's delta in the pool (paging it in on a miss) and
slot retirement releases the pin.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.buckets import bucketize_groups, debucketize_groups
from repro.serve.deltas import DeltaStore
from repro.serve.pool import BlockPool
from repro.training.serving import ContinuousBatcher, Request


def _make_forward(cfg, layout, max_len: int):
    """Trace-once factory: (prefill_eff, decode_eff) over per-slot blocks."""
    from repro.models import decode_step, prefill as model_prefill

    def slot_params(eff_blocks):                  # (B, n_blocks, bs) -> trees
        return debucketize_groups(eff_blocks, layout)

    def prefill_eff(eff_blocks, tokens):
        params_b = slot_params(eff_blocks)

        def one(p, t):
            logits, cache = model_prefill(p, cfg, {"tokens": t[None]},
                                          cache_len=max_len)
            return logits[0], cache

        return jax.vmap(one)(params_b, tokens)

    def decode_eff(eff_blocks, tok, cache):
        params_b = slot_params(eff_blocks)

        def one(p, t, c):
            logits, c2 = decode_step(p, cfg, t[None], c)
            return logits[0], c2

        return jax.vmap(one)(params_b, tok, cache)

    return prefill_eff, decode_eff


class DeltaServeEngine:
    """Jitted prefill/decode where each batch slot applies its own delta."""

    def __init__(self, cfg, store: DeltaStore, max_len: int = 128):
        if getattr(cfg, "enc_layers", 0) or getattr(cfg, "vision_tokens", 0):
            raise NotImplementedError(
                "DeltaServeEngine serves decoder-only configs")
        self.cfg = cfg
        self.store = store
        self.layout = store.layout
        self.max_len = int(max_len)
        prefill_eff, decode_eff = _make_forward(cfg, self.layout, self.max_len)
        self._prefill_eff = jax.jit(prefill_eff)
        self._decode_eff = jax.jit(decode_eff)
        # The delta path computes eff inside the SAME traced forward.
        self._prefill_delta = jax.jit(
            lambda base, pool, tables, toks:
                prefill_eff(base[None] + pool[tables], toks))
        self._decode_delta = jax.jit(
            lambda base, pool, tables, tok, cache:
                decode_eff(base[None] + pool[tables], tok, cache))

    # -- delta path (production) -------------------------------------------
    def prefill(self, pool: BlockPool, tables, tokens):
        """tables (B, n_blocks) int32; tokens (B, L) int32."""
        return self._prefill_delta(self.store.base_blocks, pool.blocks,
                                   jnp.asarray(tables), jnp.asarray(tokens))

    def decode(self, pool: BlockPool, tables, tok, cache):
        return self._decode_delta(self.store.base_blocks, pool.blocks,
                                  jnp.asarray(tables), jnp.asarray(tok),
                                  cache)

    # -- materialized path (oracle / full-copy serving) ---------------------
    def prefill_materialized(self, eff_blocks, tokens):
        return self._prefill_eff(eff_blocks, jnp.asarray(tokens))

    def decode_materialized(self, eff_blocks, tok, cache):
        return self._decode_eff(eff_blocks, jnp.asarray(tok), cache)

    def eff_blocks_for(self, params_list: List) -> jnp.ndarray:
        """Stack per-slot materialized trees -> (B, n_blocks, bs) blocks."""
        stacked = jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls), *params_list)
        blocks, layout = bucketize_groups(stacked, self.layout.bucket_size)
        if layout.shapes != self.layout.shapes:
            raise ValueError("materialized tree does not match store layout")
        return blocks

    def compile_cache_sizes(self) -> dict:
        """Jit-cache entry counts — the no-per-user-recompile witness."""
        return {"prefill": self._prefill_delta._cache_size(),
                "decode": self._decode_delta._cache_size()}


class PersonalizedBatcher(ContinuousBatcher):
    """Continuous batcher whose slots each serve their own personalized user.

    Admission ``acquire``s the request's ``user_id`` from the block pool
    (page-in on a miss, pin while scheduled); retirement releases the pin
    and zeroes the slot's block table.  Requests with ``user_id=None`` are
    served on the bare base model (all-zero table, nothing pinned).
    """

    def __init__(self, cfg, store: DeltaStore, pool: BlockPool,
                 n_slots: int = 4, max_len: int = 128,
                 engine: Optional[DeltaServeEngine] = None):
        self.store = store
        self.pool = pool
        self._engine_override = engine
        self._tables = np.zeros((n_slots, store.layout.n_buckets), np.int32)
        super().__init__(cfg, params=None, n_slots=n_slots, max_len=max_len)

    # -- ContinuousBatcher hooks -------------------------------------------
    def _build_model(self) -> None:
        self.engine = (self._engine_override
                       or DeltaServeEngine(self.cfg, self.store,
                                           self.max_len))

    def _model_prefill(self, batch):
        return self.engine.prefill(self.pool, self._tables, batch["tokens"])

    def _model_decode(self, tok):
        return self.engine.decode(self.pool, self._tables, tok, self.cache)

    def _on_admit(self, slot: int, req: Request) -> None:
        if req.user_id is None:
            self._tables[slot] = 0
            return
        entry = self.pool.acquire(req.user_id)
        self._tables[slot] = entry.table

    def _on_retire(self, slot: int, req: Request) -> None:
        self._tables[slot] = 0
        if req.user_id is not None:
            self.pool.release(req.user_id)
