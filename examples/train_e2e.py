"""End-to-end training driver: a ~25M-param qwen-family model for a few
hundred steps on the synthetic corpus, with checkpointing, eval and the
paper's compressed-sync option.

    PYTHONPATH=src python examples/train_e2e.py --steps 300 [--sync efbv]

(~25M is what a few hundred steps finish in on this 1-core CPU container in
reasonable time; on real hardware the same driver scales to the full configs
— the multi-pod dry-run proves those lower. Pass --d-model 512 --layers 8
for the ~100M variant if you have the budget.)
"""
import argparse
import sys
from dataclasses import replace

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import SyncConfig, TrainConfig
from repro.data.synthetic import SyntheticLMDataset, lm_batch_iterator
from repro.models import forward_train
from repro.models.layers import cross_entropy_loss
from repro.training.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--sync", default="dense",
                    choices=["dense", "efbv", "ef21", "local", "hier"])
    ap.add_argument("--ckpt", default="results/e2e_ckpt")
    args = ap.parse_args()

    base = get_config("qwen1.5-4b")
    cfg = replace(
        base, num_layers=args.layers, d_model=args.d_model,
        num_heads=max(4, args.d_model // 64), num_kv_heads=max(2, args.d_model // 128),
        head_dim=64, d_ff=args.d_model * 4, vocab_size=8192, dtype="float32",
    )
    print(f"model: {cfg.num_layers}L d={cfg.d_model} v={cfg.vocab_size} "
          f"-> {cfg.param_count()/1e6:.1f}M params, sync={args.sync}")

    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, length=200000, seed=0)
    it = lm_batch_iterator(ds, args.batch, args.seq, seed=1)
    tc = TrainConfig(model=cfg, seq_len=args.seq, global_batch=args.batch,
                     lr=3e-3, warmup_steps=20, total_steps=args.steps,
                     sync=SyncConfig(mode=args.sync, compressor="qsgd",
                                     sync_period=4))
    n_groups = 2 if args.sync != "dense" else 1
    state, hist = train(cfg, tc, it, n_groups=n_groups, n_pods=2,
                        steps=args.steps, ckpt_path=args.ckpt, log_every=20)

    # held-out eval
    eval_it = lm_batch_iterator(ds, args.batch, args.seq, seed=999)
    params = state.params
    if args.sync in ("local", "hier"):
        params = jax.tree_util.tree_map(lambda p: p[0], params)
    losses = []
    for _ in range(5):
        b = next(eval_it)
        eb = {"tokens": jnp.asarray(b["tokens"][:, :-1]),
              "targets": jnp.asarray(b["tokens"][:, 1:])}
        lg, _ = forward_train(params, cfg, eb)
        losses.append(float(cross_entropy_loss(lg, eb["targets"])))
    print(f"train loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}; "
          f"eval loss {np.mean(losses):.3f} (uniform would be {np.log(cfg.vocab_size):.3f})")


if __name__ == "__main__":
    main()
