"""repro.serve — delta store, block pool pager, multi-user engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import PAGE_IN_TAG, PAGE_OUT_TAG
from repro.comm.buckets import bucketize
from repro.comm.codecs import decode
from repro.core.compressors import Compressor, WireSpec, make_compressor
from repro.configs import get_config
from repro.models import init_params
from repro.obs.metrics import MetricsRegistry
from repro.serve import (BlockPool, DeltaCertificationError, DeltaServeEngine,
                         DeltaStore, PersonalizedBatcher, PoolExhausted,
                         ZERO_ROW, delta_from_params, params_from_delta,
                         personalize_leaves)
from repro.training.serving import Request

BLOCK = 4096


@pytest.fixture(scope="module")
def base():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _store(params, compressor="top_k", n_users=4, **kw):
    if compressor == "top_k":
        kw.setdefault("k_frac", 0.01)
    comp = make_compressor(compressor, **kw) if isinstance(compressor, str) \
        else compressor
    store = DeltaStore(params, comp, block_size=BLOCK, seed=7)
    key = jax.random.PRNGKey(1)
    for uid in range(n_users):
        store.put(uid, personalize_leaves(params, jax.random.fold_in(key, uid)))
    return store


# ---------------------------------------------------------------------------
# deltas
# ---------------------------------------------------------------------------
def test_delta_roundtrip_certified_bit_exact(base):
    cfg, params = base
    store = _store(params, n_users=2)
    for uid in store.user_ids():
        carrier = np.asarray(decode(store.payload(uid)))
        # decode equals the compressor's own carrier bit-for-bit
        pers = personalize_leaves(params, jax.random.fold_in(
            jax.random.PRNGKey(1), uid))
        pers_blocks, _ = bucketize(pers, BLOCK)
        ref = store.compressor(store.user_key(uid),
                               (pers_blocks - store.base_blocks).reshape(-1))
        assert carrier.tobytes() == np.asarray(ref).tobytes()


def test_params_from_delta_reconstructs(base):
    """Untouched leaves come back bitwise equal to the base; personalized
    leaves come back base + carrier."""
    cfg, params = base
    store = _store(params, n_users=1, k_frac=0.01)
    rec = store.personalized_params(0)
    flat_b = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_r = jax.tree_util.tree_leaves(rec)
    touched = untouched = 0
    for (path, pb), pr in zip(flat_b, flat_r):
        name = jax.tree_util.keystr(path).lower()
        same = np.asarray(pb).tobytes() == np.asarray(pr, np.asarray(pb).dtype).tobytes()
        if "norm" in name:
            touched += 0 if same else 1
        elif same:
            untouched += 1
    assert untouched > 0          # non-personalized leaves identical to base
    assert touched > 0            # at least one personalized leaf changed


def test_quant_delta_certifies(base):
    """Stochastic qsgd certifies too: the per-user key makes re-encode
    deterministic."""
    cfg, params = base
    store = _store(params, "qsgd", n_users=1, bits=8)
    p = store.payload(0)
    assert p.scheme == "quant"
    rec = store.personalized_params(0)
    assert jax.tree_util.tree_structure(rec) == \
        jax.tree_util.tree_structure(params)


def test_certification_rejects_nondeterministic_compressor(base):
    cfg, params = base
    calls = {"n": 0}

    def flaky(key, x):
        calls["n"] += 1
        return x + (0.0 if calls["n"] == 1 else 1.0)

    comp = Compressor("flaky", flaky, eta=0.0, omega=0.0, bits_per_dim=32.0,
                      wire=WireSpec(scheme="dense"))
    store = DeltaStore(params, comp, block_size=BLOCK)
    with pytest.raises(DeltaCertificationError):
        store.put(0, personalize_leaves(params, jax.random.PRNGKey(3)))


def test_store_charges_page_out(base):
    cfg, params = base
    store = _store(params, n_users=3)
    tags = store.ledger.bytes_by_tag()
    assert tags[PAGE_OUT_TAG] == store.total_payload_bytes()
    assert PAGE_IN_TAG not in tags  # nothing paged in yet


# ---------------------------------------------------------------------------
# pool
# ---------------------------------------------------------------------------
def test_pool_miss_hit_and_zero_block_aliasing(base):
    cfg, params = base
    store = _store(params, n_users=2)
    reg = MetricsRegistry()
    pool = BlockPool(store, capacity_blocks=16, metrics=reg)

    before = store.ledger.bytes_by_tag().get(PAGE_IN_TAG, 0)
    e = pool.acquire(0)                       # miss
    after = store.ledger.bytes_by_tag()[PAGE_IN_TAG]
    assert after - before == store.nbytes(0)  # miss charges payload.nbytes
    assert pool.misses == 1 and pool.hits == 0

    # zero blocks alias the shared row 0: resident cost is O(delta blocks)
    assert e.n_blocks < store.layout.n_buckets
    assert np.sum(e.table != ZERO_ROW) == e.n_blocks
    assert ZERO_ROW not in e.rows

    e2 = pool.acquire(0)                      # hit: zero bytes, same entry
    assert e2 is e and e.pins == 2
    assert store.ledger.bytes_by_tag()[PAGE_IN_TAG] == after
    assert pool.hits == 1
    pool.release(0), pool.release(0)
    assert reg.serve_stats()["pool/hits"] == 1.0


def test_pool_lru_evicts_unpinned_oldest(base):
    cfg, params = base
    store = _store(params, n_users=3)
    per_user = BlockPool(store, capacity_blocks=64).acquire(0).n_blocks
    pool = BlockPool(store, capacity_blocks=2 * per_user)
    pool.acquire(0); pool.release(0)
    pool.acquire(1); pool.release(1)
    pool.acquire(2); pool.release(2)          # evicts user 0 (oldest)
    assert pool.evictions >= 1
    assert not pool.is_resident(0)
    assert pool.is_resident(2)
    # re-acquiring the evicted user is a fresh miss (pages + charges again)
    before = store.ledger.bytes_by_tag()[PAGE_IN_TAG]
    pool.acquire(0)
    assert store.ledger.bytes_by_tag()[PAGE_IN_TAG] - before == store.nbytes(0)


def test_pool_pinned_entries_never_evicted(base):
    cfg, params = base
    store = _store(params, n_users=3)
    per_user = BlockPool(store, capacity_blocks=64).acquire(0).n_blocks
    pool = BlockPool(store, capacity_blocks=2 * per_user)
    pool.acquire(0)                            # pinned
    pool.acquire(1)                            # pinned
    with pytest.raises(PoolExhausted):
        pool.acquire(2)
    assert pool.is_resident(0) and pool.is_resident(1)
    pool.release(0)
    pool.acquire(2)                            # now user 0 can be evicted
    assert not pool.is_resident(0)
    assert pool.is_resident(1)


def test_pool_release_without_acquire_raises(base):
    cfg, params = base
    store = _store(params, n_users=1)
    pool = BlockPool(store, capacity_blocks=8)
    pool.acquire(0); pool.release(0)
    with pytest.raises(RuntimeError):
        pool.release(0)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------
def test_engine_bitwise_identical_to_materialized(base):
    cfg, params = base
    store = _store(params, n_users=2)
    pool = BlockPool(store, capacity_blocks=16)
    eng = DeltaServeEngine(cfg, store, max_len=32)
    pool.acquire(0); pool.acquire(1)
    tables = np.stack([pool.table_for(0), pool.table_for(1)])
    toks = np.array([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)

    logits, cache = eng.prefill(pool, tables, toks)
    eff = eng.eff_blocks_for([store.personalized_params(0),
                              store.personalized_params(1)])
    lm, cm = eng.prefill_materialized(eff, toks)
    assert np.asarray(logits).tobytes() == np.asarray(lm).tobytes()

    tok = np.asarray(jnp.argmax(logits[:, -1, :cfg.vocab_size],
                                -1))[:, None].astype(np.int32)
    for _ in range(3):
        logits, cache = eng.decode(pool, tables, tok, cache)
        lm, cm = eng.decode_materialized(eff, tok, cm)
        assert np.asarray(logits).tobytes() == np.asarray(lm).tobytes()
        tok = np.asarray(jnp.argmax(logits[:, -1, :cfg.vocab_size],
                                    -1))[:, None].astype(np.int32)


def test_engine_no_per_user_recompile(base):
    cfg, params = base
    store = _store(params, n_users=4)
    pool = BlockPool(store, capacity_blocks=32)
    eng = DeltaServeEngine(cfg, store, max_len=32)
    toks = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
    for pair in ((0, 1), (2, 3), (1, 3)):
        tables = np.stack([pool.acquire(u).table for u in pair])
        logits, cache = eng.prefill(pool, tables, toks)
        tok = np.asarray(jnp.argmax(logits[:, -1, :cfg.vocab_size],
                                    -1))[:, None].astype(np.int32)
        eng.decode(pool, tables, tok, cache)
        for u in pair:
            pool.release(u)
    sizes = eng.compile_cache_sizes()
    assert sizes == {"prefill": 1, "decode": 1}


def test_engine_rejects_encdec_configs(base):
    import dataclasses
    cfg, params = base
    store = _store(params, n_users=0)
    bad = dataclasses.replace(cfg, enc_layers=2)
    with pytest.raises(NotImplementedError):
        DeltaServeEngine(bad, store)


# ---------------------------------------------------------------------------
# personalized batcher (end to end)
# ---------------------------------------------------------------------------
def test_personalized_batcher_serves_and_unpins(base):
    cfg, params = base
    store = _store(params, n_users=5)
    pool = BlockPool(store, capacity_blocks=32)
    b = PersonalizedBatcher(cfg, store, pool, n_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(5):
        L = int(rng.integers(3, 10))
        b.submit(Request(rid=rid, prompt=rng.integers(
            1, cfg.vocab_size, size=L).astype(np.int32), max_new=4,
            user_id=rid))
    stats = b.run(max_ticks=200)
    assert stats.completed == 5
    assert pool.misses == 5                   # each user paged in once
    assert sum(e.pins for e in pool._entries.values()) == 0
    assert np.all(b._tables == ZERO_ROW)      # retired slots cleared


def test_personalized_batcher_base_user_and_repeat_hits(base):
    cfg, params = base
    store = _store(params, n_users=1)
    pool = BlockPool(store, capacity_blocks=16)
    b = PersonalizedBatcher(cfg, store, pool, n_slots=2, max_len=64)
    b.submit(Request(rid=0, prompt=np.array([3, 4], np.int32), max_new=3,
                     user_id=None))           # base model, nothing pinned
    b.submit(Request(rid=1, prompt=np.array([5, 6], np.int32), max_new=3,
                     user_id=0))
    b.submit(Request(rid=2, prompt=np.array([7, 8], np.int32), max_new=3,
                     user_id=0))              # same user again -> pool hit
    stats = b.run(max_ticks=100)
    assert stats.completed == 3
    assert pool.misses == 1 and pool.hits >= 1


def test_personalized_differs_from_base_serving(base):
    """The per-slot delta actually changes the served distribution: a user
    with a large personalization decodes different logits than user None."""
    cfg, params = base
    comp = make_compressor("top_k", k_frac=0.05)
    store = DeltaStore(params, comp, block_size=BLOCK, seed=7)
    store.put(0, personalize_leaves(params, jax.random.PRNGKey(9),
                                    match=("norm", "embed"), scale=1.0))
    pool = BlockPool(store, capacity_blocks=64)
    eng = DeltaServeEngine(cfg, store, max_len=16)
    entry = pool.acquire(0)
    toks = np.array([[1, 2, 3]], np.int32)
    lp, _ = eng.prefill(pool, np.stack([entry.table]), toks)
    lb, _ = eng.prefill(pool, np.zeros((1, store.layout.n_buckets), np.int32),
                        toks)
    assert np.asarray(lp).tobytes() != np.asarray(lb).tobytes()
