"""EF-BV: Error Feedback with Bias-Variance decomposition (Ch. 2, Fig. 2.1).

Two realizations of Algorithm 1:

1. ``efbv_round`` — the *federated simulation* form on stacked per-client
   gradients (n, d).  This reproduces the paper's experiments exactly
   (Fig. 2.2 bits-vs-suboptimality) and recovers EF21 (nu=lambda) and DIANA
   (nu=1) by parameter choice.

2. ``make_efbv_sync`` — the *distributed runtime* form: a per-worker update
   meant to run inside ``shard_map`` where each data-parallel worker group
   plays one client.  Used by training/train_step for compressed gradient
   synchronization across the data (and pod) mesh axes.

State (both forms): per-client control variates h_i -> nabla f_i(x*) and the
maintained average h_bar = mean_i h_i.  Per round:
    d_i    = C_i(g_i - h_i)
    d      = mean_i d_i                  (the only communication)
    h_i   += lambda * d_i
    g_est  = h_bar + nu * d
    h_bar += lambda * d
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.compressors import (
    Compressor,
    lambda_star,
    nu_star,
    omega_ran_independent,
)


class EFBVState(NamedTuple):
    h: jax.Array       # (n, d) per-client control variates (sim) or local h_i (shard_map)
    h_bar: jax.Array   # (d,) maintained average


def efbv_init(n: int, d: int, dtype=jnp.float32) -> EFBVState:
    return EFBVState(h=jnp.zeros((n, d), dtype), h_bar=jnp.zeros((d,), dtype))


def efbv_params(c: Compressor, n: int, mode: str = "efbv",
                eta: Optional[float] = None, omega: Optional[float] = None):
    """(lambda, nu) for the three algorithms of Fig. 2.1.

    mode: efbv   -> lambda = lambda*(eta, omega), nu = nu*(eta, omega/n)
          ef21   -> nu = lambda = lambda*  (biased-contractive error feedback)
          diana  -> lambda = 1/(1+omega), nu = 1 (variance reduction)
    """
    eta = c.eta if eta is None else eta
    omega = c.omega if omega is None else omega
    if eta is None or omega is None:
        raise ValueError(f"compressor {c.name} needs (eta, omega); estimate them first")
    om_ran = omega_ran_independent(omega, n) if not c.deterministic else omega
    lam = lambda_star(eta, omega)
    if mode == "efbv":
        return lam, nu_star(eta, om_ran)
    if mode == "ef21":
        return lam, lam
    if mode == "diana":
        return 1.0 / (1.0 + omega), 1.0
    raise ValueError(mode)


def efbv_round(key, grads: jax.Array, state: EFBVState, c: Compressor,
               lam: float, nu: float):
    """One EF-BV communication round on stacked client gradients.

    grads: (n, d) = [nabla f_i(x^t)]_i.  Returns (g_est (d,), new_state).
    Each client uses an independent key => omega_ran = omega/n.
    """
    n = grads.shape[0]
    keys = jax.random.split(key, n)
    delta = grads - state.h
    d_i = jax.vmap(lambda k, v: c(k, v))(keys, delta)
    d = jnp.mean(d_i, axis=0)
    new_h = state.h + lam * d_i
    g_est = state.h_bar + nu * d
    new_h_bar = state.h_bar + lam * d
    return g_est, EFBVState(h=new_h, h_bar=new_h_bar)


def efbv_gd(key, x0, grad_fn, state: EFBVState, c: Compressor, lam: float,
            nu: float, gamma: float, steps: int, f_fn=None):
    """Run EF-BV distributed (proximal-free) GD for ``steps`` rounds.

    grad_fn(x) -> (n, d) stacked client gradients.  Returns final x, state and
    per-round objective trace (if f_fn given).
    """

    def body(carry, k):
        x, st = carry
        g, st = efbv_round(k, grad_fn(x), st, c, lam, nu)
        x = x - gamma * g
        val = f_fn(x) if f_fn is not None else jnp.zeros(())
        return (x, st), val

    keys = jax.random.split(key, steps)
    (x, state), trace = jax.lax.scan(body, (x0, state), keys)
    return x, state, trace


# ---------------------------------------------------------------------------
# shard_map form: one worker's view. h_i lives on the worker; h_bar is
# replicated (identical psum on every worker keeps it consistent).
# ---------------------------------------------------------------------------
def efbv_sync_worker(key, grad_tree, h_tree, h_bar_tree, c: Compressor,
                     lam: float, nu: float, axis_names):
    """Per-worker EF-BV sync inside shard_map.

    grad_tree/h_tree: this worker's gradient and control variate (pytrees);
    h_bar_tree: replicated average control variate.
    Returns (g_est_tree, new_h_tree, new_h_bar_tree).
    """
    leaves, treedef = jax.tree_util.tree_flatten(grad_tree)
    h_leaves = treedef.flatten_up_to(h_tree)
    hb_leaves = treedef.flatten_up_to(h_bar_tree)
    keys = jax.random.split(key, len(leaves))

    g_est, new_h, new_hb = [], [], []
    for k, g, h, hb in zip(keys, leaves, h_leaves, hb_leaves):
        d_i = c(k, (g - h).astype(jnp.float32))
        d = jax.lax.pmean(d_i, axis_names)
        new_h.append(h + lam * d_i)
        g_est.append(hb + nu * d)
        new_hb.append(hb + lam * d)
    unf = jax.tree_util.tree_unflatten
    return unf(treedef, g_est), unf(treedef, new_h), unf(treedef, new_hb)
