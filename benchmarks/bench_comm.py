"""repro.comm benchmark: codec sizes vs the analytic model, pack-kernel
throughput, and topology-simulated round times per sync mode.

Rows:
  comm_codec/<name>       encode+decode one 64k-dim payload; derived =
                          encoded bytes (== CommLedger record), the ratio to
                          the analytic payload_bits/8 model, and round-trip
                          exactness vs the compressor output
  comm_kernel/<name>      Pallas pack kernels (interpret mode) vs jnp refs
  comm_round/<mode>       per-round encoded bytes from the ledger + simulated
                          wall-clock on two topology presets (Cohort-Squeeze
                          'hier' shows the slow-link amortization)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.comm import (CommLedger, analytic_bits, decode, encode,
                        get_topology, round_cost)
from repro.configs.base import SyncConfig
from repro.core import compressors as C

D = 1 << 16


def _codec_rows():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (D,))
    cases = [
        ("identity", C.identity()),
        ("top_k(0.05)", C.top_k(0.05)),
        ("rand_k(0.1)", C.rand_k(0.1)),
        ("block_top_k(0.05)", C.block_top_k(0.05)),
        ("qsgd_int8", C.qsgd(8)),
        ("qsgd_int4", C.qsgd(4)),
        ("qsgd_kernel_int8", C.qsgd_kernel(8)),
    ]
    rows = []
    for name, comp in cases:
        t0 = time.perf_counter()
        p = encode(comp, key, x)
        y_hat = decode(p)
        us = (time.perf_counter() - t0) * 1e6
        exact = bool(jnp.all(comp(key, x) == y_hat))
        led = CommLedger()
        led.record_payload(0, "probe", p)
        ratio = 8.0 * led.total_bytes / analytic_bits(comp, D)
        rows.append((f"comm_codec/{name}", us,
                     f"bytes={led.total_bytes};vs_analytic={ratio:.3f};exact={exact}"))
    return rows


def _kernel_rows():
    from repro.kernels import ops, ref

    rows = []
    mask = (jax.random.uniform(jax.random.PRNGKey(2), (D,)) < 0.05)
    us = timed(lambda: jax.block_until_ready(ops.pack_bits(mask)))
    words = ops.pack_bits(mask)
    ok = bool(jnp.all(ops.unpack_bits(words, D) == mask.astype(jnp.uint32)))
    rows.append(("comm_kernel/pack_bits", us,
                 f"words={words.shape[0]};roundtrip={ok}"))

    x = jax.random.normal(jax.random.PRNGKey(3), (D,)) * 5
    key = jax.random.PRNGKey(4)
    us = timed(lambda: jax.block_until_ready(ops.quantize_pack(x, key)[0]))
    q, scales = ops.quantize_pack(x, key)
    dq = ops.unpack_dequantize(q, scales, D)
    carrier = ops.quantize_dequantize(x, key)
    ok = bool(jnp.all(dq == carrier.reshape(-1)))
    rows.append(("comm_kernel/quantize_pack", us,
                 f"plane_bytes={q.size + 4 * scales.size};matches_carrier={ok}"))
    return rows


def _round_rows():
    n_params = 25_000_000  # ~100 MB fp32 model
    rows = []
    for label, sync in [
        ("dense", SyncConfig(mode="dense")),
        ("efbv_top_k0.05", SyncConfig(mode="efbv", compressor="top_k",
                                      compress_ratio=0.05)),
        ("efbv_qsgd8", SyncConfig(mode="efbv", compressor="qsgd", quant_bits=8)),
        ("hier_qsgd8_p8", SyncConfig(mode="hier", compressor="qsgd",
                                     quant_bits=8, sync_period=8)),
    ]:
        t0 = time.perf_counter()
        cost = round_cost(sync, n_params)
        us = (time.perf_counter() - t0) * 1e6
        t_wan = round_cost(sync, n_params,
                           topology=get_topology("geo_wan")).time_s
        ratio = cost.encoded_bits / cost.analytic_bits if cost.analytic_bits else 0
        rows.append((f"comm_round/{label}", us,
                     f"MB={cost.total_bytes/1e6:.2f};vs_analytic={ratio:.3f};"
                     f"t_v5p={cost.time_s*1e3:.2f}ms;t_wan={t_wan*1e3:.1f}ms"))
    return rows


def run():
    return _codec_rows() + _kernel_rows() + _round_rows()


def main():
    emit(run())


if __name__ == "__main__":
    main()
