from repro.optim.optimizers import (
    Optimizer,
    OptState,
    adamw,
    sgd,
    make_optimizer,
    clip_by_global_norm,
)
from repro.optim.schedules import cosine_schedule, linear_warmup, constant_schedule
