"""Pallas TPU kernel: double-buffered streaming quantize-pack ring.

The monolithic path (`bitpack.quant_pack_2d`) lets the pallas_call grid
machinery stage tiles; this kernel owns the data movement instead, in the
structure of the async remote-DMA ring (pallas guide §Async Remote DMA /
§Double Buffering): the flat tensor sits in HBM, a two-slot VMEM ring
copy-starts tile k+1 in while tile k is being quantize-packed, and the packed
wire planes (int8 q + fp32 scales) copy-start out while tile k+1 computes.
Every transfer is an explicit ``make_async_copy`` guarded by a per-slot DMA
semaphore — the copy-start/copy-wait skeleton a remote ring uses, with the
outbound copy landing in local HBM where a TPU deployment would
``make_async_remote_copy`` it into the neighbor's ring slot.

Pipeline per tile (slot = k % 2):

    in-DMA[k+1] start ->  wait in-DMA[k] -> wait out-DMA[k-2] (slot free)
                       -> quantize-pack tile k in VMEM -> out-DMA[k] start

Interpret mode (the CPU validation container) executes the same semaphore
structure serially; the pure-jnp oracle is ``ref.stream_quant_pack_ref`` and
the jit wrapper with shape plumbing is ``ops.stream_quantize_pack``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant8 import QBLOCK, TILE_ROWS
from repro.obs.trace import annotate

N_SLOTS = 2  # double buffering


def _stream_kernel(x_hbm, noise_hbm, q_hbm, scale_hbm, *, n_tiles: int,
                   s_levels: int):
    def body(x_buf, n_buf, q_buf, s_buf, in_sems, out_sems):
        def in_dmas(slot, k):
            rows = pl.ds(k * TILE_ROWS, TILE_ROWS)
            return (pltpu.make_async_copy(x_hbm.at[rows], x_buf.at[slot],
                                          in_sems.at[slot, 0]),
                    pltpu.make_async_copy(noise_hbm.at[rows], n_buf.at[slot],
                                          in_sems.at[slot, 1]))

        def out_dmas(slot, k):
            rows = pl.ds(k * TILE_ROWS, TILE_ROWS)
            return (pltpu.make_async_copy(q_buf.at[slot], q_hbm.at[rows],
                                          out_sems.at[slot, 0]),
                    pltpu.make_async_copy(s_buf.at[slot], scale_hbm.at[rows],
                                          out_sems.at[slot, 1]))

        # fill: tile 0's inbound copies start before the loop spins up (the
        # annotate scopes are trace-time jax.named_scopes — they label the
        # ring phases in jaxpr/XLA profiles, zero runtime cost)
        with annotate("stream/ring_fill"):
            for dma in in_dmas(0, 0):
                dma.start()

        def tile_step(k, _):
            slot = jax.lax.rem(k, N_SLOTS)
            nxt = jax.lax.rem(k + 1, N_SLOTS)

            @pl.when(k + 1 < n_tiles)
            def _prefetch():
                for dma in in_dmas(nxt, k + 1):
                    dma.start()

            for dma in in_dmas(slot, k):
                dma.wait()

            @pl.when(k >= N_SLOTS)
            def _reclaim():  # slot's previous out-copy must have drained
                for dma in out_dmas(slot, k - N_SLOTS):
                    dma.wait()

            x = x_buf[slot].astype(jnp.float32)
            scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / s_levels
            scale = jnp.where(scale == 0.0, 1.0, scale)
            q = jnp.floor(x / scale + n_buf[slot])   # noise in [0,1): stochastic
            q_buf[slot] = jnp.clip(q, -s_levels, s_levels).astype(jnp.int8)
            s_buf[slot] = scale

            for dma in out_dmas(slot, k):
                dma.start()
            return 0

        with annotate("stream/ring_steady"):
            jax.lax.fori_loop(0, n_tiles, tile_step, 0)

        # drain: the last min(N_SLOTS, n_tiles) out-copies are still in flight
        with annotate("stream/ring_drain"):
            for k in range(max(0, n_tiles - N_SLOTS), n_tiles):
                for dma in out_dmas(k % N_SLOTS, k):
                    dma.wait()

    pl.run_scoped(
        body,
        x_buf=pltpu.VMEM((N_SLOTS, TILE_ROWS, QBLOCK), x_hbm.dtype),
        n_buf=pltpu.VMEM((N_SLOTS, TILE_ROWS, QBLOCK), jnp.float32),
        q_buf=pltpu.VMEM((N_SLOTS, TILE_ROWS, QBLOCK), jnp.int8),
        s_buf=pltpu.VMEM((N_SLOTS, TILE_ROWS, 1), jnp.float32),
        in_sems=pltpu.SemaphoreType.DMA((N_SLOTS, 2)),
        out_sems=pltpu.SemaphoreType.DMA((N_SLOTS, 2)),
    )


def stream_quant_pack_2d(x2d: jax.Array, noise2d: jax.Array, bits: int = 8,
                         interpret: bool = True):
    """(rows, QBLOCK) -> (int8 plane (rows, QBLOCK), fp32 scales (rows, 1)).

    Same math (and bit-identical planes) as ``bitpack.quant_pack_2d``; the
    difference is the explicit two-slot DMA ring moving the tiles.
    """
    rows, qb = x2d.shape
    assert qb == QBLOCK and rows % TILE_ROWS == 0, (x2d.shape,)
    s = 2 ** (bits - 1) - 1
    n_tiles = rows // TILE_ROWS
    return pl.pallas_call(
        functools.partial(_stream_kernel, n_tiles=n_tiles, s_levels=s),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                   pl.BlockSpec(memory_space=pltpu.ANY)],
        out_shape=[
            jax.ShapeDtypeStruct((rows, qb), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2d, noise2d)
