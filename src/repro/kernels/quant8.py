"""Pallas TPU kernel: block-wise absmax int-s quantize + dequantize (fused).

This is the compute hot-spot of the quantization compressor (Ch. 2): every
compressed sync quantizes the full gradient delta.  Fusing quantize+dequantize
keeps the tensor in VMEM for one pass (read once, write once) instead of the
three HBM round-trips of the naive absmax -> scale -> round chain.

Layout: the flat tensor is viewed as (rows, QBLOCK) where QBLOCK is the
quantization block (one scale per row).  The Pallas grid tiles rows; each tile
is (TILE_ROWS, QBLOCK) in VMEM — QBLOCK is chosen 128-lane aligned.

Stochastic rounding takes pre-generated uniform noise as a kernel input (an
explicit functional PRNG keeps the kernel portable and the oracle exact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 8
QBLOCK = 512  # quantization block size (multiple of 128 lanes)


def _quant_kernel(x_ref, noise_ref, out_ref, *, s_levels: int):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / s_levels
    scale = jnp.where(scale == 0.0, 1.0, scale)
    y = x / scale
    q = jnp.floor(y + noise_ref[...])          # noise in [0,1): stochastic round
    q = jnp.clip(q, -s_levels, s_levels)
    out_ref[...] = (q * scale).astype(out_ref.dtype)


def quant_dequant_2d(x2d: jax.Array, noise2d: jax.Array, bits: int = 8,
                     interpret: bool = True) -> jax.Array:
    """x2d, noise2d: (rows, QBLOCK). rows must be a multiple of TILE_ROWS."""
    rows, qb = x2d.shape
    assert qb == QBLOCK and rows % TILE_ROWS == 0, (x2d.shape,)
    s = 2 ** (bits - 1) - 1
    grid = (rows // TILE_ROWS,)
    return pl.pallas_call(
        functools.partial(_quant_kernel, s_levels=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_ROWS, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, QBLOCK), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(x2d, noise2d)
