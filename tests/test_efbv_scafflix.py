"""Algorithm-level tests: EF-BV (Ch. 2) and Scafflix (Ch. 3) on convex logreg."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressors as C
from repro.core.ef_bv import EFBVState, efbv_gd, efbv_init, efbv_params, efbv_round
from repro.core.scafflix import (
    flix_objective, flix_optimum, local_optimum, logreg_grads,
    scafflix_init, scafflix_run)
from repro.core.sppm import solve_erm
from repro.data.federated import make_logreg_clients


@pytest.fixture(scope="module")
def prob():
    return make_logreg_clients(n_clients=8, m=80, d=20, mu=0.1, hetero=0.5, seed=0)


@pytest.fixture(scope="module")
def setup(prob):
    A, b = jnp.asarray(prob.A), jnp.asarray(prob.b)
    x_star = jnp.asarray(solve_erm(prob))

    def f_fn(x):
        z = jnp.einsum("nmd,d->nm", A, x)
        return jnp.mean(jnp.log1p(jnp.exp(-b * z))) + 0.5 * prob.mu * jnp.sum(x**2)

    def grad_fn(x):
        n = A.shape[0]
        return logreg_grads(jnp.tile(x[None], (n, 1)), A, b, prob.mu)

    Ls = prob.smoothness()
    return dict(A=A, b=b, x_star=x_star, f_star=float(f_fn(x_star)),
                f_fn=f_fn, grad_fn=grad_fn,
                L=float(np.mean(Ls)), Lt=float(np.sqrt(np.mean(Ls**2))), Ls=Ls)


def _run(mode, setup, n=8, steps=500):
    c = C.rand_k(0.25)
    lam, nu = efbv_params(c, n, mode)
    om_ran = c.omega / n if mode in ("efbv", "diana") else c.omega
    gamma = C.efbv_stepsize(setup["L"], setup["Lt"], c.eta, c.omega, om_ran, lam, nu)
    st = efbv_init(n, 20)
    _, _, trace = efbv_gd(jax.random.PRNGKey(0), jnp.zeros(20), setup["grad_fn"],
                          st, c, lam, nu, gamma, steps, setup["f_fn"])
    return np.asarray(trace) - setup["f_star"]


def test_efbv_converges_linearly(setup):
    gaps = _run("efbv", setup)
    assert gaps[-1] < 5e-3 and gaps[-1] < gaps[0] / 20
    # roughly monotone decrease over windows
    w = gaps.reshape(10, -1).mean(1)
    assert all(w[i + 1] < w[i] * 1.05 for i in range(len(w) - 1))


def test_efbv_beats_ef21_at_equal_rounds(setup):
    """The paper's headline: exploiting omega_ran = omega/n buys a bigger
    stepsize, hence faster convergence (Fig 2.2 qualitatively)."""
    g_efbv = _run("efbv", setup)
    g_ef21 = _run("ef21", setup)
    assert g_efbv[-1] < g_ef21[-1]


def test_diana_converges(setup):
    assert _run("diana", setup)[-1] < 1e-2


def test_efbv_hbar_invariant(setup):
    """h_bar must track mean_i h_i exactly (the server-side running average)."""
    c = C.rand_k(0.25)
    lam, nu = efbv_params(c, 8, "efbv")
    st = efbv_init(8, 20)
    x = jnp.ones(20)
    for t in range(5):
        g = setup["grad_fn"](x)
        _, st = efbv_round(jax.random.PRNGKey(t), g, st, c, lam, nu)
    np.testing.assert_allclose(np.asarray(st.h_bar),
                               np.asarray(jnp.mean(st.h, axis=0)), rtol=1e-5)


# ---------------------------------------------------------------------------
# Scafflix
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def flix(prob, setup):
    A, b = setup["A"], setup["b"]
    n = A.shape[0]
    x_loc = jnp.stack([local_optimum(A[i], b[i], prob.mu) for i in range(n)])
    return dict(x_loc=x_loc, n=n)


def _scafflix_gap(prob, setup, flix, alpha, rounds=400, p=0.2, seed=1):
    A, b = setup["A"], setup["b"]
    n = flix["n"]
    alphas = jnp.full((n,), alpha)
    xf = flix_optimum(A, b, prob.mu, alphas, flix["x_loc"], steps=20000)
    fstar = float(flix_objective(xf, A, b, prob.mu, alphas, flix["x_loc"]))
    gammas = jnp.asarray(1.0 / setup["Ls"])
    st = scafflix_init(jnp.ones(20), n, flix["x_loc"])
    gfn = lambda xt: logreg_grads(xt, A, b, prob.mu)
    ev = lambda st: flix_objective(jnp.mean(st.x, 0), A, b, prob.mu, alphas, flix["x_loc"])
    _, (trace, comms) = scafflix_run(jax.random.PRNGKey(seed), st, gfn, p, gammas,
                                     alphas, rounds, ev)
    return np.asarray(trace) - fstar, int(np.asarray(comms).sum())


def test_scafflix_converges(prob, setup, flix):
    gaps, comms = _scafflix_gap(prob, setup, flix, alpha=0.5)
    assert gaps[-1] < 1e-4
    assert 0 < comms < 400  # prob-p communication actually skips rounds


def test_personalization_accelerates(prob, setup, flix):
    """Smaller alpha (more personalization) => faster convergence (Fig 3.1a).
    Compared mid-trajectory: by round 400 both gaps reach the precision of
    the numerically-solved FLIX optimum, where the ordering is noise."""
    g_low, _ = _scafflix_gap(prob, setup, flix, alpha=0.3, rounds=150)
    g_high, _ = _scafflix_gap(prob, setup, flix, alpha=0.9, rounds=150)
    assert g_low[-1] < g_high[-1]


def test_alpha_one_recovers_erm(prob, setup, flix):
    """alpha_i = 1: FLIX == ERM, Scafflix solves the global problem."""
    gaps, _ = _scafflix_gap(prob, setup, flix, alpha=1.0, rounds=600)
    assert gaps[-1] < 1e-4
