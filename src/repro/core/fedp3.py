"""FedP3: federated personalized privacy-friendly pruning (Ch. 4, Alg. 5-7).

Mechanisms implemented:
  * server->client global pruning P_i: per-client random diagonal mask on the
    non-trained layers (Definition 4.3.1 sketch), ratio r (r=0.9 keeps 90%)
  * layer-subset training L_i (OPU-k): each client trains k uniformly chosen
    layers + the final classifier (FFC), uploading ONLY those layers —
    the privacy-friendly part (Alg. 5 line 12)
  * local pruning Q_i strategies (Alg. 6): fixed | uniform | ordered_dropout
  * aggregation (Alg. 7): simple | weighted averaging over the clients that
    trained each layer
  * LDP-FedP3 hook: Gaussian noise of scale sigma added to uploads

The model is a configurable MLP (the paper's EMNIST-L architecture family);
communication cost is counted in uploaded floats exactly as Figs. 4.2/4.4.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# MLP model (list of dense layers); layer l params = (W_l, b_l)
# ---------------------------------------------------------------------------
def init_mlp_params(key, sizes: Sequence[int]) -> List[dict]:
    layers = []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        layers.append({
            "W": jax.random.normal(k, (sizes[i], sizes[i + 1])) / np.sqrt(sizes[i]),
            "b": jnp.zeros((sizes[i + 1],)),
        })
    return layers


def mlp_apply(layers: List[dict], x: jax.Array) -> jax.Array:
    for i, l in enumerate(layers):
        x = x @ l["W"] + l["b"]
        if i < len(layers) - 1:
            x = jax.nn.relu(x)
    return x


def xent(layers, x, y, nclass):
    logits = mlp_apply(layers, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def layer_sizes(layers: List[dict]) -> List[int]:
    return [int(l["W"].size + l["b"].size) for l in layers]


# ---------------------------------------------------------------------------
# Pruning operators
# ---------------------------------------------------------------------------
def global_prune_mask(key, layers: List[dict], ratio: float) -> List[dict]:
    """P_i: keep each weight w.p. ``ratio`` (biased diagonal sketch, Def 4.3.1)."""
    masks = []
    for l in layers:
        key, k = jax.random.split(key)
        masks.append({
            "W": (jax.random.uniform(k, l["W"].shape) < ratio).astype(l["W"].dtype),
            "b": jnp.ones_like(l["b"]),
        })
    return masks


def local_prune_factor(key, strategy: str, base_ratio: float) -> jax.Array:
    """q_{i,k} per local step (Alg. 6 line 2)."""
    if strategy == "fixed":
        return jnp.asarray(1.0)
    if strategy == "uniform":
        return jax.random.uniform(key, minval=base_ratio, maxval=1.0)
    if strategy == "ordered_dropout":
        # FjORD-style: a discrete width multiplier
        opts = jnp.asarray([base_ratio, (base_ratio + 1) / 2, 1.0])
        return opts[jax.random.randint(key, (), 0, 3)]
    raise ValueError(strategy)


def apply_ordered_dropout(l: dict, q: jax.Array) -> dict:
    """Keep the first q-fraction rows/cols (Horvath et al. ordered dropout)."""
    W = l["W"]
    d1, d2 = W.shape
    r = (jnp.arange(d1)[:, None] < q * d1) & (jnp.arange(d2)[None, :] < q * d2)
    return {"W": W * r.astype(W.dtype), "b": l["b"]}


# ---------------------------------------------------------------------------
# FedP3 round
# ---------------------------------------------------------------------------
@dataclass
class FedP3Config:
    n_clients: int = 20
    clients_per_round: int = 10
    layers_per_client: int = 3      # OPU-k (k trained layers incl. FFC)
    global_prune_ratio: float = 0.9
    local_strategy: str = "fixed"   # fixed | uniform | ordered_dropout
    local_steps: int = 4
    lr: float = 0.1
    aggregation: str = "simple"     # simple | weighted
    ldp_sigma: float = 0.0
    seed: int = 0


def fedp3_train(cfg: FedP3Config, Xs: List[np.ndarray], Ys: List[np.ndarray],
                sizes: Sequence[int], rounds: int, X_test, Y_test):
    """Returns (accuracy trace, uploaded-floats trace, final params)."""
    nclass = sizes[-1]
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    key, k0 = jax.random.split(key)
    global_params = init_mlp_params(k0, sizes)
    L = len(global_params)
    ffc = L - 1  # everyone trains the final classifier

    grad_fn = jax.jit(jax.grad(xent), static_argnums=3)
    acc_trace, bytes_trace = [], []
    total_upload = 0.0

    for t in range(rounds):
        chosen = rng.choice(cfg.n_clients, size=cfg.clients_per_round, replace=False)
        uploads: Dict[int, list] = {l: [] for l in range(L)}
        upload_weights: Dict[int, list] = {l: [] for l in range(L)}

        for i in chosen:
            key, kp, kq, kl = jax.random.split(key, 4)
            # layer subset L_i: (layers_per_client-1) random hidden + FFC
            n_extra = min(cfg.layers_per_client - 1, L - 1)
            extra = rng.choice(L - 1, size=n_extra, replace=False) if n_extra else []
            L_i = sorted(set(list(extra) + [ffc]))
            # global pruning on the frozen layers
            masks = global_prune_mask(kp, global_params, cfg.global_prune_ratio)
            params = [
                dict(l) if l_idx in L_i else
                {"W": l["W"] * masks[l_idx]["W"], "b": l["b"]}
                for l_idx, l in enumerate(global_params)
            ]
            # local training (only L_i layers step)
            X_i, Y_i = jnp.asarray(Xs[i]), jnp.asarray(Ys[i])
            for k_step in range(cfg.local_steps):
                kq, kk = jax.random.split(kq)
                q = local_prune_factor(kk, cfg.local_strategy, cfg.global_prune_ratio)
                eff = [
                    apply_ordered_dropout(p, q)
                    if (cfg.local_strategy == "ordered_dropout" and l_idx not in L_i)
                    else p
                    for l_idx, p in enumerate(params)
                ]
                g = grad_fn(eff, X_i, Y_i, nclass)
                for l_idx in L_i:
                    params[l_idx] = {
                        "W": params[l_idx]["W"] - cfg.lr * g[l_idx]["W"],
                        "b": params[l_idx]["b"] - cfg.lr * g[l_idx]["b"],
                    }
            # upload only L_i (+ optional LDP noise)
            for l_idx in L_i:
                up = params[l_idx]
                if cfg.ldp_sigma > 0:
                    key, kn = jax.random.split(key)
                    up = {
                        "W": up["W"] + cfg.ldp_sigma * jax.random.normal(kn, up["W"].shape),
                        "b": up["b"],
                    }
                uploads[l_idx].append(up)
                upload_weights[l_idx].append(len(L_i))
                total_upload += up["W"].size + up["b"].size

        # aggregation (Alg. 7)
        new_params = []
        for l_idx, l in enumerate(global_params):
            ups = uploads[l_idx]
            if not ups:
                new_params.append(l)
                continue
            if cfg.aggregation == "weighted":
                w = np.asarray(upload_weights[l_idx], dtype=np.float64)
                w = w / w.sum()
            else:
                w = np.full(len(ups), 1.0 / len(ups))
            W = sum(wi * u["W"] for wi, u in zip(w, ups))
            b = sum(wi * u["b"] for wi, u in zip(w, ups))
            new_params.append({"W": W, "b": b})
        global_params = new_params

        logits = mlp_apply(global_params, jnp.asarray(X_test))
        acc = float(jnp.mean(jnp.argmax(logits, 1) == jnp.asarray(Y_test)))
        acc_trace.append(acc)
        bytes_trace.append(total_upload)
    return np.asarray(acc_trace), np.asarray(bytes_trace), global_params


def make_classification(n: int = 2000, d: int = 32, nclass: int = 10, seed: int = 0,
                        means_seed: int = 1234, sep: float = 2.0,
                        label_noise: float = 0.0):
    """Synthetic multi-class data with class-dependent Gaussian means.

    ``means_seed`` fixes the class geometry so train/test splits drawn with
    different ``seed`` values share the same distribution; ``sep`` scales the
    class separation and ``label_noise`` flips a fraction of labels (harder
    tasks for the generalization benchmarks)."""
    means = np.random.default_rng(means_seed).normal(size=(nclass, d)) * sep
    rng = np.random.default_rng(seed)
    y = rng.integers(0, nclass, size=n)
    X = means[y] + rng.normal(size=(n, d))
    if label_noise > 0:
        flip = rng.random(n) < label_noise
        y = np.where(flip, rng.integers(0, nclass, size=n), y)
    return X.astype(np.float32), y.astype(np.int32)
