"""RL001 — host synchronization inside jit-traced code.

``.item()``, ``jax.device_get``, ``np.asarray``/``np.array`` (and friends)
force a device->host transfer; under ``jax.jit`` they either fail on tracers
or, worse, silently bake a blocking sync into every step.  The rule walks
every function reachable from a jit root (see ``repro.lint.callgraph``) and
flags:

* universal sins anywhere reachable: ``.item()``, ``.tolist()``,
  ``jax.device_get``, ``np.asarray`` / ``np.array`` / ``np.copy``;
* ``float(x)`` / ``int(x)`` / ``bool(x)`` on a traced *parameter* — only in
  root functions (a non-root helper may legitimately coerce static config).
"""
from __future__ import annotations

import ast
from typing import List

from repro.lint.callgraph import dotted
from repro.lint.framework import Finding, Project, rule

_METHOD_SINS = {"item", "tolist"}
_NP_SINS = {"asarray", "array", "copy"}
_CAST_SINS = {"float", "int", "bool"}


def _numpy_aliases(graph, module: str) -> set:
    return {alias for alias, mod in graph.mod_aliases.get(module, {}).items()
            if mod == "numpy"}


def _is_device_get(graph, module: str, call: ast.Call) -> bool:
    d = dotted(call.func)
    if d in ("jax.device_get",):
        return True
    if d == "device_get":
        return graph.from_imports.get(module, {}).get("device_get",
                                                      ("",))[0] == "jax"
    return False


def _body_nodes(fn_node: ast.AST):
    """Walk a function body without descending into nested defs (they are
    separate call-graph nodes and get scanned on their own)."""
    stack = list(getattr(fn_node, "body", []))
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stack.append(child)


@rule("RL001", "host sync (.item()/device_get/np.asarray/float(tracer)) "
               "reachable from a jit/scan/pallas root")
def check(project: Project) -> List[Finding]:
    graph = project.callgraph
    out: List[Finding] = []
    by_rel = {ctx.relpath: ctx for ctx in project.files.values()}
    for fn in graph.reachable_nodes():
        ctx = by_rel.get(fn.relpath)
        if ctx is None:
            continue
        np_aliases = _numpy_aliases(graph, fn.module)
        tainted = (set(fn.params()) - fn.static_params) if fn.is_root else set()
        why = fn.root_reasons[0] if fn.root_reasons else "called from jit"
        for node in _body_nodes(fn.node):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute):
                if node.func.attr in _METHOD_SINS and not node.args:
                    out.append(ctx.finding(
                        "RL001", node,
                        f".{node.func.attr}() in `{fn.qualname}` ({why}): "
                        f"blocks on a device value inside traced code"))
                    continue
                if (isinstance(node.func.value, ast.Name)
                        and node.func.value.id in np_aliases
                        and node.func.attr in _NP_SINS):
                    out.append(ctx.finding(
                        "RL001", node,
                        f"np.{node.func.attr}() in `{fn.qualname}` ({why}): "
                        f"materializes a tracer on the host"))
                    continue
            if _is_device_get(graph, fn.module, node):
                out.append(ctx.finding(
                    "RL001", node,
                    f"jax.device_get in `{fn.qualname}` ({why}): "
                    f"device->host transfer inside traced code"))
                continue
            if (fn.is_root and isinstance(node.func, ast.Name)
                    and node.func.id in _CAST_SINS and len(node.args) == 1
                    and isinstance(node.args[0], ast.Name)
                    and node.args[0].id in tainted):
                out.append(ctx.finding(
                    "RL001", node,
                    f"{node.func.id}({node.args[0].id}) on a traced argument "
                    f"of jit root `{fn.qualname}`: concretizes a tracer"))
    return out
