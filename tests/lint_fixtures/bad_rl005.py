"""RL005 fixture: Python branching on a traced value inside jit."""
import jax


@jax.jit
def clip_positive(x):
    if x > 0:                        # RL005: x is a tracer here
        return x
    while x < 0:                     # RL005
        x = x + 1
    return x
