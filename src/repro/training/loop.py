"""Host-side training loop: data feed, jit'd step, metrics, checkpoints."""
from __future__ import annotations

from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import init_params
from repro.obs import trace as obs_trace
from repro.training.checkpoint import save_checkpoint
from repro.training.steps import TrainState, init_train_state, make_train_step
from repro.utils.logging import get_logger, log_kv

log = get_logger("train")


def _fault_model(tc: TrainConfig, n_groups: int, n_pods: int):
    """FaultModel bound to the sync cascade, or None (faults off / dense).

    Tree hier mode binds to the configured tree topology; flat hier/local
    binds to the depth-1 tree whose single ``inter`` level fans every replica
    group into the server, so the survivor mask is one per-group vector.
    """
    faults = getattr(tc.sync, "faults", None)
    if faults is None or not faults.enabled():
        return None
    if tc.sync.mode not in ("hier", "local"):
        return None
    from repro.faults import FaultModel

    if tc.sync.mode == "hier" and tc.sync.levels:
        from repro.comm.tree import get_tree_topology

        tree = get_tree_topology(tc.sync.topology)
    else:
        from repro.comm.topology import Link, get_topology
        from repro.comm.tree import TreeLevel, TreeTopology

        G = n_pods if tc.sync.mode == "hier" else n_groups
        try:
            link = get_topology(tc.sync.topology).inter
        except Exception:
            link = Link(gbps=1.0, latency_us=1000.0)
        tree = TreeTopology(f"{tc.sync.topology}-flat",
                            (TreeLevel("inter", G, link),))
    return FaultModel(faults, tree)


def train(cfg: ModelConfig, tc: TrainConfig, batches: Iterator[dict],
          n_groups: int = 1, n_pods: int = 1, steps: Optional[int] = None,
          ckpt_path: Optional[str] = None, log_every: int = 10):
    """Single-host training entry (examples / e2e driver).  The multi-pod
    launcher (launch/train.py) wraps the same step builders under a mesh."""
    steps = steps or tc.total_steps
    key = jax.random.PRNGKey(tc.seed)
    key, kinit = jax.random.split(key)
    params = init_params(kinit, cfg)
    state = init_train_state(key, params, tc, n_groups, n_pods)
    step_fn = jax.jit(make_train_step(cfg, tc, n_groups, n_pods))

    if tc.sync.mode != "dense":
        from repro.core.distributed import round_comm

        cost = round_comm(tc.sync, cfg.param_count())
        dense = 4.0 * cfg.param_count()
        stream = (f" streamed over {cost.tile_bytes >> 10} KB tiles "
                  f"(serial {cost.serial_time_s * 1e3:.2f} ms, "
                  f"{cost.stream_speedup:.2f}x)"
                  if cost.tile_bytes else " (monolithic codec)")
        log.info("sync=%s: %.3f MB/round on the slow links (%.1fx vs dense "
                 "fp32)%s, simulated %.2f ms/round on %s,%s",
                 tc.sync.mode, cost.inter_bytes / 1e6,
                 dense / max(cost.inter_bytes, 1e-9),
                 (f" + {cost.intra_bytes / 1e6:.1f} MB intra-pod"
                  if cost.intra_bytes else ""),
                 cost.time_s * 1e3, tc.sync.topology, stream)
        for lv in cost.levels:
            log.info("  level %-8s fanout %3d period %3d %-10s "
                     "%.3f MB/round  %.2f ms/round",
                     lv.name, lv.fanout, lv.period, lv.compressor,
                     lv.bytes_per_round / 1e6, lv.time_s * 1e3)
        if obs_trace.enabled():
            from repro.obs import registry

            registry.observe_round_cost(0, cost)

    fault_model = _fault_model(tc, n_groups, n_pods)
    fault_nbytes = None
    if fault_model is not None:
        log.info("fault injection on (seed=%d): degraded rounds aggregate "
                 "over deadline survivors; replayable from (seed, round)",
                 tc.sync.faults.seed)
        if (tc.sync.mode != "dense"
                and len(cost.levels) == len(fault_model.tree.levels)):
            # size each level's nominal message from the measured round cost
            # (bytes_per_round is amortized over the level period) so
            # straggler arrivals and deadline misses reflect real payloads,
            # not latency-only links
            fault_nbytes = [lv.bytes_per_round * lv.period
                            for lv in cost.levels]

    history = []
    t0 = obs_trace.wall_s()
    for step in range(steps):
        tracing = obs_trace.enabled()
        # round boundary: the span covers batch staging + step dispatch, but
        # never blocks on device values — the blocking fetch is its own span
        with obs_trace.span("round/step", round=step), \
                obs_trace.step_annotation(step):
            batch = next(batches)
            tokens = batch["tokens"]
            model_batch = {"tokens": jnp.asarray(tokens[:, :-1]),
                           "targets": jnp.asarray(tokens[:, 1:])}
            for k, v in batch.items():
                if k != "tokens":
                    model_batch[k] = jnp.asarray(v)
            if fault_model is None:
                state, metrics = step_fn(state, model_batch)
            else:
                # deterministic per-round fault plan; dropped children sync
                # with zero weight and keep their local params this round
                plan = fault_model.round_plan(step,
                                              nbytes_by_level=fault_nbytes)
                masks = tuple(jnp.asarray(m) for m in plan.survivor_masks())
                state, metrics = step_fn(state, model_batch, masks)
        if fault_model is not None and tracing:
            from repro.obs import registry

            registry.observe_fault_plan(step, plan)
        # metrics stay on device (async dispatch): one jax.device_get per log
        # point instead of a blocking float(v) transfer per metric per step
        history.append(metrics)
        log_step = step % log_every == 0 or step == steps - 1
        if tracing or log_step:
            with obs_trace.span("round/blocking_fetch", round=step):
                fetched = jax.device_get(metrics)
            if tracing:
                from repro.obs import registry

                vals = {k: float(v) for k, v in fetched.items()}
                registry.observe_train_step(step, vals)
                log_kv(log, "round", step=step, **vals)
            if log_step:
                dt = obs_trace.wall_s() - t0
                log.info("step %4d loss %.4f grad_norm %.3f (%.2fs)",
                         step, float(fetched["loss"]),
                         float(fetched["grad_norm"]), dt)
    # one transfer drains every step's still-on-device metrics
    history = [{k: float(v) for k, v in h.items()}
               for h in jax.device_get(history)]
    if ckpt_path:
        save_checkpoint(ckpt_path, state.params, step=steps)
        log.info("saved checkpoint to %s", ckpt_path)
    return state, history
