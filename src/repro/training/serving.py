"""Continuous-batching serving loop.

A slot-based scheduler over the framework's prefill/decode steps: requests
arrive with ragged prompts, occupy fixed decode slots (the production
decode_32k shape = 128 slots), finished slots are refilled from the queue
without stalling the running batch.  The decode step itself is the jitted
``decode_step`` the dry-run lowers at production scale; here it runs at
reduced scale on CPU (examples/serve_decode.py drives it).

Slot semantics: one shared cache of capacity ``max_len``; per-slot position
offsets are handled by left-padding prompts into the slot at prefill time and
masking finished slots. Prefill for a refill batches all newly admitted
requests together (prefill and decode alternate — the standard
continuous-batching compromise without paged attention).

Subclass hooks (``repro.serve.engine.PersonalizedBatcher`` uses all four):
``_build_model`` constructs the jitted steps, ``_model_prefill`` /
``_model_decode`` run them, ``_on_admit`` / ``_on_retire`` bracket a
request's residency in a slot (page-in/pin and release in the personalized
engine).  Admit/prefill/decode are traced as ``serve/*`` spans when the
``repro.obs`` flight recorder is on, and ``publish_stats`` bridges
:class:`ServeStats` into the obs metrics registry so
``python -m repro.obs.report`` covers the serving path.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace


@dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (L,) int32
    max_new: int = 32
    stop_token: Optional[int] = None
    user_id: Optional[int] = None   # personalized-delta user (None = base)
    generated: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class ServeStats:
    admitted: int = 0
    completed: int = 0
    decode_steps: int = 0
    prefills: int = 0
    tokens_out: int = 0


class ContinuousBatcher:
    """Fixed-slot continuous batching over (prefill, decode_step)."""

    def __init__(self, cfg, params, n_slots: int = 4, max_len: int = 128):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self._build_model()
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.cache = None
        self.next_tok = np.zeros((n_slots, 1), np.int32)
        self.stats = ServeStats()

    # -- model hooks (overridden by delta-serving subclasses) ---------------
    def _build_model(self) -> None:
        from repro.models import decode_step, prefill
        self._prefill = jax.jit(
            lambda p, b: prefill(p, self.cfg, b, cache_len=self.max_len))
        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, self.cfg, t, c))

    def _model_prefill(self, batch):
        return self._prefill(self.params, batch)

    def _model_decode(self, tok):
        return self._decode(self.params, tok, self.cache)

    def _on_admit(self, slot: int, req: Request) -> None:
        """A request was just placed into ``slot`` (before its prefill)."""

    def _on_retire(self, slot: int, req: Request) -> None:
        """``req`` in ``slot`` just finished (stop token or max_new)."""

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self.stats.admitted += 1

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None or r.done]

    def _admit(self) -> None:
        """Fill free slots from the queue with one batched prefill.

        All current slots are re-prefilled together (left-padded to a common
        length) — cache capacity is shared, so a refill rebuilds the batch
        cache; running requests keep their full context (prompt+generated)."""
        free = self._free_slots()
        if not free or not self.queue:
            return
        with obs_trace.span("serve/admit") as sp:
            n_new = 0
            for i in free:
                if not self.queue:
                    break
                self.slots[i] = self.queue.popleft()
                self._on_admit(i, self.slots[i])
                n_new += 1
            live = [(i, r) for i, r in enumerate(self.slots)
                    if r is not None and not r.done]
            sp.tag(new=n_new, live=len(live))
            if not live:
                return
            ctxs = [np.concatenate([r.prompt,
                                    np.asarray(r.generated, np.int32)])
                    for _, r in live]
            maxlen = max(len(c) for c in ctxs)
            batch_tokens = np.zeros((self.n_slots, maxlen), np.int32)
            for (i, r), c in zip(live, ctxs):
                batch_tokens[i, maxlen - len(c):] = c
            batch = {"tokens": jnp.asarray(batch_tokens)}
            if self.cfg.enc_layers:
                batch["src_embeds"] = jnp.zeros(
                    (self.n_slots, 8, self.cfg.enc_d_model or self.cfg.d_model))
            if self.cfg.vision_tokens:
                batch["vision_embeds"] = jnp.zeros(
                    (self.n_slots, self.cfg.vision_tokens, self.cfg.d_model))
            with obs_trace.span("serve/prefill", tokens=int(maxlen)):
                logits, self.cache = self._model_prefill(batch)
            self.next_tok = np.asarray(
                jnp.argmax(logits[:, -1, :self.cfg.vocab_size],
                           -1))[:, None].astype(np.int32)
            self.stats.prefills += 1

    # -- decode --------------------------------------------------------------
    def step(self) -> int:
        """One scheduler tick: admit if possible, then one decode step for all
        live slots. Returns the number of live requests."""
        if self._free_slots() and self.queue:
            self._admit()
        live = [i for i, r in enumerate(self.slots)
                if r is not None and not r.done]
        if not live or self.cache is None:
            return 0
        with obs_trace.span("serve/decode", live=len(live)):
            logits, self.cache = self._model_decode(jnp.asarray(self.next_tok))
        nxt = np.asarray(jnp.argmax(logits[:, -1, :self.cfg.vocab_size], -1))
        self.stats.decode_steps += 1
        for i in live:
            r = self.slots[i]
            tok = int(nxt[i])
            r.generated.append(tok)
            self.stats.tokens_out += 1
            if (r.stop_token is not None and tok == r.stop_token) or \
                    len(r.generated) >= r.max_new:
                r.done = True
                self.stats.completed += 1
                self._on_retire(i, r)
        self.next_tok = nxt[:, None].astype(np.int32)
        return len([i for i in live if not self.slots[i].done])

    def run(self, max_ticks: int = 1000) -> ServeStats:
        for _ in range(max_ticks):
            self.step()
            if not self.queue and all(r is None or r.done for r in self.slots):
                break
        self.publish_stats()
        return self.stats

    # -- observability --------------------------------------------------------
    def publish_stats(self, metrics=None) -> ServeStats:
        """Bridge ServeStats into the obs metrics registry (serve/* gauges)."""
        if metrics is None:
            from repro.obs.metrics import registry as metrics
        metrics.observe_serve(self.stats)
        return self.stats
