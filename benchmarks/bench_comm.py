"""repro.comm benchmark: codec sizes vs the analytic model, pack-kernel
throughput, topology-simulated round times per sync mode, and the streamed
(pipelined) vs monolithic (serial) codec path.

Rows:
  comm_codec/<name>       encode+decode one payload (warm-up + median of >=5
                          repeats); derived = encoded bytes (== CommLedger
                          record), the ratio to the analytic payload_bits/8
                          model, and round-trip exactness
  comm_stream/codec_*     encode_stream/decode_stream at several tile sizes;
                          asserts chunked == monolithic bit-for-bit and that
                          per-chunk ledger bytes sum to the payload
  comm_stream/<preset>    simulated round time of the streamed pipeline vs
                          the serial pack->send->unpack path (the acceptance
                          row: >=2x on geo_wan at the default tile size)
  comm_kernel/<name>      Pallas pack kernels (interpret mode) vs jnp refs,
                          including the double-buffered streaming DMA ring
  comm_round/<mode>       per-round encoded bytes from the ledger + simulated
                          wall-clock on two topology presets (Cohort-Squeeze
                          'hier' shows the slow-link amortization)

Smoke mode (env BENCH_SMOKE=1 or --smoke): tiny payloads, 1 repeat — used by
CI so codec perf regressions fail loudly instead of silently.
"""
from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.comm import (DEFAULT_TILE, DEFAULT_TILE_BYTES, CommLedger,
                        analytic_bits, decode, decode_stream, encode,
                        encode_stream, get_topology, round_cost,
                        split_payload)
from repro.configs.base import SyncConfig
from repro.core import compressors as C

D = 1 << 16


def _smoke() -> bool:
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def _codec_rows(d: int, repeats: int):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (d,))
    cases = [
        ("identity", C.identity()),
        ("top_k(0.05)", C.top_k(0.05)),
        ("rand_k(0.1)", C.rand_k(0.1)),
        ("block_top_k(0.05)", C.block_top_k(0.05)),
        ("qsgd_int8", C.qsgd(8)),
        ("qsgd_int4", C.qsgd(4)),
        ("qsgd_kernel_int8", C.qsgd_kernel(8)),
    ]
    rows = []
    for name, comp in cases:
        us = timed(lambda: decode(encode(comp, key, x)), repeats=repeats)
        p = encode(comp, key, x)
        exact = bool(jnp.all(comp(key, x) == decode(p)))
        led = CommLedger()
        led.record_payload(0, "probe", p)
        ratio = 8.0 * led.total_bytes / analytic_bits(comp, d)
        rows.append((f"comm_codec/{name}", us,
                     f"bytes={led.total_bytes};vs_analytic={ratio:.3f};exact={exact}"))
    return rows


def _stream_codec_rows(d: int, repeats: int, tiles):
    """Chunked encode/decode at several tile sizes, exactness asserted."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (d,))
    comp = C.qsgd(8)
    p = encode(comp, key, x)
    y = decode(p)
    rows = []
    for tile in tiles:
        us = timed(lambda: decode_stream(encode_stream(comp, key, x, tile=tile)),
                   repeats=repeats)
        sp = split_payload(p, tile)
        led = CommLedger()
        led.record_stream(0, "probe", sp)
        exact = bool(jnp.all(decode_stream(sp) == y))
        assert led.total_bytes == p.nbytes, (led.total_bytes, p.nbytes)
        assert exact, tile
        rows.append((f"comm_stream/codec_tile{tile}", us,
                     f"bytes={led.total_bytes};chunks={sp.n_chunks};exact={exact}"))
    return rows


def _stream_time_rows():
    """Streamed vs serial simulated round time (the acceptance comparison).

    The payload is one federated client upload: a 100M-param model's qsgd
    int8 delta (~100 MB) on each preset's slow link at the default tile.
    """
    n_params = 100_000_000
    sync = SyncConfig(mode="efbv", compressor="qsgd", quant_bits=8)
    from repro.comm import measured_payload_bits

    nbytes = measured_payload_bits(sync, n_params) / 8.0
    rows = []
    for preset in ("geo_wan", "v5p_superpod", "edge_fl"):
        link = get_topology(preset).inter
        t_serial = link.serial_codec_time_s(nbytes)
        t_stream = link.stream_time_s(nbytes, DEFAULT_TILE_BYTES)
        rows.append((f"comm_stream/{preset}_upload", t_stream * 1e6,
                     f"bytes={int(nbytes)};serial_ms={t_serial*1e3:.1f};"
                     f"stream_ms={t_stream*1e3:.1f};"
                     f"speedup={t_serial/t_stream:.2f}"))
    return rows


def _kernel_rows(d: int, repeats: int):
    from repro.kernels import ops

    rows = []
    mask = (jax.random.uniform(jax.random.PRNGKey(2), (d,)) < 0.05)
    us = timed(lambda: jax.block_until_ready(ops.pack_bits(mask)),
               repeats=repeats)
    words = ops.pack_bits(mask)
    ok = bool(jnp.all(ops.unpack_bits(words, d) == mask.astype(jnp.uint32)))
    rows.append(("comm_kernel/pack_bits", us,
                 f"words={words.shape[0]};roundtrip={ok}"))

    x = jax.random.normal(jax.random.PRNGKey(3), (d,)) * 5
    key = jax.random.PRNGKey(4)
    us = timed(lambda: jax.block_until_ready(ops.quantize_pack(x, key)[0]),
               repeats=repeats)
    q, scales = ops.quantize_pack(x, key)
    dq = ops.unpack_dequantize(q, scales, d)
    carrier = ops.quantize_dequantize(x, key)
    ok = bool(jnp.all(dq == carrier.reshape(-1)))
    rows.append(("comm_kernel/quantize_pack", us,
                 f"plane_bytes={q.size + 4 * scales.size};matches_carrier={ok}"))

    us = timed(lambda: jax.block_until_ready(ops.stream_quantize_pack(x, key)[0]),
               repeats=repeats)
    qs, ss = ops.stream_quantize_pack(x, key)
    ok = bool(jnp.all(qs == q)) and bool(jnp.all(ss == scales))
    rows.append(("comm_kernel/stream_quantize_pack", us,
                 f"plane_bytes={qs.size + 4 * ss.size};matches_monolithic={ok}"))
    return rows


def _round_rows(repeats: int):
    n_params = 25_000_000  # ~100 MB fp32 model
    rows = []
    for label, sync in [
        ("dense", SyncConfig(mode="dense")),
        ("efbv_top_k0.05", SyncConfig(mode="efbv", compressor="top_k",
                                      compress_ratio=0.05)),
        ("efbv_qsgd8", SyncConfig(mode="efbv", compressor="qsgd", quant_bits=8)),
        ("hier_qsgd8_p8", SyncConfig(mode="hier", compressor="qsgd",
                                     quant_bits=8, sync_period=8)),
    ]:
        us = timed(lambda: round_cost(sync, n_params), repeats=repeats)
        cost = round_cost(sync, n_params)
        wan = round_cost(sync, n_params, topology=get_topology("geo_wan"))
        ratio = cost.encoded_bits / cost.analytic_bits if cost.analytic_bits else 0
        rows.append((f"comm_round/{label}", us,
                     f"MB={cost.total_bytes/1e6:.2f};vs_analytic={ratio:.3f};"
                     f"t_v5p={cost.time_s*1e3:.2f}ms;t_wan={wan.time_s*1e3:.1f}ms;"
                     f"t_wan_serial={wan.serial_time_s*1e3:.1f}ms"))
    return rows


def run(smoke: bool = False):
    smoke = smoke or _smoke()
    d = 1 << 13 if smoke else D
    repeats = 1 if smoke else 5
    # smoke tiles still split the payload (qsgd blocks are 2048 coords wide)
    tiles = ((2048, 4096) if smoke
             else (DEFAULT_TILE // 4, DEFAULT_TILE, DEFAULT_TILE * 4))
    return (_codec_rows(d, repeats) + _stream_codec_rows(d, repeats, tiles)
            + _stream_time_rows() + _kernel_rows(d, repeats)
            + _round_rows(repeats))


def main():
    emit(run(smoke="--smoke" in sys.argv[1:]))


if __name__ == "__main__":
    main()
