"""Loop-aware cost correction for the roofline analysis.

XLA's HLO cost analysis counts every while-loop body ONCE, ignoring trip
counts (verified: a scan of 10 matmuls reports ~1 matmul of flops).  Our
programs have exactly three loop families, all with *statically known* trip
counts, and module cost is affine in each:

  1. the layer-period scan        — trips = num_layers / P
  2. the flash-attention q x kv scans — per-instance body cost is linear in
                                     block_q * block_k; true cost ~ Sq * Sk
  3. the encoder layer scan        — trips = enc_layers (seamless; it always
                                     equals num_layers/P there, so it folds
                                     into family 1 when scaled together)
  (grad-accum microbatching is lowered at accum=1 for costing: total
   flops/bytes are chunking-invariant; the accum loop's extra per-microbatch
   gradient reduce-scatter traffic is added analytically.)

So three small lowerings solve for the affine coefficients exactly:
  A: one period,  block_k = b0      B: two periods, block_k = b0
  C: one period,  block_k = 2*b0
  per_period = B - A;  const = A - per_period;  alpha = (C - A) / (bq*b0)
  corrected  = const + n_periods * (per_period + alpha*(Sq*Sk - bq*b0))

Applied to flops, bytes-accessed and per-kind collective bytes alike.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax

from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.launch import hlo_analysis as hlo
from repro.models import attention as attn_lib
from repro.models import period_info

from repro.comm.topology import DEFAULT_TILE_BYTES as _STREAM_TILE

B0_K = 512
B0_Q = 512


def _measures(compiled) -> Dict[str, float]:
    cost = hlo.cost_dict(compiled)
    colls = hlo.collective_bytes(compiled.as_text())
    out = {"flops": cost.get("flops", 0.0),
           "bytes": cost.get("bytes accessed", 0.0),
           "coll_total": colls.total_bytes,
           "coll_interpod": colls.inter_pod_bytes}
    for k, v in colls.bytes_by_kind.items():
        out[f"coll_{k}"] = v
    return out


def _lower_variant(cfg, mesh, shape, kind: str, n_periods: int, block_k: int,
                   sync_mode: str, builders) -> Dict[str, float]:
    P, _, _, _ = period_info(cfg)
    vcfg = dataclasses.replace(
        cfg,
        num_layers=P * n_periods,
        enc_layers=(n_periods if cfg.enc_layers else 0),
    )
    from repro.models import transformer as tf_lib

    old_q, old_k = attn_lib.BLOCK_Q, attn_lib.BLOCK_K
    old_unroll = tf_lib.UNROLL_SCAN
    attn_lib.BLOCK_Q, attn_lib.BLOCK_K = B0_Q, block_k
    # unroll the 1-2 period layer loop so HLO cost analysis (while bodies
    # counted once) actually sees both periods — the B-A diff needs it
    tf_lib.UNROLL_SCAN = True
    try:
        if kind == "train":
            low = builders["train"](vcfg, mesh, shape, sync_mode=sync_mode,
                                    grad_accum=1)
        elif kind == "prefill":
            low = builders["prefill"](vcfg, mesh, shape)
        else:
            low = builders["decode"](vcfg, mesh, shape)
        return _measures(low.compile())
    finally:
        attn_lib.BLOCK_Q, attn_lib.BLOCK_K = old_q, old_k
        tf_lib.UNROLL_SCAN = old_unroll


def corrected_costs(arch_cfg: ModelConfig, mesh, shape_name: str,
                    sync_mode: str = "dense", grad_accum: int = 1) -> Dict:
    """Returns {'raw_keys': {...}, 'corrected': {...}, 'model': {...}}."""
    from repro.launch import dryrun as dr

    shape = INPUT_SHAPES[shape_name]
    builders = {"train": dr.build_train_lowering,
                "prefill": dr.build_prefill_lowering,
                "decode": dr.build_decode_lowering}
    P, n_periods, pos_kinds, _ = period_info(arch_cfg)
    has_flash = shape.kind in ("train", "prefill") and any(
        k.startswith("attn") for k in pos_kinds)

    A = _lower_variant(arch_cfg, mesh, shape, shape.kind, 1, B0_K, sync_mode, builders)
    B = _lower_variant(arch_cfg, mesh, shape, shape.kind, 2, B0_K, sync_mode, builders)
    C = (_lower_variant(arch_cfg, mesh, shape, shape.kind, 1, 2 * B0_K, sync_mode,
                        builders) if has_flash else None)

    Sq = Sk = shape.seq_len
    # effective kv span per attention instance in one period: the banded
    # flash variant (attn_lib.BANDED) only visits window/chunk-reach blocks
    spans = []
    for kind in pos_kinds:
        if not kind.startswith("attn"):
            continue
        if attn_lib.BANDED and kind == "attn_swa":
            spans.append(min(Sk, arch_cfg.sliding_window + B0_K))
        elif attn_lib.BANDED and kind == "attn_chunk":
            spans.append(min(Sk, arch_cfg.attn_chunk + B0_K))
        else:
            spans.append(Sk)
    if arch_cfg.enc_layers:
        spans.extend([Sk, Sk])  # encoder self-attn + cross-attn per unit
    mean_span = (sum(spans) / len(spans)) if spans else Sk

    corrected = {}
    detail = {}
    for key in A:
        a, b = A[key], B.get(key, 0.0)
        per_period = b - a
        const = a - per_period
        corr = const + n_periods * per_period
        if C is not None:
            alpha = max(0.0, (C.get(key, 0.0) - a)) / (B0_Q * B0_K)
            corr += n_periods * alpha * max(0.0, Sq * mean_span - B0_Q * B0_K)
            detail[f"alpha_{key}"] = alpha
        corrected[key] = max(corr, a)
    return {"corrected": corrected, "variants": {"A": A, "B": B, "C": C},
            "n_periods": n_periods, "grad_accum": grad_accum,
            "mean_span": mean_span, "detail": detail,
            "comm_time": comm_time_model(corrected, tile_bytes=_STREAM_TILE)}


def comm_time_model(measures: Dict[str, float], topology=None,
                    tile_bytes: int = 0, faults=None) -> Dict[str, float]:
    """Bandwidth-bound collective wall-clock from the corrected per-device bytes.

    Splits the HLO-derived collective traffic onto the link topology: the
    inter-pod share rides the slow links, the rest the intra-pod fabric — the
    same byte split repro.comm's ledger records (ledger.crosscheck_hlo audits
    the totals).  This is a bytes/bandwidth *lower bound*: the HLO totals
    aggregate many collectives, so per-message latency and ring step counts
    are not attributable here — the per-round latency-aware model lives in
    repro.comm (Topology.allreduce_time_s / CommLedger.round_time_s).

    ``topology`` may also be a ``repro.comm.tree.TreeTopology``: the leaf
    level's fabric carries the intra share and the inter share hops every
    level above it in turn (device -> host -> region -> cloud), reported as
    one ``t_<level>_s`` term per level.

    With ``tile_bytes > 0`` the report adds ``t_comm_stream_s``: the
    hierarchical schedule streamed per tile, so each hop's transfer of tile
    k+1 overlaps the next hop's transfer of tile k (repro.comm.topology's
    pipelined model); serial t_comm_s stays the sum.

    With ``faults`` (a ``repro.faults.FaultConfig``) the report adds
    ``t_comm_degraded_s``: each hop's time inflated by the expected
    retransmission count and finished at the *order statistic* of the
    straggler max over that hop's children — capped by the per-level
    deadline — not the mean child time.
    """
    from repro.comm.topology import get_topology, pipelined_time_s
    from repro.comm.tree import TreeTopology

    topo = topology or get_topology("v5p_superpod")
    total = float(measures.get("coll_total", 0.0))
    inter = float(measures.get("coll_interpod", 0.0))
    intra = max(0.0, total - inter)
    if isinstance(topo, TreeTopology):
        t_intra = intra / (topo.levels[0].link.gbps * 1e9)
        stages = [t_intra]
        out = {"intra_bytes": intra, "inter_bytes": inter,
               f"t_{topo.levels[0].name}_s": t_intra, "topology": topo.name}
        for lev in topo.levels[1:]:
            t = inter / (lev.link.gbps * 1e9)
            out[f"t_{lev.name}_s"] = t
            stages.append(t)
        out["t_comm_s"] = sum(stages)
    else:
        t_intra = intra / (topo.intra.gbps * 1e9)
        t_inter = inter / (topo.inter.gbps * 1e9)
        stages = [t_intra, t_inter]
        out = {"intra_bytes": intra, "inter_bytes": inter,
               "t_intra_s": t_intra, "t_inter_s": t_inter,
               "t_comm_s": t_intra + t_inter, "topology": topo.name}
    if tile_bytes > 0:
        n_tiles = max(1, -(-int(total) // int(tile_bytes)))
        out["t_comm_stream_s"] = pipelined_time_s(tuple(stages), n_tiles)
        out["stream_tile_bytes"] = int(tile_bytes)
    if faults is not None and faults.enabled():
        from repro.comm.topology import straggler_level_time_s

        if isinstance(topo, TreeTopology):
            hops = [(lev.name, topo.level_faults(l, faults),
                     topo.n_children(l), t)
                    for l, (lev, t) in enumerate(zip(topo.levels, stages))]
        else:
            hops = [("intra", faults.link_faults("intra"),
                     topo.devices_per_pod, stages[0]),
                    ("inter", faults.link_faults("inter"),
                     topo.n_pods, stages[1])]
        degraded = 0.0
        for name, lf, n, t in hops:
            e_tx = faults.expected_transmissions(lf.loss_rate)
            base = (t * e_tx + faults.backoff_s * (e_tx - 1.0)
                    + lf.delay_rate * lf.delay_s)
            degraded += straggler_level_time_s(
                base, faults.straggler_rate, faults.straggler_sigma, n,
                faults.level_deadline_s(name))
        out["t_comm_degraded_s"] = degraded
    return out


def model_flops(cfg: ModelConfig, shape_name: str) -> Dict[str, float]:
    """MODEL_FLOPS: 6*N*D for training (N = active params), 2*N per token for
    decode, 2*N*D for prefill — the 'useful work' yardstick."""
    shape = INPUT_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return {"model_flops": 6.0 * n_active * tokens}
    if shape.kind == "prefill":
        return {"model_flops": 2.0 * n_active * tokens}
    return {"model_flops": 2.0 * n_active * shape.global_batch}
