"""Deterministic fault model: availability, stragglers, and lossy links.

Every round in the repo used to assume perfect infrastructure — all clients
arrive, every link delivers every byte, aggregation waits forever.  The
dissertation's cross-device chapters (Cohort-Squeeze, Scafflix's client
sampling) treat partial participation and heterogeneous, unreliable clients
as the *normal* case; this module makes that the simulator's vocabulary:

* **availability** — each leaf client independently checks in per round;
* **stragglers** — a straggling client's compute/link time is multiplied by
  a lognormal slowdown ``exp(sigma * |z|)``;
* **per-link faults** — each message on a tree level's link may be dropped,
  corrupted (caught by the codec checksum, then retransmitted), or delayed;
* **deadlines** — an aggregator at level ``l`` waits at most ``deadline_s``
  for its children, then aggregates over the survivors.

All randomness is a *counter-based* PRNG (splitmix64 finalizer over
``(seed, round, stream, lane)``), so any round's decisions replay bit-exactly
from ``(seed, round)`` alone — no sequential generator state to keep in step
between runs, and round ``t`` can be re-examined without replaying rounds
``0..t-1``.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

_SPLIT1 = np.uint64(0xBF58476D1CE4E5B9)
_SPLIT2 = np.uint64(0x94D049BB133111EB)
_GOLDEN = np.uint64(0x9E3779B97F4A7C15)


def _mix64(x) -> np.ndarray:
    """splitmix64 finalizer — a bijective avalanche on uint64 counters
    (modular uint64 arithmetic: wraparound is the point, not an overflow)."""
    with np.errstate(over="ignore"):
        z = np.asarray(x, np.uint64).copy()
        z ^= z >> np.uint64(30)
        z *= _SPLIT1
        z ^= z >> np.uint64(27)
        z *= _SPLIT2
        z ^= z >> np.uint64(31)
        return z


def counter_uniform(seed: int, rnd: int, stream: str, n: int,
                    lane=0) -> np.ndarray:
    """``n`` uniforms in [0, 1) addressed by ``(seed, round, stream, lane+i)``.

    Pure function of its arguments: the same address always yields the same
    draw, and distinct streams/rounds/lanes are decorrelated by the mixer.

    ``lane`` is either a scalar offset (draws address lanes ``lane..lane+n-1``)
    or an explicit ``(n,)`` array of lane indices — the cohort engine's form:
    drawing a 10^6-lane process sliced to any index set equals drawing those
    lanes directly, because each draw depends on its own lane address alone.
    """
    with np.errstate(over="ignore"):
        base = _mix64(np.uint64(seed & 0xFFFFFFFFFFFFFFFF)
                      + _GOLDEN * np.uint64(rnd & 0xFFFFFFFFFFFFFFFF))
        base ^= np.uint64(zlib.crc32(stream.encode()))
        lane = np.asarray(lane, dtype=np.uint64)
        if lane.ndim == 0:
            lanes = (np.arange(n, dtype=np.uint64) + lane) * _GOLDEN
        else:
            if lane.shape != (n,):
                raise ValueError(f"lane array shape {lane.shape} != ({n},)")
            lanes = lane * _GOLDEN
        bits = _mix64(base + lanes)
    # top 53 bits -> double in [0, 1)
    return (bits >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)


def counter_normal(seed: int, rnd: int, stream: str, n: int,
                   lane=0) -> np.ndarray:
    """Standard normals via Box-Muller on two counter-uniform streams."""
    u1 = counter_uniform(seed, rnd, stream + "/u1", n, lane)
    u2 = counter_uniform(seed, rnd, stream + "/u2", n, lane)
    r = np.sqrt(-2.0 * np.log1p(-u1))  # 1-u1 in (0, 1], log finite
    return r * np.cos(2.0 * math.pi * u2)


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LinkFaults:
    """Per-message fault rates of one link class."""
    drop_rate: float = 0.0     # message silently lost in flight
    corrupt_rate: float = 0.0  # payload mangled (codec checksum catches it)
    delay_rate: float = 0.0    # message stalled by an extra ``delay_s``
    delay_s: float = 0.0

    @property
    def loss_rate(self) -> float:
        """Probability one transmission attempt fails (drop OR corrupt)."""
        return min(1.0, self.drop_rate + self.corrupt_rate)

    def any(self) -> bool:
        return (self.drop_rate > 0 or self.corrupt_rate > 0
                or (self.delay_rate > 0 and self.delay_s > 0))


@dataclass(frozen=True)
class LevelFaults:
    """Override for one named tree level (rates + deadline)."""
    name: str
    drop_rate: Optional[float] = None
    corrupt_rate: Optional[float] = None
    delay_rate: Optional[float] = None
    delay_s: Optional[float] = None
    deadline_s: Optional[float] = None


@dataclass(frozen=True)
class FaultConfig:
    """Seedable fault-injection knobs (``SyncConfig.faults``).

    Defaults are all-off: ``FaultConfig()`` is the perfect-infrastructure
    round, and every consumer treats ``enabled() == False`` as "take the
    exact legacy code path" so a disabled config stays bit-identical to no
    config at all.
    """
    seed: int = 0
    availability: float = 1.0       # P(leaf client checks in this round)
    straggler_rate: float = 0.0     # fraction of clients straggling
    straggler_sigma: float = 1.0    # lognormal sigma of the slowdown
    drop_rate: float = 0.0          # default per-link message loss
    corrupt_rate: float = 0.0       # default per-link payload corruption
    delay_rate: float = 0.0         # default per-link stall probability
    delay_s: float = 0.0            # stall duration when delayed
    deadline_s: float = math.inf    # default per-level aggregation deadline
    levels: Optional[Tuple[LevelFaults, ...]] = None  # per-level overrides
    max_retries: int = 2            # retransmissions after a lost attempt
    backoff_s: float = 0.05         # first retry backoff
    backoff_mult: float = 2.0       # exponential backoff multiplier

    def enabled(self) -> bool:
        """True when any fault process can actually fire."""
        base = (self.availability < 1.0
                or (self.straggler_rate > 0 and self.straggler_sigma > 0)
                or self.drop_rate > 0 or self.corrupt_rate > 0
                or (self.delay_rate > 0 and self.delay_s > 0)
                or math.isfinite(self.deadline_s))
        if base:
            return True
        for lf in self.levels or ():
            if any(v for v in (lf.drop_rate, lf.corrupt_rate, lf.delay_rate)):
                return True
            if lf.deadline_s is not None and math.isfinite(lf.deadline_s):
                return True
        return False

    def _override(self, name: str) -> Optional[LevelFaults]:
        for lf in self.levels or ():
            if lf.name == name:
                return lf
        return None

    def has_override(self, level_name: str) -> bool:
        return self._override(level_name) is not None

    def link_faults(self, level_name: str) -> LinkFaults:
        """Effective per-message fault rates on ``level_name``'s link."""
        ov = self._override(level_name)
        pick = (lambda o, d: d if o is None else o)
        if ov is None:
            return LinkFaults(self.drop_rate, self.corrupt_rate,
                              self.delay_rate, self.delay_s)
        return LinkFaults(pick(ov.drop_rate, self.drop_rate),
                          pick(ov.corrupt_rate, self.corrupt_rate),
                          pick(ov.delay_rate, self.delay_rate),
                          pick(ov.delay_s, self.delay_s))

    def level_deadline_s(self, level_name: str) -> float:
        ov = self._override(level_name)
        if ov is not None and ov.deadline_s is not None:
            return ov.deadline_s
        return self.deadline_s

    def backoff_total_s(self, attempts_after_first: int) -> float:
        """Total backoff waited before ``attempts_after_first`` retries."""
        t, b = 0.0, self.backoff_s
        for _ in range(max(0, attempts_after_first)):
            t += b
            b *= self.backoff_mult
        return t

    def expected_transmissions(self, loss_rate: float) -> float:
        """E[attempts] under up-to-``max_retries`` retransmissions.

        Attempt k happens iff the first k attempts all failed:
        ``sum_{k=0..R} q^k`` — the retry-tagged ledger bytes are
        ``(E[attempts] - 1) * payload``.
        """
        q = min(1.0, max(0.0, loss_rate))
        return sum(q ** k for k in range(self.max_retries + 1))


# ---------------------------------------------------------------------------
# round plans
# ---------------------------------------------------------------------------
@dataclass
class LevelPlan:
    """One level's fault outcome for one round (children = child nodes)."""
    name: str
    survivors: np.ndarray        # bool (n_children,) — made the deadline
    arrival_s: np.ndarray        # per-child arrival time at the parent
    deadline_s: float
    n_unavailable: int           # leaves only: did not check in
    n_dead_subtree: int          # aggregators with zero surviving descendants
    n_dropped: int               # lost after exhausting retries
    n_deadline_miss: int         # arrived too late (straggle/delay/backoff)
    n_corrupt: int               # corrupted attempts (caught + retried)
    n_retries: int               # retransmission attempts on this level
    time_s: float                # level completion: min(deadline, max arrival)

    @property
    def n_children(self) -> int:
        return int(self.survivors.shape[0])

    @property
    def survivor_frac(self) -> float:
        return float(self.survivors.mean()) if self.survivors.size else 1.0


@dataclass
class RoundFaultPlan:
    """All levels' fault outcomes for one round — replayable from
    ``(seed, round)`` and directly consumable by ``tree_param_sync``."""
    round: int
    levels: List[LevelPlan] = field(default_factory=list)

    def survivor_masks(self) -> Tuple[np.ndarray, ...]:
        """float32 per-level child masks for the degraded sync paths."""
        return tuple(lv.survivors.astype(np.float32) for lv in self.levels)

    @property
    def time_s(self) -> float:
        """Degraded round completion: levels aggregate bottom-up in series."""
        return sum(lv.time_s for lv in self.levels)

    def stats(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            "drops": sum(lv.n_dropped for lv in self.levels),
            "deadline_misses": sum(lv.n_deadline_miss for lv in self.levels),
            "retries": sum(lv.n_retries for lv in self.levels),
            "corrupt": sum(lv.n_corrupt for lv in self.levels),
            "unavailable": sum(lv.n_unavailable for lv in self.levels),
            "time_s": self.time_s,
        }
        for lv in self.levels:
            out[f"survivor_frac/{lv.name}"] = lv.survivor_frac
        return out


class FaultModel:
    """A ``FaultConfig`` bound to an aggregation tree's levels.

    ``tree`` is a ``repro.comm.tree.TreeTopology`` (duck-typed: ``levels``
    with ``name``/``fanout``/``link``, and ``n_leaves``).  A flat topology is
    the depth-1 tree whose single level fans out over all clients.

    Every decision is drawn from the counter PRNG keyed by
    ``(cfg.seed, round, "<level>/<process>", child_index)``, so two models
    built from the same config produce identical plans for the same round —
    the replay property the acceptance criteria pin down.
    """

    def __init__(self, cfg: FaultConfig, tree):
        self.cfg = cfg
        self.tree = tree
        # child counts per level, leaf-most first: level 0's children are the
        # leaves; level l's children are the level-(l-1) aggregators
        self.n_children = []
        n = tree.n_leaves
        for lev in tree.levels:
            self.n_children.append(n)
            n //= lev.fanout

    def link_faults_at(self, level: int) -> LinkFaults:
        """Effective rates at ``level`` — defers to the tree's resolution
        (config override > attached level default > config globals) when the
        topology implements it (``TreeTopology.level_faults``)."""
        resolve = getattr(self.tree, "level_faults", None)
        if resolve is not None:
            return resolve(level, self.cfg)
        return self.cfg.link_faults(self.tree.levels[level].name)

    def _lanes(self, level: int, lanes) -> Tuple[int, np.ndarray]:
        """Resolve optional explicit lane indices to ``(n, lane_array)``.

        ``lanes=None`` addresses the level's children positionally
        (``0..n_children-1``); an explicit array addresses global lanes (the
        cohort engine passes population-wide client ids for level 0), making
        every per-child draw sliceable: the draw for lane ``i`` never depends
        on which other lanes are in the plan.
        """
        if lanes is None:
            n = self.n_children[level]
            return n, np.arange(n, dtype=np.uint64)
        lanes = np.asarray(lanes, dtype=np.uint64)
        return int(lanes.shape[0]), lanes

    # -- per-process draws ---------------------------------------------------
    def available(self, rnd: int, lanes=None) -> np.ndarray:
        """Leaf check-in mask for this round (availability process)."""
        n, lane = self._lanes(0, lanes)
        u = counter_uniform(self.cfg.seed, rnd, "avail", n, lane=lane)
        return u < self.cfg.availability

    def straggler_scale(self, rnd: int, level: int, lanes=None) -> np.ndarray:
        """Per-child slowdown multiplier (>= 1) at ``level``."""
        n, lane = self._lanes(level, lanes)
        name = self.tree.levels[level].name
        if self.cfg.straggler_rate <= 0 or self.cfg.straggler_sigma <= 0:
            return np.ones(n)
        hit = counter_uniform(self.cfg.seed, rnd, f"{name}/straggle", n,
                              lane=lane)
        z = np.abs(counter_normal(self.cfg.seed, rnd, f"{name}/stragglez", n,
                                  lane=lane))
        return np.where(hit < self.cfg.straggler_rate,
                        np.exp(self.cfg.straggler_sigma * z), 1.0)

    def attempt_outcomes(self, rnd: int, level: int, attempt: int,
                         lanes=None) -> Tuple[np.ndarray, np.ndarray,
                                              np.ndarray]:
        """(dropped, corrupted, delayed) masks for one transmission attempt
        of every child message on ``level`` — retries redraw via ``attempt``.

        Each attempt is its own stream (``<level>/xmit/a<k>`` for retries)
        rather than a lane offset of ``attempt * n``: offsetting by ``n``
        made retry draws depend on the population size, which would break the
        lane-sliceability contract above.
        """
        n, lane = self._lanes(level, lanes)
        name = self.tree.levels[level].name
        lf = self.link_faults_at(level)
        sfx = "" if attempt == 0 else f"/a{attempt}"
        u = counter_uniform(self.cfg.seed, rnd, f"{name}/xmit{sfx}", n,
                            lane=lane)
        dropped = u < lf.drop_rate
        corrupted = (~dropped) & (u < lf.drop_rate + lf.corrupt_rate)
        ud = counter_uniform(self.cfg.seed, rnd, f"{name}/delay{sfx}", n,
                             lane=lane)
        delayed = ud < lf.delay_rate
        return dropped, corrupted, delayed

    # -- the full round ------------------------------------------------------
    def level_plan(self, rnd: int, level: int, base_time_s,
                   alive: np.ndarray, lanes=None) -> LevelPlan:
        """Fault outcome of one level's child->parent messages.

        ``alive`` marks children that have anything to send (available
        leaves, or aggregators with >= 1 surviving descendant);
        ``base_time_s`` is the nominal per-child message time on the level's
        link — a scalar, or a per-child array when children ride
        heterogeneous links (the cohort engine's per-class uplinks).  A child
        survives iff it is alive, its message is delivered within
        ``max_retries`` retransmissions, and its arrival time — straggle *
        base + delays + retry backoffs — makes the deadline.  ``lanes``
        addresses the per-child draws explicitly (see ``_lanes``).
        """
        lev = self.tree.levels[level]
        lf = self.link_faults_at(level)
        deadline = self.cfg.level_deadline_s(lev.name)
        alive = np.asarray(alive, bool)
        n = alive.shape[0]
        base_time_s = np.asarray(base_time_s, float)

        scale = self.straggler_scale(rnd, level, lanes=lanes)
        arrival = base_time_s * scale
        delivered = np.zeros(n, bool)
        n_corrupt = n_retries = 0
        pending = alive.copy()
        for attempt in range(self.cfg.max_retries + 1):
            if not pending.any():
                break
            if attempt > 0:
                n_retries += int(pending.sum())
                arrival = np.where(
                    pending,
                    arrival + self.cfg.backoff_s
                    * self.cfg.backoff_mult ** (attempt - 1)
                    + base_time_s * scale,
                    arrival)
            dropped, corrupted, delayed = self.attempt_outcomes(
                rnd, level, attempt, lanes=lanes)
            n_corrupt += int((pending & corrupted).sum())
            arrival = np.where(pending & delayed, arrival + lf.delay_s,
                               arrival)
            ok = pending & ~dropped & ~corrupted
            delivered |= ok
            pending &= ~ok
        lost = alive & ~delivered
        made_deadline = delivered & (arrival <= deadline)
        survivors = made_deadline
        time_s = float(min(deadline, arrival[survivors].max())
                       if survivors.any() else
                       (deadline if math.isfinite(deadline)
                        else np.max(base_time_s)))
        return LevelPlan(
            name=lev.name, survivors=survivors,
            arrival_s=np.where(alive, arrival, np.inf),
            deadline_s=deadline,
            n_unavailable=int((~alive).sum()) if level == 0 else 0,
            n_dead_subtree=int((~alive).sum()) if level > 0 else 0,
            n_dropped=int(lost.sum()),
            n_deadline_miss=int((delivered & ~made_deadline).sum()),
            n_corrupt=n_corrupt, n_retries=n_retries, time_s=time_s)

    def round_plan(self, rnd: int,
                   nbytes_by_level: Optional[Sequence[float]] = None,
                   leaf_lanes=None, leaf_base_time_s=None) -> RoundFaultPlan:
        """Full per-level fault plan for one round.

        ``nbytes_by_level[l]`` sizes the nominal per-child message on level
        ``l`` (defaults to 0 — latency-only base times).  An aggregator is
        alive at level ``l`` iff at least one of its children survived level
        ``l-1``, so dead subtrees propagate up the cascade.

        ``leaf_lanes`` (optional, length ``n_leaves``) addresses the leaf
        processes by *global* lane index instead of position — the cohort
        engine passes the sampled clients' population ids, so a cohort's
        leaf-level plan is exactly the corresponding slice of the full
        population's plan.  ``leaf_base_time_s`` (scalar or per-leaf array)
        overrides level 0's nominal message time, letting heterogeneous
        client link classes set their own uplink times; upper levels are
        infrastructure and keep positional lanes.
        """
        plan = RoundFaultPlan(round=rnd)
        if leaf_lanes is not None:
            leaf_lanes = np.asarray(leaf_lanes)
            if leaf_lanes.shape[0] != self.n_children[0]:
                raise ValueError(
                    f"leaf_lanes has {leaf_lanes.shape[0]} lanes but the "
                    f"tree has {self.n_children[0]} leaves")
        alive = self.available(rnd, lanes=leaf_lanes)
        for l, lev in enumerate(self.tree.levels):
            nbytes = (float(nbytes_by_level[l])
                      if nbytes_by_level is not None else 0.0)
            base_s = lev.link.time_s(nbytes)
            if l == 0 and leaf_base_time_s is not None:
                base_s = leaf_base_time_s
            lp = self.level_plan(rnd, l, base_s, alive,
                                 lanes=leaf_lanes if l == 0 else None)
            plan.levels.append(lp)
            # parents with >= 1 surviving child carry the subtree upward
            f = lev.fanout
            alive = lp.survivors.reshape(-1, f).any(axis=1)
        return plan
