"""Ch. 5 reproductions:
  Fig 5.1/5.2 — total communication cost TK vs local rounds K per learning rate
  Fig 5.3     — sampling strategy comparison (stratified vs nice vs block)
  Fig 5.6     — hierarchical FL cost (c1=0.05, c2=1)
Derived: optimal (K, cost) per configuration; the paper's headline is the
U-shaped TK curve with larger optimal K at larger gamma, and SS <= NICE.

The hierarchical entry also reports CommLedger-simulated wall-clock: each
local round records a dense model payload on the intra links (phase 0), each
global round one on the inter links (phase 1), and the geo_wan topology
converts bytes to seconds — the physical version of the paper's abstract
c_local/c_global units."""
from __future__ import annotations


import numpy as np

from benchmarks.common import emit, now_s
from repro.comm import UPLOAD_TAG, CommLedger, get_topology
from repro.core.sppm import (
    balanced_blocks, block_sampling, nice_sampling, sigma_star_nice,
    sigma_star_stratified, solve_erm, sppm_as, stratified_sampling,
    _client_grads_at)
from repro.data.federated import make_logreg_clients

EPS = 1e-3
KS = (1, 2, 4, 8, 16)


def run():
    prob = make_logreg_clients(n_clients=20, m=60, d=16, mu=0.1, hetero=0.1, seed=3)
    x_star = solve_erm(prob)
    rows = []

    # --- Fig 5.1/5.2: TK vs K for several gammas (nice sampling, GD prox)
    for gamma in (5.0, 50.0, 500.0):
        t0 = now_s()
        best = (None, np.inf)
        curve = []
        for K in KS:
            draw, p = nice_sampling(np.random.default_rng(5), prob.n_clients, 8)
            r = sppm_as(prob, x_star, draw, p, gamma, K, T=300, solver="gd",
                        eps=EPS, c_global=0.0, seed=0)
            cost = r.total_cost if r.total_cost is not None else np.inf
            curve.append(f"K{K}:{cost if np.isfinite(cost) else 'inf'}")
            if cost < best[1]:
                best = (K, cost)
        us = (now_s() - t0) * 1e6
        rows.append((f"sppm_fig5.1/gamma={gamma}", us,
                     f"bestK={best[0]};cost={best[1]};curve=" + "|".join(curve)))

    # --- LocalGD (FedAvg-like) baseline: K local GD steps, cost = K*T as well
    t0 = now_s()
    best = (None, np.inf)
    for K in KS:
        draw, p = nice_sampling(np.random.default_rng(5), prob.n_clients, 8)
        # gamma -> infinity makes prox_gd a pure local-GD step sequence
        r = sppm_as(prob, x_star, draw, p, 1e8, K, T=300, solver="gd",
                    eps=EPS, c_global=0.0, seed=0)
        cost = r.total_cost if r.total_cost is not None else np.inf
        if cost < best[1]:
            best = (K, cost)
    us = (now_s() - t0) * 1e6
    rows.append(("sppm_fig5.2/localgd_baseline", us, f"bestK={best[0]};cost={best[1]}"))

    # --- Fig 5.3: sampling comparison at fixed budget
    gi = _client_grads_at(prob, x_star)
    blocks = balanced_blocks(gi, 8)
    t0 = now_s()
    res = {}
    for name, (draw, p) in {
        "nice": nice_sampling(np.random.default_rng(5), prob.n_clients, 8),
        "stratified": stratified_sampling(np.random.default_rng(2), blocks),
        "block": block_sampling(np.random.default_rng(2), blocks),
    }.items():
        r = sppm_as(prob, x_star, draw, p, gamma=5.0, K=8, T=200, solver="newton", seed=0)
        res[name] = float(r.errors[-50:].mean())
    us = (now_s() - t0) * 1e6
    rows.append(("sppm_fig5.3/sampling", us,
                 ";".join(f"{k}={v:.2e}" for k, v in res.items())))

    s_nice, _ = sigma_star_nice(prob, x_star, tau=8)
    s_ss = sigma_star_stratified(prob, x_star, blocks)
    rows.append(("sppm_lemma5.3.4/sigma2", 0.0,
                 f"nice={s_nice:.3e};stratified={s_ss:.3e};ss_le_nice={s_ss <= s_nice}"))

    # --- Fig 5.6: hierarchical FL, c1=0.05 c2=1
    t0 = now_s()
    best = (None, np.inf)
    for K in KS:
        draw, p = nice_sampling(np.random.default_rng(5), prob.n_clients, 8)
        r = sppm_as(prob, x_star, draw, p, gamma=50.0, K=K, T=300, solver="gd",
                    eps=EPS, c_local=0.05, c_global=1.0, seed=0)
        cost = r.total_cost if r.total_cost is not None else np.inf
        if cost < best[1]:
            best = (K, cost)
    # FedAvg reference: K=1, same costs
    draw, p = nice_sampling(np.random.default_rng(5), prob.n_clients, 8)
    ref = sppm_as(prob, x_star, draw, p, gamma=50.0, K=1, T=300, solver="gd",
                  eps=EPS, c_local=0.05, c_global=1.0, seed=0)
    refc = ref.total_cost if ref.total_cost is not None else np.inf
    us = (now_s() - t0) * 1e6
    save = (1 - best[1] / refc) * 100 if np.isfinite(refc) and np.isfinite(best[1]) else float("nan")
    rows.append(("sppm_fig5.6/hierarchical", us,
                 f"bestK={best[0]};cost={best[1]:.2f};fedavg={refc};saving={save:.1f}%"))

    # --- ledger + topology: simulated wall-clock of the best-K schedule vs
    #     FedAvg (K=1) over the same number of global rounds
    def sim_time_s(K, n_global):
        led = CommLedger()
        msg = prob.dim * 4  # one dense fp32 model per message
        for t in range(n_global):
            for _ in range(K):
                led.record(t, "client->cluster", msg, kind="intra", phase=0,
                           tag=UPLOAD_TAG)
            led.record(t, "cluster->server", msg, kind="inter", phase=1,
                       tag=UPLOAD_TAG)
        return led.total_time_s(get_topology("geo_wan"))

    if best[0] is not None and np.isfinite(best[1]) and np.isfinite(refc):
        n_glob_best = max(1, int(round(best[1] / (0.05 * best[0] + 1.0))))
        n_glob_ref = max(1, int(round(refc / (0.05 + 1.0))))
        t_best = sim_time_s(best[0], n_glob_best)
        t_ref = sim_time_s(1, n_glob_ref)
        rows.append(("sppm_fig5.6/simulated_wallclock", 0.0,
                     f"geo_wan:bestK={best[0]}:{t_best:.3f}s;fedavg={t_ref:.3f}s;"
                     f"speedup={t_ref / t_best:.2f}x"))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
