"""Minimal structured logger (stdout, no deps).

* ``REPRO_LOG_LEVEL`` selects the level (``DEBUG``/``INFO``/``WARNING``/... or
  a numeric level) at handler-install time.
* Handler install is idempotent and lock-guarded: concurrent ``get_logger``
  calls for the same name (pytest collecting modules in threads, the obs
  exporters logging from worker threads) configure exactly one handler.
* ``log_kv`` emits the structured ``event key=value ...`` lines that mirror
  the span tags in the obs JSONL, so grep joins console logs with traces.
"""
from __future__ import annotations

import json
import logging
import os
import sys
import threading

_FMT = "%(asctime)s %(levelname).1s %(name)s] %(message)s"
_LOCK = threading.Lock()
_SENTINEL = "_repro_configured"


def _env_level() -> int:
    name = os.environ.get("REPRO_LOG_LEVEL", "INFO").strip().upper()
    if name.isdigit():
        return int(name)
    return getattr(logging, name, logging.INFO)


def get_logger(name: str) -> logging.Logger:
    logger = logging.getLogger(name)
    if getattr(logger, _SENTINEL, False):  # fast path, no lock
        return logger
    with _LOCK:
        if not getattr(logger, _SENTINEL, False):
            if not logger.handlers:
                handler = logging.StreamHandler(sys.stdout)
                handler.setFormatter(logging.Formatter(_FMT,
                                                       datefmt="%H:%M:%S"))
                logger.addHandler(handler)
            logger.setLevel(_env_level())
            logger.propagate = False
            setattr(logger, _SENTINEL, True)
    return logger


def _fmt_val(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    if isinstance(v, str) and (" " in v or "=" in v or not v):
        return json.dumps(v)
    return str(v)


def format_kv(event: str, **kv) -> str:
    """``event key=value ...`` — one flat greppable line per record."""
    return " ".join([event] + [f"{k}={_fmt_val(v)}" for k, v in kv.items()])


def log_kv(logger: logging.Logger, event: str, level: int = logging.INFO,
           **kv) -> None:
    """Structured line with the same keys a span/metric would carry."""
    if logger.isEnabledFor(level):
        logger.log(level, "%s", format_kv(event, **kv))
