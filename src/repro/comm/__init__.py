"""repro.comm — wire-level payload codecs, byte-accurate ledger, and the
link-topology simulator.

Layers:
  codecs      encode/decode packed payloads for every compressor family;
              decode(encode(x)) == compressor(x) bit-for-bit; the streaming
              variants (encode_stream/decode_stream) split the same planes
              into per-tile chunks a pipelined transport ships
  buckets     bucket fusion: flatten a sync pytree into fixed-size fp32
              buckets so one fused compressor/codec pass replaces the
              per-leaf kernel loop
  ledger      CommLedger: per-round, per-link encoded byte records — the one
              audited source of truth for bits-on-the-wire (streamed chunks
              get one record each, summing exactly to the payload)
  topology    Link/Topology: cross-device vs cross-pod bandwidth/latency,
              ring-collective timing, presets (TPU superpod / WAN / edge FL),
              and the pipelined (pack | send | unpack overlapped) round-time
              model for streamed codecs
  tree        TreeTopology: arbitrary-depth aggregation trees (named levels,
              per-level fanout/Link/CodecProfile) of which the flat Topology
              is the depth-2 special case; multi-level presets
  accounting  RoundCost per sync mode (measured, amortized, simulated serial
              + streamed time) with per-level LevelCost attribution for
              aggregation trees; backs distributed.bits_per_round
"""
from repro.comm.accounting import (LevelCost, RoundCost, measured_payload_bits,
                                   payload_bits_for, round_bits, round_cost,
                                   round_ledger)
from repro.comm.buckets import (DEFAULT_BUCKET_SIZE, BucketLayout, bucketize,
                                bucketize_groups, debucketize,
                                debucketize_groups)
from repro.comm.codecs import (DEFAULT_TILE, Chunk, Payload, PayloadError,
                               StreamPayload, analytic_bits, decode,
                               decode_stream, encode, encode_stream,
                               encoded_bits, roundtrip_equal, seal_payload,
                               split_payload, stream_roundtrip_equal,
                               validate_payload, verify_payload)
from repro.comm.ledger import (BROADCAST_TAG, PAGE_IN_TAG, PAGE_OUT_TAG,
                               RETRY_TAG, UPLOAD_TAG, WIRE_SCHEME_TAGS,
                               CommLedger, CommRecord, crosscheck_hlo,
                               known_tags, register_tag)
from repro.comm.topology import (DEFAULT_PROFILE, DEFAULT_TILE_BYTES, PRESETS,
                                 CodecProfile, Link, Topology, get_topology,
                                 norm_ppf, pipelined_time_s, ring_parts_s,
                                 ring_time_s, straggler_level_time_s,
                                 stream_pipeline_s)
from repro.comm.tree import (TREE_PRESETS, TreeLevel, TreeTopology,
                             get_tree_topology, register_tree_topology)

__all__ = [
    "Payload", "PayloadError", "Chunk", "StreamPayload", "encode", "decode",
    "encode_stream", "decode_stream", "split_payload", "encoded_bits",
    "analytic_bits", "roundtrip_equal", "stream_roundtrip_equal",
    "seal_payload", "verify_payload", "validate_payload", "DEFAULT_TILE",
    "BucketLayout", "bucketize", "bucketize_groups", "debucketize",
    "debucketize_groups", "DEFAULT_BUCKET_SIZE",
    "CommLedger", "CommRecord", "crosscheck_hlo",
    "RETRY_TAG", "UPLOAD_TAG", "BROADCAST_TAG", "PAGE_IN_TAG", "PAGE_OUT_TAG",
    "WIRE_SCHEME_TAGS",
    "register_tag", "known_tags",
    "Link", "Topology", "PRESETS", "get_topology", "CodecProfile",
    "pipelined_time_s", "stream_pipeline_s", "ring_parts_s", "ring_time_s",
    "norm_ppf", "straggler_level_time_s",
    "DEFAULT_PROFILE", "DEFAULT_TILE_BYTES",
    "TreeTopology", "TreeLevel", "TREE_PRESETS", "get_tree_topology",
    "register_tree_topology",
    "RoundCost", "LevelCost", "round_cost", "round_bits", "round_ledger",
    "measured_payload_bits", "payload_bits_for",
]
