"""RL002 fixture: unseeded randomness."""
import numpy as np


def noisy(shape):
    g = np.random.default_rng()      # RL002: argless default_rng
    return np.random.randn(*shape) + g.standard_normal(shape)  # RL002
