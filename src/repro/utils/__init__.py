from repro.utils.tree import (
    tree_size,
    tree_bytes,
    tree_zeros_like,
    tree_add,
    tree_sub,
    tree_scale,
    tree_dot,
    tree_norm,
    global_norm,
    tree_map,
)
from repro.utils.logging import get_logger
