"""Measured-vs-modeled round reports: join a trace with the RoundCost model.

A traced run (``REPRO_TRACE=1``, or ``benchmarks/bench_comm.py --traced``)
leaves two artifacts:

* a trace JSONL (``repro.obs.trace.export_jsonl``) whose spans carry the
  measured wall-time of each round phase — pack -> encode -> allreduce ->
  decode -> adopt — with per-payload ``nbytes``/``level`` tags, and a meta
  header recording the sync config and round count;
* optionally a metrics JSON (``MetricsRegistry.export_json``) carrying the
  ``CommLedger`` per-level byte attribution.

This module joins them with ``repro.comm.round_cost``'s *model* of the same
round: per phase, measured wall-time next to the ``serial_time_s`` /
``pipelined_time_s`` prediction with a ``model_error%`` column, and a
per-level audit that the bytes the trace saw match the ledger exactly.
Ledger tags with no trace counterpart (``retry``: a re-sent payload is one
encode but several wire messages) are displayed but excluded from the match
verdict.  When the metrics JSON carries ``faults/*`` series (fault-injected
runs), a degraded-rounds section reports drops, retries, deadline misses and
the per-level survivor fraction.

CLI::

    python -m repro.obs.report TRACE.jsonl [--metrics METRICS.json]
        [--params N] [--rounds R] [--mode hier] [--compressor qsgd] ...

Exit status is non-zero if a ledger was provided and the per-level measured
bytes do not match it — which is what CI runs as the acceptance check.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

from repro.obs.trace import Span, load_jsonl

PHASES = ("pack", "encode", "allreduce", "decode", "adopt")

# ledger tags that have no encode-span counterpart in the trace: shown in the
# byte audit but exempt from the exact-match requirement (serve/page_in is a
# decode-side transfer; serve/page_out is a store write, not an upload)
UNTRACED_TAGS = frozenset({"retry", "serve/page_in", "serve/page_out"})

# serving-path spans (training.serving.ContinuousBatcher instrumentation)
_SERVE_SPANS = ("serve/admit", "serve/prefill", "serve/decode")

# span-name prefixes -> canonical round phase
_PHASE_PREFIXES = (
    ("sync/pack", "pack"),
    ("sync/bucketize", "pack"),
    ("codec/encode", "encode"),
    ("kernel/quantize_pack", "encode"),
    ("kernel/stream_quant_pack", "encode"),
    ("sync/allreduce", "allreduce"),
    ("comm/allreduce", "allreduce"),
    ("comm/send", "allreduce"),
    ("codec/decode", "decode"),
    ("sync/adopt", "adopt"),
    ("sync/debucketize", "adopt"),
)


def phase_of(name: str) -> Optional[str]:
    for prefix, phase in _PHASE_PREFIXES:
        if name.startswith(prefix):
            return phase
    return None


def _outermost(spans: List[Span]) -> List[Span]:
    """Drop spans enclosed by another span of the same phase (a chunked
    encode records per-chunk child spans inside the whole-payload span; only
    the outermost one counts toward the phase total)."""
    out = []
    for i, s in enumerate(spans):
        ph = phase_of(s.name)
        enclosed = any(
            j != i and phase_of(o.name) == ph and o.encloses(s)
            and (o.dur_us, o.ts_us) != (s.dur_us, s.ts_us)
            for j, o in enumerate(spans))
        if not enclosed:
            out.append(s)
    return out


def measured_phase_seconds(spans: List[Span]) -> Dict[str, float]:
    """Total measured wall-time per canonical phase (outermost spans only)."""
    phase_spans = [s for s in spans if phase_of(s.name)]
    totals = {p: 0.0 for p in PHASES}
    for s in _outermost(phase_spans):
        totals[phase_of(s.name)] += s.dur_us / 1e6
    return totals


def measured_bytes_by_level(spans: List[Span]) -> Dict[str, float]:
    """Sum of encode-span ``nbytes`` tags, grouped by their ``level`` tag
    (ambient-tagged by the sync path) — the trace's measured wire bytes."""
    enc = [s for s in spans
           if phase_of(s.name) == "encode" and "nbytes" in s.tags
           and "chunk" not in s.name]  # chunk spans re-count payload bytes
    out: Dict[str, float] = {}
    for s in _outermost(enc):
        level = str(s.tags.get("level", s.tags.get("tag", "payload")))
        out[level] = out.get(level, 0.0) + float(s.tags["nbytes"])
    return out


# ---------------------------------------------------------------------------
# the model side
# ---------------------------------------------------------------------------
def sync_from_meta(meta: dict):
    """Rebuild the SyncConfig a traced run recorded in its meta header."""
    from repro.configs.base import LevelConfig, SyncConfig

    s = dict(meta.get("sync") or {})
    if not s:
        return None
    levels = tuple(LevelConfig(**lc) for lc in s.pop("levels", ()) or ())
    return SyncConfig(levels=levels if levels else None, **s)


def modeled_phase_seconds(sync, n_params: int,
                          topology=None) -> Tuple[Dict[str, Optional[float]],
                                                  Dict[str, float]]:
    """Per-round (amortized) modeled seconds per phase, plus the per-level
    modeled bytes — decomposed from the same ``round_cost`` the rest of the
    repo reports, so the report's model column can never drift from it.

    pack/adopt (host staging, bucketize/debucketize) are not modeled:
    their entries are None and excluded from the error column.
    """
    from repro.comm import DEFAULT_PROFILE, round_cost
    from repro.comm.topology import get_topology

    if isinstance(topology, str):
        topology = get_topology(topology)
    cost = round_cost(sync, n_params, topology=topology)
    prof = DEFAULT_PROFILE
    phases: Dict[str, Optional[float]] = {"pack": None, "encode": 0.0,
                                          "allreduce": 0.0, "decode": 0.0,
                                          "adopt": None}
    level_bytes: Dict[str, float] = {}
    if cost.levels:
        for lv in cost.levels:
            full_bytes = lv.bytes_per_round * lv.period
            level_bytes[lv.name] = lv.bytes_per_round
            if lv.compressor == "identity":
                pack_s = unpack_s = 0.0
            else:
                pack_s = prof.pack_s(full_bytes)
                unpack_s = prof.unpack_s(full_bytes)
            ring_s = max(0.0, lv.serial_time_s * lv.period - pack_s - unpack_s)
            phases["encode"] += pack_s / lv.period
            phases["allreduce"] += ring_s / lv.period
            phases["decode"] += unpack_s / lv.period
    else:
        period = max(1, getattr(sync, "sync_period", 1))
        amort = period if sync.mode == "local" else 1
        full_bytes = cost.inter_bytes * amort
        level_bytes["payload"] = cost.inter_bytes
        if sync.mode in ("dense", "local"):
            pack_s = unpack_s = 0.0
        else:
            pack_s = prof.pack_s(full_bytes)
            unpack_s = prof.unpack_s(full_bytes)
        ring_s = max(0.0, cost.serial_time_s * amort - pack_s - unpack_s)
        phases["encode"] = pack_s / amort
        phases["allreduce"] = ring_s / amort
        phases["decode"] = unpack_s / amort
    return phases, level_bytes


def _serve_stats_from_metrics(mdoc: dict) -> Dict[str, float]:
    """``serve/*`` totals from a metrics JSON — either the ``serve_stats``
    extra a bench exports, or the raw metric entries from a traced run."""
    ss = mdoc.get("serve_stats")
    if ss:
        return {str(k): float(v) for k, v in ss.items()}
    out: Dict[str, float] = {}
    for m in mdoc.get("metrics", []):
        name = str(m.get("name", ""))
        if name.startswith("serve/"):
            out[name[len("serve/"):]] = float(
                m.get("total", m.get("value", 0.0)) or 0.0)
    return out


def _serve_span_table(spans: List[Span]) -> Dict[str, Tuple[int, float]]:
    """(count, total seconds) per serving span name."""
    out: Dict[str, Tuple[int, float]] = {}
    for s in spans:
        if s.name in _SERVE_SPANS:
            n, tot = out.get(s.name, (0, 0.0))
            out[s.name] = (n + 1, tot + s.dur_us / 1e6)
    return out


def _fault_stats_from_metrics(mdoc: dict) -> Dict[str, float]:
    """``faults/*`` totals from a metrics JSON — either the ``fault_stats``
    extra a bench exports, or the raw metric entries from a traced run."""
    fs = mdoc.get("fault_stats")
    if fs:
        return {str(k): float(v) for k, v in fs.items()}
    out: Dict[str, float] = {}
    for m in mdoc.get("metrics", []):
        name = str(m.get("name", ""))
        if name.startswith("faults/"):
            out[name[len("faults/"):]] = float(
                m.get("total", m.get("value", 0.0)) or 0.0)
    return out


# ---------------------------------------------------------------------------
# report assembly
# ---------------------------------------------------------------------------
def _fmt_ms(s: Optional[float]) -> str:
    return f"{s * 1e3:10.3f}" if s is not None else f"{'—':>10}"


def _fmt_err(measured: float, modeled: Optional[float]) -> str:
    if modeled is None or modeled <= 0.0:
        return f"{'—':>12}"
    return f"{(measured - modeled) / modeled * 100.0:+11.1f}%"


def build_report(trace_path: str, metrics_path: Optional[str] = None,
                 sync=None, n_params: Optional[int] = None,
                 n_rounds: Optional[int] = None) -> Tuple[str, dict]:
    """Render the measured-vs-modeled round report.

    Returns (text, result dict); ``result["bytes_match"]`` is None when no
    ledger was supplied, else the per-level exact-match verdict.
    """
    meta, spans = load_jsonl(trace_path)
    sync = sync or sync_from_meta(meta)
    n_params = n_params or meta.get("n_params")
    n_rounds = n_rounds or int(meta.get("n_rounds", 1) or 1)

    measured = measured_phase_seconds(spans)
    measured_total = sum(measured.values())
    trace_bytes = measured_bytes_by_level(spans)

    modeled: Dict[str, Optional[float]] = {p: None for p in PHASES}
    serial_s = pipelined_s = None
    if sync is not None and n_params:
        from repro.comm import round_cost

        modeled, _ = modeled_phase_seconds(sync, int(n_params))
        modeled = {p: (v * n_rounds if v is not None else None)
                   for p, v in modeled.items()}
        cost = round_cost(sync, int(n_params))
        serial_s = cost.serial_time_s * n_rounds
        pipelined_s = cost.time_s * n_rounds

    ledger_bytes: Optional[Dict[str, float]] = None
    fault_stats: Dict[str, float] = {}
    serve_stats: Dict[str, float] = {}
    if metrics_path:
        with open(metrics_path) as f:
            mdoc = json.load(f)
        lb = mdoc.get("ledger_bytes_by_tag")
        if lb:
            ledger_bytes = {str(k): float(v) for k, v in lb.items()}
        fault_stats = _fault_stats_from_metrics(mdoc)
        serve_stats = _serve_stats_from_metrics(mdoc)
    serve_spans = _serve_span_table(spans)

    lines = []
    title = meta.get("label") or trace_path
    lines.append(f"round report — {title}")
    if sync is not None:
        desc = f"mode={sync.mode} compressor={sync.compressor}"
        if getattr(sync, "levels", None):
            desc += " levels=" + ",".join(
                f"{lc.name}:{lc.compressor}/p{lc.period}" for lc in sync.levels)
        lines.append(f"  {desc} n_params={n_params} rounds={n_rounds} "
                     f"topology={getattr(sync, 'topology', '?')}")
    lines.append(f"  spans={len(spans)} evicted={meta.get('n_evicted', 0)}")
    lines.append("")
    lines.append(f"  {'phase':<10} {'measured_ms':>10} {'modeled_ms':>10} "
                 f"{'model_error%':>12}")
    for p in PHASES:
        lines.append(f"  {p:<10} {_fmt_ms(measured[p])} {_fmt_ms(modeled[p])} "
                     f"{_fmt_err(measured[p], modeled[p])}")
    modeled_total = sum(v for v in modeled.values() if v is not None)
    lines.append(f"  {'total':<10} {_fmt_ms(measured_total)} "
                 f"{_fmt_ms(modeled_total if serial_s is not None else None)} "
                 f"{_fmt_err(measured_total, modeled_total if serial_s is not None else None)}")
    if serial_s is not None:
        lines.append(f"  model serial={serial_s * 1e3:.3f} ms  "
                     f"pipelined={pipelined_s * 1e3:.3f} ms  "
                     f"(stream speedup {serial_s / pipelined_s:.2f}x)"
                     if pipelined_s else "")

    bytes_match: Optional[bool] = None
    if trace_bytes or ledger_bytes:
        lines.append("")
        lines.append(f"  {'level':<10} {'trace_bytes':>12} {'ledger_bytes':>12} "
                     f"{'match':>6}")
        levels = sorted(set(trace_bytes) | set(ledger_bytes or {}))
        if ledger_bytes is not None:
            bytes_match = True
        for lvl in levels:
            tb = trace_bytes.get(lvl)
            lb = (ledger_bytes or {}).get(lvl)
            if lvl in UNTRACED_TAGS:
                lines.append(
                    f"  {lvl:<10} "
                    f"{int(tb) if tb is not None else '—':>12} "
                    f"{int(lb) if lb is not None else '—':>12} "
                    f"{'—':>6}")
                continue
            ok = (tb is not None and lb is not None
                  and int(round(tb)) == int(round(lb)))
            if ledger_bytes is not None and not ok:
                bytes_match = False
            lines.append(
                f"  {lvl:<10} "
                f"{int(tb) if tb is not None else '—':>12} "
                f"{int(lb) if lb is not None else '—':>12} "
                f"{(str(ok) if ledger_bytes is not None else '—'):>6}")
        if bytes_match is not None:
            lines.append(f"  per-level measured bytes match CommLedger: "
                         f"{bytes_match}")

    if fault_stats:
        lines.append("")
        lines.append("  degraded rounds (fault injection):")
        counters = [(k, fault_stats[k]) for k in
                    ("drops", "retries", "deadline_misses", "corrupt",
                     "unavailable") if k in fault_stats]
        if counters:
            lines.append("    " + "  ".join(f"{k}={int(round(v))}"
                                            for k, v in counters))
        fracs = {k[len("survivor_frac/"):]: v for k, v in fault_stats.items()
                 if k.startswith("survivor_frac/")}
        if fracs:
            lines.append("    survivor_frac  " + "  ".join(
                f"{lvl}={v:.3f}" for lvl, v in sorted(fracs.items())))
        if "round_time_s" in fault_stats:
            lines.append(f"    degraded round_time="
                         f"{fault_stats['round_time_s'] * 1e3:.3f} ms")

    if serve_spans or serve_stats:
        lines.append("")
        lines.append("  serving path (continuous batcher):")
        for name in _SERVE_SPANS:
            if name in serve_spans:
                n, tot = serve_spans[name]
                lines.append(f"    {name:<14} n={n:<5} "
                             f"total={tot * 1e3:.3f} ms")
        sched = [(k, serve_stats[k]) for k in
                 ("admitted", "completed", "prefills", "decode_steps",
                  "tokens_out") if k in serve_stats]
        if sched:
            lines.append("    " + "  ".join(f"{k}={int(round(v))}"
                                            for k, v in sched))
        pool = {k[len("pool/"):]: v for k, v in serve_stats.items()
                if k.startswith("pool/")}
        if pool:
            lines.append("    pool  " + "  ".join(
                f"{k}={int(round(v))}" for k, v in sorted(pool.items())))

    result = {
        "measured_s": measured, "modeled_s": modeled,
        "measured_total_s": measured_total,
        "serial_model_s": serial_s, "pipelined_model_s": pipelined_s,
        "trace_bytes": trace_bytes, "ledger_bytes": ledger_bytes,
        "bytes_match": bytes_match, "n_spans": len(spans),
        "fault_stats": fault_stats or None,
        "serve_stats": serve_stats or None,
        "serve_spans": {k: {"n": n, "total_s": t}
                        for k, (n, t) in serve_spans.items()} or None,
    }
    return "\n".join(lines) + "\n", result


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Measured-vs-modeled round report from a trace JSONL.")
    ap.add_argument("trace", help="trace JSONL from repro.obs.trace.export_jsonl")
    ap.add_argument("--metrics", default=None,
                    help="metrics JSON with ledger_bytes_by_tag (audit)")
    ap.add_argument("--params", type=int, default=None,
                    help="model dimension (defaults to the trace meta)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="rounds the trace covers (defaults to meta)")
    ap.add_argument("--mode", default=None)
    ap.add_argument("--compressor", default=None)
    ap.add_argument("--quant-bits", type=int, default=8)
    ap.add_argument("--compress-ratio", type=float, default=0.05)
    ap.add_argument("--sync-period", type=int, default=1)
    ap.add_argument("--topology", default="v5p_superpod")
    ap.add_argument("--json", default=None,
                    help="also dump the joined report dict to this path")
    args = ap.parse_args(argv)

    sync = None
    if args.mode:
        from repro.configs.base import SyncConfig

        sync = SyncConfig(mode=args.mode, compressor=args.compressor or "qsgd",
                          quant_bits=args.quant_bits,
                          compress_ratio=args.compress_ratio,
                          sync_period=args.sync_period,
                          topology=args.topology)
    text, result = build_report(args.trace, metrics_path=args.metrics,
                                sync=sync, n_params=args.params,
                                n_rounds=args.rounds)
    sys.stdout.write(text)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=1, default=str)
            f.write("\n")
    return 1 if result["bytes_match"] is False else 0


if __name__ == "__main__":
    sys.exit(main())
