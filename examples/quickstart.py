"""Quickstart: train a tiny assigned-arch model with EF-BV compressed
gradient sync, then decode from it.

    PYTHONPATH=src python examples/quickstart.py [--arch h2o-danube-1.8b]

Everything runs on CPU in ~2 minutes: the reduced config of the chosen
architecture, the synthetic Markov corpus, the EF-BV sync mode with the int8
quantization compressor (4x fewer bits on the wire than fp32 all-reduce,
modeled bits reported), and a short greedy decode at the end.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import SyncConfig, TrainConfig
from repro.core.distributed import round_comm
from repro.data.synthetic import SyntheticLMDataset, lm_batch_iterator
from repro.models import decode_step, prefill
from repro.training.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--sync", default="efbv", choices=["dense", "efbv", "ef21", "local"])
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model} "
          f"v={cfg.vocab_size}, {cfg.param_count()/1e6:.2f}M params)")

    tc = TrainConfig(model=cfg, seq_len=64, global_batch=8, lr=3e-3,
                     warmup_steps=10, total_steps=args.steps,
                     sync=SyncConfig(mode=args.sync, compressor="qsgd", quant_bits=8))
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, length=60000, seed=0)
    it = lm_batch_iterator(ds, 8, 64, seed=1)

    n_groups = 2 if args.sync != "dense" else 1
    state, hist = train(cfg, tc, it, n_groups=n_groups, n_pods=2,
                        steps=args.steps, log_every=25)
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    cost = round_comm(tc.sync, cfg.param_count())
    print(f"encoded sync payload: {cost.encoded_bits/8e6:.2f} MB/round "
          f"(dense fp32 would be {cfg.param_count()*4/1e6:.2f} MB); "
          f"simulated round comm on {tc.sync.topology}: {cost.time_s*1e3:.2f} ms")

    # decode a continuation
    params = state.params
    if args.sync in ("local", "hier"):
        params = jax.tree_util.tree_map(lambda p: p[0], params)
    prompt = jnp.asarray(ds.tokens[:32][None].astype(np.int32))
    _, cache = prefill(params, cfg, {"tokens": prompt}, cache_len=64)
    tok = prompt[:, -1:]
    out = []
    for _ in range(16):
        logits, cache = decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy continuation token ids:", out)


if __name__ == "__main__":
    main()
