"""Link-topology simulator: cross-device vs cross-pod bandwidth/latency.

The paper's communication-efficiency story is about *heterogeneous* links:
Cohort-Squeeze (Ch. 5) pays c_local per intra-cluster round and c_global per
cross-cluster round and shows K > 1 local rounds win whenever
c_global >> c_local.  This module gives those abstract costs physical units:
a ``Topology`` holds one fast fabric link class ("intra": ICI/NVLink-scale)
and one slow one ("inter": DCN / WAN / federated edge), and converts message
or collective sizes into seconds.

Collective model (ring): an all-reduce over g participants moves
2*(g-1)/g * nbytes per device in 2*(g-1) latency-bound steps; reduce and
broadcast/gather halves are (g-1)/g each.  This matches how
launch/hlo_analysis.py counts per-device collective payload, so simulated
times compose with the HLO-derived byte totals in launch/costing.py.

The streaming extension models the *pipelined* transport the codecs feed
(``codecs.encode_stream`` / the Pallas DMA ring in ``kernels/stream.py``):
pack, send, and unpack run as a 3-stage pipeline over fixed-size tiles, so a
round costs fill (one tile through every stage) plus steady state paced by
the slowest stage — ``max(pack, send, unpack)`` per tile — instead of the
serial ``pack + send + unpack`` sum the monolithic codec pays.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

DEFAULT_TILE_BYTES = 1 << 20  # streamed transport tile (bytes on the wire)


@dataclass(frozen=True)
class CodecProfile:
    """Sustained encode/decode throughput of the payload codec (GB/s).

    Defaults are host-side numpy codec class numbers (sub-GB/s); a fused
    on-device Pallas pack runs far faster and can be profiled in instead.
    """
    pack_gbps: float = 0.75
    unpack_gbps: float = 0.75

    def pack_s(self, nbytes: float) -> float:
        return float(nbytes) / (self.pack_gbps * 1e9)

    def unpack_s(self, nbytes: float) -> float:
        return float(nbytes) / (self.unpack_gbps * 1e9)


DEFAULT_PROFILE = CodecProfile()


def pipelined_time_s(stage_totals_s: Sequence[float], n_tiles: int) -> float:
    """Wall-clock of a tiled pipeline given each stage's *total* time.

    fill: the first tile flows through every stage back to back; steady
    state: the remaining n-1 tiles emerge paced by the slowest stage.  At
    n_tiles=1 this degenerates to the serial sum; as n_tiles grows it
    approaches max(stages).
    """
    n = max(1, int(n_tiles))
    fill = sum(t / n for t in stage_totals_s)
    return fill + max(stage_totals_s) * (n - 1) / n


def stream_pipeline_s(lat_s: float, pack_total_s: float, wire_total_s: float,
                      unpack_total_s: float, n_tiles: int) -> float:
    """Streamed pack | send | unpack pipeline with per-tile wire latency.

    Every tile pays the wire's per-message latency, but tiles overlap in
    flight (the wire is itself a pipeline), so the full per-pass latency
    surfaces exactly once — in the fill, where the first tile traverses the
    wire end to end — while steady state is paced by the slowest
    bandwidth/codec stage.  ``lat_s`` is the latency of ONE tile's complete
    traversal: a point-to-point message pays one hop, a ring collective pays
    its full 2*(g-1) per-step latencies — the same per-message charge the
    serial path pays, never amortized over the tile count.  The result can
    therefore never beat either the bandwidth-only lower bound
    (``wire_total_s``) or the latency floor (``lat_s``).
    """
    return lat_s + pipelined_time_s(
        (pack_total_s, wire_total_s, unpack_total_s), n_tiles)


def ring_parts_s(link: "Link", g: int, nbytes: float) -> tuple:
    """(latency_s, bandwidth_s) decomposition of a ring all-reduce pass."""
    if g <= 1:
        return 0.0, 0.0
    steps = 2 * (g - 1)
    return steps * link.latency_us * 1e-6, (
        2.0 * (g - 1) / g * float(nbytes)) / (link.gbps * 1e9)


def ring_time_s(link: "Link", g: int, nbytes: float) -> float:
    """Ring all-reduce of an nbytes-per-node buffer over g nodes on one link."""
    lat_s, bw_s = ring_parts_s(link, g, nbytes)
    return lat_s + bw_s


@dataclass(frozen=True)
class Link:
    """One link class: sustained bandwidth (GB/s) + per-message latency."""
    gbps: float          # gigabytes per second, per link
    latency_us: float    # one-way message latency, microseconds

    def time_s(self, nbytes: float) -> float:
        return self.latency_us * 1e-6 + float(nbytes) / (self.gbps * 1e9)

    # -- streamed point-to-point message (pack | send | unpack stages) ------
    def serial_codec_time_s(self, nbytes: float,
                            profile: CodecProfile = DEFAULT_PROFILE) -> float:
        """Monolithic path: encode the whole payload, ship it, decode it."""
        return (profile.pack_s(nbytes) + self.time_s(nbytes)
                + profile.unpack_s(nbytes))

    def stream_time_s(self, nbytes: float,
                      tile_bytes: int = DEFAULT_TILE_BYTES,
                      profile: CodecProfile = DEFAULT_PROFILE) -> float:
        """Streamed path: per-tile pack/send/unpack overlap.  Each tile pays
        the per-message latency, overlapped in flight, so one full hop
        latency lands in the fill (see ``stream_pipeline_s``)."""
        n_tiles = max(1, -(-int(nbytes) // int(tile_bytes)))
        return stream_pipeline_s(self.latency_us * 1e-6,
                                 profile.pack_s(nbytes),
                                 float(nbytes) / (self.gbps * 1e9),
                                 profile.unpack_s(nbytes), n_tiles)


@dataclass(frozen=True)
class Topology:
    name: str
    n_pods: int
    devices_per_pod: int
    intra: Link          # cross-device, same pod (ICI-class)
    inter: Link          # cross-pod (DCN / WAN-class)

    @property
    def n_devices(self) -> int:
        return self.n_pods * self.devices_per_pod

    def link(self, kind: str) -> Link:
        if kind == "intra":
            return self.intra
        if kind == "inter":
            return self.inter
        raise KeyError(f"unknown link kind {kind!r} (intra|inter)")

    # -- collective timing (ring model) ------------------------------------
    def allreduce_time_s(self, nbytes: float, scope: str = "intra") -> float:
        """Ring all-reduce of an nbytes-per-device buffer.

        scope: "intra" (one pod, devices_per_pod ring), "inter" (one ring of
        pod leaders over slow links), "global" (hierarchical: intra reduce ->
        inter all-reduce -> intra broadcast, the standard 2-level schedule).
        """
        if scope == "intra":
            return self._ring(self.intra, self.devices_per_pod, nbytes)
        if scope == "inter":
            return self._ring(self.inter, self.n_pods, nbytes)
        if scope == "global":
            return (self._ring_half(self.intra, self.devices_per_pod, nbytes)
                    + self._ring(self.inter, self.n_pods, nbytes)
                    + self._ring_half(self.intra, self.devices_per_pod, nbytes))
        raise KeyError(f"unknown scope {scope!r}")

    # -- streamed collectives (pack | ring | unpack pipeline) ---------------
    def allreduce_serial_time_s(self, nbytes: float, scope: str = "intra",
                                profile: CodecProfile = DEFAULT_PROFILE) -> float:
        """Monolithic compressed all-reduce: every device encodes its full
        contribution, the ring runs, every device decodes — back to back."""
        return (profile.pack_s(nbytes) + self.allreduce_time_s(nbytes, scope)
                + profile.unpack_s(nbytes))

    def allreduce_parts_s(self, nbytes: float, scope: str = "intra") -> tuple:
        """(latency_s, bandwidth_s) decomposition of one all-reduce pass:
        the per-message ring-step latencies vs the bytes/bandwidth term."""
        if scope == "intra":
            return ring_parts_s(self.intra, self.devices_per_pod, nbytes)
        if scope == "inter":
            return ring_parts_s(self.inter, self.n_pods, nbytes)
        if scope == "global":
            hl, hb = self._ring_half_parts(self.intra, self.devices_per_pod,
                                           nbytes)
            il, ib = ring_parts_s(self.inter, self.n_pods, nbytes)
            return 2 * hl + il, 2 * hb + ib
        raise KeyError(f"unknown scope {scope!r}")

    def allreduce_stream_time_s(self, nbytes: float, scope: str = "intra",
                                tile_bytes: int = DEFAULT_TILE_BYTES,
                                profile: CodecProfile = DEFAULT_PROFILE) -> float:
        """Streamed compressed all-reduce: tiles of the encoded buffer enter
        the ring as soon as they are packed, and decode as they land.  The
        per-tile ring pays its full per-step latencies — the same charge the
        serial path pays — surfaced once in the fill (tiles overlap in
        flight); only the bandwidth/codec stages amortize over tiles, so a
        codec-bound pipeline can no longer hide the ring's latency floor."""
        n_tiles = max(1, -(-int(nbytes) // int(tile_bytes)))
        lat_s, bw_s = self.allreduce_parts_s(nbytes, scope)
        return stream_pipeline_s(lat_s, profile.pack_s(nbytes), bw_s,
                                 profile.unpack_s(nbytes), n_tiles)

    @staticmethod
    def _ring(link: Link, g: int, nbytes: float) -> float:
        return ring_time_s(link, g, nbytes)

    @staticmethod
    def _ring_half_parts(link: Link, g: int, nbytes: float) -> tuple:
        if g <= 1:
            return 0.0, 0.0
        steps = g - 1
        return steps * link.latency_us * 1e-6, (
            (g - 1) / g * float(nbytes)) / (link.gbps * 1e9)

    @staticmethod
    def _ring_half(link: Link, g: int, nbytes: float) -> float:
        """Reduce-scatter or all-gather half of the ring."""
        lat_s, bw_s = Topology._ring_half_parts(link, g, nbytes)
        return lat_s + bw_s


# ---------------------------------------------------------------------------
# presets — the scenarios the repo simulates
# ---------------------------------------------------------------------------
PRESETS: Dict[str, Topology] = {
    # 2 TPU pods: ~100 GB/s ICI per chip, ~12.5 GB/s DCN per host link
    "v5p_superpod": Topology("v5p_superpod", n_pods=2, devices_per_pod=256,
                             intra=Link(gbps=100.0, latency_us=1.0),
                             inter=Link(gbps=12.5, latency_us=25.0)),
    # geo-distributed datacenters over WAN
    "geo_wan": Topology("geo_wan", n_pods=4, devices_per_pod=64,
                        intra=Link(gbps=50.0, latency_us=2.0),
                        inter=Link(gbps=1.0, latency_us=20_000.0)),
    # cross-device federated learning: phones behind broadband uplinks
    "edge_fl": Topology("edge_fl", n_pods=100, devices_per_pod=1,
                        intra=Link(gbps=10.0, latency_us=10.0),
                        inter=Link(gbps=0.00625, latency_us=50_000.0)),
}


def get_topology(name: str) -> Topology:
    if name not in PRESETS:
        raise KeyError(f"unknown topology {name!r}; known {sorted(PRESETS)}")
    return PRESETS[name]
