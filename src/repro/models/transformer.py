"""Decoder (and encoder-decoder) stacks for every assigned architecture.

Layer heterogeneity (jamba's 7:1 mamba:attn interleave, llama4's 3:1
chunked:global iRoPE, jamba's every-2nd-layer MoE) is handled with a *period*
abstraction: the layer schedule is tiled from a pattern of length P; params
for each position-in-period are stacked across the ``num_layers / P`` periods
and the stack is driven by ``jax.lax.scan`` — one period traced once, so the
512-way SPMD dry-runs compile in HLO size O(period), not O(num_layers).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MAMBA, ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.layers import (
    _dense_init,
    cross_entropy_loss,
    embed,
    init_embed,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
    unembed,
)


# ---------------------------------------------------------------------------
# Schedule helpers
# ---------------------------------------------------------------------------
def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def period_info(cfg: ModelConfig):
    kinds = cfg.layer_kinds()
    base = len(cfg.layer_pattern) if cfg.layer_pattern else 1
    P = _lcm(base, cfg.moe_every if cfg.moe else 1)
    assert cfg.num_layers % P == 0, (cfg.name, cfg.num_layers, P)
    n_periods = cfg.num_layers // P
    pos_kinds = kinds[:P]
    pos_moe = tuple(
        cfg.moe is not None and (j % cfg.moe_every) == cfg.moe_every - 1
        for j in range(P)
    )
    return P, n_periods, pos_kinds, pos_moe


def _attn_cfg(cfg: ModelConfig, kind: str) -> dict:
    return dict(
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        kind=kind,
        window=cfg.sliding_window,
        chunk=cfg.attn_chunk,
        qk_norm=cfg.qk_norm,
        # llama4 iRoPE: global (non-chunked) layers are NoPE
        use_rope=not (cfg.attn_chunk > 0 and kind == "attn"),
        rope_theta=cfg.rope_theta,
    )


def model_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_block(key, cfg: ModelConfig, kind: str, use_moe: bool, dtype) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: dict = {"norm1": init_rmsnorm(d, dtype)}
    if kind == MAMBA:
        p["mamba"] = mamba_lib.init_mamba(ks[0], d, cfg.mamba, dtype)
    else:
        p["attn"] = attn_lib.init_attention(
            ks[0], d, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.qkv_bias, dtype)
    if cfg.cross_attn:
        p["norm_x"] = init_rmsnorm(d, dtype)
        p["xattn"] = attn_lib.init_attention(
            ks[1], d, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, False, dtype)
    if cfg.d_ff > 0:
        p["norm2"] = init_rmsnorm(d, dtype)
        if use_moe:
            p["moe"] = moe_lib.init_moe(
                ks[2], d, cfg.d_ff, cfg.moe.num_experts, cfg.mlp_gated,
                cfg.moe.shared_expert, dtype)
        else:
            p["mlp"] = init_mlp(ks[3], d, cfg.d_ff, cfg.mlp_gated, dtype)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = model_dtype(cfg)
    P, n_periods, pos_kinds, pos_moe = period_info(cfg)
    k_embed, k_blocks, k_enc, k_vis = jax.random.split(key, 4)

    params: dict = {
        "embed": init_embed(k_embed, cfg.padded_vocab(), cfg.d_model, dtype,
                            cfg.tie_embeddings),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }

    block_keys = jax.random.split(k_blocks, n_periods * P).reshape(n_periods, P, 2)
    blocks = {}
    for j in range(P):
        stacked = jax.vmap(
            lambda k, j=j: _init_block(k, cfg, pos_kinds[j], pos_moe[j], dtype)
        )(block_keys[:, j])
        blocks[f"pos{j}"] = stacked
    params["blocks"] = blocks

    if cfg.enc_layers:
        de = cfg.enc_d_model or cfg.d_model
        enc_keys = jax.random.split(k_enc, cfg.enc_layers + 1)

        def enc_block(k):
            k1, k2 = jax.random.split(k)
            return {
                "norm1": init_rmsnorm(de, dtype),
                "attn": attn_lib.init_attention(
                    k1, de, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, False, dtype),
                "norm2": init_rmsnorm(de, dtype),
                "mlp": init_mlp(k2, de, cfg.d_ff, cfg.mlp_gated, dtype),
            }

        params["encoder"] = {
            "blocks": jax.vmap(enc_block)(enc_keys[:-1]),
            "final_norm": init_rmsnorm(de, dtype),
        }
    if cfg.vision_tokens:
        params["vision_proj"] = _dense_init(k_vis, (cfg.d_model, cfg.d_model), dtype)
    return params


# ---------------------------------------------------------------------------
# Block application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------
def _apply_block_train(bp, cfg: ModelConfig, kind: str, use_moe: bool, x,
                       enc_out: Optional[jax.Array]):
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(bp["norm1"], x, cfg.norm_eps)
    if kind == MAMBA:
        h = mamba_lib.mamba_train(bp["mamba"], h, cfg.mamba, cfg.d_model)
    else:
        h = attn_lib.attention_train(bp["attn"], h, cfg_attn=_attn_cfg(cfg, kind))
    x = x + h
    if cfg.cross_attn and enc_out is not None:
        h = rmsnorm(bp["norm_x"], x, cfg.norm_eps)
        h = _cross_attention(bp["xattn"], h, enc_out, cfg)
        x = x + h
    if cfg.d_ff > 0:
        h = rmsnorm(bp["norm2"], x, cfg.norm_eps)
        if use_moe:
            h, a = moe_lib.moe_apply(
                bp["moe"], h, num_experts=cfg.moe.num_experts, top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, act=cfg.mlp_act,
                gated=cfg.mlp_gated, shared_expert=cfg.moe.shared_expert)
            aux = aux + a
        else:
            h = mlp(bp["mlp"], h, act=cfg.mlp_act, gated=cfg.mlp_gated)
        x = x + h
    return x, aux


def _cross_attention(params, x, memory, cfg: ModelConfig):
    """Non-causal attention from decoder x (B,Sq,D) to encoder memory (B,Sk,De)."""
    B, Sq, _ = x.shape
    Sk = memory.shape[1]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, Sq, H, hd)
    k = (memory @ params["wk"]).reshape(B, Sk, KV, hd)
    v = (memory @ params["wv"]).reshape(B, Sk, KV, hd)
    out = _full_attention_nomask(q, k, v)
    return out.reshape(B, Sq, H * hd) @ params["wo"]


def _full_attention_nomask(q, k, v):
    """Non-causal attention through the tiled flash kernel: the naive
    (B,H,Sq,Sk) score tensor costs 17 GB/chip per seamless encoder layer at
    S=4k — the flash path is numerically identical with O(bq*bk) transients."""
    return attn_lib._flash_attention(q, k, v, "full", 0, 0)


# ---------------------------------------------------------------------------
# Activation-sharding context: the launcher installs a PartitionSpec for the
# residual stream so scan-saved remat residuals are sharded over (data, model)
# instead of replicated over 'model' (cuts saved-activation memory 16x on the
# production mesh). No-op outside a mesh context.
# ---------------------------------------------------------------------------
_ACT_SPEC = None

# Costing-harness switch: unroll the layer-period scan into a python loop so
# HLO cost analysis (which counts while bodies ONCE, ignoring trip counts)
# sees every period.  Only used with 1-2 period variant configs.
UNROLL_SCAN = False


def stack_scan(f, init, xs):
    if not UNROLL_SCAN:
        return jax.lax.scan(f, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        xi = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    if ys and ys[0] is None:
        return carry, None
    return carry, jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)


def set_activation_sharding(spec) -> None:
    global _ACT_SPEC
    _ACT_SPEC = spec


def _constrain(x):
    if _ACT_SPEC is not None:
        x = jax.lax.with_sharding_constraint(x, _ACT_SPEC)
    return x


def _remat_wrap(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Encoder (seamless)
# ---------------------------------------------------------------------------
def encode(params, cfg: ModelConfig, src_embeds: jax.Array, remat: str = "dots") -> jax.Array:
    enc = params["encoder"]
    acfg = _attn_cfg(cfg, "attn")
    acfg["use_rope"] = True

    def body(x, bp):
        h = rmsnorm(bp["norm1"], x, cfg.norm_eps)
        h = _noncausal_self_attention(bp["attn"], h, acfg)
        x = x + h
        h = rmsnorm(bp["norm2"], x, cfg.norm_eps)
        x = x + mlp(bp["mlp"], h, act=cfg.mlp_act, gated=cfg.mlp_gated)
        return x, None

    x, _ = stack_scan(_remat_wrap(body, remat), src_embeds, enc["blocks"])
    return rmsnorm(enc["final_norm"], x, cfg.norm_eps)


def _noncausal_self_attention(params, x, acfg):
    B, S, _ = x.shape
    H, KV, hd = acfg["num_heads"], acfg["num_kv_heads"], acfg["head_dim"]
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]).reshape(B, S, KV, hd)
    v = (x @ params["wv"]).reshape(B, S, KV, hd)
    from repro.models.layers import apply_rope
    pos = jnp.arange(S)[None, :]
    q = apply_rope(q, pos, acfg["rope_theta"])
    k = apply_rope(k, pos, acfg["rope_theta"])
    out = _full_attention_nomask(q, k, v)
    return out.reshape(B, S, H * hd) @ params["wo"]


# ---------------------------------------------------------------------------
# Forward (train): returns (logits, aux_loss)
# ---------------------------------------------------------------------------
def forward_train(params, cfg: ModelConfig, batch: dict, remat: str = "dots"):
    P, n_periods, pos_kinds, pos_moe = period_info(cfg)
    tokens = batch["tokens"]
    # precomputed embeddings (grad-accum hoists the gather out of its scan —
    # GSPMD's gather partitioning is unsound inside a while body)
    if "inputs_embeds" in batch:
        x = batch["inputs_embeds"]
    else:
        x = embed(params["embed"], tokens)

    if cfg.vision_tokens and "vision_embeds" in batch:
        vis = batch["vision_embeds"] @ params["vision_proj"]
        nv = vis.shape[1]
        x = jnp.concatenate([vis.astype(x.dtype), x[:, nv:]], axis=1)

    enc_out = None
    if cfg.enc_layers:
        enc_out = encode(params, cfg, batch["src_embeds"].astype(x.dtype), remat)

    def period_body(x, bps):
        aux = jnp.zeros((), jnp.float32)
        for j in range(P):
            x, a = _apply_block_train(bps[f"pos{j}"], cfg, pos_kinds[j], pos_moe[j], x, enc_out)
            aux = aux + a
        # constrain the carry OUTPUT: this is the buffer remat saves per
        # period — sharded (data, model) it is 16x smaller than replicated
        return _constrain(x), aux

    x, auxes = stack_scan(_remat_wrap(period_body, remat), _constrain(x),
                          params["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, jnp.sum(auxes)


def loss_fn(params, cfg: ModelConfig, batch: dict, remat: str = "dots"):
    logits, aux = forward_train(params, cfg, batch, remat)
    ce = cross_entropy_loss(logits, batch["targets"], valid_vocab=cfg.vocab_size)
    aux_w = cfg.moe.aux_loss_weight if cfg.moe else 0.0
    return ce + aux_w * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with caches
# ---------------------------------------------------------------------------
def cache_specs(cfg: ModelConfig, batch: int, seq_len: int, enc_len: int = 0):
    """ShapeDtypeStruct pytree for the decode cache (+ cross-attn memory)."""
    dtype = model_dtype(cfg)
    P, n_periods, pos_kinds, pos_moe = period_info(cfg)

    def stack(spec):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n_periods,) + s.shape, s.dtype), spec)

    cache = {}
    for j, kind in enumerate(pos_kinds):
        if kind == MAMBA:
            spec = mamba_lib.mamba_cache_spec(cfg.d_model, cfg.mamba, batch, dtype)
        else:
            spec = attn_lib.cache_spec(_attn_cfg(cfg, kind), batch, seq_len, dtype)
        cache[f"pos{j}"] = stack(spec)
    out = {"layers": cache, "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.enc_layers:
        de = cfg.enc_d_model or cfg.d_model
        out["enc_memory"] = jax.ShapeDtypeStruct((batch, enc_len, de), dtype)
    return out


def _apply_block_decode(bp, cfg: ModelConfig, kind: str, use_moe: bool, x, lcache,
                        pos, enc_memory):
    h = rmsnorm(bp["norm1"], x, cfg.norm_eps)
    if kind == MAMBA:
        h, new_cache = mamba_lib.mamba_decode(bp["mamba"], h, lcache, cfg.mamba, cfg.d_model)
    else:
        h, new_cache = attn_lib.attention_decode(
            bp["attn"], h, lcache, pos, cfg_attn=_attn_cfg(cfg, kind))
    x = x + h
    if cfg.cross_attn and enc_memory is not None:
        h = rmsnorm(bp["norm_x"], x, cfg.norm_eps)
        x = x + _cross_attention(bp["xattn"], h, enc_memory, cfg)
    if cfg.d_ff > 0:
        h = rmsnorm(bp["norm2"], x, cfg.norm_eps)
        if use_moe:
            h, _ = moe_lib.moe_ffn(
                bp["moe"], h, num_experts=cfg.moe.num_experts, top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, act=cfg.mlp_act,
                gated=cfg.mlp_gated, shared_expert=cfg.moe.shared_expert,
                no_drop=True)
        else:
            h = mlp(bp["mlp"], h, act=cfg.mlp_act, gated=cfg.mlp_gated)
        x = x + h
    return x, new_cache


def decode_step(params, cfg: ModelConfig, token: jax.Array, cache: dict):
    """token (B, 1) int32; cache from cache_specs/prefill. Returns (logits, cache)."""
    P, n_periods, pos_kinds, pos_moe = period_info(cfg)
    pos = cache["pos"]
    x = embed(params["embed"], token)
    enc_memory = cache.get("enc_memory")

    def period_body(x, scanned):
        bps, lcaches = scanned
        new_caches = {}
        for j in range(P):
            x, nc = _apply_block_decode(
                bps[f"pos{j}"], cfg, pos_kinds[j], pos_moe[j], x, lcaches[f"pos{j}"],
                pos, enc_memory)
            new_caches[f"pos{j}"] = nc
        return x, new_caches

    x, new_layer_caches = stack_scan(period_body, x, (params["blocks"], cache["layers"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)
    new_cache = dict(cache)
    new_cache["layers"] = new_layer_caches
    new_cache["pos"] = pos + 1
    return logits, new_cache


def _ring_from_prefill(kv: dict, cfg_attn: dict, S: int, cache_len: int):
    """Convert full prefill K/V (B,S,KV,hd) into the decode cache.

    Windowed kinds get a ring of the last `Sc` live positions placed so that
    slot == pos % Sc; the global kind gets a slot==pos cache padded out to
    ``cache_len`` capacity so subsequent decode steps append without wrapping.
    """
    kind = cfg_attn["kind"]
    if kind == "attn_swa":
        Sc = min(cache_len, cfg_attn["window"])
    elif kind == "attn_chunk":
        Sc = min(cache_len, cfg_attn["chunk"])
    else:
        pad = cache_len - S
        if pad <= 0:
            return kv
        padded = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": padded(kv["k"]), "v": padded(kv["v"])}

    def ring(a):
        if S < Sc:
            a = jnp.pad(a, ((0, 0), (0, Sc - S), (0, 0), (0, 0)))
            return a  # slot == pos, not yet wrapped
        tail = a[:, S - Sc:, ...]
        # element j holds pos S-Sc+j whose slot is (S-Sc+j) % Sc == (j + S) % Sc
        return jnp.roll(tail, shift=S % Sc, axis=1)

    return {"k": ring(kv["k"]), "v": ring(kv["v"])}


def prefill(params, cfg: ModelConfig, batch: dict, remat: str = "dots",
            cache_len: int = 0):
    """Full-sequence forward producing (last-position logits, decode cache).

    The cache matches ``cache_specs(cfg, B, S)`` exactly: attention layers get
    their K/V (ring-rolled to window size for SWA/chunked kinds), SSD layers
    get {ssm state, conv tail}; enc-dec additionally stores the encoder memory.
    """
    P, n_periods, pos_kinds, pos_moe = period_info(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache_len = max(cache_len, S + 1)
    x = embed(params["embed"], tokens)
    if cfg.vision_tokens and "vision_embeds" in batch:
        vis = batch["vision_embeds"] @ params["vision_proj"]
        nv = vis.shape[1]
        x = jnp.concatenate([vis.astype(x.dtype), x[:, nv:]], axis=1)
    enc_out = None
    if cfg.enc_layers:
        enc_out = encode(params, cfg, batch["src_embeds"].astype(x.dtype), remat)

    def period_body(x, bps):
        caches = {}
        for j in range(P):
            kind, use_moe = pos_kinds[j], pos_moe[j]
            bp = bps[f"pos{j}"]
            h = rmsnorm(bp["norm1"], x, cfg.norm_eps)
            if kind == MAMBA:
                h, cache_j = mamba_lib.mamba_forward(
                    bp["mamba"], h, cfg.mamba, cfg.d_model, return_cache=True)
            else:
                acfg = _attn_cfg(cfg, kind)
                h, kv = attn_lib.attention_prefill(bp["attn"], h, cfg_attn=acfg)
                cache_j = _ring_from_prefill(kv, acfg, S, cache_len)
            caches[f"pos{j}"] = cache_j
            x = x + h
            if cfg.cross_attn and enc_out is not None:
                hx = rmsnorm(bp["norm_x"], x, cfg.norm_eps)
                x = x + _cross_attention(bp["xattn"], hx, enc_out, cfg)
            if cfg.d_ff > 0:
                h2 = rmsnorm(bp["norm2"], x, cfg.norm_eps)
                if use_moe:
                    h2, _ = moe_lib.moe_apply(
                        bp["moe"], h2, num_experts=cfg.moe.num_experts,
                        top_k=cfg.moe.top_k, capacity_factor=cfg.moe.capacity_factor,
                        act=cfg.mlp_act, gated=cfg.mlp_gated,
                        shared_expert=cfg.moe.shared_expert)
                else:
                    h2 = mlp(bp["mlp"], h2, act=cfg.mlp_act, gated=cfg.mlp_gated)
                x = x + h2
        return _constrain(x), caches

    x, layer_caches = stack_scan(_remat_wrap(period_body, remat), _constrain(x),
                                 params["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, -1:])
    cache = {"layers": layer_caches, "pos": jnp.asarray(S, jnp.int32)}
    if cfg.enc_layers:
        cache["enc_memory"] = enc_out
    return logits, cache
