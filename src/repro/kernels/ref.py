"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_dequant_ref(x2d: jax.Array, noise2d: jax.Array, bits: int = 8) -> jax.Array:
    """Blockwise absmax quantize-dequantize with stochastic rounding."""
    s = 2 ** (bits - 1) - 1
    x = x2d.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / s
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.floor(x / scale + noise2d)
    q = jnp.clip(q, -s, s)
    return (q * scale).astype(x2d.dtype)


def pack_mask_ref(mask2d: jax.Array) -> jax.Array:
    """(32, C) {0,1} -> (1, C) uint32: bit j of word c is mask[j, c]."""
    bits = mask2d.astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)[:, None]
    return jnp.sum(bits << shifts, axis=0, keepdims=True).astype(jnp.uint32)


def unpack_mask_ref(words2d: jax.Array) -> jax.Array:
    """(1, C) uint32 -> (32, C) {0,1} uint32."""
    shifts = jnp.arange(32, dtype=jnp.uint32)[:, None]
    return ((words2d >> shifts) & jnp.uint32(1)).astype(jnp.uint32)


def quant_pack_ref(x2d: jax.Array, noise2d: jax.Array, bits: int = 8):
    """Blockwise absmax quantize to the wire planes (int8 q, fp32 scales)."""
    s = 2 ** (bits - 1) - 1
    x = x2d.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / s
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.floor(x / scale + noise2d), -s, s)
    return q.astype(jnp.int8), scale


def unpack_dequant_ref(q2d: jax.Array, scales: jax.Array,
                       out_dtype=jnp.float32) -> jax.Array:
    return (q2d.astype(jnp.float32) * scales).astype(out_dtype)


def stream_quant_pack_ref(x2d: jax.Array, noise2d: jax.Array, bits: int = 8,
                          tile_rows: int = 8):
    """Oracle for kernels/stream: quantize-pack computed tile by tile.

    The quantization blocks along axis 1, so tiling the row axis
    cannot change the result — this oracle documents (and the tests assert)
    that the streamed ring is bit-identical to the monolithic pass.
    """
    rows = x2d.shape[0]
    assert rows % tile_rows == 0, (x2d.shape, tile_rows)
    qs, ss = [], []
    for r in range(0, rows, tile_rows):
        q, s = quant_pack_ref(x2d[r: r + tile_rows],
                              noise2d[r: r + tile_rows], bits=bits)
        qs.append(q)
        ss.append(s)
    return jnp.concatenate(qs, axis=0), jnp.concatenate(ss, axis=0)


def nm_prune_ref(w: jax.Array, scores: jax.Array, n: int = 2, m: int = 4):
    """Keep n largest scores per group of m along d_in; first-index tie-break."""
    d_in, d_out = w.shape
    g = scores.astype(jnp.float32).reshape(d_in // m, m, d_out)
    idx = jnp.arange(m).reshape(1, m, 1)
    greater = jnp.sum(g[:, None, :, :] > g[:, :, None, :], axis=2).astype(jnp.float32)
    ties = jnp.sum(
        (g[:, None, :, :] == g[:, :, None, :]) & (idx[:, :, None] > idx[:, None, :]),
        axis=2,
    ).astype(jnp.float32)
    # rank_i = #{k: s_k > s_i} + #{k < i: s_k == s_i}
    rank = greater + ties
    keep = (rank < n).astype(w.dtype).reshape(d_in, d_out)
    return w * keep, keep


def wanda_scores_ref(w, xnorm, mode="wanda", alpha=0.5, beta=0.5, ynorm=None,
                     mu_in=1.0, mu_out=1.0):
    aw = jnp.abs(w.astype(jnp.float32))
    if mode == "wanda":
        return aw * xnorm[:, None]
    if mode == "ria":
        rowsum = jnp.sum(aw, axis=1, keepdims=True)
        colsum = jnp.sum(aw, axis=0, keepdims=True)
        return (aw / rowsum + aw / colsum) * (xnorm[:, None] ** alpha)
    if mode == "symwanda":
        return beta * aw * xnorm[:, None] / mu_in + (1 - beta) * aw * ynorm[None, :] / mu_out
    raise ValueError(mode)


def wanda_prune_ref(w, xnorm, tau, mode="wanda", alpha=0.5, beta=0.5, ynorm=None,
                    mu_in=1.0, mu_out=1.0):
    s = wanda_scores_ref(w, xnorm, mode, alpha, beta, ynorm, mu_in, mu_out)
    keep = (s >= tau[None, :]).astype(w.dtype)
    return w * keep, keep
