"""Config system: dataclass configs + architecture registry.

Every assigned architecture registers a ``ModelConfig`` here via its
``src/repro/configs/<arch>.py`` module.  Configs are plain frozen dataclasses
so they hash, print, and diff cleanly; ``reduced()`` produces the CPU smoke
variant (2 layers, d_model<=512, <=4 experts) required by the deliverables.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

from repro.faults.model import FaultConfig

# ---------------------------------------------------------------------------
# Layer kinds used by the interleave schedule (jamba, llama4 iRoPE, ...)
# ---------------------------------------------------------------------------
ATTN_GLOBAL = "attn"          # full causal attention
ATTN_SWA = "attn_swa"         # sliding-window attention
ATTN_CHUNK = "attn_chunk"     # chunked-local attention (llama4 iRoPE local)
MAMBA = "mamba"               # Mamba2 SSD block


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 16
    top_k: int = 1
    capacity_factor: float = 1.25
    # dbrx-style fine-grained experts keep d_ff per expert small; llama4 adds a
    # shared expert alongside the routed ones.
    shared_expert: bool = False
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | vlm | audio | ssm | hybrid
    citation: str
    num_layers: int
    d_model: int
    num_heads: int                    # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- attention options ---
    qkv_bias: bool = False            # qwen1.5
    qk_norm: bool = False             # chameleon
    rope_theta: float = 10000.0
    sliding_window: int = 0           # >0 => SWA (h2o-danube)
    attn_chunk: int = 0               # >0 => chunked-local attention (llama4)
    # layer schedule: None => all ATTN_GLOBAL (or per-arch default); else a
    # pattern tiled over num_layers, e.g. ("mamba",)*7+("attn",) for jamba.
    layer_pattern: Optional[Sequence[str]] = None
    # --- mlp ---
    mlp_act: str = "silu"             # silu (SwiGLU) | relu2 (nemotron squared-ReLU) | gelu
    mlp_gated: bool = True
    # --- mixture of experts ---
    moe: Optional[MoEConfig] = None
    moe_every: int = 1                # apply MoE MLP every k-th layer (jamba: 2)
    # --- ssm ---
    mamba: Optional[MambaConfig] = None
    # --- encoder/decoder (seamless) ---
    enc_layers: int = 0               # >0 => encoder-decoder
    enc_d_model: int = 0
    cross_attn: bool = False
    # --- multimodal early-fusion stub ---
    vision_tokens: int = 0            # llama4: projected patch embeddings count
    audio_frontend: bool = False      # seamless: frame embeddings replace src tokens
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # long_500k eligibility: sub-quadratic decode memory (ssm / swa / chunked).
    # Set by each config; dryrun consults this.
    supports_long_context: bool = False

    # ----- derived -----
    def padded_vocab(self, multiple: int = 16) -> int:
        """Vocab rounded up so the logits dim shards over the model axis
        (seamless 256206 / mamba2 50280 are not 16-divisible; unsharded f32
        logits at train_4k cost 67 GB/chip). Dead rows are masked in the CE."""
        return -(-self.vocab_size // multiple) * multiple

    def layer_kinds(self) -> tuple:
        if self.layer_pattern is None:
            kind = ATTN_GLOBAL
            if self.sliding_window > 0:
                kind = ATTN_SWA
            elif self.attn_chunk > 0:
                kind = ATTN_CHUNK
            return (kind,) * self.num_layers
        pat = tuple(self.layer_pattern)
        reps = -(-self.num_layers // len(pat))
        return (pat * reps)[: self.num_layers]

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), used for 6ND."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n_attn = sum(1 for k in self.layer_kinds() if k.startswith("attn"))
        n_mamba = sum(1 for k in self.layer_kinds() if k == MAMBA)
        p = v * d  # embed
        if not self.tie_embeddings:
            p += v * d
        # attention
        q = self.num_heads * self.head_dim
        kv = self.num_kv_heads * self.head_dim
        attn_p = d * q + 2 * d * kv + q * d
        if self.qkv_bias:
            attn_p += q + 2 * kv
        p += n_attn * attn_p
        # mamba blocks
        if self.mamba is not None:
            di = self.mamba.expand * d
            nheads = di // self.mamba.head_dim
            # in_proj produces [z, x, B, C, dt]
            conv_dim = di + 2 * self.mamba.n_groups * self.mamba.d_state
            in_dim = 2 * di + 2 * self.mamba.n_groups * self.mamba.d_state + nheads
            mamba_p = d * in_dim + conv_dim * self.mamba.d_conv + di * d + nheads * 2 + di
            p += n_mamba * mamba_p
        # mlp / moe
        n_blocks = self.num_layers
        mlp_p = (3 if self.mlp_gated else 2) * d * ff
        if self.moe is not None:
            n_moe = len([i for i in range(n_blocks) if (i % self.moe_every) == self.moe_every - 1])
            n_dense = n_blocks - n_moe
            p += n_dense * mlp_p
            p += n_moe * (self.moe.num_experts * mlp_p + d * self.moe.num_experts)
            if self.moe.shared_expert:
                p += n_moe * mlp_p
        else:
            p += n_blocks * mlp_p
        # norms (2 per block + final)
        p += (2 * n_blocks + 1) * d
        # encoder
        if self.enc_layers:
            de = self.enc_d_model or d
            enc_attn = 4 * de * de
            enc_mlp = (3 if self.mlp_gated else 2) * de * self.d_ff
            p += self.enc_layers * (enc_attn + enc_mlp + 2 * de)
            # cross-attention in decoder
            p += self.num_layers * (4 * d * de + d)
        return int(p)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        mlp_p = (3 if self.mlp_gated else 2) * d * ff
        n_blocks = self.num_layers
        n_moe = len([i for i in range(n_blocks) if (i % self.moe_every) == self.moe_every - 1])
        inactive = n_moe * (self.moe.num_experts - self.moe.top_k) * mlp_p
        return self.param_count() - int(inactive)

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant: same family/topology, tiny dims."""
        d = min(self.d_model, 128)
        hd = 32
        nh = max(2, min(4, self.num_heads)) if self.num_heads else 0
        nkv = max(1, min(nh or 1, max(1, self.num_kv_heads * nh // max(1, self.num_heads))))
        moe = None
        if self.moe is not None:
            moe = replace(self.moe, num_experts=4, top_k=min(self.moe.top_k, 2))
        mamba = None
        if self.mamba is not None:
            mamba = replace(self.mamba, d_state=16, head_dim=16, chunk_size=8)
        pat = None
        if self.layer_pattern is not None:
            # keep the interleave character but fit in 2 layers
            pat = tuple(self.layer_pattern)[:2] if len(self.layer_pattern) >= 2 else self.layer_pattern
        return replace(
            self,
            num_layers=2,
            d_model=d,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=hd,
            d_ff=min(self.d_ff, 4 * d) or 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            attn_chunk=min(self.attn_chunk, 16) if self.attn_chunk else 0,
            layer_pattern=pat,
            moe=moe,
            mamba=mamba,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            enc_d_model=min(self.enc_d_model, d) if self.enc_d_model else 0,
            vision_tokens=min(self.vision_tokens, 4) if self.vision_tokens else 0,
            dtype="float32",
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Training / sync configuration (the paper's knobs)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LevelConfig:
    """One level of an aggregation tree's sync cascade (leaf-most first).

    Pairs by order with the levels of the ``repro.comm.tree`` topology named
    by ``SyncConfig.topology``: each level keeps its own anchor, syncing every
    ``period`` steps through its own compressor.  Periods must be nested —
    each level's period a multiple of the level below — so a level only syncs
    on steps where every faster level underneath it also syncs.
    """
    name: str
    period: int = 1
    compressor: str = "identity"      # see core/compressors.py registry
    compress_ratio: float = 0.05
    quant_bits: int = 8


@dataclass(frozen=True)
class SyncConfig:
    """How gradients are synchronized across the data/pod mesh axes.

    ``mode``:
      dense    - plain all-reduce (the non-compressed baseline, FedAvg-ish)
      efbv     - EF-BV compressed delta sync (Ch. 2); compressor taken from
                 ``compressor``; lambda/nu from the eta/omega calculus
      ef21     - EF-BV with nu=lambda (EF21 special case)
      diana    - EF-BV with nu=1 (DIANA special case)
      local    - Scafflix-style local training: sync every ``sync_period``
                 steps (expected value of prob-p skipping), control variates on
      hier     - Cohort-Squeeze hierarchical: dense intra-pod reduce every
                 step, compressed inter-pod reduce every ``sync_period`` steps.
                 With ``levels`` set, the two-level schedule generalizes to an
                 arbitrary-depth aggregation tree (repro.comm.tree): one
                 anchor, period and compressor per level, leaf-most first.
    """
    mode: str = "dense"
    compressor: str = "topk_block"    # see core/compressors.py registry
    compress_ratio: float = 0.05      # k/d for sparsifiers; bits for quantizers
    quant_bits: int = 8
    sync_period: int = 1              # Scafflix E[1/p]
    personalization_alpha: float = 1.0  # FLIX alpha (1 = no personalization)
    # link topology preset (repro.comm.topology.PRESETS, or a tree preset
    # from repro.comm.tree.TREE_PRESETS when ``levels`` is set) used to turn
    # per-round encoded bytes into simulated wall-clock
    topology: str = "v5p_superpod"
    # aggregation-tree cascade (mode="hier"): per-level sync periods and
    # compressors, leaf-most first, paired by order with the tree topology's
    # levels.  None = the classic two-level hier schedule.
    levels: Optional[Tuple[LevelConfig, ...]] = None
    # bucket fusion (repro.comm.buckets): the sync pytree is flattened into
    # fixed-size fp32 buckets so one fused compressor/codec pass replaces the
    # per-leaf kernel loop.  0 = legacy per-leaf path.
    bucket_size: int = 1 << 16
    # streaming codec pipeline (repro.comm.topology): per-tile pack/send/
    # unpack overlap in the simulated round time.  0 = monolithic serial.
    stream_tile_bytes: int = 1 << 20
    # fault injection (repro.faults): availability / straggler / lossy-link
    # processes and per-level deadlines for degraded rounds.  None (or a
    # config with all rates 0 and deadline inf) keeps every sync path
    # bit-identical to the faultless code.
    faults: Optional[FaultConfig] = None


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    seq_len: int = 4096
    global_batch: int = 256
    lr: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    optimizer: str = "adamw"
    grad_clip: float = 1.0
    sync: SyncConfig = field(default_factory=SyncConfig)
    remat: str = "dots"               # none | dots | full
    grad_accum: int = 1               # microbatch accumulation steps
    seed: int = 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    # import the per-arch modules lazily so `import repro` stays light
    if _REGISTRY:
        return
    from repro.configs import archs  # noqa: F401  (registers everything)


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
