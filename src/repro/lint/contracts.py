"""Engine 2 — abstract-interpretation contract checks (no training data).

Three contract families, reported as RC-rule findings:

* **RC001 codec fidelity** — every compressor in ``core.compressors._REGISTRY``
  is instantiated from :data:`CONTRACT_PARAMS` and abstract-evaluated via
  ``jax.eval_shape`` over a shape x dtype grid: the carrier must preserve the
  input shape, and its dtype must be the input dtype or float32 (stochastic
  quantizers promote through the f32 noise draw).

* **RC002 payload accounting** — a small *concrete* probe per compressor
  (host-side numpy encode prevents pure eval_shape here):
  ``decode(encode(x)) == c(key, x)`` elementwise, the declared plane bytes
  sum to ``payload.nbytes``, and ``codecs.extrapolate_bits(p, d, d)`` equals
  ``p.nbits`` exactly — the accounting formulas and the wire planes must
  describe the same bytes.

* **RC003 kernel static budgets** — BlockSpec/grid arithmetic of every
  Pallas kernel from module constants alone: per-invocation VMEM estimate
  under a per-kernel budget (and the ~16 MB/core ceiling), bitpack word
  width ``PACK_BITS <= 32``, sparse-block index width ``ceil(log2 block)``
  within the uint-stream packer's 56-bit bound, quant wire bits within the
  int8 plane — plus ``eval_shape`` through the jitted ``kernels.ops``
  wrappers (works because ``pallas_call`` declares ``out_shape``) to pin the
  plumbing's shape/dtype algebra.

Run via ``python -m repro.lint`` (on by default; ``--no-contracts`` skips)
or directly: ``run_contracts() -> list[Finding]``.
"""
from __future__ import annotations

import math
from typing import Dict, List

from repro.lint.framework import Finding

# Every _REGISTRY entry needs a row here — several factories have required
# kwargs (k_frac etc.) with no defaults.  test_lint asserts the coverage.
CONTRACT_PARAMS: Dict[str, dict] = {
    "identity": {},
    "rand_k": {"k_frac": 0.25},
    "top_k": {"k_frac": 0.25},
    "topk_block": {"k_frac": 0.25, "block": 256},
    "qsgd": {"bits": 8, "block": 256},
    "qsgd_sharded": {"bits": 8, "block": 64},
    "qsgd_kernel": {"bits": 8},
    "mix_k": {"k_frac_top": 0.25, "k_frac_rand": 0.25},
    "comp_k": {"k_frac_top": 0.1, "k_frac_rand": 0.5},
}

SHAPE_GRID = ((64,), (257,), (4096,), (8, 512))
DTYPE_GRID = ("float32", "bfloat16")

# per-invocation VMEM budgets (bytes) — deliberately far below the ~16 MB
# VMEM/core so a tile-constant bump that 100x's the working set fails here
# before it fails on hardware
VMEM_CEILING = 16 * 1024 * 1024
KERNEL_VMEM_BUDGETS = {
    "quant8.quant_dequant_2d": 1 << 20,
    "bitpack.pack_mask_2d": 1 << 20,
    "bitpack.unpack_mask_2d": 1 << 20,
    "bitpack.quant_pack_2d": 1 << 20,
    "bitpack.unpack_dequant_2d": 1 << 20,
    "stream.stream_quant_pack_2d": 1 << 21,
    "nm_prune.nm_prune_2d": 1 << 21,
    "wanda_score.wanda_prune_2d": 1 << 22,
}


def _finding(rule: str, path: str, message: str) -> Finding:
    return Finding(rule, path, 1, 1, message, snippet=f"<{rule} contract>")


def _allowed_dtypes(in_dtype) -> set:
    import jax.numpy as jnp
    return {jnp.dtype(in_dtype), jnp.dtype(jnp.float32)}


# ---------------------------------------------------------------------------
# RC001 — compressor shape/dtype fidelity under eval_shape
# ---------------------------------------------------------------------------
def check_compressor_grid() -> List[Finding]:
    import jax
    import jax.numpy as jnp

    from repro.core.compressors import _REGISTRY, make_compressor

    path = "src/repro/core/compressors.py"
    out: List[Finding] = []
    for name in sorted(_REGISTRY):
        if name not in CONTRACT_PARAMS:
            out.append(_finding(
                "RC001", path,
                f"compressor {name!r} has no CONTRACT_PARAMS row — the "
                f"eval_shape grid does not cover it"))
            continue
        c = make_compressor(name, **CONTRACT_PARAMS[name])
        for shape in SHAPE_GRID:
            for dtype in DTYPE_GRID:
                x = jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))
                key = jax.ShapeDtypeStruct((2,), jnp.uint32)
                try:
                    y = jax.eval_shape(lambda k, v: c(k, v), key, x)
                except Exception as e:  # noqa: BLE001 — report, don't crash
                    out.append(_finding(
                        "RC001", path,
                        f"{name} fails abstract eval on {shape} {dtype}: "
                        f"{type(e).__name__}: {e}"))
                    continue
                if tuple(y.shape) != tuple(shape):
                    out.append(_finding(
                        "RC001", path,
                        f"{name} on {shape} {dtype}: carrier shape "
                        f"{tuple(y.shape)} != input shape"))
                if jnp.dtype(y.dtype) not in _allowed_dtypes(dtype):
                    out.append(_finding(
                        "RC001", path,
                        f"{name} on {shape} {dtype}: carrier dtype {y.dtype} "
                        f"not in {{input, float32}}"))
    for name in sorted(set(CONTRACT_PARAMS) - set(_REGISTRY)):
        out.append(_finding(
            "RC001", path,
            f"CONTRACT_PARAMS row {name!r} matches no registered compressor"))
    return out


# ---------------------------------------------------------------------------
# RC002 — wire payload vs accounting byte formulas
# ---------------------------------------------------------------------------
def check_payload_accounting() -> List[Finding]:
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.comm import codecs
    from repro.core.compressors import _REGISTRY, make_compressor

    path = "src/repro/comm/codecs.py"
    out: List[Finding] = []
    d = 1000  # not a block multiple: stresses pad/trim on every scheme
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(d), jnp.float32)
    key = jax.random.PRNGKey(0)
    for name in sorted(set(_REGISTRY) & set(CONTRACT_PARAMS)):
        c = make_compressor(name, **CONTRACT_PARAMS[name])
        try:
            p = codecs.encode(c, key, x)
            y = np.asarray(codecs.decode(p))
        except Exception as e:  # noqa: BLE001 — report, don't crash
            out.append(_finding(
                "RC002", path,
                f"{name}: encode/decode raised {type(e).__name__}: {e}"))
            continue
        if y.shape != (d,):
            out.append(_finding(
                "RC002", path,
                f"{name}: decoded shape {y.shape} != ({d},)"))
        if not codecs.roundtrip_equal(c, key, x):
            out.append(_finding(
                "RC002", path,
                f"{name}: decode(encode(x)) != compressor carrier "
                f"(scheme {p.scheme})"))
        plane_bytes = sum(v.nbytes for v in p.planes.values())
        if plane_bytes != p.nbytes:
            out.append(_finding(
                "RC002", path,
                f"{name}: declared payload nbytes {p.nbytes} != plane sum "
                f"{plane_bytes}"))
        extr = codecs.extrapolate_bits(p, d, d)
        if extr != p.nbits:
            out.append(_finding(
                "RC002", path,
                f"{name}: extrapolate_bits(p, {d}, {d}) = {extr} != exact "
                f"nbits {p.nbits} — accounting formula diverges from the "
                f"wire planes at the probe size itself"))
    return out


# ---------------------------------------------------------------------------
# RC003 — Pallas kernel static budgets
# ---------------------------------------------------------------------------
def _vmem_estimates() -> Dict[str, int]:
    """Bytes resident in VMEM for one grid step, from module constants.
    f32 = 4B planes; int8 = 1B; the stream ring doubles everything by
    N_SLOTS."""
    from repro.kernels import bitpack as bp
    from repro.kernels import nm_prune as nm
    from repro.kernels import quant8 as q8
    from repro.kernels import stream as st
    from repro.kernels import wanda_score as ws

    tile = q8.TILE_ROWS * q8.QBLOCK
    pack_tile = bp.PACK_BITS * bp.PACK_LANES
    ring_slot = tile * (4 + 4 + 1) + q8.TILE_ROWS * 4  # x + noise + q + scales
    return {
        # x + noise + out, all f32
        "quant8.quant_dequant_2d": 3 * tile * 4,
        # (32, 128) u32 mask block + (1, 128) u32 words
        "bitpack.pack_mask_2d": pack_tile * 4 + bp.PACK_LANES * 4,
        "bitpack.unpack_mask_2d": pack_tile * 4 + bp.PACK_LANES * 4,
        # x f32 + noise f32 + q i8 + scales f32
        "bitpack.quant_pack_2d": tile * (4 + 4 + 1) + q8.TILE_ROWS * 4,
        "bitpack.unpack_dequant_2d": tile * (1 + 4) + q8.TILE_ROWS * 4,
        "stream.stream_quant_pack_2d": st.N_SLOTS * ring_slot,
        # w + scores + out + mask tiles, f32
        "nm_prune.nm_prune_2d": 4 * nm.TILE_R * nm.TILE_C * 4,
        # w + out + mask tiles f32 + per-row/col vectors
        "wanda_score.wanda_prune_2d": (3 * ws.TILE_R * ws.TILE_C * 4
                                       + 4 * (ws.TILE_R + ws.TILE_C) * 4),
    }


def check_kernel_budgets() -> List[Finding]:
    import jax
    import jax.numpy as jnp

    from repro.comm.codecs import _PACK_MAX_NBITS
    from repro.core.compressors import _REGISTRY, make_compressor
    from repro.kernels import bitpack as bp
    from repro.kernels import ops

    out: List[Finding] = []
    kpath = "src/repro/kernels"

    # --- VMEM working set per grid step
    for kernel, est in sorted(_vmem_estimates().items()):
        budget = KERNEL_VMEM_BUDGETS[kernel]
        path = f"{kpath}/{kernel.split('.')[0]}.py"
        if est > budget:
            out.append(_finding(
                "RC003", path,
                f"{kernel}: estimated VMEM/grid-step {est} B exceeds its "
                f"budget {budget} B"))
        if est > VMEM_CEILING:
            out.append(_finding(
                "RC003", path,
                f"{kernel}: estimated VMEM/grid-step {est} B exceeds the "
                f"~16 MB/core ceiling"))

    # --- bitpack word-width overflow
    if bp.PACK_BITS > 32:
        out.append(_finding(
            "RC003", f"{kpath}/bitpack.py",
            f"PACK_BITS={bp.PACK_BITS} > 32: mask words no longer fit uint32"))
    if bp.PACK_LANES % 128 != 0:
        out.append(_finding(
            "RC003", f"{kpath}/bitpack.py",
            f"PACK_LANES={bp.PACK_LANES} is not 128-lane aligned"))

    # --- wire-spec arithmetic of every registered compressor
    for name in sorted(set(_REGISTRY) & set(CONTRACT_PARAMS)):
        spec = make_compressor(name, **CONTRACT_PARAMS[name]).wire
        if spec is None:
            continue
        if spec.scheme == "sparse_block":
            nbits = max(1, math.ceil(math.log2(spec.block)))
            if nbits > 32:
                out.append(_finding(
                    "RC003", "src/repro/comm/codecs.py",
                    f"{name}: sparse_block offsets need {nbits} bits "
                    f"(block={spec.block}) > 32 — index plane overflows"))
            if nbits > _PACK_MAX_NBITS:
                out.append(_finding(
                    "RC003", "src/repro/comm/codecs.py",
                    f"{name}: {nbits}-bit offsets exceed the uint-stream "
                    f"packer bound ({_PACK_MAX_NBITS})"))
        if spec.scheme == "quant" and not (0 < spec.bits <= 8):
            out.append(_finding(
                "RC003", "src/repro/comm/codecs.py",
                f"{name}: quant bits={spec.bits} outside (0, 8] — the wire "
                f"plane is int8"))

    # --- grid/shape algebra through the jitted ops wrappers (eval_shape
    #     traces pallas_call abstractly: out_shape is declared)
    d = 1000
    w = -(-d // bp.PACK_BITS)
    mask = jax.ShapeDtypeStruct((d,), jnp.uint32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    x = jax.ShapeDtypeStruct((d,), jnp.float32)
    checks = [
        ("pack_bits", lambda: jax.eval_shape(
            lambda m: ops.pack_bits(m), mask), (w,), jnp.uint32),
        ("unpack_bits", lambda: jax.eval_shape(
            lambda v: ops.unpack_bits(v, d=d),
            jax.ShapeDtypeStruct((w,), jnp.uint32)), (d,), jnp.uint32),
        ("quantize_dequantize", lambda: jax.eval_shape(
            lambda v, k: ops.quantize_dequantize(v, k), x, key),
         (d,), jnp.float32),
    ]
    for label, run, want_shape, want_dtype in checks:
        try:
            res = run()
        except Exception as e:  # noqa: BLE001 — report, don't crash
            out.append(_finding(
                "RC003", f"{kpath}/ops.py",
                f"ops.{label}: abstract eval failed: "
                f"{type(e).__name__}: {e}"))
            continue
        if tuple(res.shape) != want_shape or jnp.dtype(res.dtype) != want_dtype:
            out.append(_finding(
                "RC003", f"{kpath}/ops.py",
                f"ops.{label}: eval_shape gave {tuple(res.shape)} "
                f"{res.dtype}, expected {want_shape} {want_dtype}"))

    # quantize_pack and the DMA-ring variant must agree on the wire planes
    from repro.kernels import quant8 as q8
    rows = -(-(-(-d // q8.QBLOCK)) // q8.TILE_ROWS) * q8.TILE_ROWS
    for label, fn in (("quantize_pack", ops.quantize_pack),
                      ("stream_quantize_pack", ops.stream_quantize_pack)):
        try:
            q, s = jax.eval_shape(lambda v, k, fn=fn: fn(v, k), x, key)
        except Exception as e:  # noqa: BLE001 — report, don't crash
            out.append(_finding(
                "RC003", f"{kpath}/ops.py",
                f"ops.{label}: abstract eval failed: "
                f"{type(e).__name__}: {e}"))
            continue
        want_q, want_s = (rows, q8.QBLOCK), (rows, 1)
        if tuple(q.shape) != want_q or jnp.dtype(q.dtype) != jnp.int8:
            out.append(_finding(
                "RC003", f"{kpath}/ops.py",
                f"ops.{label}: q plane {tuple(q.shape)} {q.dtype}, expected "
                f"{want_q} int8"))
        if tuple(s.shape) != want_s or jnp.dtype(s.dtype) != jnp.float32:
            out.append(_finding(
                "RC003", f"{kpath}/ops.py",
                f"ops.{label}: scales plane {tuple(s.shape)} {s.dtype}, "
                f"expected {want_s} float32"))

    # N:M prune keeps the logical (unpadded) shape
    try:
        w2 = jax.ShapeDtypeStruct((200, 300), jnp.float32)
        pruned, pmask = jax.eval_shape(
            lambda a, sc: ops.prune_nm(a, sc), w2, w2)
        if tuple(pruned.shape) != (200, 300) or tuple(pmask.shape) != (200, 300):
            out.append(_finding(
                "RC003", f"{kpath}/ops.py",
                f"ops.prune_nm: output shapes {tuple(pruned.shape)}/"
                f"{tuple(pmask.shape)} != (200, 300)"))
    except Exception as e:  # noqa: BLE001 — report, don't crash
        out.append(_finding(
            "RC003", f"{kpath}/ops.py",
            f"ops.prune_nm: abstract eval failed: {type(e).__name__}: {e}"))
    return out


def run_contracts() -> List[Finding]:
    """All three contract families; import errors become findings so the CLI
    stays usable in stripped-down environments."""
    out: List[Finding] = []
    for fn in (check_compressor_grid, check_payload_accounting,
               check_kernel_budgets):
        try:
            out.extend(fn())
        except ImportError as e:
            out.append(_finding(
                "RC000", "src/repro/lint/contracts.py",
                f"{fn.__name__}: cannot import checked modules ({e}); "
                f"run with PYTHONPATH=src from the repo root"))
    return out
