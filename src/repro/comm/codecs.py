"""Wire-level payload codecs: packed buffers for every compressor family.

The seed repo *modeled* compression savings analytically (``payload_bits``);
this module makes them real: ``encode(compressor, key, x)`` produces the
actual packed planes a transport would ship, and ``decode`` reconstructs the
dense carrier **bit-for-bit equal** to ``compressor(key, x)``.  Byte counts
therefore come from real buffers, not a formula — the CommLedger records
``payload.nbytes`` and the analytic model is only a cross-check.

Schemes (selected by the compressor's ``wire`` spec, overridable):

  dense         fp32 value plane (identity / uncompressed sync)
  sparse_idx32  uint32 global indices + fp32 values — 64 bits per kept
                coordinate, the format the paper's Fig 2.2 counting assumes
                (top-k, rand-k, mix, comp)
  sparse_block  per-block bitpacked local indices (ceil(log2 block) bits) +
                fp32 values + uint16 per-block counts (block top-k)
  sparse_bitmap presence bitmap (1 bit/coordinate, Pallas pack_mask kernel)
                + fp32 values — smaller than idx32 whenever k/d > 1/32
  quant         int8 plane (int4: two nibbles per byte) + per-block fp32
                scales; the ``kernel`` flavor is produced by the fused Pallas
                quantize-pack kernel

Encode/decode run at communication-round boundaries (host side, numpy for the
data-dependent gathers); the Pallas kernels cover the static-shape packing
that would run on-device.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compressors import Compressor, WireSpec


@dataclass
class Payload:
    """One encoded tensor as it would sit in a transport buffer.

    ``planes`` are the wire buffers (numpy, final dtypes); ``nbytes`` is their
    exact total — the single number every ledger entry and benchmark reports.
    Small per-message header fields (shape, scheme tag, gain) live in ``meta``
    and are excluded from ``nbytes``, matching the analytic model's convention.
    """
    scheme: str
    shape: tuple
    dtype: str
    planes: Dict[str, np.ndarray]
    meta: dict = field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return int(sum(p.nbytes for p in self.planes.values()))

    @property
    def nbits(self) -> int:
        return 8 * self.nbytes


# ---------------------------------------------------------------------------
# bit-stream helpers (little-endian, numpy — host-side transport packing)
# ---------------------------------------------------------------------------
def _pack_uint_stream(vals: np.ndarray, nbits: int) -> np.ndarray:
    """Pack unsigned ints < 2**nbits into a little-endian uint8 stream."""
    if vals.size == 0:
        return np.zeros((0,), np.uint8)
    bits = ((vals[:, None].astype(np.uint64) >> np.arange(nbits, dtype=np.uint64))
            & 1).astype(np.uint8).reshape(-1)
    return np.packbits(bits, bitorder="little")


def _unpack_uint_stream(buf: np.ndarray, n: int, nbits: int) -> np.ndarray:
    if n == 0:
        return np.zeros((0,), np.int64)
    bits = np.unpackbits(buf, bitorder="little")[: n * nbits].reshape(n, nbits)
    return (bits.astype(np.int64) << np.arange(nbits, dtype=np.int64)).sum(axis=1)


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------
def encode(c: Compressor, key, x, scheme: Optional[str] = None) -> Payload:
    """Compress ``x`` with ``c`` and pack the result into wire planes.

    The dense carrier ``y = c(key, x)`` is what the algorithm consumes; the
    payload is an exact packed representation of it: decode(encode(...)) == y.
    """
    spec = c.wire or WireSpec("dense")
    scheme = scheme or spec.scheme
    if scheme == "quant" and spec.axis == "kernel":
        # the fused Pallas path re-derives the planes from x with the same
        # noise; computing the dense carrier here would duplicate that pass
        return _encode_quant(None, x, spec, key)
    y = c(key, x)
    if scheme == "dense":
        return _encode_dense(y)
    if scheme == "sparse_idx32":
        return _encode_sparse_idx32(y)
    if scheme == "sparse_block":
        return _encode_sparse_block(y, spec.block)
    if scheme == "sparse_bitmap":
        return _encode_sparse_bitmap(y)
    if scheme == "quant":
        return _encode_quant(y, x, spec, key)
    raise ValueError(f"unknown wire scheme {scheme!r}")


def decode(p: Payload):
    """Reconstruct the dense compressed carrier from the wire planes."""
    if p.scheme == "dense":
        out = p.planes["values"].astype(p.meta.get("plane_dtype", p.dtype))
        return jnp.asarray(out.reshape(p.shape)).astype(p.dtype)
    if p.scheme == "sparse_idx32":
        flat = np.zeros(int(np.prod(p.shape)), np.float32)
        flat[p.planes["indices"].astype(np.int64)] = p.planes["values"]
        return jnp.asarray(flat.reshape(p.shape)).astype(p.dtype)
    if p.scheme == "sparse_block":
        return _decode_sparse_block(p)
    if p.scheme == "sparse_bitmap":
        return _decode_sparse_bitmap(p)
    if p.scheme == "quant":
        return _decode_quant(p)
    raise ValueError(f"unknown wire scheme {p.scheme!r}")


def roundtrip_equal(c: Compressor, key, x) -> bool:
    """decode(encode(x)) == compressor(x), elementwise exact."""
    y = c(key, x)
    y_hat = decode(encode(c, key, x))
    return bool(jnp.all(jnp.asarray(y) == jnp.asarray(y_hat)))


# ---------------------------------------------------------------------------
# per-scheme implementations
# ---------------------------------------------------------------------------
def _encode_dense(y) -> Payload:
    arr = np.asarray(y)
    return Payload("dense", tuple(arr.shape), str(arr.dtype),
                   {"values": arr.reshape(-1)},
                   {"plane_dtype": str(arr.dtype)})


def _encode_sparse_idx32(y) -> Payload:
    arr = np.asarray(y, np.float32).reshape(-1)
    idx = np.flatnonzero(arr)
    return Payload("sparse_idx32", tuple(np.shape(y)), str(np.asarray(y).dtype),
                   {"indices": idx.astype(np.uint32), "values": arr[idx]})


def _encode_sparse_block(y, block: int) -> Payload:
    arr = np.asarray(y, np.float32).reshape(-1)
    d = arr.shape[0]
    nbits = max(1, math.ceil(math.log2(block)))
    nb = -(-d // block)
    idx = np.flatnonzero(arr)
    counts = np.bincount(idx // block, minlength=nb).astype(np.uint16)
    local = (idx % block).astype(np.uint64)
    return Payload(
        "sparse_block", tuple(np.shape(y)), str(np.asarray(y).dtype),
        {"local_indices": _pack_uint_stream(local, nbits),
         "values": arr[idx],
         "block_counts": counts},
        {"block": block, "nbits": nbits})


def _decode_sparse_block(p: Payload):
    d = int(np.prod(p.shape))
    block, nbits = p.meta["block"], p.meta["nbits"]
    counts = p.planes["block_counts"].astype(np.int64)
    vals = p.planes["values"]
    local = _unpack_uint_stream(p.planes["local_indices"], int(counts.sum()), nbits)
    base = np.repeat(np.arange(counts.shape[0], dtype=np.int64) * block, counts)
    flat = np.zeros(d, np.float32)
    flat[base + local] = vals
    return jnp.asarray(flat.reshape(p.shape)).astype(p.dtype)


def _encode_sparse_bitmap(y) -> Payload:
    from repro.kernels import ops

    arr = np.asarray(y, np.float32).reshape(-1)
    d = arr.shape[0]
    idx = np.flatnonzero(arr)
    words = np.asarray(ops.pack_bits(jnp.asarray(arr != 0.0)))
    return Payload("sparse_bitmap", tuple(np.shape(y)), str(np.asarray(y).dtype),
                   {"mask_words": words, "values": arr[idx]},
                   {"d": d})


def _decode_sparse_bitmap(p: Payload):
    from repro.kernels import ops

    d = p.meta["d"]
    mask = np.asarray(ops.unpack_bits(jnp.asarray(p.planes["mask_words"]), d))
    # pack_bits uses a stride-W bit order; unpack restores flat order, so the
    # set bits enumerate kept coordinates in ascending flat index — the same
    # order flatnonzero produced the value plane in.
    flat = np.zeros(d, np.float32)
    flat[np.flatnonzero(mask)] = p.planes["values"]
    return jnp.asarray(flat.reshape(p.shape)).astype(p.dtype)


def _quant_scales(x, spec: WireSpec):
    """Recompute the compressor's per-block scales from the *input* tensor
    (the scales are derived data the receiver needs: they ride in the
    payload).  Mirrors each quantizer's blocking exactly."""
    s = 2 ** (spec.bits - 1) - 1
    x = jnp.asarray(x)
    if spec.axis == "last":
        last = x.shape[-1] if x.ndim else 1
        if x.ndim >= 1 and last % spec.block == 0:
            shaped = x.reshape(x.shape[:-1] + (last // spec.block, spec.block))
            scale = jnp.max(jnp.abs(shaped), axis=-1, keepdims=True) / s
        else:
            shaped = x
            scale = jnp.max(jnp.abs(x)) / s
        return jnp.where(scale == 0, 1.0, scale), shaped.shape
    flat = x.reshape(-1)
    d = flat.shape[0]
    nb = -(-d // spec.block)
    xp = jnp.pad(flat, (0, nb * spec.block - d)).reshape(nb, spec.block)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / s
    return jnp.where(scale == 0, 1.0, scale), (nb, spec.block)


def _store_q(q: np.ndarray, bits: int) -> np.ndarray:
    if bits <= 4:
        from repro.kernels import ops
        return np.asarray(ops.nibble_pack(jnp.asarray(q)))
    return q.astype(np.int8)


def _load_q(plane: np.ndarray, bits: int, n: int) -> np.ndarray:
    if bits <= 4:
        from repro.kernels import ops
        return np.asarray(ops.nibble_unpack(jnp.asarray(plane), n))
    return plane


def _encode_quant(y, x, spec: WireSpec, key) -> Payload:
    if spec.axis == "kernel":
        # fused Pallas quantize-pack: same padding + noise as the compressor's
        # quantize_dequantize, so q * scales == y bit-for-bit
        from repro.kernels import ops

        q, scales = ops.quantize_pack(jnp.asarray(x), key, bits=spec.bits)
        d = int(np.prod(np.shape(x)))
        return Payload(
            "quant", tuple(np.shape(x)), str(np.asarray(x).dtype),
            {"q": _store_q(np.asarray(q).reshape(-1)[: _q_keep(d, q.shape)], spec.bits),
             "scales": np.asarray(scales, np.float32).reshape(-1)},
            {"bits": spec.bits, "axis": "kernel", "gain": spec.gain,
             "rows": q.shape[0], "qblock": q.shape[1], "d": d})
    # derive the integer plane from the dense carrier: y = gain * q * scale,
    # so rint(y / (gain * scale)) recovers q exactly (error << 0.5)
    scale, shaped = _quant_scales(x, spec)
    y_shaped = _pad_like(jnp.asarray(y, jnp.float32), spec, shaped)
    q = jnp.rint(y_shaped / (scale * spec.gain)).astype(jnp.int32)
    s = 2 ** (spec.bits - 1) - 1
    q = jnp.clip(q, -s, s)
    qn = np.asarray(q, np.int8).reshape(-1)
    return Payload(
        "quant", tuple(np.shape(y)), str(np.asarray(y).dtype),
        {"q": _store_q(qn, spec.bits),
         "scales": np.asarray(scale, np.float32).reshape(-1)},
        {"bits": spec.bits, "axis": spec.axis, "gain": spec.gain,
         "qshape": tuple(q.shape), "scale_shape": tuple(np.shape(scale)),
         "d": int(np.prod(np.shape(y)))})


def _q_keep(d: int, qshape) -> int:
    # the kernel plane is row-padded; ship only rows that carry data
    rows_used = -(-d // qshape[1])
    return rows_used * qshape[1]


def _pad_like(y_flat, spec: WireSpec, shaped):
    """View the dense carrier in the quantizer's block layout."""
    if spec.axis == "last":
        return y_flat.reshape(shaped)
    d = y_flat.reshape(-1).shape[0]
    nb, block = shaped
    return jnp.pad(y_flat.reshape(-1), (0, nb * block - d)).reshape(nb, block)


def _decode_quant(p: Payload):
    d = p.meta["d"]
    gain = p.meta["gain"]
    if p.meta["axis"] == "kernel":
        rows, qb = p.meta["rows"], p.meta["qblock"]
        kept = _q_keep(d, (rows, qb))
        q = np.zeros((rows * qb,), np.int8)
        q[:kept] = _load_q(p.planes["q"], p.meta["bits"], kept)
        q = q.reshape(rows, qb).astype(np.float32)
        scales = p.planes["scales"].reshape(rows, 1)
        out = (q * scales).reshape(-1)[:d]
        if gain != 1.0:
            out = gain * out
        return jnp.asarray(out.reshape(p.shape)).astype(p.dtype)
    qshape = p.meta["qshape"]
    n = int(np.prod(qshape))
    q = _load_q(p.planes["q"], p.meta["bits"], n).reshape(qshape).astype(np.float32)
    scales = p.planes["scales"].reshape(p.meta["scale_shape"])
    out = q * scales
    if gain != 1.0:
        out = gain * out
    if p.meta["axis"] == "last":
        return jnp.asarray(out.reshape(p.shape)).astype(p.dtype)
    return jnp.asarray(out.reshape(-1)[:d].reshape(p.shape)).astype(p.dtype)


# ---------------------------------------------------------------------------
# size model
# ---------------------------------------------------------------------------
def encoded_bits(c: Compressor, key, x, scheme: Optional[str] = None) -> int:
    """Exact wire bits for one message (encode and count)."""
    return encode(c, key, x, scheme=scheme).nbits


def analytic_bits(c: Compressor, d: int) -> float:
    """The seed's closed-form model, kept as a cross-check target."""
    return c.payload_bits(d)
