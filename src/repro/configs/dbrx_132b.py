"""DBRX-132B. [hf:databricks/dbrx-base]

Fine-grained MoE: 16 experts, top-4 routing (more, smaller experts than
Mixtral-style designs), GQA kv=8.  Full causal attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="dbrx-132b",
        family="moe",
        citation="hf:databricks/dbrx-base",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab_size=100352,
        mlp_act="silu",
        mlp_gated=True,
        moe=MoEConfig(num_experts=16, top_k=4),
        rope_theta=500000.0,
        supports_long_context=False,
    )
)
