"""Lossy-link transmission: seal → send → verify → retry with backoff.

``transmit`` simulates one child→parent message on a faulty link: the payload
is sealed (CRC32 per plane, ``codecs.seal_payload``), each attempt may be
dropped or corrupted per the counter PRNG — the *same* draws
``FaultModel.attempt_outcomes`` uses, so a wire-level simulation and a
plan-level one agree decision-for-decision — and corrupted deliveries are
caught by ``verify_payload`` at the receiver and retransmitted after
exponential backoff.  Every attempt's bytes are charged to the
``CommLedger``: the first under the level's tag, retransmissions under
``"retry"``, so degraded rounds show exactly where the extra bytes went.

``repro.comm`` imports stay function-level: ``faults.model`` is
stdlib+numpy, and this module only touches codecs/ledger when actually
transmitting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.faults.model import FaultConfig, counter_uniform

# mirrors repro.comm.ledger.RETRY_TAG — importing the ledger here would close
# a cycle (comm.tree imports faults.model); tests pin the two values equal
RETRY_TAG = "retry"


def expected_transmissions(loss_rate: float, max_retries: int) -> float:
    """E[attempts] with per-attempt loss ``q`` and up to ``max_retries``
    retransmissions: ``sum_{k=0..R} q^k``."""
    q = min(1.0, max(0.0, loss_rate))
    return sum(q ** k for k in range(max_retries + 1))


def corrupt_payload(p, rnd: int = 0, lane: int = 0, seed: int = 0):
    """Deterministically flip one byte of the payload's largest plane
    (in place) — the canonical injected wire fault for tests/CI.  Returns
    the name of the corrupted plane, or None if every plane is empty."""
    target = None
    for k, v in p.planes.items():
        if v.nbytes and (target is None or v.nbytes > p.planes[target].nbytes):
            target = k
    if target is None:
        return None
    buf = np.ascontiguousarray(p.planes[target]).view(np.uint8).copy()
    pos = int(counter_uniform(seed, rnd, "corrupt_at", 1, lane=lane)[0]
              * buf.size) % buf.size
    buf[pos] ^= np.uint8(0xFF)
    plane = p.planes[target]
    p.planes[target] = buf.view(plane.dtype).reshape(plane.shape)
    return target


@dataclass
class TransmitResult:
    delivered: bool
    attempts: int            # transmissions actually made
    n_dropped: int           # attempts lost in flight
    n_corrupt: int           # attempts delivered damaged (checksum caught)
    backoff_s: float         # total backoff waited before retries
    payload: Optional[object]  # the verified payload, or None if lost
    error: Optional[str] = None  # last PayloadError message, if any


def transmit(payload, cfg: FaultConfig, *, rnd: int, level_name: str,
             n_children: int, child: int, ledger=None, link: str = "",
             kind: str = "inter", phase: int = 0,
             tag: str = "") -> TransmitResult:
    """Send ``payload`` over ``level_name``'s link for child ``child``.

    Each attempt redraws from the counter PRNG at lane
    ``attempt * n_children + child`` (bit-identical to
    ``FaultModel.attempt_outcomes``).  A dropped attempt delivers nothing; a
    corrupted one delivers a byte-flipped copy the receiver's
    ``verify_payload`` rejects.  Both trigger a retransmission after
    exponential backoff, up to ``cfg.max_retries`` retries.
    """
    from repro.comm.codecs import PayloadError, seal_payload, verify_payload

    lf = cfg.link_faults(level_name)
    seal_payload(payload)
    base_tag = tag or level_name
    link = link or f"{level_name}/child{child}"
    n_dropped = n_corrupt = 0
    backoff_s = 0.0
    last_err: Optional[str] = None
    for attempt in range(cfg.max_retries + 1):
        if attempt > 0:
            backoff_s += cfg.backoff_s * cfg.backoff_mult ** (attempt - 1)
        if ledger is not None:
            ledger.record(rnd, link, payload.nbytes, kind=kind, phase=phase,
                          tag=base_tag if attempt == 0 else RETRY_TAG)
        u = float(counter_uniform(cfg.seed, rnd, f"{level_name}/xmit", 1,
                                  lane=attempt * n_children + child)[0])
        if u < lf.drop_rate:
            n_dropped += 1
            continue
        if u < lf.drop_rate + lf.corrupt_rate:
            import copy as _copy
            wire = _copy.deepcopy(payload)
            hit = corrupt_payload(wire, rnd=rnd,
                                  lane=attempt * n_children + child,
                                  seed=cfg.seed)
            if hit is None:  # nothing corruptible to flip: counts as a drop
                n_dropped += 1
                continue
            try:
                verify_payload(wire)
            except PayloadError as e:
                n_corrupt += 1
                last_err = str(e)
                continue
            raise AssertionError("corrupted plane passed checksum verify")
        verify_payload(payload)
        return TransmitResult(True, attempt + 1, n_dropped, n_corrupt,
                              backoff_s, payload, last_err)
    return TransmitResult(False, cfg.max_retries + 1, n_dropped, n_corrupt,
                          backoff_s, None, last_err)
