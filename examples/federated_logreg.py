"""Federated convex benchmark: EF-BV vs EF21 vs DIANA, and Scafflix vs GD.

Reproduces the qualitative behaviour of Fig 2.2 and Fig 3.1 in one script:

    PYTHONPATH=src python examples/federated_logreg.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommLedger, encode
from repro.core import compressors as C
from repro.core.ef_bv import efbv_gd, efbv_init, efbv_params
from repro.core.scafflix import (flix_objective, flix_optimum, local_optimum,
                                 logreg_grads, scafflix_init, scafflix_run)
from repro.core.sppm import solve_erm
from repro.data.federated import make_logreg_clients


def main():
    prob = make_logreg_clients(n_clients=16, m=100, d=40, mu=0.1, hetero=0.5, seed=0)
    A, b = jnp.asarray(prob.A), jnp.asarray(prob.b)
    n, _, d = A.shape
    Ls = prob.smoothness()
    L, Lt = float(np.mean(Ls)), float(np.sqrt(np.mean(Ls**2)))
    x_star = solve_erm(prob)

    def f_fn(x):
        z = jnp.einsum("nmd,d->nm", A, x)
        return jnp.mean(jnp.log1p(jnp.exp(-b * z))) + 0.5 * prob.mu * jnp.sum(x**2)

    f_star = float(f_fn(jnp.asarray(x_star)))
    grad_fn = lambda x: logreg_grads(jnp.tile(x[None], (n, 1)), A, b, prob.mu)

    print("== Ch.2: EF-BV family, rand-k(10%), 800 rounds ==")
    comp = C.rand_k(0.1)
    # size of one encoded per-client payload (exact wire bytes, repro.comm)
    msg_bytes = encode(comp, jax.random.PRNGKey(7),
                       jax.random.normal(jax.random.PRNGKey(8), (d,))).nbytes
    for mode in ("efbv", "ef21", "diana"):
        lam, nu = efbv_params(comp, n, mode)
        om_ran = comp.omega / n if mode in ("efbv", "diana") else comp.omega
        gamma = C.efbv_stepsize(L, Lt, comp.eta, comp.omega, om_ran, lam, nu)
        _, _, tr = efbv_gd(jax.random.PRNGKey(0), jnp.zeros(d), grad_fn,
                           efbv_init(n, d), comp, lam, nu, gamma, 800, f_fn)
        gaps = np.asarray(tr) - f_star
        hit = np.argmax(gaps < 1e-3) if (gaps < 1e-3).any() else -1
        ledger = CommLedger.from_rounds(msg_bytes,
                                        len(gaps) if hit < 0 else hit + 1)
        msg = (f"bits-to-1e-3 = {ledger.cumulative_bytes()[hit] * 8}" if hit >= 0
               else f"gap {gaps[-1]:.2e}")
        print(f"  {mode:6s} lam={lam:.3f} nu={nu:.3f} gamma={gamma:.4f}  {msg}")

    print("== Ch.3: Scafflix double acceleration (p=0.2) ==")
    x_loc = jnp.stack([local_optimum(A[i], b[i], prob.mu) for i in range(n)])
    for alpha in (0.1, 0.5, 0.9):
        alphas = jnp.full((n,), alpha)
        xf = flix_optimum(A, b, prob.mu, alphas, x_loc, steps=20000)
        fstar = float(flix_objective(xf, A, b, prob.mu, alphas, x_loc))
        st = scafflix_init(jnp.ones(d), n, x_loc)
        ev = lambda s: flix_objective(jnp.mean(s.x, 0), A, b, prob.mu, alphas, x_loc)
        _, (tr, comms) = scafflix_run(jax.random.PRNGKey(1), st,
                                      lambda xt: logreg_grads(xt, A, b, prob.mu),
                                      0.2, jnp.asarray(1.0 / Ls), alphas, 400, ev)
        gaps = np.asarray(tr) - fstar
        print(f"  alpha={alpha}: gap after 400 rounds ({int(np.sum(np.asarray(comms)))} comms) "
              f"= {gaps[-1]:.2e}")
    print("(smaller alpha = more personalization = faster, matching Fig 3.1)")


if __name__ == "__main__":
    main()
