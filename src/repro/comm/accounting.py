"""Round-level communication accounting on top of the codecs + topology.

Replaces the ad-hoc analytic bits computations that each algorithm carried
(``distributed.bits_per_round``, per-bench counters): byte counts come from
*encoding an actual payload* with the configured compressor's codec, and the
topology simulator turns them into per-round wall-clock.

Measured sizes are obtained on a probe tensor.  Payload size per coordinate
is constant for every registered compressor (fixed k, fixed quant blocks), so
for very large models the probe is capped and the measured bits/coordinate is
scaled linearly — still codec-measured, never the closed-form model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax

from repro.comm import codecs
from repro.comm.topology import (DEFAULT_PROFILE, DEFAULT_TILE_BYTES,
                                 CodecProfile, Topology, get_topology)

PROBE_CAP = 1 << 20  # max coordinates actually encoded when sizing a round


@dataclass(frozen=True)
class RoundCost:
    """One synchronization round, per worker: encoded traffic + simulated time."""
    mode: str
    n_params: int
    intra_bytes: float       # fast-fabric bytes per device per round
    inter_bytes: float       # slow-link bytes per device per round
    time_s: float            # simulated wall-clock of the round (streamed
                             # pipeline when tile_bytes > 0, else serial)
    encoded_bits: float      # per-node payload bits per round (amortized)
    analytic_bits: float     # the seed's closed-form model (cross-check)
    serial_time_s: float = 0.0   # monolithic pack -> send -> unpack wall-clock
    tile_bytes: int = 0          # streamed transport tile (0 = monolithic)

    @property
    def total_bytes(self) -> float:
        return self.intra_bytes + self.inter_bytes

    @property
    def stream_speedup(self) -> float:
        return self.serial_time_s / self.time_s if self.time_s > 0 else 1.0


def measured_payload_bits(sync, n_params: int, key=None) -> float:
    """Encode a probe gradient with the configured compressor; exact bits."""
    from repro.core.distributed import build_compressor

    c = build_compressor(sync)
    key = key if key is not None else jax.random.PRNGKey(0)
    probe_d = min(int(n_params), PROBE_CAP)
    x = jax.random.normal(jax.random.fold_in(key, 1), (probe_d,))
    bits = codecs.encoded_bits(c, key, x)
    return bits * (n_params / probe_d)


def round_cost(sync, n_params: int, topology: Optional[Topology] = None,
               key=None, profile: Optional[CodecProfile] = None) -> RoundCost:
    """Per-round, per-worker communication of one sync mode.

    dense       every round: full fp32 payload on the slow links
    efbv/ef21/diana  every round: encoded compressed delta on the slow links
    local       full fp32 payload every sync_period rounds (amortized)
    hier        dense fp32 intra-pod every round + encoded compressed delta
                inter-pod every sync_period rounds (Cohort-Squeeze)

    Compressed payloads pay the codec: ``serial_time_s`` is the monolithic
    pack -> collective -> unpack sum; ``time_s`` is the streamed pipeline
    (``SyncConfig.stream_tile_bytes``-sized tiles overlapping the three
    stages) when streaming is enabled, otherwise the serial time.
    """
    from repro.core.distributed import build_compressor

    topo = topology or get_topology(getattr(sync, "topology", "v5p_superpod"))
    period = max(1, sync.sync_period)
    tile_bytes = int(getattr(sync, "stream_tile_bytes", DEFAULT_TILE_BYTES))
    prof = profile or DEFAULT_PROFILE
    dense_bytes = 4.0 * n_params
    if sync.mode in ("dense", "local"):
        enc_bits = 32.0 * n_params  # fp32 on the wire, no compressor
    else:
        enc_bits = measured_payload_bits(sync, n_params, key=key)
    enc_bytes = enc_bits / 8.0

    def _enc_times(nbytes, scope):
        """(serial, streamed) wall-clock of one encoded collective."""
        serial = topo.allreduce_serial_time_s(nbytes, scope, prof)
        if tile_bytes <= 0:
            return serial, serial
        return serial, topo.allreduce_stream_time_s(nbytes, scope, tile_bytes,
                                                    prof)

    if sync.mode == "dense":
        intra, inter = 0.0, dense_bytes
        serial_s = stream_s = topo.allreduce_time_s(dense_bytes, scope="global")
        bits = 8.0 * dense_bytes
    elif sync.mode in ("efbv", "ef21", "diana"):
        intra, inter = 0.0, enc_bytes
        serial_s, stream_s = _enc_times(enc_bytes, "global")
        bits = enc_bits
    elif sync.mode == "local":
        intra, inter = 0.0, dense_bytes / period
        serial_s = stream_s = (
            topo.allreduce_time_s(dense_bytes, scope="global") / period)
        bits = 8.0 * dense_bytes / period
    elif sync.mode == "hier":
        intra = dense_bytes
        inter = enc_bytes / period
        t_intra = topo.allreduce_time_s(dense_bytes, scope="intra")
        t_ser, t_str = _enc_times(enc_bytes, "inter")
        serial_s = t_intra + t_ser / period
        stream_s = t_intra + t_str / period
        bits = enc_bits / period
    else:
        raise KeyError(f"unknown sync mode {sync.mode!r}")

    c = build_compressor(sync)
    analytic = codecs.analytic_bits(c, n_params)
    if sync.mode == "hier":
        analytic = analytic / period
    if sync.mode == "local":
        analytic = 32.0 * n_params / period
    if sync.mode == "dense":
        analytic = 32.0 * n_params  # fp32, no compressor on the wire
    # codec-free modes (dense/local fp32 wires) have nothing to stream:
    # report tile_bytes=0 so consumers don't claim a pipeline that isn't there
    if sync.mode in ("dense", "local"):
        tile_bytes = 0
    return RoundCost(sync.mode, n_params, intra, inter,
                     stream_s if tile_bytes > 0 else serial_s,
                     bits, analytic, serial_time_s=serial_s,
                     tile_bytes=max(0, tile_bytes))


def round_bits(sync, n_params: int) -> float:
    """Per-round, per-node encoded payload bits (the Fig 2.2 y-axis unit).

    This is what ``distributed.bits_per_round`` now wraps: measured from the
    codec's packed buffers, amortized over the sync period per mode.
    """
    return round_cost(sync, n_params).encoded_bits
