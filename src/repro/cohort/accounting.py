"""Per-cohort byte attribution: analytic class formulas, oracle-checked.

At 10^5 leaves, encoding every client's payload to count bytes would cost
more than the round itself.  But every registered codec's wire size is a
*deterministic* function of the input dimension — top-k keeps exactly
ceil(ratio*d) coordinates, qsgd packs d values at a fixed bit width — so one
probe encode per (class, level) yields an exact per-message byte count, and
a cohort round's traffic is just

    level 0:  sum_k  |survivors in class k| * class_k_message_bytes
    level l:  |survivors at level l|       * level_l_message_bytes

``materialized_round_bytes`` is the small-N oracle: it performs a real
``codecs.encode`` per message and must agree byte-for-byte with the analytic
attribution (the cross-check ``tests/test_cohort.py`` and ``bench_cohort``
both assert).  Ledger records tag each level by name (registered by
``TreeTopology``), with level-0 links split per link class.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.comm import codecs
from repro.comm.ledger import CommLedger
from repro.comm.tree import TreeTopology
from repro.core.compressors import Compressor

from repro.cohort.population import LinkClass


def message_nbytes(c: Compressor, dim: int, key=None) -> int:
    """Exact wire bytes of one dim-sized message through compressor ``c``.

    Deterministic in ``dim`` for every registered compressor (plane shapes
    depend only on the input size), so one probe encode prices every message
    of the round.  ``dim`` must stay under the accounting probe cap — cohort
    models are small vectors, so this is not a practical limit.
    """
    from repro.comm.accounting import PROBE_CAP

    if dim > PROBE_CAP:
        raise ValueError(f"dim {dim} exceeds the probe cap {PROBE_CAP}; "
                         "per-message bytes would no longer be probe-exact")
    key = key if key is not None else jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (dim,))
    return int(codecs.encode(c, key, x).nbytes)


@dataclass(frozen=True)
class CohortRoundBytes:
    """One cohort round's uplink traffic, attributed per level and class."""
    round: int
    leaf_class_counts: Tuple[int, ...]   # surviving leaves per link class
    leaf_class_nbytes: Tuple[int, ...]   # total bytes per link class
    upper_counts: Tuple[int, ...]        # surviving senders per upper level
    upper_nbytes: Tuple[int, ...]        # total bytes per upper level

    @property
    def leaf_bytes(self) -> int:
        return int(sum(self.leaf_class_nbytes))

    @property
    def total_bytes(self) -> int:
        return self.leaf_bytes + int(sum(self.upper_nbytes))

    def by_level(self, tree: TreeTopology) -> Dict[str, int]:
        out = {tree.levels[0].name: self.leaf_bytes}
        for lev, b in zip(tree.levels[1:], self.upper_nbytes):
            out[lev.name] = int(b)
        return out


class CohortAccountant:
    """Prices cohort rounds analytically and records them into a ledger."""

    def __init__(self, tree: TreeTopology, classes: Sequence[LinkClass],
                 upper_compressors: Sequence[Compressor], dim: int):
        if len(upper_compressors) != len(tree.levels) - 1:
            raise ValueError(
                f"{len(upper_compressors)} upper compressors for "
                f"{len(tree.levels) - 1} upper tree levels")
        self.tree = tree
        self.classes = tuple(classes)
        self.dim = int(dim)
        self.class_nbytes = tuple(
            message_nbytes(lc.make_compressor(), dim) for lc in self.classes)
        self.upper_nbytes = tuple(
            message_nbytes(c, dim) for c in upper_compressors)

    def uplink_time_s(self, class_ids: np.ndarray) -> np.ndarray:
        """Per-leaf nominal uplink time: each class's payload on its link."""
        times = np.array([lc.link.time_s(nb) for lc, nb in
                          zip(self.classes, self.class_nbytes)])
        return times[np.asarray(class_ids)]

    def round_bytes(self, rnd: int, class_ids: np.ndarray,
                    survivor_masks: Optional[Sequence[np.ndarray]]
                    ) -> CohortRoundBytes:
        """Analytic traffic of one round: class/level counts x message bytes.

        ``survivor_masks`` is the per-level child mask tuple from the fault
        plan (None = full participation).  Dead children send nothing — their
        uplink attempt may have burned the physical channel, but the ledger
        accounts *delivered* aggregation traffic, matching the oracle which
        only encodes messages that reach a parent.
        """
        class_ids = np.asarray(class_ids)
        n_levels = len(self.tree.levels)
        if survivor_masks is None:
            masks = [np.ones(self.tree.n_children(l), bool)
                     for l in range(n_levels)]
        else:
            masks = [np.asarray(m) > 0 for m in survivor_masks]
        counts = np.bincount(class_ids[masks[0]],
                             minlength=len(self.classes))
        return CohortRoundBytes(
            round=rnd,
            leaf_class_counts=tuple(int(c) for c in counts),
            leaf_class_nbytes=tuple(int(c * nb) for c, nb in
                                    zip(counts, self.class_nbytes)),
            upper_counts=tuple(int(m.sum()) for m in masks[1:]),
            upper_nbytes=tuple(int(m.sum()) * nb for m, nb in
                               zip(masks[1:], self.upper_nbytes)),
        )

    def record(self, ledger: CommLedger, rb: CohortRoundBytes) -> None:
        """Ledger the round: level-0 links split per class, tagged by level
        name (``TreeTopology.__post_init__`` registered the tags)."""
        leaf = self.tree.levels[0]
        for lc, nb in zip(self.classes, rb.leaf_class_nbytes):
            if nb:
                ledger.record(rb.round, f"{leaf.name}->up/{lc.name}", nb,
                              kind="inter", tag=leaf.name)
        for lev, nb in zip(self.tree.levels[1:], rb.upper_nbytes):
            if nb:
                ledger.record(rb.round, f"{lev.name}->up", nb,
                              kind="inter", tag=lev.name)


def materialized_round_bytes(rnd: int, class_ids: np.ndarray,
                             classes: Sequence[LinkClass],
                             upper_compressors: Sequence[Compressor],
                             tree: TreeTopology, dim: int,
                             survivor_masks: Optional[Sequence[np.ndarray]]
                             ) -> int:
    """Small-N oracle: encode every delivered message for real, sum bytes.

    O(cohort) codec calls — run it at N <= a few hundred to certify the
    analytic attribution, never in the hot path.  Each message encodes a
    per-sender probe vector (sizes are content-independent, so any vector of
    the right dimension prices the message exactly).
    """
    class_ids = np.asarray(class_ids)
    n_levels = len(tree.levels)
    if survivor_masks is None:
        masks = [np.ones(tree.n_children(l), bool) for l in range(n_levels)]
    else:
        masks = [np.asarray(m) > 0 for m in survivor_masks]
    comps = [lc.make_compressor() for lc in classes]
    total = 0
    for i in np.flatnonzero(masks[0]):
        key = jax.random.fold_in(jax.random.PRNGKey(rnd), int(i))
        x = jax.random.normal(jax.random.fold_in(key, 1), (dim,))
        total += int(codecs.encode(comps[int(class_ids[i])], key, x).nbytes)
    for l, c in enumerate(upper_compressors, start=1):
        for i in np.flatnonzero(masks[l]):
            key = jax.random.fold_in(jax.random.PRNGKey(1000 * l + rnd),
                                     int(i))
            x = jax.random.normal(jax.random.fold_in(key, 1), (dim,))
            total += int(codecs.encode(c, key, x).nbytes)
    return total
