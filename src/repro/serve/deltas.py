"""Per-user personalized deltas stored as wire payloads.

Scafflix / FedP3 style personalization produces a *distinct* model per
client; a serving fleet cannot hold a full weight copy per user.  The delta
store keeps ONE base model plus, per user, the *wire payload* of a
compressed delta — the same packed planes (``repro.comm.codecs``) a trainer
would upload, typically kilobytes.

Coordinates: deltas live in the bucketized f32 space of ``comm.buckets`` —
the base tree is flattened once into ``(n_blocks, block_size)`` blocks and a
user's delta is the blockwise difference ``personalized - base``.  Blocks
are the pool pager's page unit (``serve.pool``), so a user whose
personalization touches a few leaves decodes to a few nonzero blocks.

Certification: ``delta_from_params`` refuses to store a payload unless
``decode(payload)`` is bit-for-bit equal to the compressor's own carrier
``c(key, delta)`` — the stored artifact provably loses nothing beyond the
compression itself.  Byte costs land on a :class:`CommLedger` under the
registered tags ``serve/page_out`` (trainer -> store, on ``put``) and
``serve/page_in`` (store -> pool, charged by the pager on a miss).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.buckets import BucketLayout, bucketize, debucketize
from repro.comm.codecs import Payload, decode, encode
from repro.comm.ledger import PAGE_OUT_TAG, CommLedger
from repro.core.compressors import Compressor, make_compressor

# Delta-block coordinates per page.  A multiple of every codec granule in the
# repo (quantizer blocks 256/512/2048, 32-bit mask words, Pallas QBLOCK=512),
# so page boundaries always align with wire-plane boundaries; small enough
# that a few-leaf personalization touches a few pages of a reduced config.
DEFAULT_BLOCK = 4096


class DeltaCertificationError(RuntimeError):
    """decode(payload) disagreed with the compressor's carrier bit-for-bit."""


def user_key(seed: int, user_id: int):
    """The per-user compression key: fold_in(PRNGKey(seed), user_id).

    Deterministic per (seed, user) so stochastic codecs (qsgd) round the same
    way on re-encode and certification can compare bitwise.
    """
    return jax.random.fold_in(jax.random.PRNGKey(int(seed)), int(user_id))


def delta_from_params(base_blocks, layout: BucketLayout, personalized,
                      compressor: Compressor, key) -> Payload:
    """Diff ``personalized`` against the base in block space, compress, pack.

    Returns the wire :class:`Payload`, certified bit-exact: the payload's
    decode equals ``compressor(key, delta)`` byte-for-byte, or raises
    :class:`DeltaCertificationError`.
    """
    pers_blocks, p_layout = bucketize(personalized, layout.bucket_size)
    if p_layout.shapes != layout.shapes:
        raise ValueError("personalized tree shape mismatch vs base: "
                         f"{p_layout.shapes} != {layout.shapes}")
    delta = (pers_blocks - base_blocks).reshape(-1)
    payload = encode(compressor, key, delta)
    carrier = np.asarray(compressor(key, delta))
    decoded = np.asarray(decode(payload))
    # elementwise exact, the same certificate as codecs.roundtrip_equal
    # (a quant dequant may emit -0.0 where the carrier has +0.0 — equal)
    if decoded.shape != carrier.shape or not np.all(decoded == carrier):
        raise DeltaCertificationError(
            f"decode(encode(delta)) != compressor carrier for {compressor.name}")
    return payload


def delta_blocks(payload: Payload, layout: BucketLayout) -> np.ndarray:
    """Decode a stored payload back to ``(n_blocks, block_size)`` f32 blocks."""
    carrier = np.asarray(decode(payload), dtype=np.float32)
    return carrier.reshape(layout.n_buckets, layout.bucket_size)


def params_from_delta(base_blocks, layout: BucketLayout, payload: Payload,
                      dtype=None):
    """Materialize the full personalized tree: debucketize(base + delta).

    The serving engine never calls this per-request — it applies the decoded
    blocks in the forward pass (``serve.engine``).  This is the oracle the
    bench certifies the engine against, and the escape hatch for exporting a
    user's model.
    """
    carrier = jnp.asarray(delta_blocks(payload, layout))
    return debucketize(base_blocks + carrier, layout, dtype=dtype)


class DeltaStore:
    """Base blocks + per-user compressed delta payloads + the byte ledger.

    The store is host-side: payloads are packed numpy planes (what a
    parameter server would hold); only the base blocks live on device.
    ``put`` charges ``serve/page_out`` for the trainer->store write; the pool
    pager charges ``serve/page_in`` on each miss it services from here.
    """

    def __init__(self, base_params, compressor: Optional[Compressor] = None,
                 block_size: int = DEFAULT_BLOCK, seed: int = 0,
                 ledger: Optional[CommLedger] = None):
        self.base_blocks, self.layout = bucketize(base_params, block_size)
        self.compressor = compressor or make_compressor("top_k", k_frac=0.01)
        self.seed = int(seed)
        self.ledger = ledger if ledger is not None else CommLedger()
        self._payloads: Dict[int, Payload] = {}
        self._events = 0

    # -- identity -----------------------------------------------------------
    def user_key(self, uid: int):
        return user_key(self.seed, uid)

    def __contains__(self, uid: int) -> bool:
        return int(uid) in self._payloads

    def __len__(self) -> int:
        return len(self._payloads)

    def user_ids(self) -> List[int]:
        return sorted(self._payloads)

    # -- write path ---------------------------------------------------------
    def put(self, uid: int, personalized_params) -> Payload:
        """Store user ``uid``'s model as a certified compressed delta."""
        uid = int(uid)
        payload = delta_from_params(self.base_blocks, self.layout,
                                    personalized_params, self.compressor,
                                    self.user_key(uid))
        return self.put_payload(uid, payload)

    def put_payload(self, uid: int, payload: Payload) -> Payload:
        """Store a pre-encoded delta payload (e.g. straight off the uplink)."""
        uid = int(uid)
        self._payloads[uid] = payload
        self.ledger.record(self._events, f"trainer->store/u{uid}",
                           payload.nbytes, kind="inter", tag=PAGE_OUT_TAG)
        self._events += 1
        return payload

    # -- read path ----------------------------------------------------------
    def payload(self, uid: int) -> Payload:
        return self._payloads[int(uid)]

    def nbytes(self, uid: int) -> int:
        return self._payloads[int(uid)].nbytes

    def blocks(self, uid: int) -> np.ndarray:
        """Decoded ``(n_blocks, block_size)`` delta blocks for ``uid``."""
        return delta_blocks(self._payloads[int(uid)], self.layout)

    def personalized_params(self, uid: int, dtype=None):
        """Materialize the user's full tree (oracle / export path)."""
        return params_from_delta(self.base_blocks, self.layout,
                                 self._payloads[int(uid)], dtype=dtype)

    def total_payload_bytes(self) -> int:
        return sum(p.nbytes for p in self._payloads.values())


def personalize_leaves(base_params, key, match: Iterable[str] = ("norm",),
                       scale: float = 0.05):
    """FedP3-style layer personalization: perturb only the leaves whose path
    mentions one of ``match`` (personalized layers); everything else stays at
    the base.  The resulting delta touches a handful of blocks — the regime
    the block pool is built for.  Bench/test generator, not a training path.
    """
    flat = jax.tree_util.tree_flatten_with_path(base_params)[0]
    treedef = jax.tree_util.tree_structure(base_params)
    pats = tuple(str(m).lower() for m in match)
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        name = jax.tree_util.keystr(path).lower()
        if any(p in name for p in pats):
            noise = jax.random.normal(jax.random.fold_in(key, i), leaf.shape,
                                      jnp.float32)
            leaf = (leaf.astype(jnp.float32)
                    + scale * noise).astype(leaf.dtype)
        leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)
