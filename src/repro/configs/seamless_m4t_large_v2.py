"""SeamlessM4T-large v2. [arXiv:2308.11596]

Encoder-decoder, multimodal speech/text.  The mel-spectrogram + conformer conv
feature extractor is the stubbed frontend (per the carve-out): input_specs()
provides precomputed frame embeddings of shape (B, S, 1024) which the 24-layer
transformer encoder consumes; the 24-layer decoder cross-attends to the
encoder memory.  vocab 256206 (NLLB unit+text vocabulary).
Decode shapes run against a precomputed encoder memory; long_500k skipped
(enc-dec full attention).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        citation="arXiv:2308.11596",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256206,
        enc_layers=24,
        enc_d_model=1024,
        cross_attn=True,
        audio_frontend=True,
        mlp_act="silu",
        mlp_gated=True,
        supports_long_context=False,
    )
)
