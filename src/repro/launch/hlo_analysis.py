"""Roofline-term extraction from compiled XLA artifacts.

``cost_analysis`` supplies HLO_FLOPs and HLO bytes; collective traffic is NOT
in cost_analysis, so we parse the post-SPMD optimized HLO text and sum the
bytes each collective moves per participating device:

    all-reduce          operand bytes  (ring: ~2x(g-1)/g x operand; we report
                        operand bytes as the canonical payload)
    all-gather          result/group   (each device contributes its shard)
    reduce-scatter      operand/group  x (group-1) ~ operand bytes scattered;
                        we count operand bytes / group x (group - 1)
    all-to-all          operand bytes x (group-1)/group
    collective-permute  operand bytes

Payload bytes are per-device; multiplying by the link count is the roofline
model's job (launch/roofline.py).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """'bf16[2048,4096]' -> bytes. Tuple types: sum of components."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(1, len(m.group(1).split(",")))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [ngroups, group_size]
        return max(1, int(m.group(2)))
    return 1


def _crosses_pod(line: str, pod_size: int = 256) -> bool:
    """True if any replica group spans the pod boundary (device ids on both
    sides of ``pod_size``) — i.e. the collective uses the slow inter-pod
    links. Unknown formats default to False (intra)."""
    m = re.search(r"replica_groups=\{\{([^=]*?)\}\}", line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(t) for t in grp.split(",") if t.strip().isdigit()]
            if ids and min(ids) < pod_size <= max(ids):
                return True
        return False
    # iota format [ngroups,gsize]<=[...] : a group crosses the pod iff its
    # id-stride pattern spans >= pod_size; conservative check via T() perm
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]", line)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        # contiguous iota: group g = [g*gsize, (g+1)*gsize) — crosses only if
        # gsize > pod_size; transposed iota (T(...)) strides across pods
        if "T(" in line[m.start():m.end() + 20]:
            return gsize > 1 and ngroups * gsize > pod_size
        return gsize > pod_size
    return False


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    count_by_kind: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    inter_pod_bytes: float = 0.0

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    def as_dict(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "inter_pod_bytes": float(self.inter_pod_bytes),
            "by_kind": {k: float(v) for k, v in self.bytes_by_kind.items()},
            "counts": dict(self.count_by_kind),
        }


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Parse optimized (post-SPMD) HLO text, sum per-device collective payload."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result line looks like: %x = bf16[..] all-reduce(...), replica_groups=..
        m = re.search(r"=\s*((?:\([^)]*\))|(?:[\w\[\],]+))\s+(" + "|".join(_COLLECTIVES) + r")[\s(.-]", ls)
        if not m:
            continue
        rtype, kind = m.group(1), m.group(2)
        if "-start" in ls and f"{kind}-start" not in ls:
            pass
        if f"{kind}-done" in ls:
            continue  # count the -start (or sync op), not the done
        rbytes = _shape_bytes(rtype)
        g = _group_size(ls)
        if kind == "all-reduce":
            payload = rbytes
        elif kind == "all-gather":
            payload = rbytes / max(g, 1)
        elif kind == "reduce-scatter":
            payload = rbytes * (g - 1) / max(g, 1) if g > 1 else rbytes
        elif kind == "all-to-all":
            payload = rbytes * (g - 1) / max(g, 1)
        else:  # collective-permute
            payload = rbytes
        stats.bytes_by_kind[kind] += payload
        stats.count_by_kind[kind] += 1
        if _crosses_pod(ls):
            stats.inter_pod_bytes += payload
    return stats


def cost_dict(compiled) -> dict:
    """Normalize compiled.cost_analysis() across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}


def memory_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out
