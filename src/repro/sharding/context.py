"""Trace-time sharding hooks.

The step builders (training/steps.py) are mesh-agnostic; the launcher installs
PartitionSpec pytrees here before lowering so internal tensors that XLA's
propagation gets wrong are pinned explicitly:

  * gradients — FSDP backward leaves weight grads replicated after the
    all-gathered matmul; without a constraint the f32 optimizer math then
    runs (and allocates) at full size. Constraining grads to the param spec
    turns that into the reduce-scatter + sharded-update pattern (ZeRO).
"""
from __future__ import annotations

from typing import Optional

import jax

_GRAD_SPECS = None
_MOE_SPECS = None


def set_grad_specs(specs) -> None:
    global _GRAD_SPECS
    _GRAD_SPECS = specs


def constrain_grads(grads):
    if _GRAD_SPECS is None:
        return grads
    return jax.tree_util.tree_map(
        lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, _GRAD_SPECS)


def set_moe_specs(specs: Optional[dict]) -> None:
    """{'impl': 'shardmap'|'scatter', 'mesh': Mesh, 'data_axes': tuple, plus
    optional 'tokens'/'expanded'/'buf' PartitionSpecs for the scatter path}.
    Installed by the launcher; None disables (tests/CPU)."""
    global _MOE_SPECS
    _MOE_SPECS = specs


def get_moe_specs() -> Optional[dict]:
    return _MOE_SPECS


# generic named constraint points (SSD head sharding, etc.)
_NAMED_SPECS: dict = {}


def set_named_specs(specs: Optional[dict]) -> None:
    global _NAMED_SPECS
    _NAMED_SPECS = specs or {}


def constrain_named(name: str, x):
    s = _NAMED_SPECS.get(name)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


def constrain_moe(name: str, x):
    if not _MOE_SPECS or name not in _MOE_SPECS:
        return x
    spec = _MOE_SPECS[name]
    sizes = dict(_MOE_SPECS["mesh"].shape)

    def ok(dim, ax):
        if ax is None:
            return None
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= sizes.get(a, 10**9)
        return ax if (dim % size == 0 and dim >= size) else None

    from jax.sharding import NamedSharding, PartitionSpec as P
    fixed = P(*[ok(d, a) for d, a in zip(x.shape, tuple(spec) + (None,) * x.ndim)])
    # NamedSharding works with or without an ambient mesh context
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MOE_SPECS["mesh"], fixed))


# §Perf variant switch read by the launchers when installing MoE specs
_MOE_GATHER_QUANT = False


def set_moe_gather_quant(v: bool) -> None:
    global _MOE_GATHER_QUANT
    _MOE_GATHER_QUANT = bool(v)


def get_moe_gather_quant() -> bool:
    return _MOE_GATHER_QUANT


_MOE_IMPL_OVERRIDE = None


def set_moe_impl_override(v) -> None:
    global _MOE_IMPL_OVERRIDE
    _MOE_IMPL_OVERRIDE = v


def get_moe_impl_override():
    return _MOE_IMPL_OVERRIDE
