"""Unit-level model tests: attention variants, SSD math, MoE dispatch, RoPE."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container lacks hypothesis: deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.models import attention as attn
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.layers import apply_rope, cross_entropy_loss, rmsnorm, init_rmsnorm
from repro.configs.base import MambaConfig


# ---------------------------------------------------------------------------
# flash attention == naive attention (all mask kinds)
# ---------------------------------------------------------------------------
def _naive_attention(q, k, v, kind, window, chunk):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, S, KV, G, hd) / math.sqrt(hd)
    s = jnp.einsum("bqkgh,bnkh->bqkgn", qf, k.astype(jnp.float32))
    qi, ki = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    ok = qi >= ki
    if kind == "attn_swa":
        ok &= (qi - ki) < window
    if kind == "attn_chunk":
        ok &= (qi // chunk) == (ki // chunk)
    s = jnp.where(ok[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgn,bnkh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd)


@pytest.mark.parametrize("kind,window,chunk", [
    ("attn", 0, 0), ("attn_swa", 8, 0), ("attn_chunk", 0, 16)])
@pytest.mark.parametrize("S", [24, 64])
def test_flash_matches_naive(kind, window, chunk, S):
    B, H, KV, hd = 2, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = attn._flash_attention(q, k, v, kind, window, chunk, block_q=16, block_k=16)
    exp = _naive_attention(q, k, v, kind, window, chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("kind,window,chunk,S", [
    ("attn_swa", 24, 0, 96), ("attn_chunk", 0, 32, 96),
    ("attn_swa", 8, 0, 64), ("attn_chunk", 0, 16, 40)])
def test_banded_flash_matches_naive(kind, window, chunk, S):
    """The §Perf banded-flash optimization is numerically identical to the
    full masked sweep (it only skips provably-masked KV blocks)."""
    B, H, KV, hd = 2, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    exp = _naive_attention(q, k, v, kind, window, chunk)
    old = attn.BANDED
    try:
        attn.BANDED = True
        got = attn._flash_attention(q, k, v, kind, window, chunk,
                                    block_q=16, block_k=16)
    finally:
        attn.BANDED = old
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5)


def test_flash_irregular_sizes():
    """Padding path: S not divisible by blocks."""
    B, S, H, KV, hd = 1, 37, 2, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = attn._flash_attention(q, k, v, "attn", 0, 0, block_q=16, block_k=16)
    exp = _naive_attention(q, k, v, "attn", 0, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


# ---------------------------------------------------------------------------
# SSD chunked scan == naive recurrence
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**10), chunk=st.sampled_from([4, 8]))
def test_ssd_matches_recurrence(seed, chunk):
    B, S, H, hd, N = 1, 24, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, 1, N))
    C_ = jax.random.normal(ks[4], (B, S, 1, N))
    D = jnp.ones((H,))
    y, state = mamba_lib._ssd_chunked(x, dt, A, B_, C_, D, chunk)

    # naive sequential recurrence
    h = np.zeros((B, H, hd, N))
    xs, dts = np.asarray(x), np.asarray(dt)
    Bs, Cs = np.asarray(B_), np.asarray(C_)
    ys = np.zeros((B, S, H, hd))
    for t in range(S):
        da = np.exp(dts[:, t] * np.asarray(A))            # (B,H)
        h = h * da[..., None, None] + np.einsum(
            "bh,bhd,bn->bhdn", dts[:, t], xs[:, t], Bs[:, t, 0])
        ys[:, t] = np.einsum("bhdn,bn->bhd", h, Cs[:, t, 0]) + xs[:, t]
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-3)
    np.testing.assert_allclose(np.asarray(state), h, atol=1e-3)


# ---------------------------------------------------------------------------
# MoE dispatch properties
# ---------------------------------------------------------------------------
def test_moe_no_drop_routes_everything():
    E, K, d = 4, 2, 32
    params = moe_lib.init_moe(jax.random.PRNGKey(0), d, 64, E, True, False, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    y, aux = moe_lib.moe_ffn(params, x, num_experts=E, top_k=K, capacity_factor=1.0,
                             act="silu", gated=True, shared_expert=False, no_drop=True)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-5  # Switch aux loss lower bound is 1 at balance


def test_moe_capacity_drops_tokens():
    """With tiny capacity, outputs must differ from the no-drop result."""
    E, K, d = 4, 1, 16
    params = moe_lib.init_moe(jax.random.PRNGKey(0), d, 32, E, True, False, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, d))
    kw = dict(num_experts=E, top_k=K, act="silu", gated=True, shared_expert=False)
    y_full, _ = moe_lib.moe_ffn(params, x, capacity_factor=4.0, **kw)
    y_tight, _ = moe_lib.moe_ffn(params, x, capacity_factor=0.25, **kw)
    assert float(jnp.max(jnp.abs(y_full - y_tight))) > 1e-6


def test_moe_matches_dense_expert_sum():
    """no_drop top-E routing == weighted sum over all experts computed densely."""
    E, d, ff = 3, 16, 24
    params = moe_lib.init_moe(jax.random.PRNGKey(2), d, ff, E, True, False, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 5, d))
    y, _ = moe_lib.moe_ffn(params, x, num_experts=E, top_k=E, capacity_factor=1.0,
                           act="silu", gated=True, shared_expert=False, no_drop=True)
    xt = x.reshape(-1, d)
    logits = xt @ params["router"]
    w = jax.nn.softmax(logits, -1)
    dense = jnp.zeros_like(xt)
    for e in range(E):
        h = xt @ params["w_in"][e]
        g = xt @ params["w_gate"][e]
        dense += w[:, e:e + 1] * ((jax.nn.silu(g) * h) @ params["w_out"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, d)), np.asarray(dense),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------
def test_rope_preserves_norm_and_relativity():
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 1, hd))
    pos = jnp.array([[0, 1, 5, 9]])
    out = apply_rope(q, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(out, axis=-1)),
                               np.asarray(jnp.linalg.norm(q, axis=-1)), rtol=1e-5)
    # relative property: <R(p)q, R(p+k)v> depends only on k
    v = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def dot_at(p1, p2):
        a = apply_rope(q[:, :1], jnp.array([[p1]]), 10000.0)
        b = apply_rope(v, jnp.array([[p2]]), 10000.0)
        return float(jnp.sum(a * b))
    assert abs(dot_at(3, 7) - dot_at(10, 14)) < 1e-3


def test_rmsnorm_scale():
    p = init_rmsnorm(8, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 8)) * 100
    y = rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y**2, -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-4)


def test_cross_entropy_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 5))
    targets = jnp.array([[0, 1, 2], [3, 4, 0]])
    got = float(cross_entropy_loss(logits, targets))
    p = jax.nn.log_softmax(logits, -1)
    exp = -float(jnp.mean(jnp.take_along_axis(p, targets[..., None], -1)))
    assert abs(got - exp) < 1e-5
