"""The cohort engine: one federated round over 10^5-10^6 clients, one jit.

The per-client ``tree_param_sync`` loop is exact but materializes every
client; cross-device rounds touch a *cohort* sampled from a population three
orders of magnitude larger.  The engine runs the whole round — broadcast,
per-client FLIX/Scafflix local steps, per-class compressed uplink, the full
anchor cascade — as a single jitted sweep over stacked per-client state:

* clients exist only while sampled (``sample_cohort`` Feistel ids +
  ``Population.client_spec`` lane derivations), so host/device memory scales
  with the cohort, never the population;
* ragged local-step counts run as a few static-shape ``lax.scan``s over
  tensor2tensor-style size buckets instead of one scan padded to the max;
* heterogeneous link classes compress through ``tree_param_sync``'s
  ``leaf_compress`` hook — a one-hot mixture of per-class fused compressor
  passes — while metro/WAN levels run the stock cascade;
* participation comes from ``FaultModel.round_plan`` addressed by the
  sampled clients' *population* ids (``leaf_lanes``), so every round —
  cohort, faults, and sweep noise — replays from ``(seed, round)`` alone.

Semantics are the *stateless-client* cross-device model: a sampled client
starts from its cell aggregator's anchor (clients keep no state between the
rare rounds they are sampled).  With full participation this is bitwise
identical to driving the per-client loop on the same cohort — the N=16
bit-exactness gate in ``tests/test_cohort.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.ledger import CommLedger
from repro.comm.tree import TreeTopology, get_tree_topology
from repro.core import compressors as comp_lib
from repro.core import distributed as dist
from repro.core.compressors import Compressor
from repro.core.distributed import CascadeLevel, TreeSyncState
from repro.faults.model import FaultConfig, FaultModel, RoundFaultPlan

from repro.cohort.accounting import CohortAccountant, CohortRoundBytes
from repro.cohort.population import (CohortBuckets, Population,
                                     bucket_boundaries, bucket_by_size,
                                     bucket_capacities, cohort_compressor,
                                     sample_cohort)


def flix_local_step(x, target, alpha, lr):
    """One FLIX/Scafflix local step on the quadratic client objective.

    The client's personalized model is ``x~ = alpha*x + (1-alpha)*x_i*``
    (Ch. 6's explicit mixture); its local loss ``0.5*||x~ - x_i*||^2`` has
    gradient ``alpha*(x~ - x_i*)`` in ``x``, so the step contracts ``x``
    toward the local optimum at rate ``lr * alpha^2`` — alpha=1 is pure
    FedAvg-style local SGD, alpha -> 0 leaves the global model untouched
    (a fully personalized client has nothing to learn from the server).
    Elementwise, so the vectorized sweep and the per-client reference loop
    produce bitwise-identical iterates.
    """
    x_t = alpha * x + (1.0 - alpha) * target
    return x - lr * (alpha * (x_t - target))


def _make_cohort_sweep(levels: Tuple[CascadeLevel, ...], dim: int,
                       boundaries: Tuple[int, ...], lr: float,
                       n_link_classes: int,
                       class_compressors: Tuple[Compressor, ...]):
    """Build the round sweep for ``jax.jit`` (jit factory idiom).

    Everything shape-like — cascade levels, bucket boundaries/capacities,
    link-class count — is closed over statically; per-round data (cohort
    spec arrays, survivor masks, the round key) are traced arguments, so one
    trace serves every round of a run.
    """
    mixed = n_link_classes > 1

    def sweep(key, state, targets, alphas, steps, onehot, bidx, bvalid,
              masks):
        f0 = levels[0].fanout
        # broadcast: every sampled client starts from its cell anchor
        # (stateless-client semantics — see module docstring); in a depth-1
        # cascade the only anchor is the unstacked root
        a0 = state.anchors[0]["x"]
        x = (jnp.repeat(a0[None], f0, axis=0) if a0.ndim == 1
             else jnp.repeat(a0, f0, axis=0))

        # ragged local training as static-shape scans, one per size bucket
        a_col = alphas[:, None]
        for b, boundary in enumerate(boundaries):
            idx = bidx[b]
            safe = jnp.clip(idx, 0, x.shape[0] - 1)
            xb, tb = x[safe], targets[safe]
            ab, mb = a_col[safe], steps[safe]

            def local(xb, s, tb=tb, ab=ab, mb=mb):
                nxt = flix_local_step(xb, tb, ab, lr)
                return jnp.where((s < mb)[:, None], nxt, xb), None

            xb, _ = jax.lax.scan(local, xb, jnp.arange(boundary))
            # padded slots scatter out of bounds and are dropped
            sidx = jnp.where(bvalid[b], safe, x.shape[0])
            x = x.at[sidx].set(xb, mode="drop")

        if mixed:
            # per-class fused compression: each client's delta goes through
            # its own link class's operator, blended by the one-hot class
            # matrix (rows are one-hot, so this IS per-client dispatch)
            def leaf_compress(keys, delta_b, d):
                def per_class(core):
                    out = jnp.zeros_like(core)
                    for k, ck in enumerate(class_compressors):
                        yk = jax.vmap(lambda kk, v, ck=ck: ck(kk, v))(keys,
                                                                      core)
                        out = out + onehot[:, k, None] * yk
                    return out
                return dist.fused_apply(per_class, delta_b, d)
        else:
            leaf_compress = None

        new_x, new_state = dist.tree_param_sync(
            key, {"x": x}, state, levels, bucket_size=dim,
            survivors=masks, leaf_compress=leaf_compress)

        d_local = x - targets
        metrics = {
            "target_dist": jnp.sqrt(jnp.mean(jnp.sum(d_local ** 2, axis=1))),
            "root_norm": jnp.sqrt(jnp.sum(new_state.anchors[-1]["x"] ** 2)),
        }
        return new_state, metrics

    return sweep


@dataclass
class CohortRoundReport:
    """Everything one engine round produced besides the new state."""
    round: int
    cohort_ids: np.ndarray
    class_ids: np.ndarray
    bytes: CohortRoundBytes
    plan: Optional[RoundFaultPlan]
    staged_nbytes: int           # host bytes staged for the sweep (O(cohort))
    padded_steps: int            # total scan work after bucketing
    metrics: Dict[str, float] = field(default_factory=dict)

    @property
    def n_participants(self) -> int:
        if self.plan is None:
            return int(self.cohort_ids.shape[0])
        return int(self.plan.levels[0].survivors.sum())


class CohortEngine:
    """A ``Population`` bound to a cohort size: rounds as jitted sweeps.

    ``cohort_size`` leaves occupy ``pop.tree`` rescaled via
    ``with_n_leaves``; the anchor cascade runs the population's per-class
    compressors at the leaf hop and ``upper_compressors`` (default: dense
    middle hops, 1% top-k on the WAN root hop) above, all
    periods 1 — every round is a full cascade sync, the cross-device
    regime where each round IS the communication event.
    """

    def __init__(self, pop: Population, cohort_size: int, lr: float = 0.1,
                 fault_config: Optional[FaultConfig] = None,
                 upper_compressors: Optional[Sequence[Compressor]] = None,
                 ledger: Optional[CommLedger] = None, metrics=None):
        self.pop = pop
        self.cohort_size = int(cohort_size)
        self.lr = float(lr)
        self.ledger = ledger
        self.metrics = metrics
        base = get_tree_topology(pop.tree)
        self.tree: TreeTopology = base.with_n_leaves(self.cohort_size)

        if upper_compressors is None:
            # middle hops ship the dense aggregate (fat metro fiber); the
            # top (WAN) hop sparsifies hard — the Ch. 5 shape where each
            # slower link carries a more compressed payload
            upper_compressors = tuple(
                cohort_compressor("top_k", 0.01, 8) if l == base.depth - 1
                else cohort_compressor("identity", 0.05, 8)
                for l in range(1, base.depth))
        self.upper_compressors = tuple(upper_compressors)
        self.class_compressors = tuple(lc.make_compressor()
                                       for lc in pop.classes)
        self.cascade = self._build_cascade()
        self.accountant = CohortAccountant(self.tree, pop.classes,
                                           self.upper_compressors, pop.dim)
        self.fault_model = (FaultModel(fault_config, self.tree)
                            if fault_config is not None else None)

        self.boundaries = bucket_boundaries(pop.samples_max,
                                            min_size=pop.samples_min)
        self.capacities = bucket_capacities(
            self.boundaries, self.cohort_size, pop.samples_min,
            pop.samples_max)
        self._sweep = jax.jit(_make_cohort_sweep(
            self.cascade, pop.dim, self.boundaries, self.lr,
            len(pop.classes), self.class_compressors))

    def _build_cascade(self) -> Tuple[CascadeLevel, ...]:
        def lam_of(c: Compressor) -> float:
            return (comp_lib.lambda_star(c.eta, c.omega)
                    if c.eta is not None and c.omega is not None else 1.0)

        # heterogeneous leaves: the mean mixes per-class operators, so take
        # the most conservative class step size (min lambda_star contracts
        # for every class; equals the single class's lambda when K == 1)
        lam0 = min(lam_of(c) for c in self.class_compressors)
        leaf_c = (self.class_compressors[0]
                  if len(self.class_compressors) == 1
                  else comp_lib.identity())  # placeholder: leaf_compress wins
        out = [CascadeLevel(self.tree.levels[0].name, leaf_c, lam0, 1,
                            self.tree.levels[0].fanout)]
        for lev, c in zip(self.tree.levels[1:], self.upper_compressors):
            out.append(CascadeLevel(lev.name, c, lam_of(c), 1, lev.fanout))
        return tuple(out)

    # -- per-round derivations -----------------------------------------------
    def round_key(self, rnd: int):
        return jax.random.fold_in(jax.random.PRNGKey(self.pop.seed), rnd)

    def init_state(self) -> TreeSyncState:
        return dist.tree_sync_state_init(
            {"x": jnp.zeros((self.pop.dim,), jnp.float32)}, self.cascade)

    def round_cohort(self, rnd: int) -> np.ndarray:
        return sample_cohort(self.pop.seed, rnd, self.pop.n_clients,
                             self.cohort_size)

    def round_plan(self, rnd: int, ids: np.ndarray,
                   class_ids: np.ndarray) -> Optional[RoundFaultPlan]:
        """Fault plan addressed by population ids: the cohort's leaf draws
        are the population plan's slice at ``ids`` (lane-sliceability)."""
        if self.fault_model is None:
            return None
        nbytes = [0.0] + list(self.accountant.upper_nbytes)
        return self.fault_model.round_plan(
            rnd, nbytes_by_level=nbytes, leaf_lanes=ids,
            leaf_base_time_s=self.accountant.uplink_time_s(class_ids))

    def buckets(self, n_samples: np.ndarray) -> CohortBuckets:
        return bucket_by_size(n_samples, self.boundaries, self.capacities)

    # -- the round -----------------------------------------------------------
    def round(self, state: TreeSyncState,
              rnd: int) -> Tuple[TreeSyncState, CohortRoundReport]:
        ids = self.round_cohort(rnd)
        spec = self.pop.client_spec(ids)
        cb = self.buckets(spec.n_samples)
        plan = self.round_plan(rnd, ids, spec.class_ids)
        smasks = plan.survivor_masks() if plan is not None else None
        masks = (tuple(jnp.asarray(m) for m in smasks)
                 if smasks is not None else None)

        onehot = np.zeros((self.cohort_size, len(self.pop.classes)),
                          np.float32)
        onehot[np.arange(self.cohort_size), spec.class_ids] = 1.0
        steps = spec.n_samples.astype(np.int32)
        staged = [spec.targets, spec.flix_alpha, steps, onehot,
                  *cb.index, *cb.valid] + ([m for m in smasks]
                                           if smasks is not None else [])
        staged_nbytes = int(sum(a.nbytes for a in staged))

        new_state, jmetrics = self._sweep(
            self.round_key(rnd), state, spec.targets, spec.flix_alpha,
            steps, onehot, tuple(cb.index), tuple(cb.valid), masks)

        rb = self.accountant.round_bytes(rnd, spec.class_ids, smasks)
        if self.ledger is not None:
            self.accountant.record(self.ledger, rb)
        report = CohortRoundReport(
            round=rnd, cohort_ids=ids, class_ids=spec.class_ids, bytes=rb,
            plan=plan, staged_nbytes=staged_nbytes,
            padded_steps=cb.padded_steps,
            metrics={k: float(v) for k, v in jmetrics.items()})
        if self.metrics is not None:
            self.metrics.observe_cohort_round(rnd, report)
        return new_state, report

    def run(self, n_rounds: int,
            state: Optional[TreeSyncState] = None
            ) -> Tuple[TreeSyncState, list]:
        state = self.init_state() if state is None else state
        reports = []
        for rnd in range(n_rounds):
            state, rep = self.round(state, rnd)
            reports.append(rep)
        return state, reports
