"""CLI: ``python -m repro.lint [--format=text|json] [paths...]``.

Exit status: 0 when every finding is baselined or suppressed, 1 otherwise.
CI runs the JSON form and uploads the report as an artifact; developers run
the bare form from the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.lint.framework import (
    Finding,
    all_rules,
    apply_baseline,
    build_project,
    load_baseline,
    run_rules,
    write_baseline,
)

DEFAULT_PATHS = ("src/repro", "benchmarks")
DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baseline.json")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS})")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline fingerprint file (default: the committed "
                         "src/repro/lint/baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "and exit 0")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip engine 2 (eval_shape contract checks)")
    ap.add_argument("--rules", default=None,
                    help="comma list of engine-1 rules to run (default: all)")
    args = ap.parse_args(argv)

    paths = args.paths or [p for p in DEFAULT_PATHS if os.path.isdir(p)]
    if not paths:
        print("repro.lint: no lintable paths (run from the repo root or "
              "pass paths)", file=sys.stderr)
        return 2

    rule_names = ([r.strip().upper() for r in args.rules.split(",")]
                  if args.rules else None)
    unknown = set(rule_names or ()) - set(all_rules())
    if unknown:
        print(f"repro.lint: unknown rules {sorted(unknown)}", file=sys.stderr)
        return 2

    project = build_project(paths)
    findings: List[Finding] = run_rules(project, rule_names)
    if not args.no_contracts:
        from repro.lint.contracts import run_contracts
        findings.extend(run_contracts())

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"wrote {len(findings)} fingerprint(s) to {args.baseline}")
        return 0

    fresh, n_baselined = apply_baseline(findings, load_baseline(args.baseline))

    if args.format == "json":
        json.dump({
            "findings": [f.to_json() for f in fresh],
            "baselined": n_baselined,
            "checked_files": len(project.files),
            "paths": paths,
            "baseline": args.baseline,
        }, sys.stdout, indent=1)
        sys.stdout.write("\n")
    else:
        for f in fresh:
            print(f.format())
        tail = f" ({n_baselined} baselined)" if n_baselined else ""
        print(f"repro.lint: {len(fresh)} finding(s) in "
              f"{len(project.files)} file(s){tail}")
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
