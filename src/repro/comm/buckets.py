"""Bucket fusion: flatten a gradient/delta pytree into fixed-size fp32 buckets.

The per-leaf sync loops in ``core/distributed.py`` launched one compressor
kernel per pytree leaf — dozens of tiny XLA programs for a transformer's
parameter tree.  Bucketing concatenates every leaf into one flat fp32 vector,
pads it to a whole number of fixed-size buckets, and views it as an
``(n_buckets, bucket_size)`` matrix, so the whole tree is compressed/encoded
in a single fused pass and the streaming codecs (``codecs.encode_stream``)
can treat one bucket as one wire tile.

``DEFAULT_BUCKET_SIZE`` is a multiple of every codec granule in the repo
(quantizer blocks 256/512/2048, the 32-bit mask words, the Pallas QBLOCK), so
bucket boundaries always align with wire-chunk boundaries.

Layouts are shape-only metadata (hashable, jit-static); bucketize/debucketize
are pure reshape/concat/pad, so round-trips are value-exact in every dtype.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

DEFAULT_BUCKET_SIZE = 1 << 16  # coords per bucket; multiple of all codec granules


@dataclass(frozen=True)
class BucketLayout:
    """Where each leaf lives inside the flat bucketed vector."""
    treedef: object
    shapes: Tuple[tuple, ...]    # per-leaf shapes (group axis excluded)
    dtypes: Tuple[str, ...]      # per-leaf dtypes (restored by debucketize)
    sizes: Tuple[int, ...]
    offsets: Tuple[int, ...]     # start coordinate of each leaf
    d: int                       # total coordinates (sum of sizes)
    bucket_size: int

    @property
    def n_buckets(self) -> int:
        return max(1, -(-self.d // self.bucket_size))

    @property
    def padded_d(self) -> int:
        return self.n_buckets * self.bucket_size


def _layout(leaves, treedef, bucket_size: int, group_axis: bool) -> BucketLayout:
    shapes = tuple(tuple(l.shape[1:] if group_axis else l.shape) for l in leaves)
    sizes = tuple(_prod(s) for s in shapes)
    offsets, acc = [], 0
    for s in sizes:
        offsets.append(acc)
        acc += s
    return BucketLayout(treedef, shapes, tuple(str(l.dtype) for l in leaves),
                        sizes, tuple(offsets), acc, int(bucket_size))


def _prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def bucketize(tree, bucket_size: int = DEFAULT_BUCKET_SIZE):
    """Pytree -> ((n_buckets, bucket_size) float32, BucketLayout)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    layout = _layout(leaves, treedef, bucket_size, group_axis=False)
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])
    pad = layout.padded_d - layout.d
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(layout.n_buckets, layout.bucket_size), layout


def bucketize_groups(tree_g, bucket_size: int = DEFAULT_BUCKET_SIZE):
    """Pytree with leading group axis G -> ((G, n_buckets, bucket_size)
    float32, BucketLayout).  The layout describes the per-group view (group
    axis excluded), so it is shared with the groupless ``bucketize`` of the
    matching replicated tree (e.g. h_bar next to h)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree_g)
    layout = _layout(leaves, treedef, bucket_size, group_axis=True)
    G = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.astype(jnp.float32).reshape(G, -1) for l in leaves], axis=1)
    pad = layout.padded_d - layout.d
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat.reshape(G, layout.n_buckets, layout.bucket_size), layout


def debucketize(buckets, layout: BucketLayout, dtype=None):
    """Inverse of ``bucketize``; ``dtype`` overrides the recorded leaf dtypes
    (the sync states keep everything float32 regardless of the param dtype)."""
    flat = buckets.reshape(-1)[: layout.d]
    leaves = []
    for shape, dt, size, off in zip(layout.shapes, layout.dtypes,
                                    layout.sizes, layout.offsets):
        leaf = flat[off: off + size].reshape(shape)
        leaves.append(leaf.astype(dtype or dt))
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


def debucketize_groups(buckets_g, layout: BucketLayout, dtype=None):
    """Inverse of ``bucketize_groups`` (leading group axis preserved)."""
    G = buckets_g.shape[0]
    flat = buckets_g.reshape(G, -1)[:, : layout.d]
    leaves = []
    for shape, dt, size, off in zip(layout.shapes, layout.dtypes,
                                    layout.sizes, layout.offsets):
        leaf = flat[:, off: off + size].reshape((G,) + shape)
        leaves.append(leaf.astype(dtype or dt))
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)
