"""Shared scaffolding for the repro static analyzer (``python -m repro.lint``).

Engine 1 rules (``repro.lint.rules``) are AST passes over a :class:`Project`
— every parsed file plus the import maps and the jit call-graph the rules
share.  This module owns everything that is not rule logic:

* file discovery + parsing into :class:`FileCtx` objects;
* :class:`Finding` and its stable *fingerprint* (rule + repo-relative path +
  the stripped source line, deliberately line-number-free so a baseline
  survives unrelated edits above the finding);
* per-line ``# repro: noqa[RL001]`` / ``# repro: noqa[RL001,RL004]``
  suppressions;
* the committed-baseline file (JSON list of fingerprints).

Rules register themselves via :func:`rule` and implement
``run(project) -> list[Finding]``; the CLI in ``__main__`` wires discovery,
suppression, baseline filtering and exit codes together.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Set

NOQA_RE = re.compile(r"#\s*repro:\s*noqa\[([A-Za-z0-9_,\s]+)\]")

# directories never linted even when a parent path is given
_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "build", "dist"}


@dataclass(frozen=True)
class Finding:
    """One diagnostic: ``path:line:col RULE message``.

    ``snippet`` is the stripped source line the finding sits on; it anchors
    the fingerprint so baselines don't churn when line numbers shift.
    """
    rule: str
    path: str          # repo-relative, forward slashes
    line: int
    col: int
    message: str
    snippet: str = ""

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}:{self.path}:{self.snippet}"

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col} {self.rule} {self.message}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "fingerprint": self.fingerprint}


@dataclass
class FileCtx:
    """One parsed source file."""
    path: str              # absolute
    relpath: str           # repo-relative, forward slashes
    module: str            # dotted module name ("repro.comm.ledger", ...)
    source: str
    lines: List[str]
    tree: ast.AST

    def noqa_rules(self, lineno: int) -> Set[str]:
        """Rule names suppressed on ``lineno`` (1-based)."""
        if 1 <= lineno <= len(self.lines):
            m = NOQA_RE.search(self.lines[lineno - 1])
            if m:
                return {r.strip().upper() for r in m.group(1).split(",")
                        if r.strip()}
        return set()

    def snippet(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0) + 1
        return Finding(rule, self.relpath, line, col, message,
                       self.snippet(line))


@dataclass
class Project:
    """Every file under the linted paths, plus lazily-built shared analyses."""
    root: str                              # repo root (absolute)
    files: Dict[str, FileCtx] = field(default_factory=dict)  # by relpath
    parse_errors: List[Finding] = field(default_factory=list)
    _callgraph: Optional[object] = None

    def add_file(self, path: str) -> None:
        path = os.path.abspath(path)
        rel = os.path.relpath(path, self.root).replace(os.sep, "/")
        try:
            with open(path, encoding="utf-8") as f:
                source = f.read()
            tree = ast.parse(source, filename=path)
        except (SyntaxError, UnicodeDecodeError) as e:
            line = getattr(e, "lineno", 1) or 1
            self.parse_errors.append(Finding(
                "PARSE", rel, line, 1, f"cannot parse: {e.__class__.__name__}: {e}"))
            return
        self.files[rel] = FileCtx(path, rel, _module_name(rel), source,
                                  source.splitlines(), tree)

    @property
    def callgraph(self):
        if self._callgraph is None:
            from repro.lint.callgraph import CallGraph
            self._callgraph = CallGraph.build(self)
        return self._callgraph


def _module_name(relpath: str) -> str:
    """Dotted module name for a repo-relative path (best effort)."""
    p = relpath[:-3] if relpath.endswith(".py") else relpath
    parts = p.split("/")
    if parts[:2] == ["src", "repro"]:
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def find_repo_root(paths: Iterable[str]) -> str:
    """Nearest ancestor of the linted paths containing ``src/repro`` (falls
    back to the cwd) — anchors repo-relative fingerprints and the ledger
    tag-registry lookup, independent of where the CLI is invoked from."""
    for p in list(paths) + [os.getcwd()]:
        d = os.path.abspath(p)
        if os.path.isfile(d):
            d = os.path.dirname(d)
        while True:
            if os.path.isdir(os.path.join(d, "src", "repro")):
                return d
            parent = os.path.dirname(d)
            if parent == d:
                break
            d = parent
    return os.getcwd()


def build_project(paths: Iterable[str], root: Optional[str] = None) -> Project:
    paths = list(paths)
    project = Project(root=os.path.abspath(root or find_repo_root(paths)))
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                project.add_file(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    project.add_file(os.path.join(dirpath, fn))
    return project


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------
_RULES: Dict[str, "Rule"] = {}


@dataclass(frozen=True)
class Rule:
    name: str
    description: str
    run: Callable[[Project], List[Finding]]


def rule(name: str, description: str):
    """Decorator registering ``fn(project) -> list[Finding]`` as a rule."""
    def deco(fn):
        _RULES[name] = Rule(name, description, fn)
        return fn
    return deco


def all_rules() -> Dict[str, Rule]:
    import repro.lint.rules  # noqa: F401 — registration side effect
    return dict(_RULES)


def run_rules(project: Project, names: Optional[Iterable[str]] = None
              ) -> List[Finding]:
    """Run engine-1 rules, dropping findings suppressed by an inline noqa."""
    rules = all_rules()
    selected = [rules[n] for n in (names or sorted(rules))]
    findings = list(project.parse_errors)
    for r in selected:
        for f in r.run(project):
            ctx = project.files.get(f.path)
            if ctx is not None and f.rule in ctx.noqa_rules(f.line):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------
def load_baseline(path: str) -> Set[str]:
    if not path or not os.path.exists(path):
        return set()
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    return set(doc.get("fingerprints", []))


def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    fps = sorted({f.fingerprint for f in findings})
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"fingerprints": fps}, f, indent=1)
        f.write("\n")


def apply_baseline(findings: List[Finding], baseline: Set[str]
                   ) -> "tuple[List[Finding], int]":
    """Returns (fresh findings, number suppressed by the baseline)."""
    fresh = [f for f in findings if f.fingerprint not in baseline]
    return fresh, len(findings) - len(fresh)
