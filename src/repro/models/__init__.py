from repro.models.transformer import (
    init_params,
    forward_train,
    loss_fn,
    prefill,
    decode_step,
    cache_specs,
    period_info,
    model_dtype,
)
