"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container lacks hypothesis: deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels import nm_prune as nmk
from repro.kernels import ops, quant8, ref


# ---------------------------------------------------------------------------
# quant8
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(7,), (100, 33), (3, 5, 17), (4096,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits", [4, 8])
def test_quant_shapes_dtypes(shape, dtype, bits):
    key = jax.random.PRNGKey(42)
    x = (jax.random.normal(key, shape) * 5).astype(dtype)
    out = ops.quantize_dequantize(x, key, bits=bits)
    assert out.shape == x.shape and out.dtype == x.dtype
    s = 2 ** (bits - 1) - 1
    flat = np.asarray(x, np.float32).reshape(-1)
    err = np.abs(np.asarray(out, np.float32).reshape(-1) - flat)
    # error bounded by the global max scale plus the output dtype's own
    # round-off of the dequantized value (bf16: eps = 2^-7)
    dtype_eps = np.finfo(np.float32).eps if dtype == jnp.float32 else 2.0**-7
    amax = np.abs(flat).max()
    assert err.max() <= amax / s + amax * dtype_eps + 1e-2


def test_quant_kernel_vs_oracle_exact():
    key = jax.random.PRNGKey(0)
    rows = quant8.TILE_ROWS * 3
    x = jax.random.normal(key, (rows, quant8.QBLOCK)) * 7
    noise = jax.random.uniform(jax.random.PRNGKey(1), x.shape)
    out = quant8.quant_dequant_2d(x, noise, bits=8)
    exp = ref.quant_dequant_ref(x, noise, bits=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-6)


def test_quant_zero_block_safe():
    x = jnp.zeros((quant8.TILE_ROWS, quant8.QBLOCK))
    noise = jnp.full(x.shape, 0.99)
    out = quant8.quant_dequant_2d(x, noise)
    assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# nm_prune
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(n=st.sampled_from([1, 2, 3]), m=st.sampled_from([4, 8]),
       seed=st.integers(0, 2**16))
def test_nm_kernel_vs_oracle(n, m, seed):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (nmk.TILE_R, nmk.TILE_C))
    s = jnp.abs(w)
    out, mask = nmk.nm_prune_2d(w, s, n=n, m=m)
    eo, em = ref.nm_prune_ref(w, s, n=n, m=m)
    np.testing.assert_allclose(np.asarray(mask), np.asarray(em))
    np.testing.assert_allclose(np.asarray(out), np.asarray(eo))


def test_nm_with_ties():
    """Tie-breaking must keep exactly n per group even with equal scores."""
    w = jnp.ones((nmk.TILE_R, nmk.TILE_C))
    s = jnp.ones_like(w)
    _, mask = nmk.nm_prune_2d(w, s, n=2, m=4)
    grp = np.asarray(mask).reshape(-1, 4, nmk.TILE_C)
    assert (grp.sum(1) == 2).all()


@pytest.mark.parametrize("shape", [(132, 70), (256, 256), (300, 129)])
def test_nm_ops_padding(shape):
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, shape)
    out, mask = ops.prune_nm(w, jnp.abs(w), 2, 4)
    assert out.shape == shape
    # interior groups are exactly 2:4 (shape[0] may not divide 4 at the tail)
    r4 = (shape[0] // 4) * 4
    grp = np.asarray(mask)[:r4].reshape(-1, 4, shape[1])
    assert (grp.sum(1) == 2).all()


# ---------------------------------------------------------------------------
# wanda_score fused kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["wanda", "ria", "symwanda"])
@pytest.mark.parametrize("dims", [(256, 128), (384, 256)])
def test_wanda_kernel_modes(mode, dims):
    d_in, d_out = dims
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    W = jax.random.normal(k1, (d_in, d_out)) * 0.2
    X = jax.random.normal(k2, (64, d_in)) * jnp.exp(
        jax.random.normal(jax.random.PRNGKey(9), (d_in,)))
    out, mask = ops.prune_scored(W, X, mode=mode, sparsity=0.5)
    assert out.shape == W.shape
    kept = float(mask.mean())
    assert abs(kept - 0.5) < 0.02
    np.testing.assert_allclose(np.asarray(out), np.asarray(W * mask), rtol=1e-6)
