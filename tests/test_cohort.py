"""Cohort simulator (repro.cohort): population laws, Feistel sampling,
size bucketing, the vectorized round engine, and byte attribution."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container lacks hypothesis: deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

from benchmarks.bench_cohort import _bitident_pop, reference_round
from repro.cohort import (CohortEngine, LinkClass, Population,
                          bucket_boundaries, bucket_by_size,
                          bucket_capacities, cohort_compressor,
                          link_classes_from_tree, materialized_round_bytes,
                          message_nbytes, sample_cohort)
from repro.comm import Link, TreeLevel, TreeTopology, get_tree_topology
from repro.comm.ledger import CommLedger
from repro.comm.tree import register_tree_topology
from repro.data.federated import dirichlet_mixtures, dirichlet_split
from repro.faults import FaultConfig, FaultModel


# ---------------------------------------------------------------------------
# population law
# ---------------------------------------------------------------------------
class TestPopulation:
    def test_spec_is_population_slice(self):
        """The design contract: a cohort's spec equals the population-wide
        derivation sliced at its ids (clients are pure functions of id)."""
        pop = Population(n_clients=10_000, dim=16)
        ids = np.array([7, 9_999, 0, 4_321])
        batch = pop.client_spec(ids)
        for i, cid in enumerate(ids):
            one = pop.client_spec(np.array([cid]))
            np.testing.assert_array_equal(batch.targets[i], one.targets[0])
            assert batch.class_ids[i] == one.class_ids[0]
            assert batch.flix_alpha[i] == one.flix_alpha[0]
            assert batch.n_samples[i] == one.n_samples[0]

    def test_derivations_bounded_and_typed(self):
        pop = Population(n_clients=50_000)
        spec = pop.client_spec(np.arange(2_000))
        assert spec.targets.dtype == np.float32
        assert spec.n_samples.min() >= pop.samples_min
        assert spec.n_samples.max() <= pop.samples_max
        assert spec.flix_alpha.min() >= pop.flix_min
        assert spec.flix_alpha.max() <= pop.flix_max
        # class mix tracks the configured weights at population scale
        mix = pop.class_mix_counts(np.arange(20_000)) / 20_000
        for got, lc in zip(mix, pop.classes):
            assert abs(got - lc.weight) < 0.02, (got, lc)

    def test_default_classes_from_tree_and_weight_validation(self):
        classes = link_classes_from_tree(get_tree_topology("edge_fl_tree"))
        assert abs(sum(lc.weight for lc in classes) - 1.0) < 1e-12
        bad = tuple(dataclasses.replace(lc, weight=0.5) for lc in classes)
        with pytest.raises(ValueError, match="weights"):
            Population(n_clients=10, classes=bad)
        with pytest.raises(ValueError, match="ids outside"):
            Population(n_clients=10).client_spec(np.array([10]))

    def test_cohort_resolver_rejects_unflattenable(self):
        # qsgd resolves to the dense quantizer (stacked cohort rows), and
        # sharding-safe flatten=False operators are rejected up front
        assert cohort_compressor("qsgd", 0.05, 8).flatten
        with pytest.raises(ValueError, match="not flattenable"):
            cohort_compressor("qsgd_sharded", 0.05, 8)


class TestDirichlet:
    def test_iid_limit_and_concentration(self):
        # alpha -> inf: every client's mixture approaches uniform (IID)
        mix = dirichlet_mixtures(512, n_classes=8, alpha=1e6, seed=1)
        np.testing.assert_allclose(mix, 1.0 / 8, atol=2e-3)
        np.testing.assert_allclose(mix.sum(axis=1), 1.0, atol=1e-12)
        # alpha -> 0: each client concentrates on a single class, and the
        # argmax class varies across clients (not one global winner)
        mix0 = dirichlet_mixtures(512, n_classes=8, alpha=1e-3, seed=1)
        assert mix0.max(axis=1).mean() > 0.95
        assert len(np.unique(mix0.argmax(axis=1))) >= 4

    def test_lane_sliceable(self):
        full = dirichlet_mixtures(1_000, n_classes=5, alpha=0.3, seed=2)
        ids = np.array([3, 999, 140, 7])
        np.testing.assert_array_equal(
            dirichlet_mixtures(ids, n_classes=5, alpha=0.3, seed=2),
            full[ids])

    def test_rejects_bad_alpha(self):
        with pytest.raises(ValueError, match="alpha"):
            dirichlet_mixtures(4, 3, alpha=0.0)

    def test_split_alpha_limits_noncontiguous_labels(self):
        # labels {1, 3, 7}: non-contiguous label sets must not be indexed
        # positionally by raw value
        rng = np.random.default_rng(0)
        labels = rng.choice([1, 3, 7], size=3_000, p=[0.5, 0.3, 0.2])
        # alpha -> inf: every client's label histogram ~ the global one
        parts = dirichlet_split(labels, 10, alpha=1e6, seed=3)
        assert sum(len(p) for p in parts) == len(labels)
        for p in parts:
            frac1 = np.mean(labels[p] == 1)
            assert abs(frac1 - 0.5) < 0.1, frac1
        # alpha -> 0: each label's mass lands on ~one client, so nearly all
        # samples concentrate on as many clients as there are labels
        parts0 = dirichlet_split(labels, 10, alpha=1e-3, seed=3)
        sizes = sorted((len(p) for p in parts0), reverse=True)
        assert sum(sizes[:3]) > 0.95 * len(labels), sizes


# ---------------------------------------------------------------------------
# cohort sampling
# ---------------------------------------------------------------------------
class TestSampleCohort:
    def test_distinct_in_range_replayable(self):
        ids = sample_cohort(0, 5, 1_000_000, 50_000)
        assert ids.shape == (50_000,)
        assert len(np.unique(ids)) == 50_000
        assert ids.min() >= 0 and ids.max() < 1_000_000
        np.testing.assert_array_equal(ids,
                                      sample_cohort(0, 5, 1_000_000, 50_000))

    def test_varies_by_round_and_seed(self):
        a = sample_cohort(0, 1, 10_000, 500)
        assert not np.array_equal(a, sample_cohort(0, 2, 10_000, 500))
        assert not np.array_equal(a, sample_cohort(1, 1, 10_000, 500))

    def test_full_population_is_permutation(self):
        ids = sample_cohort(4, 0, 257, 257)  # odd size forces cycle walking
        np.testing.assert_array_equal(np.sort(ids), np.arange(257))

    def test_rejects_bad_cohort(self):
        with pytest.raises(ValueError):
            sample_cohort(0, 0, 100, 101)
        with pytest.raises(ValueError):
            sample_cohort(0, 0, 100, 0)


# ---------------------------------------------------------------------------
# size bucketing
# ---------------------------------------------------------------------------
class TestBuckets:
    def test_every_member_placed_once_within_boundary(self):
        rng = np.random.default_rng(1)
        sizes = rng.integers(8, 65, size=3_000)
        bb = bucket_boundaries(64, min_size=8)
        caps = bucket_capacities(bb, 3_000, 8, 64)
        cb = bucket_by_size(sizes, bb, caps)
        placed = np.concatenate([ix[v] for ix, v in zip(cb.index, cb.valid)])
        np.testing.assert_array_equal(np.sort(placed), np.arange(3_000))
        for b, (ix, v) in enumerate(zip(cb.index, cb.valid)):
            # spill-up only: a member never lands below its size's bucket
            assert (sizes[ix[v]] <= bb[b]).all()
        assert cb.padded_steps < 3_000 * 64

    def test_spill_up_and_top_overflow(self):
        sizes = np.array([8, 8, 8, 64])
        cb = bucket_by_size(sizes, (8, 64), (2, 4))
        placed = sorted(np.concatenate(
            [ix[v] for ix, v in zip(cb.index, cb.valid)]).tolist())
        assert placed == [0, 1, 2, 3]
        assert cb.valid[1].sum() == 2  # one spilled member + the size-64 one
        with pytest.raises(RuntimeError, match="capacities exhausted"):
            bucket_by_size(sizes, (8, 64), (2, 1))
        with pytest.raises(ValueError, match="top boundary"):
            bucket_by_size(np.array([65]), (8, 64), (2, 2))


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
def _faulted(seed=3):
    return FaultConfig(seed=seed, availability=0.7, drop_rate=0.1)


class TestEngineBitExactness:
    @pytest.mark.parametrize("cfg", [None, _faulted()],
                             ids=["nofault", "faulted"])
    def test_engine_matches_per_client_loop(self, cfg):
        """The acceptance gate: a 16-client population through the jitted
        vectorized sweep reproduces the per-client ``tree_param_sync`` loop
        bitwise, with and without participation faults."""
        eng = CohortEngine(_bitident_pop(), cohort_size=16, fault_config=cfg)
        se, sr = eng.init_state(), eng.init_state()
        for rnd in range(3):
            se, _ = eng.round(se, rnd)
            sr = reference_round(eng, sr, rnd)
            for a, b in zip(se.anchors, sr.anchors):
                assert (np.asarray(a["x"]).tobytes()
                        == np.asarray(b["x"]).tobytes())

    def test_heterogeneous_classes_match_masked_reference(self):
        """K=2 link classes: the one-hot blended ``leaf_compress`` equals
        compressing each client with its own class operator.

        Depth-1 tree so the root anchor directly exposes the level-0 update
        (in deeper cascades the top-down adoption pass overwrites the lower
        anchors with the root's, hiding the per-class deltas).
        """
        classes = (
            LinkClass("fast", 0.5, Link(gbps=0.1, latency_us=100.0),
                      compressor="identity"),
            LinkClass("slow", 0.5, Link(gbps=0.001, latency_us=50_000.0),
                      compressor="top_k", compress_ratio=0.25),
        )
        register_tree_topology(TreeTopology("cohort_het_flat", (
            TreeLevel("uplink", 8, Link(gbps=0.001, latency_us=50_000.0)),
        )))
        pop = Population(n_clients=1_000, dim=32, tree="cohort_het_flat",
                         classes=classes)
        eng = CohortEngine(pop, cohort_size=8)
        state = eng.init_state()
        rnd = 0
        ids = eng.round_cohort(rnd)
        spec = pop.client_spec(ids)
        assert len(np.unique(spec.class_ids)) == 2  # both operators exercised
        new_state, _ = eng.round(state, rnd)

        # reference: per-client local scans, then a hand-rolled delta pass
        # dispatching each client's own class compressor
        from benchmarks.bench_cohort import _client_local
        root = state.anchors[0]["x"]
        x = jnp.stack([
            _client_local(root, jnp.asarray(spec.targets[i]),
                          jnp.float32(spec.flix_alpha[i]),
                          spec.n_samples[i], eng.lr)
            for i in range(8)])
        comps = [lc.make_compressor() for lc in pop.classes]
        keys = jax.random.split(eng.round_key(rnd), 8)
        d_ref = jnp.stack([
            comps[int(spec.class_ids[i])](keys[i], x[i] - root)
            for i in range(8)])
        want = root + eng.cascade[0].lam * jnp.mean(d_ref, axis=0)
        np.testing.assert_allclose(np.asarray(new_state.anchors[0]["x"]),
                                   np.asarray(want), rtol=0, atol=1e-6)

    def test_round_replayable_and_stateless_between_engines(self):
        """(seed, round) fully determines a round: a fresh engine instance
        replays the same cohort, faults, and resulting state."""
        pop = Population(n_clients=20_000, dim=16)
        a = CohortEngine(pop, cohort_size=100, fault_config=_faulted())
        b = CohortEngine(pop, cohort_size=100, fault_config=_faulted())
        sa, sb = a.init_state(), b.init_state()
        for rnd in (0, 1):
            sa, ra = a.round(sa, rnd)
            sb, rb = b.round(sb, rnd)
            np.testing.assert_array_equal(ra.cohort_ids, rb.cohort_ids)
            np.testing.assert_array_equal(
                ra.plan.levels[0].survivors, rb.plan.levels[0].survivors)
            assert ra.bytes == rb.bytes
            for x, y in zip(sa.anchors, sb.anchors):
                assert (np.asarray(x["x"]).tobytes()
                        == np.asarray(y["x"]).tobytes())

    def test_personalization_pull(self):
        """FLIX semantics: local steps contract clients toward their targets
        (target_dist shrinks over rounds on a fixed cohort tree)."""
        pop = Population(n_clients=5_000, dim=16, alpha=10.0)
        eng = CohortEngine(pop, cohort_size=100)
        state = eng.init_state()
        dists = []
        for rnd in range(4):
            state, rep = eng.round(state, rnd)
            dists.append(rep.metrics["target_dist"])
        assert dists[-1] < dists[0], dists


class TestAccounting:
    def test_analytic_matches_oracle(self):
        pop = Population(n_clients=10_000, dim=32)
        eng = CohortEngine(pop, cohort_size=60, fault_config=_faulted(7))
        state = eng.init_state()
        for rnd in range(2):
            state, rep = eng.round(state, rnd)
            smasks = rep.plan.survivor_masks()
            oracle = materialized_round_bytes(
                rnd, rep.class_ids, pop.classes, eng.upper_compressors,
                eng.tree, pop.dim, smasks)
            assert rep.bytes.total_bytes == oracle
            # every surviving leaf is accounted in exactly one class bucket
            assert (sum(rep.bytes.leaf_class_counts)
                    == int(smasks[0].sum()))

    def test_ledger_records_per_level_tags(self):
        ledger = CommLedger()
        pop = Population(n_clients=10_000, dim=32)
        eng = CohortEngine(pop, cohort_size=60, ledger=ledger)
        state, rep = eng.round(eng.init_state(), 0)
        by_tag = ledger.bytes_by_tag()
        for name, nb in rep.bytes.by_level(eng.tree).items():
            assert by_tag.get(name) == nb, (name, by_tag)
        # per-class split: level-0 links carry the class name
        links = ledger.bytes_by_link()
        leaf = eng.tree.levels[0].name
        class_links = {k: v for k, v in links.items()
                       if k.startswith(f"{leaf}->up/")}
        assert sum(class_links.values()) == rep.bytes.leaf_bytes

    def test_message_nbytes_probe_cap(self):
        from repro.comm.accounting import PROBE_CAP
        from repro.core import compressors as C

        with pytest.raises(ValueError, match="probe cap"):
            message_nbytes(C.identity(), PROBE_CAP + 1)


class TestEngineObservability:
    def test_observe_cohort_round(self):
        from repro.obs.metrics import MetricsRegistry

        reg = MetricsRegistry()
        pop = Population(n_clients=10_000, dim=16)
        eng = CohortEngine(pop, cohort_size=100, fault_config=_faulted(),
                           metrics=reg)
        eng.round(eng.init_state(), 0)
        snap = reg.to_dict()
        names = {m["name"] for m in snap["metrics"]}
        assert "cohort/bytes/total" in names
        assert "cohort/participants" in names
        assert "cohort/target_dist" in names
        assert "faults/round_time_s" in names  # plan forwarded


# ---------------------------------------------------------------------------
# population-scale fault lane-sliceability (the property the engine rides on)
# ---------------------------------------------------------------------------
class TestFaultLaneSlicing:
    def _model(self, n_leaves):
        tree = get_tree_topology("edge_fl_tree").with_n_leaves(n_leaves)
        cfg = FaultConfig(seed=9, availability=0.8, drop_rate=0.1,
                          straggler_rate=0.2, straggler_sigma=1.0)
        return FaultModel(cfg, tree)

    @settings(max_examples=8, deadline=None)
    @given(rnd=st.integers(min_value=0, max_value=50),
           start=st.integers(min_value=0, max_value=990_000))
    def test_draws_slice_million_lane_population(self, rnd, start):
        """Every per-leaf fault process sliced at ANY index set equals
        drawing those lanes directly (the contract the engine's
        ``leaf_lanes`` addressing rides on)."""
        lanes = np.unique((np.arange(1_000) * 977 + start) % 1_000_000)
        m = self._model(1_000_000)
        np.testing.assert_array_equal(m.available(rnd, lanes=lanes),
                                      m.available(rnd)[lanes])
        np.testing.assert_array_equal(
            m.straggler_scale(rnd, 0, lanes=lanes),
            m.straggler_scale(rnd, 0)[lanes])
        for attempt in (0, 1):
            part = m.attempt_outcomes(rnd, 0, attempt, lanes=lanes)
            full = m.attempt_outcomes(rnd, 0, attempt)
            for x, y in zip(part, full):
                np.testing.assert_array_equal(x, y[lanes])

    def test_round_plan_leaf_lanes_slice_million_lane_plan(self):
        """Full plans: round_plan(leaf_lanes=ids) leaf survivors/arrivals ==
        the 10^6-leaf population plan's rows at those ids."""
        pop_model = self._model(1_000_000)
        plan_pop = pop_model.round_plan(3)
        ids = sample_cohort(0, 3, 1_000_000, 2_000)
        coh_model = self._model(2_000)
        plan_coh = coh_model.round_plan(3, leaf_lanes=ids)
        np.testing.assert_array_equal(plan_coh.levels[0].survivors,
                                      plan_pop.levels[0].survivors[ids])
        np.testing.assert_array_equal(plan_coh.levels[0].arrival_s,
                                      plan_pop.levels[0].arrival_s[ids])

    def test_retry_draws_population_size_independent(self):
        """Retry attempts draw from per-attempt streams, not lane offsets of
        attempt*n — the draw for lane i is the same in any population."""
        small = self._model(100)
        big = self._model(1_000_000)
        lanes = np.array([0, 7, 42, 99])
        for attempt in (0, 1, 2):
            a = small.attempt_outcomes(5, 0, attempt, lanes=lanes)
            b = big.attempt_outcomes(5, 0, attempt, lanes=lanes)
            for x, y in zip(a, b):
                np.testing.assert_array_equal(x, y)
