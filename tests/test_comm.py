"""repro.comm: codec round-trips, ledger bookkeeping, topology simulation,
pack kernels vs refs, and the HLO cross-check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CommLedger, Payload, analytic_bits, crosscheck_hlo,
                        decode, encode, get_topology, round_cost)
from repro.configs.base import SyncConfig
from repro.core import compressors as C
from repro.kernels import ops, ref


def _all_compressors():
    return [
        C.identity(),
        C.rand_k(0.25),
        C.top_k(0.05),
        C.block_top_k(0.1, block=64),
        C.qsgd(8, 64),
        C.qsgd(4, 64),
        C.qsgd(8, 64, stochastic=False),
        C.qsgd_sharded(8, 256),
        C.qsgd_kernel(8),
        C.mix_k(0.1, 0.3),
        C.comp_k(0.1, 0.5),
        C.scale_compressor(C.rand_k(0.25), 0.7),
        C.scale_compressor(C.qsgd(8, 64), 0.5),
    ]


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("comp", _all_compressors(), ids=lambda c: c.name)
def test_roundtrip_exact_every_compressor(comp):
    """decode(encode(x)) == compressor(x), elementwise exact."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (1000,)) * 3
    y = comp(key, x)
    y_hat = decode(encode(comp, key, x))
    assert bool(jnp.all(jnp.asarray(y) == jnp.asarray(y_hat)))


@pytest.mark.parametrize("comp", [C.qsgd_sharded(8, 256), C.top_k(0.1),
                                  C.qsgd_kernel(8)], ids=lambda c: c.name)
def test_roundtrip_exact_2d(comp):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 256))
    assert bool(jnp.all(comp(key, x) == decode(encode(comp, key, x))))


def test_bitmap_scheme_roundtrip():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(jax.random.PRNGKey(5), (777,))
    comp = C.top_k(0.2)
    p = encode(comp, key, x, scheme="sparse_bitmap")
    assert bool(jnp.all(comp(key, x) == decode(p)))
    # bitmap beats idx32 once k/d > 1/32
    assert p.nbytes < encode(comp, key, x).nbytes


def test_encoded_size_matches_analytic_model():
    """Acceptance: top-k @ k/d=0.05 and qsgd int8 within 10% of payload_bits."""
    key = jax.random.PRNGKey(0)
    d = 1 << 16
    x = jax.random.normal(jax.random.PRNGKey(1), (d,))
    for comp in (C.top_k(0.05), C.qsgd(8), C.qsgd_sharded(8, 256)):
        p = encode(comp, key, x)
        assert abs(8.0 * p.nbytes / analytic_bits(comp, d) - 1.0) <= 0.10, comp.name


def test_payload_nbytes_is_plane_sum_and_ledger_agrees():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (4096,))
    comp = C.top_k(0.05)
    p = encode(comp, key, x)
    assert p.nbytes == sum(v.nbytes for v in p.planes.values())
    led = CommLedger()
    led.record_payload(0, "a->b", p)
    assert led.total_bytes == p.nbytes
    assert led.total_bits == p.nbits


# ---------------------------------------------------------------------------
# pack kernels vs refs (interpret mode)
# ---------------------------------------------------------------------------
def test_pack_mask_kernel_vs_ref():
    from repro.kernels import bitpack

    mask = (jax.random.uniform(jax.random.PRNGKey(0), (32, 256)) < 0.3)
    mask = mask.astype(jnp.uint32)
    words = bitpack.pack_mask_2d(mask)
    np.testing.assert_array_equal(np.asarray(words),
                                  np.asarray(ref.pack_mask_ref(mask)))
    back = bitpack.unpack_mask_2d(words)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(mask))
    np.testing.assert_array_equal(np.asarray(ref.unpack_mask_ref(words)),
                                  np.asarray(mask))


@pytest.mark.parametrize("d", [31, 32, 1000, 32 * 128, 32 * 128 + 5])
def test_pack_bits_roundtrip_flat(d):
    mask = (jax.random.uniform(jax.random.PRNGKey(d), (d,)) < 0.1).astype(jnp.uint32)
    words = ops.pack_bits(mask)
    assert words.shape[0] == -(-d // 32)
    np.testing.assert_array_equal(np.asarray(ops.unpack_bits(words, d)),
                                  np.asarray(mask))


def test_quant_pack_kernel_vs_ref():
    from repro.kernels import bitpack, quant8

    rows = quant8.TILE_ROWS * 2
    x = jax.random.normal(jax.random.PRNGKey(0), (rows, quant8.QBLOCK)) * 7
    noise = jax.random.uniform(jax.random.PRNGKey(1), x.shape)
    q, scales = bitpack.quant_pack_2d(x, noise, bits=8)
    qr, sr = ref.quant_pack_ref(x, noise, bits=8)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(sr), rtol=1e-7)
    # unpack-dequant inverts to the fused quantize-dequantize carrier
    dq = bitpack.unpack_dequant_2d(q, scales)
    np.testing.assert_array_equal(
        np.asarray(dq), np.asarray(ref.quant_dequant_ref(x, noise, bits=8)))


def test_quantize_pack_matches_carrier():
    """ops.quantize_pack planes dequantize to ops.quantize_dequantize exactly."""
    x = jax.random.normal(jax.random.PRNGKey(7), (3000,)) * 4
    key = jax.random.PRNGKey(8)
    q, scales = ops.quantize_pack(x, key, bits=8)
    np.testing.assert_array_equal(
        np.asarray(ops.unpack_dequantize(q, scales, 3000)),
        np.asarray(ops.quantize_dequantize(x, key, bits=8)))


def test_nibble_pack_roundtrip():
    q = jnp.asarray(np.random.default_rng(0).integers(-8, 8, size=333), jnp.int8)
    packed = ops.nibble_pack(q)
    assert packed.nbytes == (333 + 1) // 2
    np.testing.assert_array_equal(np.asarray(ops.nibble_unpack(packed, 333)),
                                  np.asarray(q))


# ---------------------------------------------------------------------------
# ledger
# ---------------------------------------------------------------------------
def test_ledger_aggregates():
    led = CommLedger()
    led.record(0, "a->b", 100, kind="intra", phase=0)
    led.record(0, "b->c", 50, kind="inter", phase=1)
    led.record(1, "a->b", 100, kind="intra", phase=0)
    assert led.total_bytes == 250
    assert led.n_rounds() == 2
    assert led.bytes_by_round() == {0: 150, 1: 100}
    assert led.bytes_by_kind() == {"intra": 200, "inter": 50}
    assert led.bytes_by_link() == {"a->b": 200, "b->c": 50}
    assert led.cumulative_bytes() == [150, 250]
    assert led.bits_per_node(10) == 200.0


def test_ledger_round_time_phases_serialize_links_parallel():
    topo = get_topology("geo_wan")
    led = CommLedger()
    # two parallel intra links in phase 0, one inter link in phase 1
    led.record(0, "w0->hub", 10_000, kind="intra", phase=0)
    led.record(0, "w1->hub", 10_000, kind="intra", phase=0)
    led.record(0, "hub->root", 10_000, kind="inter", phase=1)
    t = led.round_time_s(topo, 0)
    t_intra = topo.intra.time_s(10_000)
    t_inter = topo.inter.time_s(10_000)
    assert t == pytest.approx(t_intra + t_inter)  # phases add, links overlap
    assert led.total_time_s(topo) == pytest.approx(t)


def test_crosscheck_hlo_against_parser():
    """Ledger totals audit against the HLO collective-bytes parser."""
    from repro.launch.hlo_analysis import collective_bytes

    hlo = "  %ar = f32[1000] all-reduce(f32[1000] %p), replica_groups={{0,1}}"
    stats = collective_bytes(hlo)
    led = CommLedger()
    led.record(0, "allreduce", 4000, kind="intra")
    chk = crosscheck_hlo(led, stats)
    assert chk["consistent"] and chk["ratio"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------
def test_topology_presets_and_ring_model():
    topo = get_topology("v5p_superpod")
    assert topo.n_devices == 512
    nb = 1 << 20
    # ring all-reduce moves ~2x the buffer; must exceed a point-to-point send
    assert topo.allreduce_time_s(nb, "intra") > topo.intra.time_s(nb)
    # global (hierarchical) schedule is dominated by the slow inter ring
    assert topo.allreduce_time_s(nb, "global") > topo.allreduce_time_s(nb, "intra")
    with pytest.raises(KeyError):
        get_topology("nope")
    with pytest.raises(KeyError):
        topo.link("sideways")


def test_streamed_time_never_beats_bandwidth_or_latency_floor():
    """The pipelined model must respect two physical floors at EVERY tile
    size: the bandwidth-only bound (bytes / link rate) and the per-message
    latency of one full pass (a ring pays its 2*(g-1) step latencies per
    tile; in-flight overlap can hide all but the first pass, never more).
    The old model amortized the ring latency over the tile count, so a
    codec-bound stream could undercut the serial path's latency floor."""
    topo = get_topology("edge_fl")  # 100-pod ring, 50 ms per step: latency-bound
    nbytes = 5e6
    for scope, g, link in (("inter", topo.n_pods, topo.inter),
                           ("intra", topo.devices_per_pod, topo.intra)):
        lat_floor, bw_floor = topo.allreduce_parts_s(nbytes, scope)
        for tile in (1 << 12, 1 << 16, 1 << 20, 1 << 24):
            t = topo.allreduce_stream_time_s(nbytes, scope, tile)
            assert t >= bw_floor
            assert t >= lat_floor
    # point-to-point: never beats bytes/bandwidth nor one hop latency
    link = topo.inter
    for tile in (1 << 12, 1 << 16, 1 << 20):
        t = link.stream_time_s(nbytes, tile)
        assert t >= nbytes / (link.gbps * 1e9)
        assert t >= link.latency_us * 1e-6


def test_streamed_allreduce_charges_full_ring_latency_when_codec_bound():
    """Regression for the amortized-latency bug: with a slow codec and many
    tiles, the streamed collective still pays the whole 2*(g-1)*latency ring
    fill (the serial path's per-message charge), not latency/n_tiles."""
    from repro.comm import CodecProfile

    topo = get_topology("edge_fl")
    slow_codec = CodecProfile(pack_gbps=0.01, unpack_gbps=0.01)
    nbytes = 64e6  # 64 tiles at 1 MB
    lat_floor, _ = topo.allreduce_parts_s(nbytes, "inter")  # 9.9 s of steps
    t = topo.allreduce_stream_time_s(nbytes, "inter", 1 << 20, slow_codec)
    assert lat_floor == pytest.approx(2 * 99 * 50e-3)
    assert t >= lat_floor + slow_codec.pack_s(nbytes)  # fill + steady state
    # and it still beats the serial path (pipelining helps, floor respected)
    assert t < topo.allreduce_serial_time_s(nbytes, "inter", slow_codec)


def test_measured_bits_extrapolation_crosscheck_4x_probe_cap():
    """Satellite acceptance: beyond PROBE_CAP the index planes are sized
    analytically from the true d.  Cross-check at n = 4 * PROBE_CAP against
    a genuine full-size encode for each sparse family + a quantizer."""
    from repro.comm import payload_bits_for
    from repro.comm.accounting import PROBE_CAP

    d = 4 * PROBE_CAP
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    for comp in (C.top_k(0.05), C.block_top_k(0.05), C.qsgd(8),
                 C.qsgd_sharded(8, 256)):
        est = payload_bits_for(comp, d, key=key)
        true = encode(comp, key, x).nbits
        # k rounds once per probe vs once at full size: sub-0.1% slack
        assert abs(est / true - 1.0) < 1e-3, comp.name


def test_round_cost_hier_faster_than_dense_on_slow_links():
    """Cohort-Squeeze's point: compressed + amortized inter-pod sync wins."""
    n = 100_000
    topo = get_topology("geo_wan")
    dense = round_cost(SyncConfig(mode="dense"), n, topology=topo)
    hier = round_cost(SyncConfig(mode="hier", compressor="qsgd", quant_bits=8,
                                 sync_period=8), n, topology=topo)
    assert hier.time_s < dense.time_s
    assert hier.inter_bytes < dense.inter_bytes / 8


# ---------------------------------------------------------------------------
# compressor plumbing regressions (satellites)
# ---------------------------------------------------------------------------
def test_scale_compressor_keeps_flatten_and_wire():
    base = C.qsgd_sharded(8, 256)
    sc = C.scale_compressor(base, 0.5)
    assert sc.flatten is False  # was silently reset to True before
    assert sc.wire is not None and sc.wire.gain == pytest.approx(0.5)
    # scaling twice composes the gain
    assert C.scale_compressor(sc, 0.5).wire.gain == pytest.approx(0.25)
    # and the scaled sharded compressor still preserves 2D shapes unflattened
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256))
    assert sc(jax.random.PRNGKey(1), x).shape == x.shape
