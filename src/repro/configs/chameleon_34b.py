"""Chameleon-34B. [arXiv:2405.09818]

Early-fusion mixed-modal: images are VQ-tokenized into the same 65536-entry
vocabulary, so the backbone consumes one interleaved token stream (the VQ-GAN
tokenizer is the stubbed frontend).  Uses QK-norm for training stability.
Full causal attention -> long_500k skipped (quadratic decode memory).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="chameleon-34b",
        family="vlm",
        citation="arXiv:2405.09818",
        num_layers=48,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=65536,
        qk_norm=True,
        mlp_act="silu",
        mlp_gated=True,
        supports_long_context=False,
    )
)
