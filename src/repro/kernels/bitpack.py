"""Pallas TPU kernels for wire-format packing (repro.comm codecs).

Two kernel families back the payload codecs:

  * ``pack_mask_2d`` / ``unpack_mask_2d`` — 1-bit mask <-> uint32 words.
    Sparsifier payloads ship a presence bitmap (1 bit per coordinate) next to
    the kept values; packing 32 mask bits into one word is a pure VPU
    reduction.  Layout: the (32, C) input block is reduced along the sublane
    axis — bit j of word [0, c] is mask[j, c] — so the word stream for a flat
    vector uses a stride-W bit order (see kernels/ops.pack_bits for the host
    view).  Lanes stay 128-aligned; no in-kernel reshapes.

  * ``quant_pack_2d`` / ``unpack_dequant_2d`` — fused blockwise absmax
    quantize straight to the int8 wire plane + per-block fp32 scales, and the
    inverse.  Unlike kernels/quant8 (quantize-*dequantize*, the on-chip
    compressor carrier) these emit the actual transport buffers: one VMEM pass
    produces what goes on the wire, instead of quantize -> dequantize ->
    re-quantize on the host.

Pure-jnp oracles live in kernels/ref.py; ``interpret`` defaults to True for
the CPU validation container and is flipped off on real TPUs by the launcher.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quant8 import QBLOCK, TILE_ROWS

PACK_BITS = 32      # bits per packed word (uint32)
PACK_LANES = 128    # lane tile for the word axis


# ---------------------------------------------------------------------------
# mask bitpack
# ---------------------------------------------------------------------------
def _pack_kernel(mask_ref, out_ref):
    bits = mask_ref[...].astype(jnp.uint32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, dimension=0)
    out_ref[...] = jnp.sum(bits << shifts, axis=0, keepdims=True).astype(jnp.uint32)


def _unpack_kernel(words_ref, out_ref):
    words = words_ref[...]
    shifts = jax.lax.broadcasted_iota(jnp.uint32, out_ref.shape, dimension=0)
    out_ref[...] = ((jnp.broadcast_to(words, out_ref.shape) >> shifts)
                    & jnp.uint32(1)).astype(jnp.uint32)


def pack_mask_2d(mask2d: jax.Array, interpret: bool = True) -> jax.Array:
    """(32, C) {0,1} mask -> (1, C) uint32 words; C % PACK_LANES == 0."""
    rows, c = mask2d.shape
    assert rows == PACK_BITS and c % PACK_LANES == 0, (mask2d.shape,)
    grid = (c // PACK_LANES,)
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((PACK_BITS, PACK_LANES), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, PACK_LANES), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, c), jnp.uint32),
        interpret=interpret,
    )(mask2d.astype(jnp.uint32))


def unpack_mask_2d(words2d: jax.Array, interpret: bool = True) -> jax.Array:
    """(1, C) uint32 words -> (32, C) {0,1} uint32 mask."""
    one, c = words2d.shape
    assert one == 1 and c % PACK_LANES == 0, (words2d.shape,)
    grid = (c // PACK_LANES,)
    return pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, PACK_LANES), lambda j: (0, j))],
        out_specs=pl.BlockSpec((PACK_BITS, PACK_LANES), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((PACK_BITS, c), jnp.uint32),
        interpret=interpret,
    )(words2d)


# ---------------------------------------------------------------------------
# fused quantize-pack / unpack-dequantize
# ---------------------------------------------------------------------------
def _quant_pack_kernel(x_ref, noise_ref, q_ref, scale_ref, *, s_levels: int):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / s_levels
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.floor(x / scale + noise_ref[...])      # noise in [0,1): stochastic
    q = jnp.clip(q, -s_levels, s_levels)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


def _unpack_dequant_kernel(q_ref, scale_ref, out_ref):
    out_ref[...] = (q_ref[...].astype(jnp.float32) * scale_ref[...]).astype(
        out_ref.dtype)


def quant_pack_2d(x2d: jax.Array, noise2d: jax.Array, bits: int = 8,
                  interpret: bool = True):
    """(rows, QBLOCK) -> (int8 plane (rows, QBLOCK), fp32 scales (rows, 1)).

    Same math as quant8.quant_dequant_2d but emits the wire planes; the two
    kernels agree bit-for-bit (q * scale reproduces the dequantized carrier).
    """
    rows, qb = x2d.shape
    assert qb == QBLOCK and rows % TILE_ROWS == 0, (x2d.shape,)
    s = 2 ** (bits - 1) - 1
    grid = (rows // TILE_ROWS,)
    return pl.pallas_call(
        functools.partial(_quant_pack_kernel, s_levels=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_ROWS, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, QBLOCK), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_ROWS, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, qb), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2d, noise2d)


def unpack_dequant_2d(q2d: jax.Array, scales: jax.Array, out_dtype=jnp.float32,
                      interpret: bool = True) -> jax.Array:
    """Inverse of quant_pack_2d: int8 plane + (rows, 1) scales -> dense."""
    rows, qb = q2d.shape
    assert qb == QBLOCK and rows % TILE_ROWS == 0, (q2d.shape,)
    assert scales.shape == (rows, 1), (scales.shape,)
    grid = (rows // TILE_ROWS,)
    return pl.pallas_call(
        _unpack_dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_ROWS, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, qb), out_dtype),
        interpret=interpret,
    )(q2d, scales)
