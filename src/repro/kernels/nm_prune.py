"""Pallas TPU kernel: N:M structured sparsity mask application.

Given a weight tile and a pruning-score tile, keep the N highest-scoring
entries of every contiguous group of M along the input dim and zero the rest
(SymWanda Tab. 6.6 / 2:4 semi-structured setting).  Rank-within-group is
computed with compare-count (no sort): for group element i,
    rank_i = #{k : s_k > s_i} + #{k < i : s_k == s_i}
which is exact, branch-free and vectorizes on the VPU (M is small: 4).

Tiles: (TILE_R, TILE_C) of the (d_in, d_out) weight; groups run along d_in
(rows), so TILE_R is a multiple of M.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 128
TILE_C = 128


def _nm_kernel(w_ref, s_ref, out_ref, mask_ref, *, n: int, m: int):
    w = w_ref[...]
    s = s_ref[...].astype(jnp.float32)
    R, C = s.shape
    g = s.reshape(R // m, m, C)
    # rank by compare-count with index tie-break (static M-loop, VPU-friendly)
    idx = jnp.arange(m).reshape(1, m, 1)
    ranks = []
    for i in range(m):
        si = g[:, i : i + 1, :]
        greater = jnp.sum((g > si).astype(jnp.float32), axis=1, keepdims=True)
        ties = jnp.sum(((g == si) & (idx < i)).astype(jnp.float32),
                       axis=1, keepdims=True)
        ranks.append(greater + ties)
    rank = jnp.concatenate(ranks, axis=1)
    keep = (rank < n).astype(w.dtype).reshape(R, C)
    mask_ref[...] = keep
    out_ref[...] = w * keep


def nm_prune_2d(w: jax.Array, scores: jax.Array, n: int = 2, m: int = 4,
                interpret: bool = True):
    """w, scores: (d_in, d_out) with d_in % TILE_R == 0, d_out % TILE_C == 0.
    Returns (pruned w, mask)."""
    d_in, d_out = w.shape
    assert d_in % TILE_R == 0 and d_out % TILE_C == 0 and TILE_R % m == 0
    grid = (d_in // TILE_R, d_out // TILE_C)
    spec = pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_nm_kernel, n=n, m=m),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
        ],
        interpret=interpret,
    )(w, scores)
