"""SymWanda / RIA / R2-DSnoT tests (Ch. 6), incl. kernel cross-validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container lacks hypothesis: deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import symwanda as sw
from repro.kernels import ops as kops


@pytest.fixture(scope="module")
def layer():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    d_in, d_out, T = 256, 128, 384
    W = jax.random.normal(k1, (d_in, d_out)) / np.sqrt(d_in)
    scales = jnp.exp(jax.random.normal(k2, (d_in,)))
    X = jax.random.normal(k3, (T, d_in)) * scales + scales * 0.3
    return W, X


def test_wanda_beats_magnitude(layer):
    W, X = layer
    e = {}
    for m in ("magnitude", "wanda", "ria", "symwanda"):
        Wp, _ = sw.prune(W, X, method=m, sparsity=0.5)
        e[m] = float(sw.reconstruction_error(W, Wp, X))
    assert e["wanda"] < e["magnitude"]          # the paper's core observation
    assert e["ria"] < e["magnitude"]
    assert e["symwanda"] < e["magnitude"]


def test_symwanda_recovers_wanda_at_beta1(layer):
    W, X = layer
    s_sym = sw.score_symwanda(W, X, beta=1.0)
    s_wanda = sw.score_wanda(W, X)
    # beta=1: same ordering (scores differ by a global normalizer)
    ra = jnp.argsort(s_sym.reshape(-1))
    rb = jnp.argsort(s_wanda.reshape(-1))
    assert float(jnp.mean(ra == rb)) > 0.99


@settings(max_examples=10, deadline=None)
@given(sp=st.sampled_from([0.3, 0.5, 0.7]))
def test_mask_sparsity_exact(layer, sp):
    W, X = layer
    _, mask = sw.prune(W, X, method="wanda", sparsity=sp)
    got = 1 - float(mask.mean())
    assert abs(got - sp) < 0.02


def test_nm_structure(layer):
    W, X = layer
    _, mask = sw.prune(W, X, method="ria", structured_nm=(2, 4))
    m = np.asarray(mask).T.reshape(W.shape[1], W.shape[0] // 4, 4)
    assert (m.sum(-1) == 2).all()


def test_dsnot_improves_reconstruction(layer):
    W, X = layer
    Wp, mask = sw.prune(W, X, method="wanda", sparsity=0.6)
    e0 = float(sw.reconstruction_error(W, Wp, X))
    Wd, md = sw.r2_dsnot(W, mask, X, sw.DSnoTConfig(iters=30))
    e1 = float(sw.reconstruction_error(W, Wd, X))
    assert e1 < e0
    assert abs(float(md.mean()) - float(mask.mean())) < 1e-6  # sparsity preserved


def test_stochria_close_to_ria(layer):
    W, X = layer
    full = sw.score_ria(W, X)
    sub = sw.score_stochria(W, X, key=jax.random.PRNGKey(0), sample_frac=0.25)
    # rankings approximately agree => pruning decisions mostly identical
    mf = sw.mask_unstructured(full, 0.5)
    ms = sw.mask_unstructured(sub, 0.5)
    assert float((mf == ms).mean()) > 0.8


# ---------------------------------------------------------------------------
# kernels agree with the core module
# ---------------------------------------------------------------------------
def test_kernel_wanda_matches_module(layer):
    W, X = layer
    Wp_mod, m_mod = sw.prune(W, X, method="wanda", sparsity=0.5)
    Wp_k, m_k = kops.prune_scored(W, X, mode="wanda", sparsity=0.5)
    np.testing.assert_allclose(np.asarray(m_mod), np.asarray(m_k))
    np.testing.assert_allclose(np.asarray(Wp_mod), np.asarray(Wp_k), rtol=1e-6)


def test_kernel_nm_matches_module(layer):
    W, X = layer
    s = sw.score_wanda(W, X)
    m_mod = sw.mask_nm(s, 2, 4)
    _, m_k = kops.prune_nm(W, s, 2, 4)
    np.testing.assert_allclose(np.asarray(m_mod), np.asarray(m_k))
