from repro.data.synthetic import SyntheticLMDataset, lm_batch_iterator
from repro.data.federated import (
    FederatedLogReg,
    make_logreg_clients,
    dirichlet_split,
    classwise_split,
)
