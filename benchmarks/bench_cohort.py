"""Cohort-simulator benchmark: million-client populations, jitted rounds.

Sweeps population x cohort x link-class mix on ``edge_fl_tree`` and pins the
three properties the cohort engine exists for:

* a full federated round over >= 10^5 sampled clients — broadcast, bucketed
  FLIX local steps, per-class compressed uplink, the whole anchor cascade —
  runs as ONE jitted sweep (the headline ``round_pop1e6_c1e5`` row, kept at
  full size even under ``BENCH_SMOKE=1``);
* memory scales with the cohort, never the population: staged host bytes and
  retained device bytes are identical across a 10x population change at a
  fixed cohort, and grow with the cohort (``mem_*`` rows, asserted);
* bytes are attributed analytically per link class x level and certified
  against a materialized small-N payload oracle (``ledger_oracle`` row,
  asserted byte-exact), with the 16-leaf engine bitwise-identical to the
  per-client ``tree_param_sync`` loop (``bitident16`` row, asserted).

Byte-bearing rows use availability/drop faults only — pure counter-PRNG
threshold draws, so survivor counts (and therefore bytes) are exact across
platforms; straggler/deadline processes go through libm exp/log and could
flip borderline survivors between CI machines.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (device_live_bytes, host_peak_rss_mb, now_s,
                               timed)
from repro.cohort import (CohortEngine, LinkClass, Population,
                          flix_local_step, materialized_round_bytes)
from repro.comm.topology import Link
from repro.comm.tree import TreeLevel, TreeTopology, register_tree_topology
from repro.core import distributed as dist
from repro.faults import FaultConfig

# availability + drop only: analytic bytes stay platform-exact (see module
# docstring)
BYTE_FAULTS = FaultConfig(seed=11, availability=0.9, drop_rate=0.05)


def _smoke() -> bool:
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0")


# ---------------------------------------------------------------------------
# headline: one jitted round over 1e5 clients from a 1e6 population
# ---------------------------------------------------------------------------
def _headline_rows():
    pop = Population(n_clients=1_000_000, dim=32)
    eng = CohortEngine(pop, cohort_size=100_000, fault_config=BYTE_FAULTS)
    state = eng.init_state()
    t0 = now_s()
    state, rep = eng.round(state, 0)              # includes jit compile
    compile_s = now_s() - t0

    holder = {"state": state, "rnd": 1}

    def one_round():
        holder["state"], holder["rep"] = eng.round(holder["state"],
                                                   holder["rnd"])
        holder["rnd"] += 1

    us = timed(one_round, repeats=3, warmup=1)
    rep = holder["rep"]
    return [
        ("cohort/round_pop1e6_c1e5", us,
         f"bytes={rep.bytes.total_bytes};parts={rep.n_participants};"
         f"compile_s={compile_s:.1f};tdist={rep.metrics['target_dist']:.4f};"
         f"peak_rss_mb={host_peak_rss_mb():.0f}"),
    ]


# ---------------------------------------------------------------------------
# memory: O(cohort), not O(population)
# ---------------------------------------------------------------------------
def _mem_round(n_pop: int, cohort: int):
    pop = Population(n_clients=n_pop, dim=32)
    eng = CohortEngine(pop, cohort_size=cohort)
    before = device_live_bytes()
    state, rep = eng.round(eng.init_state(), 0)
    jax.block_until_ready(state.anchors[-1]["x"])
    retained = device_live_bytes() - before
    return rep.staged_nbytes, retained


def _mem_rows():
    cohort = 2_000
    staged_a, dev_a = _mem_round(100_000, cohort)
    staged_b, dev_b = _mem_round(1_000_000, cohort)
    # 10x the population, identical footprint: every staged/retained array is
    # shaped by the cohort (clients exist only while sampled)
    assert staged_a == staged_b, (staged_a, staged_b)
    assert dev_a == dev_b, (dev_a, dev_b)
    staged_c, dev_c = _mem_round(1_000_000, 4 * cohort)
    # per-round arrays are O(cohort); the device state retained BETWEEN
    # rounds is the anchor cascade — O(tree infrastructure), so it does not
    # grow with the cohort either (stateless clients leave nothing behind)
    assert staged_c > 3 * staged_a, (staged_c, staged_a)
    assert dev_c == dev_a, (dev_c, dev_a)
    return [
        ("cohort/mem_pop_invariant", 0.0,
         f"staged_pop1e5={staged_a};staged_pop1e6={staged_b};"
         f"dev_pop1e5={dev_a};dev_pop1e6={dev_b};equal=True"),
        ("cohort/mem_cohort_scaling", 0.0,
         f"staged_c2k={staged_a};staged_c8k={staged_c};"
         f"dev_retained_c2k={dev_a};dev_retained_c8k={dev_c};"
         f"peak_rss_mb={host_peak_rss_mb():.0f}"),
    ]


# ---------------------------------------------------------------------------
# bit-exactness: 16-leaf engine == per-client tree_param_sync loop
# ---------------------------------------------------------------------------
def _bitident_pop() -> Population:
    register_tree_topology(TreeTopology("cohort_bitident16", (
        TreeLevel("uplink", 4, Link(gbps=0.00625, latency_us=50_000.0)),
        TreeLevel("metro", 2, Link(gbps=1.0, latency_us=2_000.0)),
        TreeLevel("wan", 2, Link(gbps=1.0, latency_us=20_000.0)),
    )))
    only = (LinkClass("only", 1.0, Link(gbps=0.00625, latency_us=50_000.0),
                      compressor="top_k", compress_ratio=0.25),)
    return Population(n_clients=5_000, dim=32, tree="cohort_bitident16",
                      classes=only)


def _client_local(xi, target, alpha, m, lr):
    """One client's local steps, scanned independently (the per-client
    reference the engine's vectorized bucketed sweep must reproduce)."""
    def body(x, _):
        return flix_local_step(x, target, alpha, lr), None
    xi, _ = jax.lax.scan(body, xi, None, length=int(m))
    return xi


def reference_round(eng: CohortEngine, state, rnd: int):
    """The per-client loop: materialize every sampled client, run its local
    steps one client at a time, then one direct ``tree_param_sync`` call."""
    ids = eng.round_cohort(rnd)
    spec = eng.pop.client_spec(ids)
    plan = eng.round_plan(rnd, ids, spec.class_ids)
    smasks = plan.survivor_masks() if plan is not None else None
    masks = (tuple(jnp.asarray(m) for m in smasks)
             if smasks is not None else None)
    x0 = jnp.repeat(state.anchors[0]["x"], eng.cascade[0].fanout, axis=0)
    rows = [_client_local(x0[i], jnp.asarray(spec.targets[i]),
                          jnp.float32(spec.flix_alpha[i]),
                          spec.n_samples[i], eng.lr)
            for i in range(x0.shape[0])]
    _, new_state = dist.tree_param_sync(
        eng.round_key(rnd), {"x": jnp.stack(rows)}, state, eng.cascade,
        bucket_size=eng.pop.dim, survivors=masks)
    return new_state


def _bitident_rows():
    pop = _bitident_pop()
    results = []
    for label, cfg in (("nofault", None),
                       ("faulted", FaultConfig(seed=3, availability=0.7,
                                               drop_rate=0.1))):
        eng = CohortEngine(pop, cohort_size=16, fault_config=cfg)
        se, sr = eng.init_state(), eng.init_state()
        for rnd in range(3):
            se, rep = eng.round(se, rnd)
            sr = reference_round(eng, sr, rnd)
            for l, (a, b) in enumerate(zip(se.anchors, sr.anchors)):
                ae, ar = np.asarray(a["x"]), np.asarray(b["x"])
                assert ae.tobytes() == ar.tobytes(), (label, rnd, l)
        results.append((label, rep))
    return [
        ("cohort/bitident16", 0.0,
         f"bytes={results[0][1].bytes.total_bytes};rounds=3;bitwise=True;"
         f"faulted_parts={results[1][1].n_participants}"),
    ]


# ---------------------------------------------------------------------------
# ledger: analytic attribution == materialized payload oracle
# ---------------------------------------------------------------------------
def _oracle_rows():
    pop = Population(n_clients=50_000, dim=32)
    eng = CohortEngine(pop, cohort_size=80, fault_config=BYTE_FAULTS)
    state = eng.init_state()
    checked = 0
    for rnd in range(2):
        state, rep = eng.round(state, rnd)
        smasks = (rep.plan.survivor_masks()
                  if rep.plan is not None else None)
        oracle = materialized_round_bytes(
            rnd, rep.class_ids, pop.classes, eng.upper_compressors,
            eng.tree, pop.dim, smasks)
        assert rep.bytes.total_bytes == oracle, (rnd, rep.bytes, oracle)
        checked += 1
    by_level = rep.bytes.by_level(eng.tree)
    lv = ";".join(f"{k}={v}" for k, v in by_level.items())
    return [
        ("cohort/ledger_oracle_n80", 0.0,
         f"bytes={rep.bytes.total_bytes};rounds={checked};exact=True;{lv}"),
    ]


# ---------------------------------------------------------------------------
# bucketing: padded scan work vs max-padding
# ---------------------------------------------------------------------------
def _bucket_rows():
    pop = Population(n_clients=1_000_000, dim=32)
    eng = CohortEngine(pop, cohort_size=20_000)
    spec = pop.client_spec(eng.round_cohort(0))
    cb = eng.buckets(spec.n_samples)
    maxpad = eng.cohort_size * pop.samples_max
    ratio = cb.padded_steps / maxpad
    assert ratio < 1.0, ratio
    return [
        ("cohort/bucket_speedup", 0.0,
         f"padded_steps={cb.padded_steps};maxpad={maxpad};"
         f"work_ratio={ratio:.3f};buckets={len(cb.boundaries)}"),
    ]


# ---------------------------------------------------------------------------
# sweep: population x cohort x class mix
# ---------------------------------------------------------------------------
def _sweep_rows():
    grid = [(200_000, 2_000), (1_000_000, 2_000)]
    if not _smoke():
        grid += [(1_000_000, 20_000)]
    rows = []
    for n_pop, cohort in grid:
        pop = Population(n_clients=n_pop, dim=32)
        eng = CohortEngine(pop, cohort_size=cohort,
                           fault_config=BYTE_FAULTS)
        state, rep = eng.round(eng.init_state(), 0)
        holder = {"s": state, "r": 1}

        def one(eng=eng, holder=holder):
            holder["s"], _ = eng.round(holder["s"], holder["r"])
            holder["r"] += 1

        us = timed(one, repeats=3, warmup=0)
        mix = ",".join(str(c) for c in pop.class_mix_counts(rep.cohort_ids))
        rows.append((f"cohort/sweep_pop{n_pop//1000}k_c{cohort//1000}k", us,
                     f"bytes={rep.bytes.total_bytes};"
                     f"parts={rep.n_participants};mix={mix}"))
    return rows


def run():
    rows = []
    rows += _bitident_rows()
    rows += _oracle_rows()
    rows += _bucket_rows()
    rows += _sweep_rows()
    rows += _mem_rows()
    rows += _headline_rows()
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
