"""Compression operators and the C(eta, omega) calculus (Ch. 2, EF-BV).

The dissertation's unified compressor class C(eta, omega) bounds
  (i)  || E[C(x)] - x ||        <= eta   ||x||      (relative bias)
  (ii) E|| C(x) - E[C(x)] ||^2  <= omega ||x||^2    (relative variance)

Implemented operators (all shape-preserving, "value-sparse"):
  * identity
  * rand-k           — unbiased sparsifier, U(omega) with omega = d/k - 1
  * top-k            — biased contractive, B(alpha) with alpha = k/d
                       (=> C(eta, 0) with eta = sqrt(1 - k/d))
  * block top-k      — top-k within fixed blocks (TPU-friendly); contractive
                       with alpha >= k/d (equality when energy is uniform)
  * qsgd (s-level)   — stochastic-rounding quantizer, unbiased; blockwise
                       absmax scaling; omega estimated empirically (the
                       classical bound min(d/s^2, sqrt(d)/s) applies to
                       2-norm scaling over the full vector)
  * mix-(k,k')       — mixture: top-k with prob rho else rand-k' (App. A.1.1)
  * comp-(k,k')      — composition: top-k applied to rand-k' output (A.1.2)
  * scale(C, lam)    — lam*C; Prop 2.2.1: eta' = lam*eta + 1 - lam,
                       omega' = lam^2 * omega

The optimal scalings of Prop 2.2.2 / Sect. 2.4:
  lambda* = min((1-eta) / ((1-eta)^2 + omega),     1)
  nu*     = min((1-eta) / ((1-eta)^2 + omega_ran), 1)
with omega_ran = omega/n for n independent compressors (Sect. 2.2.2).

Every operator also reports ``payload_bits(d)`` — the bits a real system puts
on the wire — used by the EXPERIMENTS bit-accounting exactly as the paper
plots Fig 2.2 (bits per node vs suboptimality).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class WireSpec:
    """How a compressor's output is packed on the wire (repro.comm.codecs).

    scheme: dense | sparse_idx32 | sparse_block | sparse_bitmap | quant
    block/bits: quantizer blocking; axis: "flat" (blocks over the flattened
    tensor), "last" (blocks along the last dim, sharding-safe), or "kernel"
    (the Pallas quantize-pack layout).  ``gain`` is a post-scale applied by
    scale_compressor — the receiver multiplies it back in after dequant.
    """
    scheme: str = "dense"
    block: int = 0
    bits: int = 32
    axis: str = "flat"
    gain: float = 1.0


@dataclass(frozen=True)
class Compressor:
    name: str
    fn: Callable            # (key, flat_x) -> flat_x_hat
    eta: Optional[float]    # relative bias bound (None = unknown, estimate)
    omega: Optional[float]  # relative variance bound
    bits_per_dim: float     # payload bits per coordinate of the input
    deterministic: bool = False
    # sharding-safe operators handle any shape themselves: reshape(-1) of a
    # 2D-sharded leaf forces a GSPMD all-gather, so they must NOT flatten
    flatten: bool = True
    # wire format for repro.comm.codecs.encode/decode (None -> dense)
    wire: Optional[WireSpec] = None

    def __call__(self, key, x):
        if not self.flatten:
            return self.fn(key, x)
        shape = x.shape
        out = self.fn(key, x.reshape(-1))
        return out.reshape(shape)

    def payload_bits(self, d: int) -> float:
        return self.bits_per_dim * d

    def contractive_alpha(self) -> Optional[float]:
        """1 - (eta^2 + omega) when < 1 (Eq. 2.3); None otherwise."""
        if self.eta is None or self.omega is None:
            return None
        r = self.eta**2 + self.omega
        return (1.0 - r) if r < 1 else None


# ---------------------------------------------------------------------------
# Scaling calculus (Prop 2.2.1 / 2.2.2)
# ---------------------------------------------------------------------------
def scale_compressor(c: Compressor, lam: float) -> Compressor:
    eta = None if c.eta is None else lam * c.eta + (1.0 - lam)
    omega = None if c.omega is None else lam**2 * c.omega
    wire = c.wire
    if wire is not None:
        wire = replace(wire, gain=wire.gain * lam)
    return Compressor(
        name=f"scale({c.name},{lam:.4g})",
        fn=lambda key, x, c=c, lam=lam: lam * c.fn(key, x),
        eta=eta,
        omega=omega,
        bits_per_dim=c.bits_per_dim,
        deterministic=c.deterministic,
        # keep the flatten flag: dropping it silently re-enabled the
        # reshape(-1) that forces a GSPMD all-gather on sharded leaves
        flatten=c.flatten,
        wire=wire,
    )


def lambda_star(eta: float, omega: float) -> float:
    return min((1.0 - eta) / ((1.0 - eta) ** 2 + omega), 1.0)


def nu_star(eta: float, omega_ran: float) -> float:
    return min((1.0 - eta) / ((1.0 - eta) ** 2 + omega_ran), 1.0)


def omega_ran_independent(omega: float, n: int) -> float:
    """Independent randomness across n workers: omega_ran = omega / n."""
    return omega / n


def efbv_rates(eta: float, omega: float, omega_ran: float, lam: float, nu: float):
    """r, r_av, s*, theta* from Sect. 2.4 (used for stepsize selection)."""
    r = (1 - lam + lam * eta) ** 2 + lam**2 * omega
    r_av = (1 - nu + nu * eta) ** 2 + nu**2 * omega_ran
    s_star = math.sqrt((1 + r) / (2 * r)) - 1
    theta_star = s_star * (1 + s_star) * r / max(r_av, 1e-30)
    return r, r_av, s_star, theta_star


def efbv_stepsize(L: float, L_tilde: float, eta: float, omega: float,
                  omega_ran: float, lam: float, nu: float) -> float:
    """Upper bound of Thm 2.4.1: gamma <= 1 / (L + L~ sqrt(r_av/r)/s*)."""
    r, r_av, s_star, _ = efbv_rates(eta, omega, omega_ran, lam, nu)
    if r >= 1 or s_star <= 0:
        return 1.0 / (2 * L)
    return 1.0 / (L + L_tilde * math.sqrt(r_av / r) / s_star)


# ---------------------------------------------------------------------------
# Operators
# ---------------------------------------------------------------------------
def identity() -> Compressor:
    return Compressor("identity", lambda key, x: x, eta=0.0, omega=0.0,
                      bits_per_dim=32.0, deterministic=True,
                      wire=WireSpec("dense"))


def rand_k(k_frac: float) -> Compressor:
    """Keep a uniformly random floor(k_frac*d) coordinates scaled by d/k."""

    def fn(key, x):
        d = x.shape[0]
        k = max(1, int(round(k_frac * d)))
        scores = jax.random.uniform(key, (d,))
        thresh = -jax.lax.top_k(-scores, k)[0][-1]  # k-th smallest
        mask = (scores <= thresh).astype(x.dtype)
        return x * mask * (d / k)

    omega = 1.0 / k_frac - 1.0
    return Compressor(f"rand_k({k_frac:g})", fn, eta=0.0, omega=omega,
                      bits_per_dim=k_frac * (32 + 32),
                      wire=WireSpec("sparse_idx32"))


def top_k(k_frac: float) -> Compressor:
    """Keep the floor(k_frac*d) largest-magnitude coordinates (global)."""

    def fn(key, x):
        d = x.shape[0]
        k = max(1, int(round(k_frac * d)))
        thresh = jax.lax.top_k(jnp.abs(x), k)[0][-1]
        mask = (jnp.abs(x) >= thresh).astype(x.dtype)
        return x * mask

    eta = math.sqrt(max(0.0, 1.0 - k_frac))
    return Compressor(f"top_k({k_frac:g})", fn, eta=eta, omega=0.0,
                      bits_per_dim=k_frac * (32 + 32), deterministic=True,
                      wire=WireSpec("sparse_idx32"))


def block_top_k(k_frac: float, block: int = 2048) -> Compressor:
    """Exact top-k within contiguous blocks — the TPU-friendly variant used by
    the compressed grad-sync (bounded VMEM working set, no global sort).
    Contractive with alpha >= k/d: within each block b,
    ||C(x_b)-x_b||^2 <= (1-k_b/|b|)||x_b||^2, and k_b/|b| = k_frac."""

    def fn(key, x):
        d = x.shape[0]
        nb = -(-d // block)
        pad = nb * block - d
        xp = jnp.pad(x, (0, pad)).reshape(nb, block)
        kb = max(1, int(round(k_frac * block)))
        thresh = jax.lax.top_k(jnp.abs(xp), kb)[0][:, -1:]
        mask = (jnp.abs(xp) >= thresh).astype(x.dtype)
        return (xp * mask).reshape(-1)[:d]

    eta = math.sqrt(max(0.0, 1.0 - k_frac))
    return Compressor(f"block_top_k({k_frac:g},{block})", fn, eta=eta, omega=0.0,
                      bits_per_dim=k_frac * (32 + math.log2(block)),
                      deterministic=True,
                      wire=WireSpec("sparse_block", block=block))


def qsgd(bits: int = 8, block: int = 2048, stochastic: bool = True) -> Compressor:
    """Blockwise absmax s-level quantizer; stochastic rounding => unbiased."""
    s = 2 ** (bits - 1) - 1

    def fn(key, x):
        d = x.shape[0]
        nb = -(-d // block)
        pad = nb * block - d
        xp = jnp.pad(x, (0, pad)).reshape(nb, block)
        scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / s
        scale = jnp.where(scale == 0, 1.0, scale)
        y = xp / scale
        if stochastic:
            noise = jax.random.uniform(key, y.shape, minval=-0.5, maxval=0.5)
            q = jnp.round(y + noise)
        else:
            q = jnp.round(y)
        q = jnp.clip(q, -s, s)
        return (q * scale).reshape(-1)[:d]

    # blockwise absmax stochastic rounding: per-coordinate error <= scale/2,
    # so variance <= block * scale^2/4 / ||x_b||^2 <= block/(4 s^2) (worst case
    # one dominant coordinate).  We report that worst-case bound.
    omega = block / (4.0 * s * s)
    return Compressor(f"qsgd({bits}b,{block})", fn,
                      eta=0.0 if stochastic else None,
                      omega=omega if stochastic else None,
                      bits_per_dim=float(bits),
                      deterministic=not stochastic,
                      wire=WireSpec("quant", block=block, bits=bits, axis="flat"))


def mix_k(k_frac_top: float, k_frac_rand: float, rho: float = 0.5) -> Compressor:
    """mix-(k,k') (App. A.1.1): top-k with prob rho, rand-k' with prob 1-rho."""
    t = top_k(k_frac_top)
    r = rand_k(k_frac_rand)

    def fn(key, x):
        k1, k2, k3 = jax.random.split(key, 3)
        coin = jax.random.uniform(k1) < rho
        return jnp.where(coin, t.fn(k2, x), r.fn(k3, x))

    bits = rho * t.bits_per_dim + (1 - rho) * r.bits_per_dim
    return Compressor(f"mix({k_frac_top:g},{k_frac_rand:g},{rho:g})", fn,
                      eta=None, omega=None, bits_per_dim=bits,
                      wire=WireSpec("sparse_idx32"))


def comp_k(k_frac_top: float, k_frac_rand: float) -> Compressor:
    """comp-(k,k') (App. A.1.2): top-k applied to the output of rand-k'
    (random support of size k', then the k largest among it, unscaled)."""

    def fn(key, x):
        d = x.shape[0]
        kr = max(1, int(round(k_frac_rand * d)))
        kt = max(1, int(round(k_frac_top * d)))
        scores = jax.random.uniform(key, (d,))
        thresh_r = -jax.lax.top_k(-scores, kr)[0][-1]
        sel = scores <= thresh_r
        masked = jnp.where(sel, jnp.abs(x), -jnp.inf)
        thresh_t = jax.lax.top_k(masked, kt)[0][-1]
        mask = (masked >= thresh_t).astype(x.dtype)
        return x * mask

    return Compressor(f"comp({k_frac_top:g},{k_frac_rand:g})", fn,
                      eta=None, omega=None,
                      bits_per_dim=k_frac_top * (32 + 32),
                      wire=WireSpec("sparse_idx32"))


def qsgd_sharded(bits: int = 8, block: int = 256, stochastic: bool = True) -> Compressor:
    """Sharding-safe qsgd: blocks run along the LAST axis only, so a
    (data, model)-sharded parameter leaf is quantized without the
    reshape(-1) that would force GSPMD to all-gather it (measured 1.3 TB/chip
    of temp in the hier param sync before this).  Falls back to a per-leaf
    scalar scale when the last dim doesn't block evenly."""
    s = 2 ** (bits - 1) - 1

    def fn(key, x):
        last = x.shape[-1] if x.ndim else 1
        if x.ndim >= 1 and last % block == 0:
            shaped = x.reshape(x.shape[:-1] + (last // block, block))
            scale = jnp.max(jnp.abs(shaped), axis=-1, keepdims=True) / s
        else:
            shaped = x
            scale = jnp.max(jnp.abs(x)) / s
        scale = jnp.where(scale == 0, 1.0, scale)
        y = shaped / scale
        if stochastic:
            noise = jax.random.uniform(key, y.shape)
            q = jnp.floor(y + noise)
        else:
            q = jnp.round(y)
        q = jnp.clip(q, -s, s) * scale
        return q.reshape(x.shape)

    return Compressor(f"qsgd_sharded({bits}b,{block})", fn,
                      eta=0.0 if stochastic else None,
                      omega=block / (4.0 * s * s) if stochastic else None,
                      bits_per_dim=float(bits), flatten=False,
                      wire=WireSpec("quant", block=block, bits=bits, axis="last"))


def qsgd_kernel(bits: int = 8, interpret: bool = True) -> Compressor:
    """qsgd backed by the fused Pallas quantize-dequantize kernel."""
    from repro.kernels.ops import quantize_dequantize
    from repro.kernels.quant8 import QBLOCK

    s = 2 ** (bits - 1) - 1

    def fn(key, x):
        return quantize_dequantize(x, key, bits=bits, interpret=interpret)

    return Compressor(f"qsgd_kernel({bits}b)", fn, eta=0.0,
                      omega=QBLOCK / (4.0 * s * s), bits_per_dim=float(bits),
                      wire=WireSpec("quant", block=QBLOCK, bits=bits, axis="kernel"))


_REGISTRY = {
    "identity": identity,
    "rand_k": rand_k,
    "top_k": top_k,
    "topk_block": block_top_k,
    "qsgd": qsgd,
    "qsgd_sharded": qsgd_sharded,
    "qsgd_kernel": qsgd_kernel,
    "mix_k": mix_k,
    "comp_k": comp_k,
}


def make_compressor(name: str, **kw) -> Compressor:
    if name not in _REGISTRY:
        raise KeyError(f"unknown compressor {name!r}; known {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kw)


# ---------------------------------------------------------------------------
# Empirical (eta, omega) estimation — used when closed forms are unknown
# (mix/comp) and to validate the closed forms property-style in tests.
# ---------------------------------------------------------------------------
def estimate_eta_omega(c: Compressor, key, dim: int, n_vectors: int = 16,
                       n_samples: int = 64) -> tuple:
    """Empirical sup over test vectors of relative bias / variance."""
    kv, ks = jax.random.split(key)
    xs = jax.random.normal(kv, (n_vectors, dim))
    # heavy-tailed probes stress top-k style operators
    xs = xs * jnp.exp(2.0 * jax.random.normal(jax.random.fold_in(kv, 1), (n_vectors, dim)))

    def one_vector(x, key):
        keys = jax.random.split(key, n_samples)
        ys = jax.vmap(lambda k: c(k, x))(keys)
        mean = jnp.mean(ys, axis=0)
        bias = jnp.linalg.norm(mean - x) / (jnp.linalg.norm(x) + 1e-12)
        var = jnp.mean(jnp.sum((ys - mean) ** 2, axis=-1)) / (jnp.sum(x**2) + 1e-12)
        return bias, var

    keys = jax.random.split(ks, n_vectors)
    biases, variances = jax.vmap(one_vector)(xs, keys)
    return float(jnp.max(biases)), float(jnp.max(variances))


# ---------------------------------------------------------------------------
# Pytree plumbing
# ---------------------------------------------------------------------------
def tree_compress(c: Compressor, key, tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [c(k, leaf) for k, leaf in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)
