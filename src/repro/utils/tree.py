"""Small pytree algebra used across the optimizer / compression stack.

These are intentionally dependency-free (no optax): the paper's algorithms
(EF-BV control variates, Scafflix client states, SPPM prox solvers) are all
expressed as pytree-to-pytree maps, so a tiny algebra keeps them readable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

tree_map = jax.tree_util.tree_map


def tree_size(tree) -> int:
    """Total number of scalar elements in the pytree."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total number of bytes of the pytree's leaves."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree):
    return tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return tree_map(jnp.subtract, a, b)


def tree_scale(s, tree):
    return tree_map(lambda x: s * x, tree)


def tree_dot(a, b) -> jax.Array:
    """Sum of elementwise products across two same-structure pytrees.

    NB: deliberately sum(x*y), NOT jnp.vdot — vdot's reshape(-1) cannot be
    represented on a 2D-sharded operand, so GSPMD would all-gather the full
    tensor (catastrophic for FSDP gradient clipping at 100B scale)."""
    parts = tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return jax.tree_util.tree_reduce(jnp.add, parts, jnp.zeros((), jnp.float32))


def tree_norm(tree) -> jax.Array:
    """Euclidean norm of the concatenated pytree."""
    return jnp.sqrt(tree_dot(tree, tree))


def global_norm(tree) -> jax.Array:
    return tree_norm(tree)
