from repro.training.steps import (
    TrainState,
    init_train_state,
    make_train_step,
    make_prefill_step,
    make_decode_step,
)
from repro.training.loop import train
from repro.training.checkpoint import save_checkpoint, load_checkpoint
