from repro.sharding.rules import (
    param_specs,
    opt_state_specs,
    batch_specs,
    cache_pspecs,
    maybe_axis,
    DATA_AXES,
)
