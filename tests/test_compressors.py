"""Property tests for the C(eta, omega) compressor contracts (Ch. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container lacks hypothesis: deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import compressors as C

DIMS = st.integers(min_value=8, max_value=300)


def _vec(key, d, heavy=False):
    x = jax.random.normal(key, (d,))
    if heavy:
        x = x * jnp.exp(2 * jax.random.normal(jax.random.fold_in(key, 1), (d,)))
    return x


# ---------------------------------------------------------------------------
# top-k: deterministic contraction  ||C(x)-x||^2 <= (1 - k/d) ||x||^2
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(d=DIMS, kf=st.sampled_from([0.05, 0.2, 0.5, 0.9]), seed=st.integers(0, 2**20))
def test_topk_contractive(d, kf, seed):
    x = _vec(jax.random.PRNGKey(seed), d, heavy=True)
    c = C.top_k(kf)
    err = float(jnp.sum((c(jax.random.PRNGKey(0), x) - x) ** 2))
    k = max(1, int(round(kf * d)))
    bound = (1 - k / d) * float(jnp.sum(x**2))
    assert err <= bound + 1e-5 * float(jnp.sum(x**2))


@settings(max_examples=20, deadline=None)
@given(d=st.integers(64, 400), kf=st.sampled_from([0.1, 0.25]), seed=st.integers(0, 2**20))
def test_block_topk_contractive(d, kf, seed):
    x = _vec(jax.random.PRNGKey(seed), d, heavy=True)
    c = C.block_top_k(kf, block=64)
    err = float(jnp.sum((c(jax.random.PRNGKey(0), x) - x) ** 2))
    assert err <= (1 - kf) * float(jnp.sum(x**2)) + 1e-5 * float(jnp.sum(x**2)) + 1e-6


# ---------------------------------------------------------------------------
# rand-k: unbiased, variance <= (d/k - 1)||x||^2
# ---------------------------------------------------------------------------
def test_randk_unbiased_and_variance():
    d, kf = 64, 0.25
    c = C.rand_k(kf)
    x = _vec(jax.random.PRNGKey(3), d)
    keys = jax.random.split(jax.random.PRNGKey(7), 4000)
    ys = jax.vmap(lambda k: c(k, x))(keys)
    mean = jnp.mean(ys, axis=0)
    assert float(jnp.linalg.norm(mean - x)) < 0.05 * float(jnp.linalg.norm(x))
    var = float(jnp.mean(jnp.sum((ys - x) ** 2, axis=1)))
    omega = 1 / kf - 1
    assert var <= (omega + 0.3) * float(jnp.sum(x**2))


# ---------------------------------------------------------------------------
# qsgd: unbiased stochastic rounding; per-coordinate error < scale
# ---------------------------------------------------------------------------
def test_qsgd_unbiased():
    c = C.qsgd(bits=4, block=64)
    x = _vec(jax.random.PRNGKey(5), 128) * 10
    keys = jax.random.split(jax.random.PRNGKey(11), 4000)
    ys = jax.vmap(lambda k: c(k, x))(keys)
    mean = jnp.mean(ys, axis=0)
    assert float(jnp.max(jnp.abs(mean - x))) < 0.2  # scale/sqrt(n) noise


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**20), bits=st.sampled_from([4, 8]))
def test_qsgd_bounded_error(seed, bits):
    c = C.qsgd(bits=bits, block=64)
    x = _vec(jax.random.PRNGKey(seed), 200, heavy=True)
    y = c(jax.random.PRNGKey(seed + 1), x)
    s = 2 ** (bits - 1) - 1
    # per-block absmax scale bounds the rounding error
    xp = jnp.pad(x, (0, (-len(x)) % 64)).reshape(-1, 64)
    yp = jnp.pad(y, (0, (-len(y)) % 64)).reshape(-1, 64)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / s
    assert bool(jnp.all(jnp.abs(yp - xp) <= scale + 1e-6))


# ---------------------------------------------------------------------------
# scaling calculus (Prop 2.2.1/2.2.2) against empirical estimates
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kf", [0.1, 0.3])
def test_scaled_randk_contractive(kf):
    c = C.rand_k(kf)
    lam = C.lambda_star(c.eta, c.omega)
    sc = C.scale_compressor(c, lam)
    assert sc.contractive_alpha() is not None  # lam* makes it contractive
    eta_hat, omega_hat = C.estimate_eta_omega(sc, jax.random.PRNGKey(0), 64,
                                              n_vectors=8, n_samples=200)
    assert eta_hat <= sc.eta + 0.1
    assert omega_hat <= sc.omega * 1.5 + 0.05


def test_efbv_rates_monotone_in_n():
    """omega_ran = omega/n: nu* grows with n and r_av shrinks (EF-BV's point)."""
    c = C.rand_k(0.2)
    nus = [C.nu_star(c.eta, C.omega_ran_independent(c.omega, n)) for n in (1, 4, 64)]
    assert nus == sorted(nus)
    rs = [C.efbv_rates(c.eta, c.omega, c.omega / n,
                       C.lambda_star(c.eta, c.omega), nu)[1]
          for n, nu in zip((1, 4, 64), nus)]
    assert rs == sorted(rs, reverse=True)


def test_mix_comp_estimable():
    for c in (C.mix_k(0.1, 0.3), C.comp_k(0.1, 0.5)):
        eta, omega = C.estimate_eta_omega(c, jax.random.PRNGKey(2), 48,
                                          n_vectors=6, n_samples=100)
        assert 0 <= eta < 1.0
        assert omega >= 0


def test_tree_compress_shapes():
    tree = {"a": jnp.ones((3, 5)), "b": jnp.ones((7,))}
    out = C.tree_compress(C.top_k(0.5), jax.random.PRNGKey(0), tree)
    assert out["a"].shape == (3, 5) and out["b"].shape == (7,)
