"""Learning-rate schedules (scalar step -> lr), pure jnp."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def sched(step):
        return jnp.asarray(lr, jnp.float32)

    return sched


def linear_warmup(lr: float, warmup_steps: int):
    def sched(step):
        frac = jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
        return jnp.asarray(lr * frac, jnp.float32)

    return sched


def cosine_schedule(lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
        prog = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.asarray(lr, jnp.float32) * warm * cos

    return sched
