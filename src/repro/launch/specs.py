"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
against these.  Shapes come from the assigned INPUT_SHAPES table; the
multimodal stubs follow the carve-out (precomputed frame/patch embeddings).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.models import cache_specs, model_dtype


def train_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    dtype = model_dtype(cfg)
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.vision_tokens:
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), dtype)
    if cfg.enc_layers:
        # audio frames / source length: match target length for the assigned shape
        specs["src_embeds"] = jax.ShapeDtypeStruct(
            (B, S, cfg.enc_d_model or cfg.d_model), dtype)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    dtype = model_dtype(cfg)
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.vision_tokens:
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), dtype)
    if cfg.enc_layers:
        specs["src_embeds"] = jax.ShapeDtypeStruct(
            (B, S, cfg.enc_d_model or cfg.d_model), dtype)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """One new token against a cache of shape.seq_len context."""
    B, S = shape.global_batch, shape.seq_len
    enc_len = min(S, 32768) if cfg.enc_layers else 0
    cache = cache_specs(cfg, B, S, enc_len=enc_len)
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "cache": cache,
    }


def input_specs(cfg: ModelConfig, shape) -> dict:
    shape = INPUT_SHAPES[shape] if isinstance(shape, str) else shape
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)


def skip_reason(cfg: ModelConfig, shape_name: str) -> str | None:
    """Why a (arch, shape) combination is skipped, or None if it runs."""
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return (f"{cfg.name}: full quadratic attention — 500k decode KV cache "
                "is out of scope per the assignment (no SWA/chunked/SSM variant)")
    return None
